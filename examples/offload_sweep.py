"""Offload sweep: slot budget vs hit rate / bytes moved / modeled throughput.

The paper's central trade-off: how far can device residency shrink before the
miss/transfer tax erases the memory win? Sweeps num_slots on the reduced paper
arch under the rotary policy and prints the frontier, plus the int8 and
grouped-int4 (Q4_K_M analog) variants that shrink slot bytes ~2x / ~4x at
equal slot count.

    PYTHONPATH=src python examples/offload_sweep.py
"""
import jax
import numpy as np

from repro.config import ResidencyConfig, get_config
from repro.configs import reduce_for_smoke
from repro.core import InitializationError, RotaryEngine
from repro.models import init_params
from repro.models.transformer import Runtime


def main():
    cfg = reduce_for_smoke(get_config("qwen36-35b-a3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 12)).astype(np.int32)
    e = cfg.moe.num_experts
    print(f"{'slots':>5} | {'quant':>5} | {'hit':>6} | {'MB moved':>8} | "
          f"{'slot MB':>8} | {'model ms/tok':>12}")
    for quant in (None, "int8", "int4"):
        for slots in (e, 6, 5, 4, 3):
            try:
                eng = RotaryEngine(
                    cfg, params,
                    ResidencyConfig(mode="rotary" if slots < e else "full",
                                    num_slots=slots, quantization=quant),
                    rt=Runtime(cache_len=64), batch=1,
                )
            except InitializationError as err:
                print(f"{slots:5d} | {str(quant):>5} | failed to initialize: {err}")
                continue
            eng.generate(prompt, 12)
            s = eng.stats.summary()
            slot_mb = sum(st.total_bytes for st in eng.manager.stores) / 2**20
            print(f"{slots:5d} | {str(quant):>5} | {s['hit_rate']:6.3f} | "
                  f"{s['bytes_loaded_MB']:8.2f} | {slot_mb:8.2f} | "
                  f"{s['modeled_ms_per_token']:12.3f}")


if __name__ == "__main__":
    main()
