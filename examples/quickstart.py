"""Quickstart: build the paper's MoE, run it under rotary residency with the
current hot-path features — chunked prefill, speculative decode, grouped-int4
slots — and check the exactness contract. ~2 minutes on a laptop CPU.

    PYTHONPATH=src python examples/quickstart.py

The same switches on the CLI: ``python -m repro.launch.serve --engine rotary
--residency rotary --prefill-chunk 16 --spec-k 4 --quantization int4``.
"""
import jax
import numpy as np

from repro.config import ResidencyConfig, get_config
from repro.configs import reduce_for_smoke
from repro.core import RotaryEngine
from repro.models import init_params, param_summary
from repro.models.transformer import Runtime


def main():
    full = get_config("qwen36-35b-a3b")                 # the paper's model class
    print("full arch:", param_summary(full))
    cfg = reduce_for_smoke(full)                        # same structure, tiny dims
    params = init_params(cfg, jax.random.PRNGKey(0))

    prompt = np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 24)).astype(np.int32)
    outputs = {}
    for label, rescfg, kw in (
        # full residency: every expert on-device (the reference)
        ("full", ResidencyConfig(mode="full"), {}),
        # the paper's technique: 5 of 8 experts resident, chunked prefill
        # (one compiled launch per 8-token chunk) + 4-token speculative
        # windows (one launch per 4 drafted tokens)
        ("rotary", ResidencyConfig(mode="rotary", num_slots=5),
         dict(prefill_chunk=8, spec_k=4)),
        # same, with grouped-int4 slot uploads (~0.28x the f16 link bytes)
        ("rotary+int4", ResidencyConfig(mode="rotary", num_slots=5,
                                        quantization="int4"),
         dict(prefill_chunk=8, spec_k=4)),
    ):
        eng = RotaryEngine(cfg, params, rescfg,
                           rt=Runtime(cache_len=64), batch=1, **kw)
        outputs[label] = eng.generate(prompt, 10)
        s = eng.stats.summary()
        print(f"{label:12s} tokens={outputs[label][0].tolist()}")
        print(f"             hit_rate={s['hit_rate']} uploaded={s['bytes_uploaded_MB']}MB "
              f"prefill_chunks={s['prefill_chunks']} spec_windows={s['spec_windows']} "
              f"modeled_ms/token={s['modeled_ms_per_token']}")
        if rescfg.mode != "full":
            # per-layer residency breakdown: the first place to look when
            # hit_rate regresses (which layer misses, rotates backwards?)
            print(eng.stats.per_layer_table())
    # the exactness contract: residency, chunked prefill and speculation must
    # not change greedy outputs (int4 is exactness-clean within its format,
    # so its tokens may differ from the f16 store's)
    assert (outputs["full"] == outputs["rotary"]).all(), \
        "residency must not change outputs"
    print("\nOK: rotary residency + chunked prefill + spec-4 decode generated"
          " IDENTICAL tokens with only 5/8 experts device-resident"
          " (misses host-corrected / replayed, prefetch hidden behind compute).")


if __name__ == "__main__":
    main()
