"""Quickstart: build the paper's MoE, run it under rotary residency, compare
policies — 2 minutes on a laptop CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.config import ResidencyConfig, get_config
from repro.configs import reduce_for_smoke
from repro.core import RotaryEngine
from repro.models import init_params, param_summary
from repro.models.transformer import Runtime


def main():
    full = get_config("qwen36-35b-a3b")                 # the paper's model class
    print("full arch:", param_summary(full))
    cfg = reduce_for_smoke(full)                        # same structure, tiny dims
    params = init_params(cfg, jax.random.PRNGKey(0))

    prompt = np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 12)).astype(np.int32)
    outputs = {}
    for mode in ("full", "rotary"):
        eng = RotaryEngine(
            cfg, params,
            ResidencyConfig(mode=mode, num_slots=5),    # 5 of 8 experts resident
            rt=Runtime(cache_len=64), batch=1,
        )
        outputs[mode] = eng.generate(prompt, 10)
        s = eng.stats.summary()
        print(f"{mode:7s} tokens={outputs[mode][0].tolist()}")
        print(f"        hit_rate={s['hit_rate']} bytes_loaded={s['bytes_loaded_MB']}MB "
              f"modeled_ms/token={s['modeled_ms_per_token']}")
    assert (outputs["full"] == outputs["rotary"]).all(), "residency must not change outputs"
    print("\nOK: rotary residency generated IDENTICAL tokens with only 5/8 experts"
          " device-resident (misses host-corrected, prefetch hidden behind compute).")


if __name__ == "__main__":
    main()
