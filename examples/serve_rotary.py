"""Serving scenario: continuous batching with rotary residency + deadlines.

Submits a mixed stream of requests (some with tight deadlines) against the
compiled serving engine; residency rotates between steps from routing
telemetry. Shows per-request outcomes and the residency/stall accounting.

    PYTHONPATH=src python examples/serve_rotary.py
"""
import numpy as np

import jax

from repro.config import ResidencyConfig, get_config
from repro.configs import reduce_for_smoke
from repro.models import init_params
from repro.models.transformer import Runtime
from repro.serving import SamplerConfig, ServingEngine


def main():
    cfg = reduce_for_smoke(get_config("qwen36-35b-a3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(
        cfg, params, rt=Runtime(cache_len=128), num_slots=4,
        residency=ResidencyConfig(mode="rotary", num_slots=5),
        sampler=SamplerConfig(temperature=0.8, top_k=50, seed=0),
    )
    rng = np.random.default_rng(1)
    reqs = []
    for i in range(8):
        plen = int(rng.integers(4, 24))
        deadline = 0.001 if i == 5 else None     # one infeasible deadline
        reqs.append(eng.submit(rng.integers(0, cfg.vocab_size, plen),
                               max_new=8, deadline_s=deadline))
    done = eng.run()
    for r in sorted(reqs, key=lambda r: r.uid):
        status = "REJECTED (deadline)" if r.truncated and not r.output else \
                 ("truncated" if r.truncated else "ok")
        print(f"req {r.uid}: prompt={len(r.prompt):2d} out={len(r.output):2d} {status}")
    print("\nengine stats:", eng.stats.summary())
    print("completed:", len(done), "rejected:", len(eng.scheduler.rejected))


if __name__ == "__main__":
    main()
