"""Serving scenario: continuous batching over the paged KV pool with rotary
residency, bucketed admission prefill, per-row speculative decode, and
deadlines.

Submits a mixed stream of requests (some with tight deadlines) against the
compiled serving engine: admitted prompts prefill together through one
shared compiled bucketed program and splice into pages drawn from the KV
pool, rows join/leave the live decode window as they arrive/finish (a
finishing request's pages recycle immediately), residency rotates between
window launches from routing telemetry, and greedy rows self-draft up to
``spec_cap`` tokens per compiled window (per-row accept rates learned by
the scheduler). Shows per-request outcomes, the residency/stall/speculation
accounting, the page-pool counters, and the TTFT / inter-token latency
percentiles.

    PYTHONPATH=src python examples/serve_rotary.py

The CLI equivalent: ``python -m repro.launch.serve --engine batch
--residency rotary --spec-cap 4 --quantization int4 --arrival-rate 40``
(the rotary engine variant adds ``--prefill-chunk`` / ``--spec-k``).
"""
import numpy as np

import jax

from repro.config import ResidencyConfig, get_config
from repro.configs import reduce_for_smoke
from repro.models import init_params
from repro.models.transformer import Runtime
from repro.serving import SamplerConfig, ServingEngine


def main():
    cfg = reduce_for_smoke(get_config("qwen36-35b-a3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(
        cfg, params, rt=Runtime(cache_len=128), num_slots=4,
        # int4 slot store: rotations ship ~0.28x the f16 bytes
        residency=ResidencyConfig(mode="rotary", num_slots=5,
                                  quantization="int4"),
        # greedy sampling so the speculative window path engages (spec_cap=4:
        # up to 4 self-drafted tokens per row per compiled launch)
        sampler=SamplerConfig(temperature=0.0, seed=0),
        spec_cap=4,
        bucketed_prefill=True,     # the default: one shared program per bucket
        # paged KV pool (the default on KV-only stacks): 16-position pages,
        # request-level joins between window launches
        kv_page_size=16,
    )
    rng = np.random.default_rng(1)
    reqs = []
    for i in range(8):
        plen = int(rng.integers(4, 24))
        deadline = 0.001 if i == 5 else None     # one infeasible deadline
        reqs.append(eng.submit(rng.integers(0, cfg.vocab_size, plen),
                               max_new=8, deadline_s=deadline))
    done = eng.run()
    for r in sorted(reqs, key=lambda r: r.uid):
        status = "REJECTED (deadline)" if r.truncated and not r.output else \
                 ("truncated" if r.truncated else "ok")
        print(f"req {r.uid}: prompt={len(r.prompt):2d} out={len(r.output):2d} {status}")
    s = eng.summary()              # engine stats + latency percentiles
    print("\nengine stats:", s)
    print("per-layer residency:")   # which layer misses / rotates backwards
    print(eng.stats.per_layer_table())
    print(f"speculation: {s['spec_windows']} windows, accept_rate={s['accept_rate']}")
    print(f"kv pool: {s['kv_pages_hwm']} pages peak, "
          f"{s['kv_pages_allocated']} allocated / {s['kv_pages_released']} released")
    print(f"latency: ttft p50/p99 = {s['ttft_p50_ms']}/{s['ttft_p99_ms']} ms, "
          f"itl p50/p99 = {s['itl_p50_ms']}/{s['itl_p99_ms']} ms")
    print("completed:", len(done), "rejected:", len(eng.scheduler.rejected))


if __name__ == "__main__":
    main()
