"""End-to-end driver: train a ~small MoE for a few hundred steps with
checkpoints and auto-resume (kill it mid-run and rerun — it continues).

    PYTHONPATH=src python examples/train_moe.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.config import RunConfig, ShardingConfig, get_config
from repro.configs import reduce_for_smoke
from repro.data import ShardedLoader, SyntheticSpec
from repro.models import init_params
from repro.models.transformer import Runtime
from repro.training import init_train_state, make_train_step, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    ap.add_argument("--ckpt", default="/tmp/repro_train_moe")
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    run = RunConfig(learning_rate=1e-3, total_steps=args.steps, warmup_steps=20,
                    checkpoint_every=50, log_every=10)
    rt = Runtime()
    mgr = CheckpointManager(args.ckpt, keep=2)

    params = init_params(cfg, jax.random.PRNGKey(0))
    state = init_train_state(cfg, params, ShardingConfig())
    start = 0
    got = mgr.restore_latest(state)
    if got:
        start, state, _ = got
        print(f"resumed at step {start}")

    spec = SyntheticSpec(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                         kind="topic", num_topics=4, topic_len=16)
    loader = ShardedLoader(spec, start_step=start)
    step_fn = jax.jit(make_train_step(cfg, rt, run, num_micro=2))

    t0 = time.time()
    state, metrics = train_loop(
        cfg, state, step_fn, loader, run, num_steps=args.steps - start,
        ckpt_manager=mgr,
        log=lambda s, m: print(f"step {s:4d} loss {m['loss']:.4f} "
                               f"lr {m['lr']:.2e}", flush=True),
    )
    mgr.wait()
    loader.close()
    print(f"trained {args.steps - start} steps in {time.time()-t0:.1f}s; "
          f"final loss {metrics['loss']:.4f}")


if __name__ == "__main__":
    main()
