from repro.distributed.fault_tolerance import FaultTolerantCoordinator, JobState  # noqa: F401
from repro.distributed import sharding  # noqa: F401
