"""Fault tolerance: heartbeat failure detection + deterministic restart policy.

On real fleets this wraps the coordination service; here the same state machine
runs against a simulated clock so the restart logic (including elastic
downsize) is unit-testable. The contract with the trainer:

  * every worker heartbeats each step; a worker silent for ``timeout_s`` is
    declared failed;
  * on failure the job transitions RUNNING -> RESTARTING, reloads the latest
    committed checkpoint (manager skips uncommitted partials), and resumes on
    the surviving mesh (elastic resharding) once ``min_workers`` are healthy;
  * repeated failures back off exponentially up to ``max_restarts``.

Straggler mitigation for training: a worker whose step time exceeds
``straggler_factor`` x median for ``straggler_patience`` consecutive steps is
treated as failed (preemptive restart beats a 10x-slow fleet).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional


class JobState(enum.Enum):
    RUNNING = "running"
    RESTARTING = "restarting"
    FAILED = "failed"


@dataclass
class WorkerHealth:
    last_heartbeat: float = 0.0
    step_times: List[float] = field(default_factory=list)
    slow_streak: int = 0
    alive: bool = True


class FaultTolerantCoordinator:
    def __init__(
        self,
        num_workers: int,
        *,
        timeout_s: float = 60.0,
        min_workers: Optional[int] = None,
        max_restarts: int = 5,
        straggler_factor: float = 3.0,
        straggler_patience: int = 3,
    ):
        self.num_workers = num_workers
        self.timeout_s = timeout_s
        self.min_workers = min_workers or num_workers
        self.max_restarts = max_restarts
        self.straggler_factor = straggler_factor
        self.straggler_patience = straggler_patience
        self.workers: Dict[int, WorkerHealth] = {
            i: WorkerHealth() for i in range(num_workers)
        }
        self.state = JobState.RUNNING
        self.restarts = 0
        self.restart_log: List[Dict] = []

    # ------------------------------------------------------------------
    def heartbeat(self, worker: int, now: float, step_time: Optional[float] = None) -> None:
        w = self.workers[worker]
        w.last_heartbeat = now
        if step_time is not None:
            w.step_times.append(step_time)
            if len(w.step_times) > 32:
                w.step_times.pop(0)

    def _median_step(self) -> float:
        all_t = sorted(
            t for w in self.workers.values() if w.alive for t in w.step_times[-8:]
        )
        return all_t[len(all_t) // 2] if all_t else 0.0

    def check(self, now: float) -> JobState:
        """Advance the state machine; call once per coordinator tick."""
        med = self._median_step()
        failed = []
        for i, w in self.workers.items():
            if not w.alive:
                continue
            if now - w.last_heartbeat > self.timeout_s:
                failed.append((i, "heartbeat timeout"))
                continue
            if med > 0 and w.step_times:
                if w.step_times[-1] > self.straggler_factor * med:
                    w.slow_streak += 1
                    if w.slow_streak >= self.straggler_patience:
                        failed.append((i, f"straggler ({w.step_times[-1]:.2f}s vs median {med:.2f}s)"))
                else:
                    w.slow_streak = 0
        for i, reason in failed:
            self.workers[i].alive = False
            self.restart_log.append({"worker": i, "reason": reason, "at": now})
        if failed:
            self.restarts += 1
            if self.restarts > self.max_restarts:
                self.state = JobState.FAILED
            else:
                self.state = JobState.RESTARTING
        return self.state

    def alive_workers(self) -> List[int]:
        return [i for i, w in self.workers.items() if w.alive]

    def try_resume(self, now: float) -> bool:
        """RESTARTING -> RUNNING when enough healthy workers remain (elastic:
        the surviving set becomes the new mesh)."""
        if self.state is not JobState.RESTARTING:
            return self.state is JobState.RUNNING
        if len(self.alive_workers()) >= self.min_workers:
            self.state = JobState.RUNNING
            for i in self.alive_workers():
                self.workers[i].last_heartbeat = now
            return True
        return False

    def backoff_s(self) -> float:
        return min(60.0 * 2 ** max(self.restarts - 1, 0), 900.0)
