"""Partition rules: mapping every tensor in the system onto mesh axes.

Axis roles (DESIGN.md §4): DP batch over ("pod","data"); TP/EP over "model";
ZeRO-1 shards optimizer moments over the dp axes; optional FSDP adds dp-axis
sharding to parameter storage (all-gathered per layer by GSPMD at use).

Rules are name/shape based over the params pytree produced by
``repro.models.init_params``; every launcher and the dry-run go through
``make_shardings`` so there is exactly one source of truth.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import ModelConfig, ShapeConfig, ShardingConfig


def _path_keys(path) -> Tuple[str, ...]:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(str(p.idx))
    return tuple(keys)


def param_spec(
    path, leaf, cfg: ModelConfig, sh: ShardingConfig, *, fsdp: bool = False
) -> P:
    """PartitionSpec for one parameter leaf."""
    keys = _path_keys(path)
    name = keys[-1] if keys else ""
    tp = sh.tp_axis
    fa = sh.dp_axes if fsdp else None   # fsdp storage axes
    ndim = np.ndim(leaf)
    # NOTE: stacked layer params have a leading `reps` dim (never sharded);
    # specs below address the trailing dims and are padded on the left.
    def pad(spec_tail: Tuple) -> P:
        lead = ndim - len(spec_tail)
        return P(*([None] * lead), *spec_tail)

    if name in ("embed",):
        return P(tp, None) if not fsdp else P(tp, fa)
    if name in ("lm_head",):
        return P(None, tp) if not fsdp else P(fa, tp)
    if name in ("frontend_proj",):
        return P(None, None)
    if keys and "experts" in keys:
        # routed experts [reps?, E, D, F] — EP over the expert dim
        if name == "w_down":
            return pad((tp, None if not fsdp else fa, None))
        return pad((tp, None, None if not fsdp else fa))
    if name in ("router", "shared_gate"):
        return pad((None, None))
    if name in ("wq", "wk", "wv", "wo"):
        # §Perf iteration 3: shard attention projections over heads ONLY when
        # the head count divides the axis — otherwise the flattened [D, H*dh]
        # split cuts heads mid-head_dim and GSPMD reshards every layer
        # (starcoder2-7b: 36 heads / 16 -> 77 s/step of collectives).
        heads = (
            cfg.attention.num_heads if name in ("wq", "wo")
            else cfg.attention.num_kv_heads
        )
        if heads % _tp_size_hint() != 0:
            return pad((None, None))
        if name == "wo":
            return pad((tp, None if not fsdp else fa))
        return pad((None if not fsdp else fa, tp))
    if name in ("w_gate", "w_up", "w_in", "w_a", "w_b",
                "w_q", "w_k", "w_v", "w_if", "w_rg", "w_ig"):
        return pad((None if not fsdp else fa, tp))
    if name in ("w_down", "w_out"):
        return pad((tp, None if not fsdp else fa))
    if name in ("conv_w",):
        return pad((None, tp))
    if name in ("r",):                     # slstm block-diag [4, H, dh, dh]
        return pad((None, None, None))
    if name in ("lam", "conv_b", "skip", "b", "b_if"):
        return pad((tp,)) if name in ("lam", "conv_b", "skip") else pad((None,))
    # norms / scales / biases: replicated
    return P(*([None] * ndim))


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh axes don't divide (tiny odd dims like
    xlstm's [.., 2H] gate projections are replicated instead of padded)."""
    entries = []
    for i, entry in enumerate(spec):
        if entry is None:
            entries.append(None)
            continue
        axes = entry if isinstance(entry, (tuple, list)) else (entry,)
        size = int(np.prod([dict(mesh.shape)[a] for a in axes]))
        if shape[i] % size != 0:
            entries.append(None)
        else:
            entries.append(entry)
    return P(*entries)


def make_param_shardings(
    cfg: ModelConfig, mesh: Mesh, sh: ShardingConfig, params_shape: Any, *, fsdp: bool = False
) -> Any:
    set_tp_size_hint(dict(mesh.shape)[sh.tp_axis])
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh,
            sanitize_spec(
                param_spec(path, leaf, cfg, sh, fsdp=fsdp), np.shape(leaf), mesh
            ),
        ),
        params_shape,
    )


def opt_spec(path, leaf, cfg: ModelConfig, sh: ShardingConfig, *, zero1: bool = True) -> P:
    """Optimizer moments: ZeRO-1 — param spec + dp sharding on the first free dim."""
    keys = _path_keys(path)
    if keys and keys[-1] == "step":
        return P()
    # moments mirror params below {"m"/"v"}/...
    sub_path = path[1:]
    base = param_spec(sub_path, leaf, cfg, sh)
    if not zero1:
        return base
    specs = list(base) + [None] * (np.ndim(leaf) - len(base))
    for i, s in enumerate(specs):
        if s is None and np.shape(leaf)[i] % _dp_size_hint(sh) == 0 and np.shape(leaf)[i] > 1:
            specs[i] = sh.dp_axes if len(sh.dp_axes) > 1 else sh.dp_axes[0]
            break
    return P(*specs)


_DP_SIZE = {"hint": 16}
_TP_SIZE = {"hint": 16}


def _dp_size_hint(sh: ShardingConfig) -> int:
    return _DP_SIZE["hint"]


def set_dp_size_hint(n: int) -> None:
    _DP_SIZE["hint"] = n


def _tp_size_hint() -> int:
    return _TP_SIZE["hint"]


def set_tp_size_hint(n: int) -> None:
    _TP_SIZE["hint"] = n


def make_train_state_shardings(
    cfg: ModelConfig, mesh: Mesh, sh: ShardingConfig, state_shape: Any, *, fsdp: bool = False
) -> Any:
    set_dp_size_hint(int(np.prod([mesh.shape[a] for a in sh.dp_axes])))

    def spec_for(path, leaf):
        keys = _path_keys(path)
        if keys[0] == "params":
            return NamedSharding(
                mesh,
                sanitize_spec(
                    param_spec(path[1:], leaf, cfg, sh, fsdp=fsdp),
                    np.shape(leaf), mesh,
                ),
            )
        if keys[0] == "opt":
            return NamedSharding(
                mesh,
                sanitize_spec(
                    opt_spec(path[1:], leaf, cfg, sh, zero1=sh.zero1),
                    np.shape(leaf), mesh,
                ),
            )
        if keys[0] == "ef":
            # [pod, *param_shape] bf16: pod-split + one free dim over "data"
            base_leaf = jax.ShapeDtypeStruct(tuple(np.shape(leaf)[1:]), np.float32)
            base = param_spec(path[1:], base_leaf, cfg, sh)
            specs = list(base) + [None] * (np.ndim(leaf) - 1 - len(base))
            for i, s in enumerate(specs):
                if (s is None and np.shape(leaf)[i + 1] > 1
                        and np.shape(leaf)[i + 1] % mesh.shape["data"] == 0):
                    specs[i] = "data"
                    break
            return NamedSharding(mesh, P("pod", *specs))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(spec_for, state_shape)


# ---------------------------------------------------------------------------
# Activations / inputs / decode state
# ---------------------------------------------------------------------------
def dp_size(mesh: Mesh, sh: ShardingConfig) -> int:
    return int(np.prod([mesh.shape[a] for a in sh.dp_axes]))


def _dp_or_none(mesh: Optional[Mesh], sh: ShardingConfig, n: int):
    """dp axes if the batch dim divides them, else None (e.g. long_500k B=1)."""
    if mesh is not None and n % dp_size(mesh, sh) != 0:
        return None
    return sh.dp_axes if len(sh.dp_axes) > 1 else sh.dp_axes[0]


def batch_spec(sh: ShardingConfig, mesh: Optional[Mesh] = None, global_batch: int = 0) -> P:
    return P(_dp_or_none(mesh, sh, global_batch), None)


def token_spec(sh: ShardingConfig, mesh: Optional[Mesh] = None, global_batch: int = 0) -> P:
    """decode-step tokens [B]."""
    return P(_dp_or_none(mesh, sh, global_batch))


def frontend_spec(sh: ShardingConfig, mesh: Optional[Mesh] = None, global_batch: int = 0) -> P:
    return P(_dp_or_none(mesh, sh, global_batch), None, None)


def state_spec(
    path, leaf, cfg: ModelConfig, sh: ShardingConfig, shape: ShapeConfig,
    mesh: Optional[Mesh] = None,
) -> P:
    """Decode/prefill per-layer state: KV caches [reps, B, S, Hkv, dh] shard
    batch over dp and the *sequence* over the model axis (long caches dominate
    HBM; seq-sharding keeps every arch uniform regardless of kv-head count).
    Recurrent states [reps, B, W...]: batch over dp, width over model."""
    keys = _path_keys(path)
    dp = _dp_or_none(mesh, sh, shape.global_batch)
    nd = np.ndim(leaf)
    name = keys[-1] if keys else ""
    if name in ("k", "v") and nd == 5:
        return P(None, dp, sh.tp_axis, None, None)
    if name == "h" and nd == 3:                   # rglru h [reps, B, W]
        return P(None, dp, sh.tp_axis)
    if name == "conv" and nd == 4:                # [reps, B, cw-1, W]
        return P(None, dp, None, sh.tp_axis)
    if name in ("c",) and nd == 5:                # mlstm C [reps, B, H, dk, dv]
        return P(None, dp, None, None, None)
    if nd >= 2:
        return P(None, dp, *([None] * (nd - 2)))
    return P(*([None] * nd))


def make_state_shardings(
    cfg: ModelConfig, mesh: Mesh, sh: ShardingConfig, state_shape: Any, shape: ShapeConfig
) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh,
            sanitize_spec(
                state_spec(path, leaf, cfg, sh, shape, mesh), np.shape(leaf), mesh
            ),
        ),
        state_shape,
    )
