"""Roofline analysis over the dry-run artifacts (§Roofline).

Terms per (arch x shape x mesh) cell, all **seconds per step, per chip** — the
HLO numbers from hlo_analysis are already per-device (post-SPMD):

  compute    = HLO_FLOPs_local / peak_FLOPs          (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes_local / HBM_bw              (819 GB/s)
  collective = wire_bytes_local / ICI_bw             (50 GB/s per link, 1 link
                                                      conservative)

The bound on step time is max(terms); the useful-work fraction is

  roofline_fraction = (MODEL_FLOPS/chips / peak) / max(terms)

with MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (fwd) — so the fraction folds
both "how much of compiled compute is useful" (FLOP ratio) and "is compute even
the binding term" into one score.

Usage: python -m repro.launch.roofline --dryrun artifacts/dryrun.json
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def analytic_bytes(rec: Dict) -> float:
    """Designed per-chip HBM traffic per step (lower bound): weights touched
    (x3 for train fwd/bwd/recompute, re-read per microbatch) + activation
    stream + KV/state traffic. The parsed HLO bytes are an upper bound that
    includes CPU-backend materialization the TPU fuses away; the truth lies
    between."""
    import math

    from repro.config import get_config
    from repro.configs.shapes import SHAPES
    from repro.models.params import analytic_params

    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = rec.get("chips", 256)
    tp = 16
    dp = chips // tp
    kind = shape.kind
    d = cfg.d_model

    params_b = 2 * analytic_params(cfg) / tp               # bf16, TP/EP-sharded
    if kind == "train":
        micro = max(shape.global_batch // dp, 1)
        tokens_dev = shape.global_batch * shape.seq_len / chips
        act = tokens_dev * d * 2 * 12 * cfg.num_layers * 2   # fwd+bwd streams
        return 3 * params_b * micro + act
    if kind == "prefill":
        tokens_dev = shape.global_batch * shape.seq_len / chips
        act = tokens_dev * d * 2 * 8 * cfg.num_layers
        return params_b + act
    # decode: weights once + full KV/state read (seq sharded over tp)
    kv = 0.0
    if cfg.uses_kv_cache:
        a = cfg.attention
        rows = max(shape.global_batch // dp, 1)
        for k in cfg.layer_kinds:
            if k in ("attn_mlp", "attn_moe", "local_attn"):
                cap = shape.seq_len
                if k == "local_attn" and a.window:
                    cap = min(a.window, cap)
                kv += 2 * rows * (cap / tp) * a.num_kv_heads * a.head_dim * 2
    if cfg.has_moe:
        # only routed experts' weights stream per step
        m = cfg.moe
        mats = 3 if cfg.mlp == "swiglu" else 2
        routed_frac = min(1.0, shape.global_batch / dp * m.top_k / (m.storage_experts / tp))
        expert_b = 2 * m.storage_experts * mats * d * m.expert_d_ff / tp
        params_b = params_b - expert_b + routed_frac * expert_b
    return params_b + kv


def cell_terms(rec: Dict) -> Optional[Dict]:
    if not rec.get("ok") or rec.get("skipped"):
        return None
    hlo = rec.get("hlo") or {}
    chips = rec.get("chips", 256)
    compute = hlo.get("flops", 0.0) / PEAK_FLOPS
    mem_hi = hlo.get("hbm_bytes", 0.0) / HBM_BW
    try:
        mem_lo = analytic_bytes(rec) / HBM_BW
    except Exception:   # noqa: BLE001
        mem_lo = mem_hi
    memory = math_sqrt_geo(mem_lo, mem_hi)
    coll = hlo.get("collective_bytes", 0.0) / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    ideal = rec.get("model_flops_global", 0.0) / chips / PEAK_FLOPS
    bound = max(terms.values())
    frac = ideal / bound if bound > 0 else 0.0
    flop_ratio = (
        rec.get("model_flops_global", 0.0) / chips / hlo["flops"]
        if hlo.get("flops") else 0.0
    )
    return {
        **terms,
        "memory_lo": mem_lo,
        "memory_hi": mem_hi,
        "dominant": dominant,
        "ideal_s": ideal,
        "bound_s": bound,
        "roofline_fraction": frac,
        "model_flop_ratio": flop_ratio,
        "peak_GiB": (rec.get("memory") or {}).get("peak_GiB"),
    }


def math_sqrt_geo(lo: float, hi: float) -> float:
    """Geometric mean of the analytic lower and parsed upper memory bounds —
    the headline memory term (both bounds are also reported)."""
    if lo <= 0 or hi <= 0:
        return max(lo, hi)
    return (lo * hi) ** 0.5


SUGGESTIONS = {
    "collective": "shrink TP/EP traffic: lower effective TP for small dims, "
                  "overlap or compress collectives, a2a instead of AR for MoE",
    "memory": "cut HBM traffic: fuse elementwise chains, quantize weights/KV, "
              "larger microbatch to amortize weight reads",
    "compute": "cut wasted FLOPs: causal block skipping, lower capacity factor, "
               "drop remat recompute where memory allows",
}


def render_table(results: Dict[str, Dict], mesh: str, variant: str = "base") -> str:
    rows: List[str] = []
    header = (
        "| arch | shape | compute (ms) | memory (ms) [lo–hi] | collective (ms) | "
        "dominant | peak GiB | MODEL/HLO flops | roofline frac |\n"
        "|---|---|---|---|---|---|---|---|---|"
    )
    rows.append(header)
    for key in sorted(results):
        rec = results[key]
        if rec.get("mesh") != mesh or rec.get("variant", "base") != variant:
            continue
        t = cell_terms(rec)
        if t is None:
            if rec.get("skipped"):
                rows.append(
                    f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                    f"skipped (full attention) | — | — | — |"
                )
            else:
                rows.append(
                    f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                    f"FAILED: {str(rec.get('error', ''))[:60]} | — | — | — |"
                )
            continue
        rows.append(
            f"| {rec['arch']} | {rec['shape']} "
            f"| {t['compute']*1e3:.2f} "
            f"| {t['memory']*1e3:.2f} [{t['memory_lo']*1e3:.1f}–{t['memory_hi']*1e3:.0f}] "
            f"| {t['collective']*1e3:.2f} | **{t['dominant']}** "
            f"| {t['peak_GiB']:.1f} | {t['model_flop_ratio']:.3f} "
            f"| {t['roofline_fraction']:.3f} |"
        )
    return "\n".join(rows)


def worst_cells(results: Dict[str, Dict], n: int = 5) -> List[str]:
    scored = []
    for key, rec in results.items():
        t = cell_terms(rec)
        if t and rec.get("mesh") == "single" and rec.get("variant", "base") == "base":
            scored.append((t["roofline_fraction"], key, t["dominant"]))
    scored.sort()
    return [f"{k} (frac={f:.3f}, {d}-bound)" for f, k, d in scored[:n]]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="artifacts/dryrun.json")
    ap.add_argument("--out", default="artifacts/roofline.md")
    args = ap.parse_args()
    with open(args.dryrun) as f:
        results = json.load(f)

    parts = ["# Roofline (single-pod 16x16, per-chip per-step)\n"]
    parts.append(render_table(results, "single"))
    parts.append("\n\n# Multi-pod (2x16x16) — distribution proof\n")
    parts.append(render_table(results, "multi"))
    parts.append("\n\n# Rotary-residency serve_step variants\n")
    parts.append(render_table(results, "single", variant="rotary"))
    parts.append("\n\n## Worst cells (hillclimb candidates)\n")
    for w in worst_cells(results):
        parts.append(f"- {w}")
    parts.append("\n\n## Dominant-term playbook\n")
    for k, v in SUGGESTIONS.items():
        parts.append(f"- **{k}**: {v}")
    out = "\n".join(parts)
    with open(args.out, "w") as f:
        f.write(out)
    print(out)


if __name__ == "__main__":
    main()
