"""Production meshes. Functions, not module constants — importing this module
never touches jax device state (the dry-run sets the 512-device XLA flag before
any jax initialization)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests only."""
    return jax.make_mesh((data, model), ("data", "model"))
