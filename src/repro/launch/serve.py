"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Two engines (DESIGN.md §2):
  * ``--engine rotary``  — the paper-faithful per-layer engine
    (repro.core.engine.RotaryEngine): host-resident experts, rotating slots,
    hidden-state-guided prefetch, host-GEMM miss correction. MoE archs only.
  * ``--engine batch``   — compiled continuous-batching engine
    (repro.serving.ServingEngine), any arch; optional rotary residency
    rotating between steps. KV lives in a paged pool on KV-cache-only
    stacks (``--kv-pages`` / ``--kv-page-size``); ``--arrival-rate`` replays
    a seeded Poisson arrival trace against the live engine (request-level
    joins between window launches) instead of submitting everything up
    front.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

# CLI spelling -> ResidencyConfig.quantization ("none" is how the default is
# spelled on the command line; None itself is impossible to type)
QUANT_CHOICES = {"none": None, "int8": "int8", "int4": "int4"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--engine", default="batch", choices=["batch", "rotary"])
    ap.add_argument("--residency", default="full",
                    choices=["full", "rotary", "lru", "static"])
    ap.add_argument("--slots", type=int, default=0, help="residency slots per layer")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--batch", type=int, default=1,
                    help="rotary-engine decode batch (requests served per group)")
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--host-routing", action="store_true",
                    help="seed-style per-layer host routing (benchmark baseline)")
    ap.add_argument("--spec-k", type=int, default=1,
                    help="rotary-engine speculative window (tokens per fused "
                         "launch; 1 = single-token decode)")
    ap.add_argument("--spec-cap", type=int, default=4,
                    help="batch-engine per-row speculative length cap "
                         "(1 disables speculation)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="rotary-engine chunked prefill: power-of-two chunk "
                         "length (0 = legacy full-sequence layer walk). Long "
                         "prompts ingest at one compiled launch + one "
                         "coalesced rotation window per chunk")
    ap.add_argument("--quantization", default="none",
                    choices=sorted(QUANT_CHOICES),
                    help="slot-store weight format (int4 = grouped "
                         "two-nibbles-per-byte, ~4x smaller rotations)")
    ap.add_argument("--quant-group", type=int, default=64,
                    help="int4 rows per scale/min group (Q4_K_M-style)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="batch-engine Poisson arrival rate (requests/s): "
                         "submit on a seeded arrival trace and tick the "
                         "engine live — requests join/leave the window as "
                         "they arrive/finish (0 = submit everything up "
                         "front)")
    ap.add_argument("--kv-pages", type=int, default=0,
                    help="batch-engine KV pool size in pages (0 = auto: "
                         "batch-slots full rows)")
    ap.add_argument("--kv-page-size", type=int, default=16,
                    help="KV pool page granularity in cache positions")
    ap.add_argument("--prefetch", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="asynchronous predictive expert prefetch: shadow-"
                         "generation uploads hidden under in-flight launches, "
                         "boundary = confirm/correct/flip (rotary engine: "
                         "plus predictive slot steering; batch engine: "
                         "overlap only). --no-prefetch (the default) keeps "
                         "the synchronous rotation path as the exactness "
                         "baseline. Loud error on unsupported combos "
                         "(host routing, LRU, non-paged batch engine)")
    ap.add_argument("--warmup", action="store_true",
                    help="pre-compile the batch-engine program family before "
                         "serving (first-request latency then measures "
                         "serving, not tracing)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="capture an event-level trace of the run and write "
                         "Chrome trace-event JSON (load in Perfetto / "
                         "chrome://tracing; audit with "
                         "`python -m repro.obs.audit PATH`)")
    ap.add_argument("--metrics-port", type=int, default=0,
                    help="serve live Prometheus metrics on "
                         "127.0.0.1:PORT/metrics while the run is in flight "
                         "(0 = off; batch engine only)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax; > 0 draws "
                         "from the warped distribution through the SAME "
                         "speculative windows, kept exact by stochastic "
                         "acceptance)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest logits before sampling "
                         "(0 = no top-k cut)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling: keep the smallest prefix of "
                         "probability mass >= p (1.0 = no cut)")
    ap.add_argument("--sample-seed", type=int, default=None,
                    help="PRNG seed for the sampling streams (default: "
                         "--seed). Streams are keyed per request/position, "
                         "so a fixed seed reproduces tokens bitwise across "
                         "runs regardless of batching")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.config import ResidencyConfig, get_config
    from repro.configs import reduce_for_smoke
    from repro.models import init_params
    from repro.models.transformer import Runtime
    from repro.serving import SamplerConfig, ServingEngine

    cfg = reduce_for_smoke(get_config(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    tracer = None
    if args.trace_out:
        from repro.obs import Tracer
        tracer = Tracer()
    rt = Runtime(cache_len=args.cache_len)
    rng = np.random.default_rng(args.seed)
    slots = args.slots or (cfg.moe.num_experts * 3 // 4 if cfg.has_moe else 0)
    rescfg = None
    if args.residency != "full" and cfg.has_moe:
        rescfg = ResidencyConfig(mode=args.residency, num_slots=slots,
                                 quantization=QUANT_CHOICES[args.quantization],
                                 quant_group_size=args.quant_group)

    if args.engine == "rotary":
        from repro.core import RotaryEngine

        assert cfg.has_moe, "--engine rotary requires an MoE arch"
        b = max(1, args.batch)
        eng = RotaryEngine(
            cfg, params,
            rescfg or ResidencyConfig(
                mode="rotary", num_slots=slots,
                quantization=QUANT_CHOICES[args.quantization],
                quant_group_size=args.quant_group,
            ),
            rt=rt, batch=b, host_routing=args.host_routing,
            spec_k=max(1, args.spec_k),
            prefill_chunk=args.prefill_chunk or None,
            prefetch=args.prefetch,
            trace=tracer,
        )
        gen_kw = {}
        if args.temperature > 0:
            gen_kw = dict(greedy=False, sampler=SamplerConfig(
                temperature=args.temperature, top_k=args.top_k,
                top_p=args.top_p,
                seed=args.sample_seed if args.sample_seed is not None
                else args.seed,
            ))
        # serve requests in decode groups of --batch (device-resident hot path
        # amortizes the per-step host interaction over all rows of the group)
        for g0 in range(0, args.requests, b):
            n = min(b, args.requests - g0)
            prompt = rng.integers(
                0, cfg.vocab_size, (b, args.prompt_len)
            ).astype(np.int32)
            out = eng.generate(prompt, args.max_new, **gen_kw)
            for i in range(n):
                print(f"req {g0 + i}: {out[i].tolist()}")
        print("stats:", eng.stats.summary())
        print("per-layer residency:")
        print(eng.stats.per_layer_table())
        if tracer is not None:
            tracer.write(args.trace_out)
            print(f"trace: {len(tracer)} events -> {args.trace_out}")
        return

    eng = ServingEngine(
        cfg, params, rt=rt, num_slots=args.batch_slots, residency=rescfg,
        sampler=SamplerConfig(
            temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
            seed=args.sample_seed if args.sample_seed is not None
            else args.seed,
        ),
        spec_cap=max(1, args.spec_cap),
        kv_page_size=args.kv_page_size,
        kv_pages=args.kv_pages or None,
        prefetch=args.prefetch,
        trace=tracer,
    )
    metrics_server = None
    if args.metrics_port:
        from repro.obs import serve_metrics
        metrics_server = serve_metrics(eng.metrics_registry, args.metrics_port)
        print(f"metrics: http://127.0.0.1:{args.metrics_port}/metrics")
    if args.warmup:
        n = eng.warmup(max_prompt_len=args.prompt_len)
        print(f"warmup: {n} programs compiled")
    prompts = [
        rng.integers(0, cfg.vocab_size, int(rng.integers(4, args.prompt_len + 1)))
        for _ in range(args.requests)
    ]
    if args.arrival_rate > 0:
        # live Poisson replay: requests join the window at their arrival
        # times and the engine ticks between joins (continuous batching)
        at = np.cumsum(rng.exponential(1.0 / args.arrival_rate, args.requests))
        at -= at[0]
        i, t0 = 0, time.perf_counter()
        while i < len(prompts) or not eng.scheduler.idle:
            now = time.perf_counter() - t0
            while i < len(prompts) and at[i] <= now:
                eng.submit(prompts[i], args.max_new)
                i += 1
            if not eng.scheduler.idle:
                eng.tick()
            elif i < len(prompts):
                time.sleep(min(1e-3, max(0.0, at[i] - now)))
        eng.stats.wall_s += time.perf_counter() - t0
        done = eng.scheduler.completed
    else:
        for p in prompts:
            eng.submit(p, args.max_new)
        done = eng.run()
    for r in done:
        print(f"req {r.uid}: prompt_len={len(r.prompt)} -> {r.output}")
    print("stats:", eng.summary())
    if metrics_server is not None:
        # self-scrape once so CI can assert the exposition round-trips
        from urllib.request import urlopen
        body = urlopen(
            f"http://127.0.0.1:{args.metrics_port}/metrics"
        ).read().decode()
        print(f"metrics: scraped {len(body.splitlines())} exposition lines")
        metrics_server.shutdown()
    if tracer is not None:
        tracer.write(args.trace_out)
        print(f"trace: {len(tracer)} events -> {args.trace_out}")


if __name__ == "__main__":
    main()
