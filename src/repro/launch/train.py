"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains REDUCED configs for real (the e2e example);
on a TPU fleet the same entry point runs the full config on the production
mesh. Fault tolerance: auto-resume from the latest committed checkpoint, so
``kill -9`` + relaunch continues bit-exact (integration-tested).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="train the reduced (smoke) config — CPU container default")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    from repro.checkpoint import CheckpointManager
    from repro.config import RunConfig, ShardingConfig, get_config
    from repro.configs import reduce_for_smoke
    from repro.data import ShardedLoader, SyntheticSpec
    from repro.models import init_params
    from repro.models.transformer import Runtime
    from repro.training import init_train_state, make_train_step, train_loop

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_for_smoke(cfg)
    run = RunConfig(
        learning_rate=args.lr, total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
        checkpoint_every=args.ckpt_every, checkpoint_dir=args.ckpt_dir, log_every=args.log_every,
    )
    sh = ShardingConfig()
    rt = Runtime(sharding=sh)
    mgr = CheckpointManager(args.ckpt_dir, keep=3)

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    state = init_train_state(cfg, params, sh)
    start_step = 0
    got = mgr.restore_latest(state)
    if got is not None:
        start_step, state, _ = got
        print(f"resumed from step {start_step}")

    s_tok = args.seq - (cfg.frontend_len if cfg.frontend else 0)
    spec = SyntheticSpec(vocab_size=cfg.vocab_size, seq_len=s_tok,
                         global_batch=args.batch, kind="topic", seed=args.seed)
    loader = ShardedLoader(spec, start_step=start_step)
    step_fn = jax.jit(make_train_step(cfg, rt, run, num_micro=args.micro))

    if cfg.frontend:
        fe = jnp.asarray(
            np.random.default_rng(0).standard_normal(
                (args.batch, cfg.frontend_len, cfg.frontend_dim)
            ),
            jnp.float32,
        )
        base_fn = step_fn
        step_fn = lambda s, t, l: base_fn(s, t, l, fe)  # noqa: E731

    def log(step, m):
        print(f"step {step:5d} loss {m['loss']:.4f} gnorm {m['grad_norm']:.3f} "
              f"lr {m['lr']:.2e}", flush=True)

    t0 = time.time()
    state, metrics = train_loop(
        cfg, state, step_fn, loader, run,
        num_steps=args.steps - start_step, ckpt_manager=mgr, log=log,
    )
    mgr.wait()
    loader.close()
    dt = time.time() - t0
    print(f"done: {args.steps - start_step} steps in {dt:.1f}s "
          f"({(args.steps - start_step) / max(dt, 1e-9):.2f} steps/s), "
          f"final loss {metrics.get('loss', float('nan')):.4f}")


if __name__ == "__main__":
    main()
