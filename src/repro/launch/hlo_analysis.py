"""HLO-text analyzer: loop-aware FLOPs, HBM-byte and collective-byte counts.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified on this
jax build), so every scanned layer/microbatch/chunk would be undercounted by
its trip count. This module parses ``compiled.as_text()`` instead:

  * while ops carry ``backend_config={"known_trip_count":{"n":...}}`` — a call
    graph walk assigns every computation its cumulative execution multiplier;
  * dot ops contribute ``2 * prod(out) * prod(contracting)`` FLOPs (operand
    shapes resolved from the per-computation symbol table);
  * collective ops contribute wire bytes with ring factors:
    all-reduce 2(n-1)/n * operand, all-gather (n-1)/n * result,
    reduce-scatter (n-1)/n * operand, all-to-all (n-1)/n, permute 1.0 —
    n parsed from replica_groups (both ``{{0,1},..}`` and ``[g,n]<=[..]`` forms);
  * HBM bytes sum operands+outputs of *scheduled* ops only (ops inside
    kLoop-fusion bodies move through registers/VMEM, not HBM).

All numbers are per-device (the module is post-SPMD-partitioning).
"""
from __future__ import annotations

import json
import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\("
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s+\(.*\)\s*->.*\{")
_PARAM_RE = re.compile(r"%([\w.\-]+)\s*=\s*(.+?)\s+parameter\(")
_CALL_ATTR_RE = re.compile(
    r"(?:calls=|condition=|body=|to_apply=|true_computation=|false_computation=)%([\w.\-]+)"
)
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Sum bytes over every dtype[dims] group in a type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)   # name -> type str


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = Computation(mc.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if mo:
            op = Op(mo.group(1), mo.group(2), mo.group(3), line)
            cur.ops.append(op)
            cur.symbols[op.name] = op.type_str
    return comps, entry


def _multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    mult: Dict[str, float] = defaultdict(float)
    fusion_body: Dict[str, bool] = defaultdict(bool)

    def visit(name: str, m: float, in_fusion: bool) -> None:
        mult[name] += m
        fusion_body[name] |= in_fusion
        comp = comps.get(name)
        if comp is None:
            return
        for op in comp.ops:
            if op.opcode == "while":
                trip = 1
                mt = _TRIP_RE.search(op.line)
                if mt:
                    trip = int(mt.group(1))
                body = re.search(r"body=%([\w.\-]+)", op.line)
                cond = re.search(r"condition=%([\w.\-]+)", op.line)
                if body:
                    visit(body.group(1), m * trip, in_fusion)
                if cond:
                    visit(cond.group(1), m * (trip + 1), in_fusion)
            elif op.opcode == "fusion":
                mc = re.search(r"calls=%([\w.\-]+)", op.line)
                if mc:
                    visit(mc.group(1), m, True)
            elif op.opcode == "conditional":
                mb = _BRANCHES_RE.search(op.line)
                if mb:
                    for b in re.findall(r"%([\w.\-]+)", mb.group(1)):
                        visit(b, m, in_fusion)          # upper bound: all branches
                else:
                    for c in _CALL_ATTR_RE.findall(op.line):
                        visit(c, m, in_fusion)
            elif op.opcode in ("call", "custom-call", "reduce", "scatter",
                               "map", "sort", "select-and-scatter"):
                for c in _CALL_ATTR_RE.findall(op.line):
                    visit(c, m, in_fusion)

    visit(entry, 1.0, False)
    return dict(mult), dict(fusion_body)


def _dot_flops(op: Op, comp: Computation) -> float:
    out_dims = shape_dims(op.type_str)
    operands = re.findall(r"\(%([\w.\-]+)[,)]", op.line)
    # operands may carry type prefixes in scheduled HLO:
    #   dot(%a, %b)  or  dot(f32[32,64]{1,0} %a, f32[64,16]{1,0} %b)
    ml = re.search(r"dot\((?:\S+\s+)?%([\w.\-]+),\s*(?:\S+\s+)?%([\w.\-]+)\)", op.line)
    if not ml:
        return 0.0
    lhs_t = comp.symbols.get(ml.group(1))
    if lhs_t is None:
        return 0.0
    lhs_dims = shape_dims(lhs_t)
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1
    if mc and mc.group(1):
        for d in mc.group(1).split(","):
            contract *= lhs_dims[int(d)] if int(d) < len(lhs_dims) else 1
    return 2.0 * math.prod(out_dims or [0]) * contract


def _group_size(line: str) -> int:
    me = _GROUPS_EXPL_RE.search(line)
    if me:
        return len(me.group(1).split(","))
    mi = _GROUPS_IOTA_RE.search(line)
    if mi:
        return int(mi.group(2))                      # [groups, group_size]<=[N]
    return 1


def _collective_bytes(op: Op, comp: Computation) -> Tuple[float, int]:
    """Wire bytes (per device) for one collective op + group size."""
    n = _group_size(op.line)
    if n <= 1 and op.opcode != "collective-permute":
        return 0.0, n
    if op.opcode == "all-gather":
        base = shape_bytes(op.type_str)              # result
        factor = (n - 1) / n
    elif op.opcode == "all-reduce":
        base = _operand_bytes(op, comp)
        factor = 2.0 * (n - 1) / n
    elif op.opcode in ("reduce-scatter", "all-to-all"):
        base = _operand_bytes(op, comp)
        factor = (n - 1) / n
    else:                                            # collective-permute
        base = _operand_bytes(op, comp)
        factor = 1.0
    return base * factor, n


def _operand_bytes(op: Op, comp: Computation) -> int:
    total = 0
    for name in re.findall(r"%([\w.\-]+)", op.line.split("(", 1)[1]):
        t = comp.symbols.get(name)
        if t is not None:
            total += shape_bytes(t)
    return total or shape_bytes(op.type_str)


@dataclass
class HloAnalysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)   # opcode -> bytes
    collective_counts: Dict[str, int] = field(default_factory=dict)
    dots: int = 0

    def to_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": self.collectives,
            "collective_counts": self.collective_counts,
            "dots": self.dots,
        }


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """Normalized ``compiled.cost_analysis()``.

    Newer JAX returns one flat {metric: value} dict; older builds (including
    the pinned 0.4.x) return a one-entry-per-partition list of such dicts.
    Returns the entry dict either way ({} for an empty list).
    """
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if ca else {}
    return dict(ca)


def analyze_hlo(text: str) -> HloAnalysis:
    comps, entry = parse_module(text)
    if entry is None:
        return HloAnalysis()
    mult, fusion_body = _multipliers(comps, entry)
    out = HloAnalysis()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        scheduled = not fusion_body.get(cname, False)
        for op in comp.ops:
            if op.opcode == "dot":
                out.flops += m * _dot_flops(op, comp)
                out.dots += 1
            if op.opcode in COLLECTIVES:
                b, _ = _collective_bytes(op, comp)
                out.collective_bytes += m * b
                out.collectives[op.opcode] = out.collectives.get(op.opcode, 0.0) + m * b
                out.collective_counts[op.opcode] = (
                    out.collective_counts.get(op.opcode, 0) + int(m)
                )
            if scheduled and op.opcode not in (
                "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
                # loop-carry/aliasing copies: the TPU memory scheduler elides
                # these; counting them would dwarf real HBM traffic
                "copy", "copy-start", "copy-done",
                # the while op's carries stay in place; its body is counted
                "while",
            ):
                if op.opcode in ("dynamic-slice", "gather"):
                    # reads only the slice, not the whole operand
                    b = 2 * shape_bytes(op.type_str)
                elif op.opcode in ("dynamic-update-slice", "scatter"):
                    # in-place: writes (and RMWs) only the update region
                    upd = _update_bytes(op, comp)
                    b = 2 * upd
                elif op.opcode == "fusion":
                    b = _fusion_bytes(op, comp, comps)
                else:
                    b = shape_bytes(op.type_str) + _operand_bytes(op, comp)
                out.hbm_bytes += m * b
    return out


def _fusion_bytes(op: Op, comp: Computation, comps: Dict[str, Computation]) -> float:
    """HBM bytes of one fusion: output + per-operand reads, where an operand
    consumed ONLY by dynamic-slice/gather inside the fused computation counts
    the slice size, not the full array (the layer-stack weight slices)."""
    mcall = re.search(r"calls=%([\w.\-]+)", op.line)
    operands = re.findall(r"%([\w.\-]+)", op.line.split("(", 1)[1].split(")", 1)[0])
    inner = comps.get(mcall.group(1)) if mcall else None
    out_b = shape_bytes(op.type_str)
    if inner is not None and inner.ops:
        body_ops = [o for o in inner.ops if o.opcode != "parameter"]
        # pure dtype/layout fusions (convert/transpose/copy chains): Mosaic
        # fuses these into the producing/consuming GEMM on TPU — no HBM trip.
        # (The CPU backend materializes f32 copies of bf16 weights; counting
        # them would triple every bf16 model's memory term.)
        if body_ops and all(
            o.opcode in ("convert", "bitcast", "copy", "transpose", "reshape",
                         "broadcast")
            for o in body_ops
        ):
            return 0.0
        # slice-extraction fusions (DS/gather + dtype/layout ops only): the
        # slice moves once; the f32 upcast copy is CPU legalization that a
        # bf16 MXU consumes directly
        slicers = [o for o in body_ops if o.opcode in ("dynamic-slice", "gather")]
        if slicers and all(
            o.opcode in ("dynamic-slice", "gather") + _PASSTHROUGH
            for o in body_ops
        ):
            return 2.0 * sum(shape_bytes(o.type_str) for o in slicers)
        # a DUS anywhere in the fusion -> in-place update of the big operand;
        # only the update region moves
        dus = [o for o in body_ops if o.opcode == "dynamic-update-slice"]
        if dus:
            out_b = sum(2 * _update_bytes(o, inner) for o in dus)
    total = float(out_b)
    if inner is None:
        return total + _operand_bytes(op, comp)
    params = [o for o in inner.ops if o.opcode == "parameter"]
    def pidx(o):
        mm = re.search(r"parameter\((\d+)\)", o.line)
        return int(mm.group(1)) if mm else 0
    params.sort(key=pidx)
    for i, name in enumerate(operands):
        t = comp.symbols.get(name)
        full = shape_bytes(t) if t else 0
        if i < len(params):
            consumers = _effective_consumers(params[i].name, inner)
            if consumers and all(
                c.opcode in ("dynamic-slice", "gather") for c in consumers
            ):
                full = sum(shape_bytes(c.type_str) for c in consumers)
            elif consumers and all(
                c.opcode == "dynamic-update-slice" for c in consumers
            ):
                full = 0        # aliased destination: write counted via out_b
        total += full
    return total


_PASSTHROUGH = ("convert", "bitcast", "copy", "reshape", "transpose", "broadcast")


def _effective_consumers(pname: str, inner: Computation) -> List[Op]:
    """Transitive consumers of a fused parameter, looking THROUGH dtype/layout
    ops (a bf16 cache converted to f32 before its DUS is still just the DUS's
    aliased destination on TPU)."""
    out: List[Op] = []
    seen = set()
    frontier = [pname]
    while frontier:
        cur = frontier.pop()
        if cur in seen:
            continue
        seen.add(cur)
        for o in inner.ops:
            if o.opcode == "parameter" or o.name == cur:
                continue
            if re.search(rf"%{re.escape(cur)}\b", o.line.split("=", 1)[-1]):
                if o.opcode in _PASSTHROUGH:
                    frontier.append(o.name)
                else:
                    out.append(o)
    return out


def _update_bytes(op: Op, comp: Computation) -> int:
    """Bytes of the update operand of a DUS/scatter (2nd operand)."""
    names = re.findall(r"%([\w.\-]+)", op.line.split("(", 1)[1])
    if len(names) >= 2:
        t = comp.symbols.get(names[1])
        if t is not None:
            return shape_bytes(t)
    return shape_bytes(op.type_str)
