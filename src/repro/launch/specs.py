"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every dry-run cell.

No device allocation happens here — states and train states come from
``jax.eval_shape`` over the real constructors, so the dry-run lowers exactly
what the launchers run.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import ModelConfig, RunConfig, ShapeConfig, ShardingConfig
from repro.distributed import sharding as shrules
from repro.models import transformer as tfm
from repro.models.transformer import Runtime
from repro.training.trainer import init_train_state, make_train_step


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def runtime_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Optional[Mesh],
                sh: ShardingConfig) -> Runtime:
    cache_len = shape.seq_len if shape.kind in ("decode",) else shape.seq_len
    return Runtime(sharding=sh, mesh=mesh, cache_len=cache_len,
                   q_chunk=512, kv_chunk=1024, loss_chunk=512)


def token_seq_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Frontend archs consume part of the sequence budget as embeddings."""
    f = cfg.frontend_len if cfg.frontend is not None else 0
    return shape.seq_len - f


def num_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh: Optional[Mesh],
                     sh: ShardingConfig) -> int:
    """One row per device per microbatch (~4k tokens at 4k seq): activation
    residency stays bounded for every arch in the pool; see §Perf for the
    microbatch-size iteration."""
    if mesh is None:
        return 1
    dp = shrules.dp_size(mesh, sh)
    return max(shape.global_batch // dp, 1)


def use_fsdp(cfg: ModelConfig, mesh: Optional[Mesh], sh: ShardingConfig) -> bool:
    """Shard param storage over dp too when TP-only storage exceeds ~6 GB/chip."""
    if mesh is None:
        return False
    from repro.models.params import analytic_params

    tp = mesh.shape[sh.tp_axis]
    return analytic_params(cfg) * 2 / tp > 6e9


# ---------------------------------------------------------------------------
# Cells
# ---------------------------------------------------------------------------
def train_cell(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, sh: ShardingConfig,
    run: Optional[RunConfig] = None,
) -> Tuple[Any, Tuple, Dict]:
    """Returns (fn, arg_structs, kwargs-for-jit) for a train_step lowering."""
    run = run or RunConfig()
    rt = runtime_for(cfg, shape, mesh, sh)
    nm = num_microbatches(cfg, shape, mesh, sh)
    fsdp = use_fsdp(cfg, mesh, sh)
    pod_comp = sh.grad_compression == "int8_ef" and "pod" in mesh.shape

    s_tok = token_seq_len(cfg, shape)
    params_shape = jax.eval_shape(
        lambda: tfm.init_params(cfg, jax.random.PRNGKey(0))
    )
    state_shape = jax.eval_shape(
        lambda p: init_train_state(cfg, p, sh, pod_count=dict(mesh.shape).get("pod", 1)),
        params_shape,
    )
    state_shardings = shrules.make_train_state_shardings(
        cfg, mesh, sh, state_shape, fsdp=fsdp
    )
    b = shape.global_batch
    args = [state_shape, sds((b, s_tok), jnp.int32), sds((b, s_tok), jnp.int32)]
    in_shardings = [
        state_shardings,
        NamedSharding(mesh, shrules.batch_spec(sh, mesh, b)),
        NamedSharding(mesh, shrules.batch_spec(sh, mesh, b)),
    ]
    if cfg.frontend is not None:
        args.append(sds((b, cfg.frontend_len, cfg.frontend_dim), jnp.float32))
        in_shardings.append(NamedSharding(mesh, shrules.frontend_spec(sh, mesh, b)))

    step = make_train_step(
        cfg, rt, run, num_micro=nm,
        pod_compression=pod_comp, pod_count=mesh.shape.get("pod", 1),
    )
    jit_kwargs = dict(
        in_shardings=tuple(in_shardings),
        donate_argnums=(0,),
    )
    return step, tuple(args), jit_kwargs


def prefill_cell(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, sh: ShardingConfig
) -> Tuple[Any, Tuple, Dict]:
    rt = runtime_for(cfg, shape, mesh, sh)
    s_tok = token_seq_len(cfg, shape)
    b = shape.global_batch

    def prefill_step(params, tokens, frontend=None):
        return tfm.prefill_model(cfg, params, tokens, rt, frontend)

    params_shape = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
    p_shardings = shrules.make_param_shardings(
        cfg, mesh, sh, params_shape, fsdp=use_fsdp(cfg, mesh, sh)
    )
    args = [params_shape, sds((b, s_tok), jnp.int32)]
    in_shardings = [p_shardings, NamedSharding(mesh, shrules.batch_spec(sh, mesh, b))]
    if cfg.frontend is not None:
        args.append(sds((b, cfg.frontend_len, cfg.frontend_dim), jnp.float32))
        in_shardings.append(NamedSharding(mesh, shrules.frontend_spec(sh, mesh, b)))
    return prefill_step, tuple(args), dict(in_shardings=tuple(in_shardings))


def decode_cell(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, sh: ShardingConfig,
    *, residency_slots: int = 0,
) -> Tuple[Any, Tuple, Dict]:
    """serve_step: one new token against a seq_len KV cache.

    ``residency_slots > 0`` lowers the rotary-residency variant: per-MoE-layer
    slot buffers (+1 zero miss slot) and LUTs enter as donated step inputs.
    """
    rt = runtime_for(cfg, shape, mesh, sh)
    b = shape.global_batch

    params_shape = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
    if residency_slots > 0:
        params_shape = _strip_experts(params_shape)
    state_shape = jax.eval_shape(lambda: tfm.zero_state(cfg, b, rt.cache_len))
    # dbrx-class: bf16 params exceed TP-sharded HBM; store FSDP-style
    # (per-layer all-gather) — §Perf iterates with int8 weights instead
    p_shardings = shrules.make_param_shardings(
        cfg, mesh, sh, params_shape, fsdp=use_fsdp(cfg, mesh, sh)
    )
    s_shardings = shrules.make_state_shardings(cfg, mesh, sh, state_shape, shape)

    res_shape = None
    if residency_slots > 0:
        res_shape = _residency_structs(cfg, residency_slots)

    def serve_step(params, token, state, lengths, residency=None):
        return tfm.decode_model(cfg, params, token, state, lengths, rt,
                                residency=residency)

    args = [
        params_shape,
        sds((b,), jnp.int32),
        state_shape,
        sds((b,), jnp.int32),
    ]
    in_shardings = [
        p_shardings,
        NamedSharding(mesh, shrules.token_spec(sh, mesh, b)),
        s_shardings,
        NamedSharding(mesh, P()),
    ]
    if res_shape is not None:
        args.append(res_shape)
        in_shardings.append(_residency_shardings(cfg, res_shape, mesh, sh))
    return serve_step, tuple(args), dict(
        in_shardings=tuple(in_shardings), donate_argnums=(2,),
    )


def _strip_experts(params_shape: Any) -> Any:
    """Residency mode: the full expert store lives in HOST memory, not in the
    device params (DESIGN.md §2) — remove it from the lowered signature."""
    def strip(d):
        if isinstance(d, dict):
            return {k: strip(v) for k, v in d.items() if k != "experts"}
        if isinstance(d, tuple):
            return tuple(strip(v) for v in d)
        if isinstance(d, list):
            return [strip(v) for v in d]
        return d

    return strip(params_shape)


def _residency_structs(cfg: ModelConfig, num_slots: int) -> Any:
    m = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    names = (("w_gate", "w_up", "w_down") if cfg.mlp == "swiglu" else ("w_up", "w_down"))
    segs = []
    for unit, reps in cfg.segments:
        if not any(k == "attn_moe" for k in unit):
            segs.append({})
            continue
        slots = {}
        for n in names:
            shp = (
                (reps, num_slots + 1, m.expert_d_ff, cfg.d_model)
                if n == "w_down"
                else (reps, num_slots + 1, cfg.d_model, m.expert_d_ff)
            )
            slots[n] = sds(shp, dt)
        segs.append({"slots": slots, "lut": sds((reps, m.num_experts), jnp.int32)})
    return tuple(segs)


def _residency_shardings(cfg: ModelConfig, res_shape: Any, mesh: Mesh,
                         sh: ShardingConfig) -> Any:
    """Slot buffers shard the FFN dim over the model axis (slot dim stays whole:
    any expert can land in any slot on every chip's HBM — per-chip residency,
    DESIGN.md §2 note (i))."""
    def spec(path, leaf):
        keys = shrules._path_keys(path)
        name = keys[-1] if keys else ""
        if name == "lut":
            return NamedSharding(mesh, P(None, None))
        if name == "w_down":
            return NamedSharding(mesh, P(None, None, sh.tp_axis, None))
        return NamedSharding(mesh, P(None, None, None, sh.tp_axis))

    return jax.tree_util.tree_map_with_path(spec, res_shape)
