import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import — jax locks the device count at
first init, and the production meshes need 512 host placeholder devices.

Each cell lowers the exact step the launchers run (train_step / prefill_step /
serve_step), with the one source of truth for shardings
(repro.distributed.sharding), then records:

  * ``compiled.memory_analysis()``  — proves per-device residency fits;
  * ``compiled.cost_analysis()``    — XLA's (loop-body-once) numbers, kept for
    reference;
  * loop-aware FLOPs / HBM bytes / collective bytes from
    ``repro.launch.hlo_analysis`` over ``compiled.as_text()`` — the roofline
    inputs (§Roofline).

Cells run in SUBPROCESSES (one fresh jax per cell): a pathological cell can't
poison the sweep, and compile memory is returned between cells. Results stream
into a JSON file; finished cells are skipped on re-run (resumable).

Usage:
  python -m repro.launch.dryrun                     # full sweep, both meshes
  python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k --mesh single
  python -m repro.launch.dryrun --residency         # + rotary serve_step cells
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Dict, List, Optional


def cell_id(arch: str, shape: str, mesh: str, variant: str) -> str:
    return f"{arch}|{shape}|{mesh}|{variant}"


def run_cell(arch: str, shape_name: str, mesh_kind: str, variant: str,
             moe_impl: str) -> Dict:
    """Lower+compile one cell in THIS process. Returns the result record."""
    import jax

    from repro.config import get_config, ShardingConfig
    from repro.configs.shapes import SHAPES
    from repro.launch import specs as S
    from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis
    from repro.launch.mesh import make_production_mesh
    from repro.models.params import analytic_params

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    dp_axes = ("pod", "data") if multi else ("data",)
    # decode variants: "base" = gathered expert weights (paper-faithful local
    # path, collective-catastrophic at EP scale), "epdecode" = §Perf iteration
    # (local experts + psum), "rotary" = slot-buffer residency.
    impl = moe_impl
    if shape.kind == "decode":
        impl = "epsum" if variant == "epdecode" else "dense"
    # NOTE: int8_ef pod compression is lowered separately (benchmarks/
    # compression_bench.py) — the manual-pod shard_map around the full grad
    # computation trips an XLA SPMD partitioner CHECK on this build
    # (spmd_partitioner_util.cc:504); EXPERIMENTS.md §Perf logs the hypothesis.
    sh = ShardingConfig(dp_axes=dp_axes, moe_impl=impl,
                        remat_policy="full", grad_compression=None)

    rec: Dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "variant": variant,
        "chips": int(mesh.devices.size), "moe_impl": moe_impl,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "kind": shape.kind,
    }
    t0 = time.time()
    if shape.kind == "train":
        fn, args, kw = S.train_cell(cfg, shape, mesh, sh)
    elif shape.kind == "prefill":
        fn, args, kw = S.prefill_cell(cfg, shape, mesh, sh)
    else:
        slots = 0
        if variant == "rotary":
            # paper budget: ~1/4 of experts resident per chip + top_k margin
            slots = max(cfg.moe.top_k + 2, cfg.moe.num_experts // 4)
            rec["residency_slots"] = slots
        fn, args, kw = S.decode_cell(cfg, shape, mesh, sh, residency_slots=slots)

    lowered = jax.jit(fn, **kw).lower(*args)
    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_GiB": ma.argument_size_in_bytes / 2**30,
        "output_GiB": ma.output_size_in_bytes / 2**30,
        "temp_GiB": ma.temp_size_in_bytes / 2**30,
        "alias_GiB": ma.alias_size_in_bytes / 2**30,
        "peak_GiB": (
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes
        ) / 2**30,
    }
    ca = xla_cost_analysis(compiled)
    rec["xla_cost"] = {k: float(v) for k, v in ca.items()
                      if k in ("flops", "bytes accessed")}
    t2 = time.time()
    text = compiled.as_text()
    rec["hlo"] = analyze_hlo(text).to_dict()
    rec["analyze_s"] = round(time.time() - t2, 2)
    # archive the partitioned HLO so the roofline can be re-derived offline
    import gzip
    hlo_dir = os.environ.get("REPRO_HLO_DIR", "artifacts/hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    fname = f"{arch}_{shape_name}_{mesh_kind}_{variant}.hlo.gz"
    with gzip.open(os.path.join(hlo_dir, fname), "wt") as f:
        f.write(text)
    rec["hlo_path"] = os.path.join(hlo_dir, fname)

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = analytic_params(cfg, active_only=cfg.has_moe)
    mf = 6.0 * n_active * tokens if shape.kind == "train" else 2.0 * n_active * tokens
    rec["model_flops_global"] = mf
    rec["model_params"] = analytic_params(cfg)
    rec["model_params_active"] = n_active
    rec["ok"] = True
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun.json")
    ap.add_argument("--residency", action="store_true",
                    help="also lower rotary-residency serve_step for MoE archs")
    ap.add_argument("--moe-impl", default="epsum")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--single-cell", nargs=4, metavar=("ARCH", "SHAPE", "MESH", "VARIANT"),
                    help="internal: run one cell in-process and print JSON")
    args = ap.parse_args()

    if args.single_cell:
        arch, shape, mesh, variant = args.single_cell
        try:
            rec = run_cell(arch, shape, mesh, variant, args.moe_impl)
        except Exception as e:  # noqa: BLE001
            rec = {
                "arch": arch, "shape": shape, "mesh": mesh, "variant": variant,
                "ok": False, "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:],
            }
        print("\n===CELL_RESULT===")
        print(json.dumps(rec))
        return

    # ---- sweep driver ------------------------------------------------
    from repro.config import get_config
    from repro.configs import ALL_ARCHS
    from repro.configs.shapes import SHAPES, shape_applies

    archs = list(ALL_ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    # always load existing results; --force only forces RE-RUNNING selected
    # cells (never discards other archs' records)
    results: Dict[str, Dict] = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    cells: List = []
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            if not shape_applies(cfg, SHAPES[shape]):
                skip_key = cell_id(arch, shape, "-", "skip")
                results.setdefault(skip_key, {
                    "arch": arch, "shape": shape, "ok": True, "skipped": True,
                    "reason": "full-attention arch: long_500k requires a "
                              "sub-quadratic path (DESIGN.md §6)",
                })
                continue
            for mesh in meshes:
                cells.append((arch, shape, mesh, "base"))
                if cfg.has_moe and SHAPES[shape].kind == "decode":
                    cells.append((arch, shape, mesh, "epdecode"))
                    if args.residency:
                        cells.append((arch, shape, mesh, "rotary"))

    print(f"dry-run: {len(cells)} cells -> {args.out}", flush=True)
    for i, (arch, shape, mesh, variant) in enumerate(cells):
        key = cell_id(arch, shape, mesh, variant)
        if key in results and results[key].get("ok") and not args.force:
            print(f"[{i+1}/{len(cells)}] {key} cached", flush=True)
            continue
        print(f"[{i+1}/{len(cells)}] {key} ...", flush=True)
        t0 = time.time()
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--single-cell", arch, shape, mesh, variant,
               "--moe-impl", args.moe_impl]
        try:
            proc = subprocess.run(
                cmd, capture_output=True, text=True, timeout=args.timeout,
                env={**os.environ, "PYTHONPATH": os.environ.get("PYTHONPATH", "src")},
            )
            tail = proc.stdout.rsplit("===CELL_RESULT===", 1)
            if len(tail) == 2:
                rec = json.loads(tail[1])
            else:
                rec = {"ok": False, "error": f"no result (rc={proc.returncode})",
                       "stderr": proc.stderr[-2000:]}
        except subprocess.TimeoutExpired:
            rec = {"ok": False, "error": f"timeout {args.timeout}s"}
        rec.update({"arch": arch, "shape": shape, "mesh": mesh, "variant": variant})
        rec["wall_s"] = round(time.time() - t0, 1)
        results[key] = rec
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        status = "OK" if rec.get("ok") else f"FAIL: {rec.get('error', '?')[:120]}"
        print(f"    {status} ({rec['wall_s']}s)", flush=True)

    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"done: {n_ok}/{len(results)} ok", flush=True)


if __name__ == "__main__":
    main()
