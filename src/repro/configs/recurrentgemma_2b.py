"""RecurrentGemma-2B — Griffin-style RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427; hf]. Sub-quadratic (windowed attention): long_500k applies.

26 layers = 8 x (rglru, rglru, local_attn) + 2 trailing rglru.
"""
from repro.config import AttentionConfig, ModelConfig, RecurrentConfig, register


@register("recurrentgemma-2b")
def recurrentgemma_2b() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        d_model=2560,
        vocab_size=256000,
        segments=(
            (("rglru", "rglru", "local_attn"), 8),
            (("rglru",), 2),
        ),
        attention=AttentionConfig(num_heads=10, num_kv_heads=1, head_dim=256, window=2048),
        recurrent=RecurrentConfig(lru_width=2560, conv_width=4, num_heads=10),
        d_ff=7680,
        mlp="swiglu",
        norm="rmsnorm",
        tie_embeddings=True,
        sub_quadratic=True,
        source="arXiv:2402.19427; hf",
    )
