"""DBRX-132B — coarse-grained MoE, 16 experts top-4. [hf:databricks/dbrx-base; unverified]"""
from repro.config import AttentionConfig, ModelConfig, MoEConfig, register


@register("dbrx-132b")
def dbrx() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        family="moe",
        d_model=6144,
        vocab_size=100352,
        segments=((("attn_moe",), 40),),
        attention=AttentionConfig(num_heads=48, num_kv_heads=8, head_dim=128,
                                  rope_theta=500_000.0),
        moe=MoEConfig(num_experts=16, top_k=4, expert_d_ff=10752),
        mlp="swiglu",
        norm="layernorm",
        source="hf:databricks/dbrx-base; unverified",
    )
