"""Pixtral-12B — mistral-nemo decoder backbone; pixtral-ViT frontend is a STUB
(input_specs provides precomputed patch embeddings). [hf:mistralai/Pixtral-12B-2409; unverified]
"""
from repro.config import AttentionConfig, ModelConfig, register


@register("pixtral-12b")
def pixtral_12b() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        family="vlm",
        d_model=5120,
        vocab_size=131072,
        segments=((("attn_mlp",), 40),),
        attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=160,
                                  rope_theta=1_000_000.0),
        d_ff=14336,
        mlp="swiglu",
        norm="rmsnorm",
        frontend="vision_patches",
        frontend_len=1024,        # 1024 precomputed patch embeddings prepended
        frontend_dim=5120,
        source="hf:mistralai/Pixtral-12B-2409; unverified",
    )
