"""Assigned input-shape cells (same four for every LM arch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV cache of
``seq_len``); ``train_4k`` lowers ``train_step``; ``prefill_32k`` lowers ``prefill_step``.
``long_500k`` applies only to sub-quadratic archs (ModelConfig.sub_quadratic) — the skip
for pure full-attention archs is recorded in DESIGN.md §6 and the dry-run table.
"""
from __future__ import annotations

from typing import Dict, List

from repro.config.base import ModelConfig, ShapeConfig

SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig(name="train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig(name="prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig(name="decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig(name="long_500k", seq_len=524_288, global_batch=1, kind="decode"),
}


def shape_applies(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Whether a shape cell is runnable for this architecture."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


def applicable_shapes(cfg: ModelConfig) -> List[ShapeConfig]:
    return [s for s in SHAPES.values() if shape_applies(cfg, s)]
