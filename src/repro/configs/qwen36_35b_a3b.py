"""Qwen3.6-35B-A3B-class MoE — the paper's own validation model (§6, Table 2).

The paper names "Qwen3.6-35B-A3B" (GGUF Q4_K_M, ~19.7 GB); we model it on the public
Qwen3-30B-A3B recipe: 48L, d_model=2048, 32Q/4KV heads (head_dim 128, qk-norm), 128 routed
experts top-8 with expert_d_ff=768, vocab 151936. This is the primary arch for the rotary
residency experiments (DESIGN.md §7). [hf:Qwen/Qwen3-30B-A3B; proxy for the paper's model]
"""
from repro.config import AttentionConfig, ModelConfig, MoEConfig, register


@register("qwen36-35b-a3b")
def qwen36_35b_a3b() -> ModelConfig:
    return ModelConfig(
        name="qwen36-35b-a3b",
        family="moe",
        d_model=2048,
        vocab_size=151936,
        segments=((("attn_moe",), 48),),
        attention=AttentionConfig(num_heads=32, num_kv_heads=4, head_dim=128, qk_norm=True,
                                  rope_theta=1_000_000.0),
        moe=MoEConfig(num_experts=128, top_k=8, expert_d_ff=768, norm_topk_prob=True),
        mlp="swiglu",
        norm="rmsnorm",
        source="paper §6 Table 2; modeled on hf:Qwen/Qwen3-30B-A3B",
    )
