"""StarCoder2-3B — dense GQA+RoPE code LM. [arXiv:2402.19173; hf]"""
from repro.config import AttentionConfig, ModelConfig, register


@register("starcoder2-3b")
def starcoder2_3b() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        d_model=3072,
        vocab_size=49152,
        segments=((("attn_mlp",), 30),),
        attention=AttentionConfig(num_heads=24, num_kv_heads=2, head_dim=128),
        d_ff=12288,
        mlp="gelu_mlp",
        norm="layernorm",
        source="arXiv:2402.19173; hf",
    )
