"""Phi-3-mini-3.8B — dense MHA (kv=heads) with RoPE + SwiGLU. [arXiv:2404.14219; unverified]"""
from repro.config import AttentionConfig, ModelConfig, register


@register("phi3-mini-3.8b")
def phi3_mini() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        family="dense",
        d_model=3072,
        vocab_size=32064,
        segments=((("attn_mlp",), 32),),
        attention=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=96),
        d_ff=8192,
        mlp="swiglu",
        norm="rmsnorm",
        source="arXiv:2404.14219; unverified",
    )
