"""xLSTM-350M — alternating mLSTM (matrix memory) / sLSTM (scalar memory) blocks.
[arXiv:2405.04517; unverified]. Sub-quadratic: long_500k applies.
"""
from repro.config import ModelConfig, RecurrentConfig, register


@register("xlstm-350m")
def xlstm_350m() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        family="ssm",
        d_model=1024,
        vocab_size=50304,
        segments=((("mlstm", "slstm"), 12),),   # 24 layers
        recurrent=RecurrentConfig(num_heads=4),
        d_ff=0,
        mlp="none",
        norm="rmsnorm",
        tie_embeddings=True,
        sub_quadratic=True,
        source="arXiv:2405.04517; unverified",
    )
