"""Architecture configs. Importing this package populates the registry.

Assigned archs (10) + the paper's own validation model (qwen36-35b-a3b).
"""
from repro.configs import (  # noqa: F401
    dbrx_132b,
    musicgen_large,
    phi3_mini_3_8b,
    pixtral_12b,
    qwen2_moe_a2_7b,
    qwen3_4b,
    qwen36_35b_a3b,
    recurrentgemma_2b,
    starcoder2_3b,
    starcoder2_7b,
    xlstm_350m,
)
from repro.configs.reduced import reduce_for_smoke  # noqa: F401
from repro.configs.shapes import SHAPES, applicable_shapes, shape_applies  # noqa: F401

ASSIGNED_ARCHS = (
    "starcoder2-7b",
    "starcoder2-3b",
    "qwen3-4b",
    "phi3-mini-3.8b",
    "qwen2-moe-a2.7b",
    "dbrx-132b",
    "xlstm-350m",
    "recurrentgemma-2b",
    "pixtral-12b",
    "musicgen-large",
)
PAPER_ARCH = "qwen36-35b-a3b"
ALL_ARCHS = ASSIGNED_ARCHS + (PAPER_ARCH,)
