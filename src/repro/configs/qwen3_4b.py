"""Qwen3-4B — dense GQA with qk-norm. [hf:Qwen/Qwen3-8B family; hf]"""
from repro.config import AttentionConfig, ModelConfig, register


@register("qwen3-4b")
def qwen3_4b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        family="dense",
        d_model=2560,
        vocab_size=151936,
        segments=((("attn_mlp",), 36),),
        # Qwen3 decouples head_dim from d_model/num_heads (explicit head_dim=128).
        attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128, qk_norm=True,
                                  rope_theta=1_000_000.0),
        d_ff=9728,
        mlp="swiglu",
        norm="rmsnorm",
        tie_embeddings=True,
        source="hf:Qwen/Qwen3-8B; hf",
    )
