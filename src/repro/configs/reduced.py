"""Reduced configs for CPU smoke tests: same family/block structure, tiny dims.

The FULL configs are only exercised via the dry-run (ShapeDtypeStruct, no allocation);
every smoke test instantiates the reduced config and runs a real forward/train step.
"""
from __future__ import annotations

import dataclasses

from repro.config.base import AttentionConfig, ModelConfig, MoEConfig, RecurrentConfig


def reduce_for_smoke(
    cfg: ModelConfig,
    *,
    d_model: int = 64,
    head_dim: int = 16,
    vocab: int = 256,
    max_repeats: int = 2,
) -> ModelConfig:
    """Shrink a full config while preserving its structural family.

    Preserved: block-kind units, GQA-ness (MHA stays MHA, MQA stays MQA, grouped stays
    grouped), MoE shared/routed split, qk-norm, windowing, frontend kind, norm/mlp type.
    """
    attn = cfg.attention
    if attn is not None:
        if attn.num_kv_heads == attn.num_heads:
            heads, kv = 4, 4              # MHA
        elif attn.num_kv_heads == 1:
            heads, kv = 4, 1              # MQA
        else:
            heads, kv = 4, 2              # grouped
        attn = AttentionConfig(
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=head_dim,
            rope_theta=attn.rope_theta,
            qk_norm=attn.qk_norm,
            window=min(attn.window, 16) if attn.window else None,
            logit_soft_cap=attn.logit_soft_cap,
        )
    moe = cfg.moe
    if moe is not None:
        moe = MoEConfig(
            num_experts=8,
            top_k=min(moe.top_k, 2),
            expert_d_ff=48,
            num_shared_experts=min(moe.num_shared_experts, 2),
            shared_d_ff=48 if moe.num_shared_experts else 0,
            # cf=8 with E=8,k<=2 makes capacity >= T: reduced configs are
            # DROPLESS, so train/prefill/decode paths agree exactly (tests)
            capacity_factor=8.0,
            norm_topk_prob=moe.norm_topk_prob,
        )
    rec = cfg.recurrent
    if rec is not None:
        rec = RecurrentConfig(
            lru_width=d_model if rec.lru_width else 0,
            conv_width=rec.conv_width,
            num_heads=2,
        )
    segments = tuple((unit, min(reps, max_repeats)) for unit, reps in cfg.segments)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=d_model,
        vocab_size=vocab,
        segments=segments,
        attention=attn,
        moe=moe,
        recurrent=rec,
        d_ff=128 if cfg.d_ff else 0,
        frontend_len=8 if cfg.frontend else 0,
        frontend_dim=d_model if cfg.frontend else 0,
    )
