"""Qwen1.5/2-MoE-A2.7B — fine-grained MoE, 60 routed top-4 + 4 shared.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.config import AttentionConfig, ModelConfig, MoEConfig, register


@register("qwen2-moe-a2.7b")
def qwen2_moe() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        d_model=2048,
        vocab_size=151936,
        segments=((("attn_moe",), 24),),
        attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=128),
        moe=MoEConfig(
            num_experts=60,
            top_k=4,
            expert_d_ff=1408,
            num_shared_experts=4,
            shared_d_ff=1408,
            norm_topk_prob=False,
            padded_experts=64,          # EP: 60 -> 64 never-routed dummies
        ),
        mlp="swiglu",
        norm="rmsnorm",
        source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
    )
