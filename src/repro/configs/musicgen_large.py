"""MusicGen-Large — decoder-only transformer over EnCodec tokens; the EnCodec/conditioning
frontend is a STUB (input_specs provides precomputed frame embeddings). [arXiv:2306.05284; hf]
"""
from repro.config import AttentionConfig, ModelConfig, register


@register("musicgen-large")
def musicgen_large() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        d_model=2048,
        vocab_size=2048,
        segments=((("attn_mlp",), 48),),
        attention=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=64),
        d_ff=8192,
        mlp="gelu_mlp",
        norm="layernorm",
        frontend="audio_frames",
        frontend_len=256,        # 256 precomputed conditioning-frame embeddings prepended
        frontend_dim=2048,
        source="arXiv:2306.05284; hf",
    )
