"""StarCoder2-7B — dense GQA+RoPE code LM. [arXiv:2402.19173; hf]"""
from repro.config import AttentionConfig, ModelConfig, register


@register("starcoder2-7b")
def starcoder2_7b() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        d_model=4608,
        vocab_size=49152,
        segments=((("attn_mlp",), 32),),
        attention=AttentionConfig(num_heads=36, num_kv_heads=4, head_dim=128),
        d_ff=18432,
        mlp="gelu_mlp",
        norm="layernorm",
        source="arXiv:2402.19173; hf",
    )
