"""Host-side span/event tracer with Chrome trace-event export.

The tracer is a ring buffer of ``(phase, name, track, lane, t0, dur, unit,
args)`` tuples recorded with :func:`time.perf_counter`.  Every asynchronous
machine in the engine gets its own *track* (launch / pull / rotation /
prefetch / kv_pool / request) and, in serving, every request gets its own
*lane* so the Perfetto timeline shows one row per in-flight request.

Tracing is opt-in.  The engines normalise ``trace=None`` (and any tracer with
``enabled=False``) to *no tracer at all* — every emission site is guarded by
a plain ``if tr is not None`` so the tracing-off hot path executes exactly
the same instructions as before this subsystem existed.  That is the
"disabled overhead is unmeasurable" contract the decode benchmark asserts
structurally (see ``benchmarks/decode_hot_path.py``).

Span records carry the tracer's *current unit* — a monotonically increasing
id the engine bumps once per decode step / spec window / prefill chunk /
serving tick via :meth:`Tracer.new_unit`.  The contract auditor
(``repro.obs.audit``) groups events by unit to check the standing dispatch
invariants (one launch + one queue-draining pull per miss-free unit,
rotation strictly after the pull, prefetch ship strictly between launch and
pull).
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional

# Track names in display order.  Chrome trace tids are assigned from this
# list first so the Perfetto timeline always shows the machines in pipeline
# order; unknown tracks are appended on demand.
MACHINE_TRACKS = ("launch", "pull", "rotation", "prefetch", "kv_pool")

_PID_MACHINES = 1
_PID_REQUESTS = 2


class _Span:
    """Context manager recording one complete ("X") event on exit."""

    __slots__ = ("_tr", "name", "track", "args", "t0", "duration_s")

    def __init__(self, tr: "Tracer", name: str, track: str, args):
        self._tr = tr
        self.name = name
        self.track = track
        self.args = args
        self.t0 = 0.0
        self.duration_s = 0.0

    def __enter__(self) -> "_Span":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t1 = time.perf_counter()
        self.duration_s = t1 - self.t0
        tr = self._tr
        tr._buf.append(
            ("X", self.name, self.track, None, self.t0, self.duration_s,
             tr.unit, self.args)
        )


class Tracer:
    """Ring-buffered span/event recorder.

    Parameters
    ----------
    capacity:
        Maximum number of retained records; older records are dropped
        (ring-buffer semantics) so long runs stay bounded.
    enabled:
        A tracer constructed with ``enabled=False`` is normalised away by
        the engines (they keep no tracer reference at all), making the
        disabled path bit-identical to the untraced one.
    """

    def __init__(self, capacity: int = 200_000, *, enabled: bool = True):
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self._buf: deque = deque(maxlen=self.capacity)
        self._epoch = time.perf_counter()
        # Current contract unit (decode step / window / chunk / tick).  0
        # means "outside any unit" (warm start, prefill walk, teardown);
        # the auditor ignores those records for per-unit invariants.
        self.unit = 0
        self._next_unit = 0
        self.unit_kind: Optional[str] = None

    # ------------------------------------------------------------- recording
    def span(self, name: str, track: str = "launch",
             args: Optional[Dict[str, Any]] = None) -> _Span:
        """Record a complete event covering the ``with`` body."""
        return _Span(self, name, track, args)

    def complete(self, name: str, track: str, t0: float, t1: float,
                 lane: Optional[int] = None,
                 args: Optional[Dict[str, Any]] = None) -> None:
        """Record a complete event with explicit perf_counter endpoints.

        Used for request-lane phases (queued/prefill/decode) whose
        boundaries are already stamped on the ``Request`` object.
        """
        self._buf.append(("X", name, track, lane, t0, max(0.0, t1 - t0),
                          self.unit, args))

    def instant(self, name: str, track: str = "launch",
                lane: Optional[int] = None,
                args: Optional[Dict[str, Any]] = None) -> None:
        self._buf.append(("i", name, track, lane, time.perf_counter(), 0.0,
                          self.unit, args))

    def new_unit(self, kind: str) -> int:
        """Open the next contract unit (step / window / chunk / tick)."""
        self._next_unit += 1
        self.unit = self._next_unit
        self.unit_kind = kind
        self.instant("unit", "launch", args={"kind": kind})
        return self.unit

    # ------------------------------------------------------------ inspection
    def __len__(self) -> int:
        return len(self._buf)

    def records(self) -> List[tuple]:
        return list(self._buf)

    def overlap_ms(self) -> float:
        """Span-derived prefetch overlap: total prefetch-ship duration.

        This is the trace-native replacement for the wall-clock side
        channel the residency manager keeps in ``EngineStats.overlap_ms``;
        a regression test checks the two agree on a miss-starved run.
        """
        return sum(r[5] for r in self._buf
                   if r[0] == "X" and r[1] == "prefetch_ship") * 1e3

    # -------------------------------------------------------------- export
    def chrome_trace(self) -> Dict[str, Any]:
        """Render the buffer as a Chrome trace-event JSON object.

        Machines map to ``pid=1`` with one tid per track; request lanes map
        to ``pid=2`` with tid = request uid.  Metadata events name both so
        Perfetto shows readable track labels.
        """
        tids: Dict[str, int] = {t: i for i, t in enumerate(MACHINE_TRACKS)}
        events: List[Dict[str, Any]] = []
        lanes = set()
        for ph, name, track, lane, t0, dur, unit, args in self._buf:
            ts_us = (t0 - self._epoch) * 1e6
            if lane is not None:
                pid, tid = _PID_REQUESTS, int(lane)
                lanes.add(tid)
            else:
                if track not in tids:
                    tids[track] = len(tids)
                pid, tid = _PID_MACHINES, tids[track]
            ev: Dict[str, Any] = {
                "ph": ph, "name": name, "pid": pid, "tid": tid,
                "ts": round(ts_us, 3), "cat": track,
            }
            if ph == "X":
                ev["dur"] = round(dur * 1e6, 3)
            if ph == "i":
                ev["s"] = "t"
            a = dict(args) if args else {}
            a["unit"] = unit
            ev["args"] = a
            events.append(ev)
        meta: List[Dict[str, Any]] = [
            {"ph": "M", "name": "process_name", "pid": _PID_MACHINES,
             "args": {"name": "machines"}},
            {"ph": "M", "name": "process_name", "pid": _PID_REQUESTS,
             "args": {"name": "requests"}},
        ]
        for track, tid in sorted(tids.items(), key=lambda kv: kv[1]):
            meta.append({"ph": "M", "name": "thread_name",
                         "pid": _PID_MACHINES, "tid": tid,
                         "args": {"name": track}})
        for lane in sorted(lanes):
            meta.append({"ph": "M", "name": "thread_name",
                         "pid": _PID_REQUESTS, "tid": lane,
                         "args": {"name": f"request {lane}"}})
        return {"traceEvents": meta + events,
                "displayTimeUnit": "ms"}

    def write(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


def resolve_tracer(trace: Optional[Tracer]) -> Optional[Tracer]:
    """Normalise an engine ``trace=`` argument.

    Returns ``None`` for ``None`` *and* for disabled tracers, so the
    engines' emission guards (``if tr is not None``) make the disabled
    path identical to the untraced one — provably zero overhead.
    """
    if trace is None or not trace.enabled:
        return None
    return trace


def span_overlap_ms(events: Iterable[Dict[str, Any]]) -> float:
    """Sum prefetch-ship span durations (ms) from exported Chrome events."""
    total_us = 0.0
    for ev in events:
        if ev.get("ph") == "X" and ev.get("name") == "prefetch_ship":
            total_us += float(ev.get("dur", 0.0))
    return total_us / 1e3
