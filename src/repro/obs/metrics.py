"""Counters, gauges, and bucketed histograms with Prometheus exposition.

The registry backs the serving latency summary (TTFT/ITL percentiles that
used to be hand-rolled ``np.percentile`` calls over request timestamps) and
collects per-event distributions the aggregate ``EngineStats`` bag cannot
express: window wall time and per-dispatch upload bytes.  ``serve.py
--metrics-port`` serves :meth:`MetricsRegistry.exposition` over HTTP;
:meth:`MetricsRegistry.summary` is the one-shot dict the benchmark drivers
merge into ``BENCH_decode.json`` / ``BENCH_serving.json`` rows.

Histograms keep both Prometheus-style cumulative bucket counts (for
exposition) and the raw samples (bounded) so percentiles stay exact —
swapping the serving summary onto the registry must not change the numbers
the gates compare.
"""
from __future__ import annotations

import bisect
import math
from typing import Dict, List, Optional, Sequence

# Default bucket boundaries (upper bounds) per histogram family.
LATENCY_MS_BUCKETS = (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
                      1000.0, 2500.0, 5000.0, 10000.0)
BYTES_BUCKETS = (4096.0, 65536.0, 1048576.0, 4194304.0, 16777216.0,
                 67108864.0, 268435456.0)

_MAX_RAW_SAMPLES = 200_000


class Counter:
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Gauge:
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Cumulative-bucket histogram that also retains raw samples.

    ``percentile`` reads the raw samples (exact, matching the legacy
    ``np.percentile`` behaviour with linear interpolation); the bucket
    counts exist for Prometheus exposition.  Raw retention is capped at
    ``_MAX_RAW_SAMPLES`` — past that, percentiles fall back to bucket
    interpolation (serving runs in this repo never get close).
    """

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = LATENCY_MS_BUCKETS):
        self.name = name
        self.help = help
        self.bounds: List[float] = sorted(float(b) for b in buckets)
        self.bucket_counts: List[int] = [0] * (len(self.bounds) + 1)  # +Inf
        self.count = 0
        self.sum = 0.0
        self._raw: List[float] = []

    def reset(self) -> None:
        """Drop all samples (callers that rebuild a distribution from a
        source of truth — e.g. the serving latency summary re-deriving
        TTFT/ITL from completed requests — reset before re-observing)."""
        self.bucket_counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self._raw = []

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        self.bucket_counts[bisect.bisect_left(self.bounds, v)] += 1
        if len(self._raw) < _MAX_RAW_SAMPLES:
            self._raw.append(v)

    def percentile(self, q: float) -> float:
        """q in [0, 100], linear interpolation over raw samples."""
        if self.count == 0:
            return 0.0
        if len(self._raw) == self.count:
            xs = sorted(self._raw)
            pos = (q / 100.0) * (len(xs) - 1)
            lo = int(math.floor(pos))
            hi = min(lo + 1, len(xs) - 1)
            return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)
        return self._bucket_percentile(q)

    def _bucket_percentile(self, q: float) -> float:
        target = (q / 100.0) * self.count
        seen = 0
        lo = 0.0
        for i, c in enumerate(self.bucket_counts):
            hi = self.bounds[i] if i < len(self.bounds) else lo
            if seen + c >= target:
                if c == 0:
                    return hi
                frac = (target - seen) / c
                return lo + (hi - lo) * frac
            seen += c
            lo = hi
        return lo

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """Named metric store with Prometheus text exposition."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # ------------------------------------------------------------- accessors
    def counter(self, name: str, help: str = "") -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name, help)
        return c

    def gauge(self, name: str, help: str = "") -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name, help)
        return g

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = LATENCY_MS_BUCKETS) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, help, buckets)
        return h

    # ------------------------------------------------------------ ingestion
    def set_from(self, counters: Dict[str, float]) -> None:
        """Mirror an aggregate stats dict into gauges (live exposition)."""
        for k, v in counters.items():
            if isinstance(v, (int, float)):
                self.gauge(f"engine_{k}").set(v)

    # -------------------------------------------------------------- output
    def exposition(self) -> str:
        """Prometheus text format (version 0.0.4)."""
        lines: List[str] = []
        for c in sorted(self._counters.values(), key=lambda m: m.name):
            if c.help:
                lines.append(f"# HELP {c.name} {c.help}")
            lines.append(f"# TYPE {c.name} counter")
            lines.append(f"{c.name} {_fmt(c.value)}")
        for g in sorted(self._gauges.values(), key=lambda m: m.name):
            if g.help:
                lines.append(f"# HELP {g.name} {g.help}")
            lines.append(f"# TYPE {g.name} gauge")
            lines.append(f"{g.name} {_fmt(g.value)}")
        for h in sorted(self._histograms.values(), key=lambda m: m.name):
            if h.help:
                lines.append(f"# HELP {h.name} {h.help}")
            lines.append(f"# TYPE {h.name} histogram")
            cum = 0
            for bound, cnt in zip(h.bounds, h.bucket_counts):
                cum += cnt
                lines.append(f'{h.name}_bucket{{le="{_fmt(bound)}"}} {cum}')
            lines.append(f'{h.name}_bucket{{le="+Inf"}} {h.count}')
            lines.append(f"{h.name}_sum {_fmt(h.sum)}")
            lines.append(f"{h.name}_count {h.count}")
        return "\n".join(lines) + "\n"

    def summary(self) -> Dict[str, object]:
        """One-shot dump merged into benchmark JSON rows."""
        out: Dict[str, object] = {}
        for c in self._counters.values():
            out[c.name] = c.value
        for g in self._gauges.values():
            out[g.name] = g.value
        for h in self._histograms.values():
            out[h.name] = {
                "count": h.count,
                "sum": round(h.sum, 6),
                "mean": round(h.mean, 6),
                "p50": round(h.percentile(50), 6),
                "p95": round(h.percentile(95), 6),
                "p99": round(h.percentile(99), 6),
            }
        return out


def _fmt(v: float) -> str:
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def serve_metrics(registry_fn, port: int):
    """Start a daemon HTTP thread serving ``/metrics`` from ``registry_fn()``.

    ``registry_fn`` is called per scrape so gauges mirror live engine state.
    Returns the ``http.server`` instance (call ``shutdown()`` to stop).
    Binds to 127.0.0.1 only — this is a local debugging surface.
    """
    import http.server
    import threading

    class Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 (http.server API)
            if self.path not in ("/metrics", "/"):
                self.send_error(404)
                return
            body = registry_fn().exposition().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):  # silence per-request stderr noise
            pass

    server = http.server.ThreadingHTTPServer(("127.0.0.1", port), Handler)
    threading.Thread(target=server.serve_forever, daemon=True).start()
    return server
