"""Contract auditor: mechanically check dispatch invariants over a trace.

The repo's correctness story rests on a handful of standing contracts that
until now lived only as dispatch-count assertions scattered across tests.
The auditor replays a captured trace (the Chrome trace-event JSON a
``Tracer`` exports) and checks them structurally, per contract *unit* — one
decode step, spec window, prefill chunk, or serving tick, as stamped by
``Tracer.new_unit``:

1. **One launch + one pull per miss-free unit.**  A unit with no recorded
   miss, relaunch, or replay must contain exactly one primary ``launch``
   span and exactly one primary queue-draining ``pull`` span.
2. **Rotation strictly at boundaries.**  A ``rotation`` span belonging to a
   unit must not begin before that unit's primary pull begins — rotation
   never races the in-flight window.
3. **Prefetch ship strictly between launch and pull.**  A ``prefetch_ship``
   span must start at-or-after its unit's primary launch starts and finish
   before the primary pull begins — that interval *is* the overlap window,
   so ``overlap_ms`` is derived from these spans rather than trusted from
   the wall-clock side channel in the residency manager.
4. **No KV page used after release.**  ``kv_use`` events (the page set a
   serving window touches) must reference only pages currently granted by
   a ``kv_ensure`` and not yet returned by a ``kv_release``.

``audit(...)`` accepts a Tracer, an exported dict, a list of events, or a
path to a trace file, and returns an :class:`AuditReport`.  Run as a module
(``python -m repro.obs.audit trace.json``) it exits non-zero on violations
— that is what ``make smoke-trace`` and the benchmark drivers call.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Set, Union

from .tracer import Tracer, span_overlap_ms

# Rounded-microsecond timestamps can reorder genuinely ordered records by at
# most the rounding quantum; tolerate that, nothing more.
_EPS_US = 0.01


class AuditError(AssertionError):
    """Raised by :meth:`AuditReport.raise_for_violations`."""


class AuditReport:
    def __init__(self):
        self.violations: List[str] = []
        self.units_checked = 0
        self.miss_free_units = 0
        self.launches = 0
        self.pulls = 0
        self.rotations = 0
        self.prefetch_spans = 0
        self.kv_events = 0
        self.overlap_ms = 0.0

    @property
    def ok(self) -> bool:
        return not self.violations

    def raise_for_violations(self) -> None:
        if self.violations:
            raise AuditError(
                f"{len(self.violations)} contract violation(s):\n  "
                + "\n  ".join(self.violations[:20])
            )

    def summary(self) -> Dict[str, Any]:
        return {
            "ok": self.ok,
            "violations": len(self.violations),
            "units_checked": self.units_checked,
            "miss_free_units": self.miss_free_units,
            "launches": self.launches,
            "pulls": self.pulls,
            "rotations": self.rotations,
            "prefetch_spans": self.prefetch_spans,
            "kv_events": self.kv_events,
            "overlap_ms_from_spans": round(self.overlap_ms, 3),
        }


TraceLike = Union[Tracer, Dict[str, Any], List[Dict[str, Any]], str]


def _events(trace: TraceLike) -> List[Dict[str, Any]]:
    if isinstance(trace, Tracer):
        trace = trace.chrome_trace()
    if isinstance(trace, str):
        with open(trace) as f:
            trace = json.load(f)
    if isinstance(trace, dict):
        trace = trace.get("traceEvents", [])
    return [ev for ev in trace if ev.get("ph") != "M"]


def _kind(ev: Dict[str, Any]) -> Optional[str]:
    return (ev.get("args") or {}).get("kind")

def _unit(ev: Dict[str, Any]) -> int:
    return int((ev.get("args") or {}).get("unit", 0) or 0)

def _end(ev: Dict[str, Any]) -> float:
    return float(ev["ts"]) + float(ev.get("dur", 0.0))


def audit(trace: TraceLike) -> AuditReport:
    events = _events(trace)
    rep = AuditReport()
    rep.overlap_ms = span_overlap_ms(events)

    units: Dict[int, Dict[str, List[Dict[str, Any]]]] = {}
    for ev in events:
        u = _unit(ev)
        name = ev.get("name")
        if name == "launch":
            rep.launches += 1
        elif name == "pull":
            rep.pulls += 1
        elif name == "rotation":
            rep.rotations += 1
        elif name == "prefetch_ship":
            rep.prefetch_spans += 1
        if u <= 0:
            continue
        bucket = units.setdefault(u, {})
        bucket.setdefault(name, []).append(ev)

    for u in sorted(units):
        bucket = units[u]
        rep.units_checked += 1
        launches = bucket.get("launch", [])
        pulls = bucket.get("pull", [])
        primary_launches = [e for e in launches if _kind(e) in (None, "primary")]
        primary_pulls = [e for e in pulls if _kind(e) in (None, "primary")]

        exempt = bool(
            bucket.get("miss")
            or bucket.get("replay")
            or any(_kind(e) == "relaunch" for e in launches + pulls)
        )
        # Contract 1: exact dispatch economy on the miss-free fast path.
        if not exempt and (launches or pulls):
            rep.miss_free_units += 1
            if len(primary_launches) != 1:
                rep.violations.append(
                    f"unit {u}: {len(primary_launches)} primary launches "
                    f"in a miss-free unit (want exactly 1)"
                )
            if len(primary_pulls) != 1:
                rep.violations.append(
                    f"unit {u}: {len(primary_pulls)} primary pulls in a "
                    f"miss-free unit (want exactly 1)"
                )

        pull0 = min(primary_pulls, key=lambda e: e["ts"]) if primary_pulls \
            else None
        launch0 = min(primary_launches, key=lambda e: e["ts"]) \
            if primary_launches else None

        # Contract 2: rotation only after the unit's pull has begun.
        if pull0 is not None:
            for rot in bucket.get("rotation", []):
                if float(rot["ts"]) + _EPS_US < float(pull0["ts"]):
                    rep.violations.append(
                        f"unit {u}: rotation at ts={rot['ts']} begins "
                        f"mid-window, before the primary pull at "
                        f"ts={pull0['ts']}"
                    )

        # Contract 3: prefetch ship inside the launch→pull overlap window.
        for ship in bucket.get("prefetch_ship", []):
            if launch0 is not None and \
                    float(ship["ts"]) + _EPS_US < float(launch0["ts"]):
                rep.violations.append(
                    f"unit {u}: prefetch_ship at ts={ship['ts']} dispatched "
                    f"before the launch at ts={launch0['ts']}"
                )
            if pull0 is not None and \
                    _end(ship) > float(pull0["ts"]) + _EPS_US:
                rep.violations.append(
                    f"unit {u}: prefetch_ship ending at ts={_end(ship)} "
                    f"overruns the pull at ts={pull0['ts']}"
                )

    _audit_kv(events, rep)
    return rep


def _audit_kv(events: List[Dict[str, Any]], rep: AuditReport) -> None:
    """Contract 4: page-lifetime discipline, replayed in event order."""
    live: Set[int] = set()
    owner: Dict[int, int] = {}
    kv = [ev for ev in events
          if ev.get("name") in ("kv_reserve", "kv_ensure", "kv_release",
                                "kv_use")]
    kv.sort(key=lambda e: float(e["ts"]))
    rep.kv_events = len(kv)
    for ev in kv:
        args = ev.get("args") or {}
        name = ev["name"]
        if name == "kv_ensure":
            for p in args.get("pages", []):
                live.add(int(p))
                owner[int(p)] = int(args.get("uid", -1))
        elif name == "kv_release":
            for p in args.get("pages", []):
                p = int(p)
                if p not in live:
                    rep.violations.append(
                        f"kv: uid {args.get('uid')} released page {p} "
                        f"which was not live (double release?)"
                    )
                live.discard(p)
        elif name == "kv_use":
            for p in args.get("pages", []):
                if int(p) not in live:
                    rep.violations.append(
                        f"kv: page {p} used at ts={ev['ts']} after release "
                        f"(or never granted)"
                    )


def main(argv: Optional[Iterable[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Audit a Chrome trace-event JSON for dispatch-contract "
                    "violations.")
    ap.add_argument("trace", help="path to a trace file written by "
                                  "Tracer.write / serve.py --trace-out")
    args = ap.parse_args(list(argv) if argv is not None else None)
    rep = audit(args.trace)
    print("audit:", json.dumps(rep.summary()))
    if not rep.ok:
        for v in rep.violations:
            print("VIOLATION:", v)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
