"""Observability: span tracer, metrics registry, and the contract auditor.

See ``docs/ARCHITECTURE.md`` ("Observability") for the track/lane map and
the invariant list the auditor enforces.
"""
from .audit import AuditError, AuditReport, audit
from .metrics import (BYTES_BUCKETS, LATENCY_MS_BUCKETS, Counter, Gauge,
                      Histogram, MetricsRegistry, serve_metrics)
from .tracer import (MACHINE_TRACKS, Tracer, resolve_tracer, span_overlap_ms)

__all__ = [
    "AuditError", "AuditReport", "audit",
    "BYTES_BUCKETS", "LATENCY_MS_BUCKETS", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "serve_metrics",
    "MACHINE_TRACKS", "Tracer", "resolve_tracer", "span_overlap_ms",
]
