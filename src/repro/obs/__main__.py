"""``python -m repro.obs TRACE.json`` — run the contract auditor on a trace.

Equivalent to ``python -m repro.obs.audit`` but avoids runpy's re-execution
warning (the package eagerly imports the audit module).
"""
import sys

from repro.obs.audit import main

sys.exit(main(sys.argv[1:]))
