from repro.config.base import (
    AttentionConfig,
    ModelConfig,
    MoEConfig,
    RecurrentConfig,
    ResidencyConfig,
    RunConfig,
    ShapeConfig,
    ShardingConfig,
    flat_overrides,
)
from repro.config.registry import get_config, list_archs, register

__all__ = [
    "AttentionConfig",
    "ModelConfig",
    "MoEConfig",
    "RecurrentConfig",
    "ResidencyConfig",
    "RunConfig",
    "ShapeConfig",
    "ShardingConfig",
    "flat_overrides",
    "get_config",
    "list_archs",
    "register",
]
