"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.config.base import ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str) -> Callable[[Callable[[], ModelConfig]], Callable[[], ModelConfig]]:
    def deco(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
        if arch_id in _REGISTRY:
            raise ValueError(f"duplicate arch id {arch_id!r}")
        _REGISTRY[arch_id] = fn
        return fn

    return deco


def get_config(arch_id: str) -> ModelConfig:
    # Import lazily so `import repro.config` never pulls the whole config package.
    import repro.configs  # noqa: F401  (populates the registry)

    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs() -> List[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
