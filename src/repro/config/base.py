"""Config system: frozen dataclasses describing models, residency, sharding and runs.

Every architecture in ``repro.configs`` builds a :class:`ModelConfig`; every launcher
entry point consumes a (:class:`ModelConfig`, :class:`ShapeConfig`, :class:`ShardingConfig`)
triple. Configs are plain data — no jax imports here — so they can be constructed,
serialized and diffed without touching device state.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Mapping, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block kinds understood by the model builder (repro.models.transformer).
# ---------------------------------------------------------------------------
BLOCK_KINDS = (
    "attn_mlp",     # full attention + dense MLP
    "attn_moe",     # full attention + MoE FFN
    "local_attn",   # sliding-window attention + dense MLP
    "mlstm",        # xLSTM matrix-memory block
    "slstm",        # xLSTM scalar-memory block
    "rglru",        # RecurrentGemma RG-LRU block (+ dense MLP)
)

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclass(frozen=True)
class AttentionConfig:
    """Grouped-query attention settings."""

    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    window: Optional[int] = None          # sliding-window size for local attention
    logit_soft_cap: Optional[float] = None

    def __post_init__(self) -> None:
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(
                f"num_heads={self.num_heads} must be divisible by "
                f"num_kv_heads={self.num_kv_heads}"
            )

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts FFN settings (routed + optional shared experts)."""

    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3
    # normalize top-k router weights to sum to 1 (qwen-style) or use raw softmax mass
    norm_topk_prob: bool = True
    # EP padding: expert weights stored as [padded_experts, ...] with
    # never-routed zero dummies so the expert dim divides the model axis
    # (DESIGN.md §4: qwen2-moe 60 -> 64). 0 = num_experts (no padding).
    padded_experts: int = 0

    def __post_init__(self) -> None:
        if self.top_k > self.num_experts:
            raise ValueError("top_k cannot exceed num_experts")
        if self.padded_experts and self.padded_experts < self.num_experts:
            raise ValueError("padded_experts must be >= num_experts")

    @property
    def storage_experts(self) -> int:
        return self.padded_experts or self.num_experts


@dataclass(frozen=True)
class RecurrentConfig:
    """Settings for recurrent block kinds (rglru / xlstm)."""

    lru_width: int = 0             # RG-LRU hidden width (0 -> d_model)
    conv_width: int = 4            # temporal-conv width in the RG-LRU block
    num_heads: int = 4             # recurrence heads (xLSTM / RG-LRU block diagonal)


@dataclass(frozen=True)
class ModelConfig:
    """Complete architecture description.

    ``segments`` encodes the layer stack as a sequence of (unit, repeats): the unit is a
    tuple of block kinds executed in order, and the unit is scanned ``repeats`` times with
    stacked parameters. e.g. recurrentgemma-2b:
    ``((("rglru","rglru","local_attn"), 8), (("rglru",), 2))`` = 26 layers.
    """

    name: str
    family: str
    d_model: int
    vocab_size: int
    segments: Tuple[Tuple[Tuple[str, ...], int], ...]
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    d_ff: int = 0                      # dense-MLP hidden size (0 for pure-ssm archs)
    mlp: str = "swiglu"                # "swiglu" | "gelu_mlp" | "none"
    norm: str = "rmsnorm"              # "rmsnorm" | "layernorm"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # Modality frontends are STUBS per the assignment: input_specs() provides
    # precomputed patch/frame embeddings of length ``frontend_len``.
    frontend: Optional[str] = None     # None | "vision_patches" | "audio_frames"
    frontend_len: int = 0
    frontend_dim: int = 0
    sub_quadratic: bool = False        # True -> long_500k shape applies
    source: str = ""                   # provenance note [paper/hf id; tier]

    def __post_init__(self) -> None:
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        for unit, reps in self.segments:
            if reps <= 0:
                raise ValueError("segment repeats must be positive")
            for kind in unit:
                if kind not in BLOCK_KINDS:
                    raise ValueError(f"unknown block kind {kind!r}")
        needs_attn = any(
            k in ("attn_mlp", "attn_moe", "local_attn")
            for unit, _ in self.segments
            for k in unit
        )
        if needs_attn and self.attention is None:
            raise ValueError(f"{self.name}: attention blocks present but no AttentionConfig")
        needs_moe = any(k == "attn_moe" for unit, _ in self.segments for k in unit)
        if needs_moe and self.moe is None:
            raise ValueError(f"{self.name}: attn_moe blocks present but no MoEConfig")

    @property
    def num_layers(self) -> int:
        return sum(len(unit) * reps for unit, reps in self.segments)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        kinds: list[str] = []
        for unit, reps in self.segments:
            kinds.extend(list(unit) * reps)
        return tuple(kinds)

    @property
    def has_moe(self) -> bool:
        return any(k == "attn_moe" for k in self.layer_kinds)

    @property
    def uses_kv_cache(self) -> bool:
        return any(k in ("attn_mlp", "attn_moe", "local_attn") for k in self.layer_kinds)

    def with_overrides(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, default=str)


# ---------------------------------------------------------------------------
# Residency — the paper's contribution, configured here.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ResidencyConfig:
    """Rotary accelerator-residency settings (the paper's §4/§5 machinery).

    ``mode``:
      * ``full``   — every expert resident in HBM (EP-sharded); paper's "whole warehouse".
      * ``rotary`` — slot-group residency with cyclic forward/reverse rotation (the paper).
      * ``lru``    — least-recently-used eviction baseline the paper contrasts against.
      * ``static`` — fixed top-frequency resident set, never rotated.
    ``granularity``: "expert" for MoE archs; "layer" for dense/ssm archs where the
    technique degrades to layer-group residency (DESIGN.md §6).
    """

    mode: str = "full"
    num_slots: int = 0                  # device-resident slots per MoE layer (0 = all)
    granularity: str = "expert"
    rotation_stride: int = 1
    prefetch_margin: int = 2            # slots reserved for in-flight prefetch
    predictor_ema: float = 0.8
    reverse_threshold: float = 0.85     # demand-correlation trigger for reverse rotation
    pin_shared: bool = True             # shared experts occupy pinned slots
    hbm_budget_bytes: Optional[int] = None
    host_compute_misses: bool = True    # paper's n-cpu-moe: misses run on host
    # None | "int8" (per-channel) | "int4" (grouped two-nibbles-per-byte with
    # per-group f16 scale+min — the Q4_K_M analog; repro.quant)
    quantization: Optional[str] = None
    quant_group_size: int = 64          # int4 rows per scale/min group

    def __post_init__(self) -> None:
        if self.mode not in ("full", "rotary", "lru", "static"):
            raise ValueError(f"unknown residency mode {self.mode!r}")
        if self.granularity not in ("expert", "layer"):
            raise ValueError(f"unknown granularity {self.granularity!r}")
        if self.quantization not in (None, "int8", "int4"):
            raise ValueError(f"unknown quantization {self.quantization!r}")
        if self.quant_group_size < 2 or self.quant_group_size % 2:
            raise ValueError("quant_group_size must be an even integer >= 2")


@dataclass(frozen=True)
class ShardingConfig:
    """Partitioning rules mapping model dims onto mesh axes."""

    dp_axes: Tuple[str, ...] = ("data",)      # batch axes ("pod","data") when multi-pod
    tp_axis: str = "model"                    # TP/EP axis
    seq_axis: Optional[str] = "data"          # SP axis for long prefill (batch < dp size)
    remat_policy: str = "dots_saveable"       # "none"|"full"|"dots_saveable"
    scan_layers: bool = True
    grad_compression: Optional[str] = None    # None | "int8_ef" (error feedback)
    zero1: bool = True                        # shard optimizer state over dp axes
    use_pallas: bool = False                  # Mosaic kernels (real TPU only)
    # MoE dispatch: "dense" (GShard one-hot einsum baseline), "sorted" (local
    # sort/scatter), "epsum" (shard_map EP: AG tokens -> local sorted -> RS).
    # "epsum" falls back to "sorted" when no mesh is active.
    moe_impl: str = "epsum"


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                                  # "train" | "prefill" | "decode"

    def __post_init__(self) -> None:
        if self.kind not in ("train", "prefill", "decode"):
            raise ValueError(f"unknown shape kind {self.kind!r}")


@dataclass(frozen=True)
class RunConfig:
    """Training/serving run hyperparameters."""

    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatch: int = 0                        # 0 = no gradient accumulation
    seed: int = 0
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10


def flat_overrides(cfg: Any, overrides: Mapping[str, Any]) -> Any:
    """Apply dotted-path overrides, e.g. {"moe.top_k": 2} on a dataclass tree."""
    out = cfg
    for key, value in overrides.items():
        parts = key.split(".")
        out = _set_path(out, parts, value)
    return out


def _set_path(cfg: Any, parts: Sequence[str], value: Any) -> Any:
    if len(parts) == 1:
        return dataclasses.replace(cfg, **{parts[0]: value})
    child = getattr(cfg, parts[0])
    new_child = _set_path(child, parts[1:], value)
    return dataclasses.replace(cfg, **{parts[0]: new_child})
