"""Shard-aware input pipeline with background prefetch.

``ShardedLoader`` materializes each global batch with the mesh's batch sharding
(host -> device transfer happens once, per-shard) and prefetches ``depth``
batches on a worker thread so step N+1's H2D overlaps step N's compute — the
data-side analog of the residency engine's double buffering.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.synthetic import SyntheticSpec, batch_at_step


class ShardedLoader:
    def __init__(
        self,
        spec: SyntheticSpec,
        mesh: Optional[Mesh] = None,
        dp_axes: Tuple[str, ...] = ("data",),
        depth: int = 2,
        start_step: int = 0,
    ):
        self.spec = spec
        self.mesh = mesh
        self.sharding = (
            NamedSharding(mesh, P(dp_axes, None)) if mesh is not None else None
        )
        self.depth = depth
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _put(self, step: int) -> None:
        tokens, labels = batch_at_step(self.spec, step)
        if self.sharding is not None:
            tokens = jax.device_put(tokens, self.sharding)
            labels = jax.device_put(labels, self.sharding)
        self._q.put((step, tokens, labels))

    def _worker(self) -> None:
        step = self.step
        while not self._stop.is_set():
            try:
                self._put(step)
                step += 1
            except Exception:              # pragma: no cover - surfaced on get
                self._q.put((step, None, None))
                return

    def __iter__(self) -> Iterator[Tuple[int, jax.Array, jax.Array]]:
        return self

    def __next__(self):
        step, tokens, labels = self._q.get()
        if tokens is None:
            raise RuntimeError("data worker died")
        return step, tokens, labels

    def close(self) -> None:
        self._stop.set()
        while not self._q.empty():
            self._q.get_nowait()
