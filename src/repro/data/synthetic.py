"""Deterministic synthetic token streams.

Two generators:
  * ``uniform_stream`` — iid tokens (training-throughput benchmarks).
  * ``topic_stream``  — tokens drawn from a latent *topic* that advances along a
    cycle and recurs, inducing recurring router-demand patterns in MoE models.
    This is the workload the paper's "cyclical return on recurring semantic
    context" targets, and what ``benchmarks/residency_policies.py`` replays.

Everything is seeded and reproducible across restarts (checkpoint/resume tests
compare bitwise).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class SyntheticSpec:
    vocab_size: int
    seq_len: int
    global_batch: int
    kind: str = "topic"            # "uniform" | "topic"
    num_topics: int = 8
    topic_len: int = 64            # tokens per topic visit
    cycle: Tuple[int, ...] = ()    # explicit topic cycle; () = 0..T-1 loop
    seed: int = 0


def _topic_token_sampler(vocab: int, num_topics: int, seed: int):
    """Each topic owns a sparse preferred-token distribution (Zipf-ish)."""
    rng = np.random.default_rng(seed)
    support = max(16, vocab // num_topics)
    tables = []
    for t in range(num_topics):
        toks = rng.choice(vocab, size=support, replace=False)
        w = 1.0 / np.arange(1, support + 1)
        tables.append((toks, w / w.sum()))
    return tables


def batch_at_step(spec: SyntheticSpec, step: int) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic (tokens, labels) [B, S] for a global step (resume-safe)."""
    rng = np.random.default_rng(np.random.SeedSequence([spec.seed, step]))
    b, s = spec.global_batch, spec.seq_len
    if spec.kind == "uniform":
        tokens = rng.integers(0, spec.vocab_size, (b, s), dtype=np.int64)
    else:
        tables = _topic_token_sampler(spec.vocab_size, spec.num_topics, spec.seed)
        cycle = spec.cycle or tuple(range(spec.num_topics))
        tokens = np.empty((b, s), np.int64)
        for i in range(0, s, spec.topic_len):
            phase = (step * (s // spec.topic_len) + i // spec.topic_len) % len(cycle)
            toks, p = tables[cycle[phase]]
            n = min(spec.topic_len, s - i)
            tokens[:, i : i + n] = rng.choice(toks, size=(b, n), p=p)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = -1                      # last position has no target
    return tokens.astype(np.int32), labels.astype(np.int32)


def stream(spec: SyntheticSpec, start_step: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    step = start_step
    while True:
        yield batch_at_step(spec, step)
        step += 1
