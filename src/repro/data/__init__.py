from repro.data.pipeline import ShardedLoader  # noqa: F401
from repro.data.synthetic import SyntheticSpec, batch_at_step, stream  # noqa: F401
