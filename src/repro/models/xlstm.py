"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory) [arXiv:2405.04517].

Both use exponential gating with the max-stabilizer trick. Training/prefill run a
`lax.scan` over time (compact HLO — one fused loop body regardless of seq_len);
decode is the identical single-step recurrence, so train/decode consistency is a
property test. States are O(1) in sequence length — these archs carry the
``long_500k`` cell.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import RecurrentConfig
from repro.models.layers import Params, dense_init

State = Dict[str, jax.Array]


# ===========================================================================
# mLSTM
# ===========================================================================
def init_mlstm(key: jax.Array, d_model: int, rcfg: RecurrentConfig, dtype: Any) -> Params:
    h = rcfg.num_heads
    d_inner = 2 * d_model
    ku, kq, kk, kv, ki, kf, ko, kd, kskip = jax.random.split(key, 9)
    return {
        "w_up": dense_init(ku, (d_model, 2 * d_inner), dtype),       # cell branch | gate branch
        "w_q": dense_init(kq, (d_inner, d_inner), dtype),
        "w_k": dense_init(kk, (d_inner, d_inner), dtype),
        "w_v": dense_init(kv, (d_inner, d_inner), dtype),
        "w_if": dense_init(ki, (d_inner, 2 * h), jnp.float32),       # i,f pre-activations
        "b_if": jnp.concatenate([jnp.zeros((h,)), jnp.ones((h,)) * 3.0]).astype(jnp.float32),
        "skip": jnp.ones((d_inner,), dtype),
        "w_down": dense_init(kd, (d_inner, d_model), dtype, fan_in=d_inner),
    }


def mlstm_zero_state(batch: int, d_model: int, rcfg: RecurrentConfig) -> State:
    h = rcfg.num_heads
    dh = (2 * d_model) // h
    return {
        "c": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def _mlstm_cell(
    state: State, q: jax.Array, k: jax.Array, v: jax.Array, i_pre: jax.Array, f_pre: jax.Array
) -> Tuple[State, jax.Array]:
    """One step. q/k/v [B,H,dh] f32; i/f pre-activations [B,H]. Returns h [B,H,dh]."""
    dh = q.shape[-1]
    log_f = -jax.nn.softplus(-f_pre)                      # log sigmoid(f)
    m_new = jnp.maximum(log_f + state["m"], i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + state["m"] - m_new)
    k_scaled = k / jnp.sqrt(dh)
    c = f_g[..., None, None] * state["c"] + i_g[..., None, None] * (
        v[..., :, None] * k_scaled[..., None, :]
    )
    n = f_g[..., None] * state["n"] + i_g[..., None] * k_scaled
    num = jnp.einsum("bhvk,bhk->bhv", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    h_out = num / den[..., None]
    return {"c": c, "n": n, "m": m_new}, h_out


def _mlstm_inner(p: Params, x: jax.Array, state: State) -> Tuple[jax.Array, State]:
    """x [B,S,D] -> (y [B,S,D], state). scan over S."""
    b, s, d = x.shape
    up = x @ p["w_up"]
    cell_in, gate_in = jnp.split(up, 2, axis=-1)          # [B,S,2D] each
    d_inner = cell_in.shape[-1]
    hh = p["b_if"].shape[0] // 2
    dh = d_inner // hh
    q = (cell_in @ p["w_q"]).reshape(b, s, hh, dh).astype(jnp.float32)
    k = (cell_in @ p["w_k"]).reshape(b, s, hh, dh).astype(jnp.float32)
    v = (cell_in @ p["w_v"]).reshape(b, s, hh, dh).astype(jnp.float32)
    if_pre = cell_in.astype(jnp.float32) @ p["w_if"] + p["b_if"]   # [B,S,2H]
    i_pre, f_pre = jnp.split(if_pre, 2, axis=-1)

    def step(st, inp):
        qt, kt, vt, it, ft = inp
        st, h_out = _mlstm_cell(st, qt, kt, vt, it, ft)
        return st, h_out

    xs = (
        q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3), v.transpose(1, 0, 2, 3),
        i_pre.transpose(1, 0, 2), f_pre.transpose(1, 0, 2),
    )
    state, hs = jax.lax.scan(step, state, xs)              # hs [S,B,H,dh]
    h_seq = hs.transpose(1, 0, 2, 3).reshape(b, s, d_inner).astype(x.dtype)
    h_seq = h_seq + p["skip"] * cell_in
    y = (h_seq * jax.nn.silu(gate_in)) @ p["w_down"]
    return y, state


def mlstm_train(p: Params, x: jax.Array, rcfg: RecurrentConfig) -> jax.Array:
    state = mlstm_zero_state(x.shape[0], x.shape[-1], rcfg)
    y, _ = _mlstm_inner(p, x, state)
    return y


def mlstm_prefill(p: Params, x: jax.Array, rcfg: RecurrentConfig) -> Tuple[jax.Array, State]:
    state = mlstm_zero_state(x.shape[0], x.shape[-1], rcfg)
    return _mlstm_inner(p, x, state)


def mlstm_decode(p: Params, x: jax.Array, state: State) -> Tuple[jax.Array, State]:
    """x [B,1,D]."""
    return _mlstm_inner_step(p, x, state)


def _mlstm_inner_step(p: Params, x: jax.Array, state: State) -> Tuple[jax.Array, State]:
    b, s, d = x.shape
    assert s == 1
    up = x @ p["w_up"]
    cell_in, gate_in = jnp.split(up, 2, axis=-1)
    d_inner = cell_in.shape[-1]
    hh = p["b_if"].shape[0] // 2
    dh = d_inner // hh
    sq = cell_in[:, 0]
    q = (sq @ p["w_q"]).reshape(b, hh, dh).astype(jnp.float32)
    k = (sq @ p["w_k"]).reshape(b, hh, dh).astype(jnp.float32)
    v = (sq @ p["w_v"]).reshape(b, hh, dh).astype(jnp.float32)
    if_pre = sq.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    i_pre, f_pre = jnp.split(if_pre, 2, axis=-1)
    state, h_out = _mlstm_cell(state, q, k, v, i_pre, f_pre)
    h_seq = h_out.reshape(b, 1, d_inner).astype(x.dtype) + p["skip"] * cell_in
    y = (h_seq * jax.nn.silu(gate_in)) @ p["w_down"]
    return y, state


# ===========================================================================
# sLSTM
# ===========================================================================
def init_slstm(key: jax.Array, d_model: int, rcfg: RecurrentConfig, dtype: Any) -> Params:
    h = rcfg.num_heads
    dh = d_model // h
    kz, ki, kf, ko, kr, kd, ku = jax.random.split(key, 7)
    return {
        # input projections for z,i,f,o fused: [D, 4D]
        "w_in": dense_init(kz, (d_model, 4 * d_model), jnp.float32),
        # block-diagonal recurrent weights per head: [4, H, dh, dh]
        "r": (jax.random.normal(kr, (4, h, dh, dh), jnp.float32) / jnp.sqrt(dh)),
        "b": jnp.concatenate(
            [jnp.zeros((2 * d_model,)), jnp.ones((d_model,)) * 3.0, jnp.zeros((d_model,))]
        ).astype(jnp.float32),
        # post-cell gated MLP (proj factor 4/3, GLU)
        "w_up": dense_init(ku, (d_model, 2 * ((4 * d_model) // 3)), dtype),
        "w_down": dense_init(kd, ((4 * d_model) // 3, d_model), dtype, fan_in=(4 * d_model) // 3),
    }


def slstm_zero_state(batch: int, d_model: int, rcfg: RecurrentConfig) -> State:
    h = rcfg.num_heads
    dh = d_model // h
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, h, dh), -1e30, jnp.float32)}


def _slstm_cell(p: Params, state: State, x_t: jax.Array) -> Tuple[State, jax.Array]:
    """x_t [B,D] f32 -> h [B,D]."""
    b, d = x_t.shape
    _, h, dh, _ = p["r"].shape
    pre = x_t @ p["w_in"] + p["b"]                          # [B,4D]
    pre = pre.reshape(b, 4, h, dh)
    rec = jnp.einsum("bhd,ghde->bghe", state["h"], p["r"])   # [B,4,H,dh]
    z_pre, i_pre, f_pre, o_pre = jnp.moveaxis(pre + rec, 1, 0)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + state["m"], i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + state["m"] - m_new)
    c = f_g * state["c"] + i_g * z
    n = f_g * state["n"] + i_g
    h_new = o * c / jnp.maximum(n, 1.0)
    new_state = {"c": c, "n": n, "h": h_new, "m": m_new}
    return new_state, h_new.reshape(b, d)


def _slstm_inner(p: Params, x: jax.Array, state: State) -> Tuple[jax.Array, State]:
    b, s, d = x.shape
    xf = x.astype(jnp.float32)

    def step(st, x_t):
        st, h_out = _slstm_cell(p, st, x_t)
        return st, h_out

    state, hs = jax.lax.scan(step, state, xf.transpose(1, 0, 2))  # [S,B,D]
    h_seq = hs.transpose(1, 0, 2).astype(x.dtype)
    up = h_seq @ p["w_up"]
    a, g = jnp.split(up, 2, axis=-1)
    y = (a * jax.nn.gelu(g)) @ p["w_down"]
    return y, state


def slstm_train(p: Params, x: jax.Array, rcfg: RecurrentConfig) -> jax.Array:
    state = slstm_zero_state(x.shape[0], x.shape[-1], rcfg)
    y, _ = _slstm_inner(p, x, state)
    return y


def slstm_prefill(p: Params, x: jax.Array, rcfg: RecurrentConfig) -> Tuple[jax.Array, State]:
    state = slstm_zero_state(x.shape[0], x.shape[-1], rcfg)
    return _slstm_inner(p, x, state)


def slstm_decode(p: Params, x: jax.Array, state: State) -> Tuple[jax.Array, State]:
    b, s, d = x.shape
    assert s == 1
    state, h_out = _slstm_cell(p, state, x[:, 0].astype(jnp.float32))
    h_seq = h_out.reshape(b, 1, d).astype(x.dtype)
    up = h_seq @ p["w_up"]
    a, g = jnp.split(up, 2, axis=-1)
    y = (a * jax.nn.gelu(g)) @ p["w_down"]
    return y, state
