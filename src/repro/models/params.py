"""Parameter and model-FLOP accounting (roofline §: MODEL_FLOPS = 6·N·D).

``count_params`` walks a real params pytree; ``analytic_params`` computes the same
from the config without allocating (used for full-size archs on the CPU host).
``active_params`` restricts MoE layers to top-k routed + shared experts, which is
what enters 6·N_active·D for MoE archs.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np

from repro.config.base import ModelConfig


def count_params(params: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def _block_params(cfg: ModelConfig, kind: str, active_only: bool) -> int:
    d = cfg.d_model
    n = 0
    norm = d if cfg.norm == "rmsnorm" else 2 * d

    def mlp_params(d_ff: int) -> int:
        mats = 3 if cfg.mlp == "swiglu" else 2
        return mats * d * d_ff

    if kind in ("attn_mlp", "attn_moe", "local_attn"):
        a = cfg.attention
        n += 2 * norm
        n += d * a.num_heads * a.head_dim * 2              # wq, wo
        n += d * a.num_kv_heads * a.head_dim * 2           # wk, wv
        if a.qk_norm:
            n += 2 * a.head_dim
        if kind == "attn_moe":
            m = cfg.moe
            experts = m.top_k if active_only else m.storage_experts
            mats = 3 if cfg.mlp == "swiglu" else 2
            n += d * m.num_experts                         # router (always read)
            n += experts * mats * d * m.expert_d_ff
            if m.num_shared_experts:
                sf = m.num_shared_experts * m.shared_d_ff
                n += 3 * d * sf + d                        # fused shared + gate
        else:
            n += mlp_params(cfg.d_ff)
        return n
    if kind == "mlstm":
        d_inner = 2 * d
        n += norm
        n += d * 2 * d_inner                               # up
        n += 3 * d_inner * d_inner                         # q,k,v
        n += d_inner * 2 * cfg.recurrent.num_heads + 2 * cfg.recurrent.num_heads
        n += d_inner                                       # skip
        n += d_inner * d                                   # down
        return n
    if kind == "slstm":
        h = cfg.recurrent.num_heads
        dh = d // h
        n += norm
        n += d * 4 * d + 4 * d                             # w_in + b
        n += 4 * h * dh * dh                               # recurrent block-diag
        up = (4 * d) // 3
        n += d * 2 * up + up * d
        return n
    if kind == "rglru":
        w = cfg.recurrent.lru_width or d
        n += 2 * norm
        n += 2 * d * w                                     # branch in-projs
        n += cfg.recurrent.conv_width * w + w              # conv
        n += 2 * w * w + w                                 # gates + lambda
        n += w * d                                         # out
        n += mlp_params(cfg.d_ff)
        return n
    raise ValueError(kind)


def analytic_params(cfg: ModelConfig, active_only: bool = False) -> int:
    n = cfg.vocab_size * cfg.d_model                       # embed
    if not cfg.tie_embeddings:
        n += cfg.d_model * cfg.vocab_size                  # lm head
    if cfg.frontend is not None and cfg.frontend_dim != cfg.d_model:
        n += cfg.frontend_dim * cfg.d_model
    n += cfg.d_model if cfg.norm == "rmsnorm" else 2 * cfg.d_model
    for kind in cfg.layer_kinds:
        n += _block_params(cfg, kind, active_only)
    return n


def model_flops(cfg: ModelConfig, tokens: int) -> int:
    """MODEL_FLOPS = 6 · N(_active) · tokens  (fwd+bwd; fwd-only callers divide by 3)."""
    return 6 * analytic_params(cfg, active_only=cfg.has_moe) * tokens


def param_summary(cfg: ModelConfig) -> Dict[str, float]:
    total = analytic_params(cfg, active_only=False)
    active = analytic_params(cfg, active_only=True)
    return {
        "total_params_B": total / 1e9,
        "active_params_B": active / 1e9,
        "bf16_bytes_GB": 2 * total / 2**30,
    }
