"""Mixture-of-Experts FFN: router, dispatch implementations, residency hooks.

Dispatch implementations (ShardingConfig.moe_impl):

* ``dense``  — GShard-style one-hot dispatch/combine einsums with per-batch-row
  capacity. Simple, shards predictably under plain jit (tokens over dp, experts
  over model), but the dispatch einsum itself costs O(T*E*C*D) FLOPs — it is the
  *baseline* the perf loop improves on.
* ``sorted`` — single-device sort-based dispatch: argsort assignments by expert,
  scatter into an [E, C, D] buffer, batched expert GEMMs, weighted scatter-add
  combine. O(T*k*D) data movement, zero dispatch FLOPs. Used by the rotary engine
  and as the per-device body of ``epsum``.
* ``epsum``  — expert parallelism under shard_map: all-gather tokens over the EP
  axis, each device runs ``sorted`` dispatch for its local experts, partial
  outputs reduce-scatter back. Predictable collectives (1 AG + 1 RS per layer).

Decode uses ``moe_gathered``: per-token expert weights are *gathered* (optionally
through the rotary slot LUT) and applied as grouped GEMVs — exactly active-param
FLOPs, no capacity padding. This is the compiled half of the paper's technique;
misses surface as a mask the engine corrects between steps.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import MoEConfig
from repro.models.layers import Params, dense_init

Aux = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def init_moe(key: jax.Array, d_model: int, mcfg: MoEConfig, mlp_kind: str, dtype: Any) -> Params:
    kr, kg, ku, kd, ksg, ksu, ksd, kgate = jax.random.split(key, 8)
    # expert weights stored [storage_experts, ...] (padded with never-routed
    # dummies when the expert count doesn't divide the EP axis)
    e, f = mcfg.storage_experts, mcfg.expert_d_ff
    p: Params = {"router": dense_init(kr, (d_model, mcfg.num_experts), jnp.float32)}
    if mlp_kind == "swiglu":
        p["experts"] = {
            "w_gate": dense_init(kg, (e, d_model, f), dtype),
            "w_up": dense_init(ku, (e, d_model, f), dtype),
            "w_down": dense_init(kd, (e, f, d_model), dtype, fan_in=f),
        }
    else:
        p["experts"] = {
            "w_up": dense_init(ku, (e, d_model, f), dtype),
            "w_down": dense_init(kd, (e, f, d_model), dtype, fan_in=f),
        }
    if mcfg.num_shared_experts > 0:
        sf = mcfg.shared_d_ff * mcfg.num_shared_experts  # fused shared experts
        p["shared"] = {
            "w_gate": dense_init(ksg, (d_model, sf), dtype),
            "w_up": dense_init(ksu, (d_model, sf), dtype),
            "w_down": dense_init(ksd, (sf, d_model), dtype, fan_in=sf),
        }
        p["shared_gate"] = dense_init(kgate, (d_model, 1), dtype)
    return p


def expert_param_bytes(d_model: int, mcfg: MoEConfig, mlp_kind: str, dtype_bytes: int = 2) -> int:
    """Bytes of ONE routed expert (the unit of residency)."""
    mats = 3 if mlp_kind == "swiglu" else 2
    return mats * d_model * mcfg.expert_d_ff * dtype_bytes


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------
def router_logits(p: Params, x2d: jax.Array) -> jax.Array:
    """x2d [T, D] -> router logits f32 [T, E]."""
    return x2d.astype(jnp.float32) @ p["router"]


def topk_route(logits: jax.Array, mcfg: MoEConfig) -> Tuple[jax.Array, jax.Array, Aux]:
    """logits [T,E] -> (ids [T,k] int32, weights [T,k] f32, aux losses)."""
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, mcfg.top_k)
    if mcfg.norm_topk_prob:
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    e = mcfg.num_experts
    # Switch-style load-balance loss + router z-loss
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(ids, e, dtype=jnp.float32)).sum(axis=1), axis=0
    )  # [E] fraction routed (counting multiplicity/k handled by scale)
    mean_prob = jnp.mean(probs, axis=0)
    aux: Aux = {
        "load_balance": e * jnp.sum(frac_tokens / mcfg.top_k * mean_prob),
        "router_z": jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
    }
    return ids.astype(jnp.int32), weights, aux


def _expert_ffn(experts: Params, xs: jax.Array) -> jax.Array:
    """Batched expert FFN. xs [E, C, D] against stacked weights -> [E, C, D].
    bf16 operands, f32 accumulation (MXU-native mixed precision)."""
    def mm(a, w):
        return jnp.einsum("ecd,edf->ecf", a, w,
                          preferred_element_type=jnp.float32).astype(a.dtype)

    if "w_gate" in experts:
        h = jax.nn.silu(mm(xs, experts["w_gate"])) * mm(xs, experts["w_up"])
    else:
        h = jax.nn.gelu(mm(xs, experts["w_up"]))
    return jnp.einsum("ecf,efd->ecd", h, experts["w_down"],
                      preferred_element_type=jnp.float32).astype(xs.dtype)


def _shared_ffn(p: Params, x: jax.Array) -> jax.Array:
    sp = p["shared"]
    if "w_gate" in sp:
        h = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
    else:
        h = jax.nn.gelu(x @ sp["w_up"])
    y = h @ sp["w_down"]
    gate = jax.nn.sigmoid(x @ p["shared_gate"])
    return y * gate


# ---------------------------------------------------------------------------
# dense: GShard one-hot dispatch (per batch row)
# ---------------------------------------------------------------------------
def moe_dense(p: Params, mcfg: MoEConfig, x: jax.Array) -> Tuple[jax.Array, Aux]:
    """x [B, S, D] -> [B, S, D]. Per-row capacity C = ceil(S*k/E * cf)."""
    b, s, d = x.shape
    e, k = mcfg.storage_experts, mcfg.top_k
    cap = max(k, int(math.ceil(s * k / mcfg.num_experts * mcfg.capacity_factor)))
    logits = router_logits(p, x.reshape(-1, d))        # [T, num_experts]
    ids, weights, aux = topk_route(logits, mcfg)       # ids < num_experts
    ids = ids.reshape(b, s, k)
    weights = weights.reshape(b, s, k)

    # position of each assignment within its expert, per batch row, k-major
    onehot = jax.nn.one_hot(ids, e, dtype=jnp.int32)             # [B,S,k,E]
    flat = onehot.transpose(0, 2, 1, 3).reshape(b, s * k, e)     # k-major order
    pos = jnp.cumsum(flat, axis=1) - 1                            # [B,S*k,E]
    pos = (pos * flat).sum(-1).reshape(b, k, s).transpose(0, 2, 1)  # [B,S,k]
    keep = pos < cap

    disp = (
        jax.nn.one_hot(ids, e, dtype=x.dtype)[..., None]
        * jax.nn.one_hot(pos, cap, dtype=x.dtype)[..., None, :]
        * keep[..., None, None].astype(x.dtype)
    ).sum(axis=2)                                                  # [B,S,E,C]
    combine = (
        jax.nn.one_hot(ids, e, dtype=jnp.float32)[..., None]
        * jax.nn.one_hot(pos, cap, dtype=jnp.float32)[..., None, :]
        * (weights * keep.astype(jnp.float32))[..., None, None]
    ).sum(axis=2)                                                  # [B,S,E,C] f32

    expert_in = jnp.einsum("bsec,bsd->becd", disp, x)              # [B,E,C,D]
    expert_out = jax.vmap(_expert_ffn, in_axes=(None, 0))(p["experts"], expert_in)
    y = jnp.einsum("becd,bsec->bsd", expert_out.astype(jnp.float32), combine)
    y = y.astype(x.dtype)
    if mcfg.num_shared_experts > 0:
        y = y + _shared_ffn(p, x)
    return y, aux


# ---------------------------------------------------------------------------
# sorted: scatter-based local dispatch (zero dispatch FLOPs)
# ---------------------------------------------------------------------------
def sorted_dispatch(
    x2d: jax.Array, ids: jax.Array, num_experts: int, capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Build [E, C, D] expert batches by sort + scatter.

    Returns (buffer [E,C,D], dest [T*k] flat slot per assignment or -1 if dropped,
    tok [T*k] source token per assignment).
    """
    t, k = ids.shape
    flat_e = ids.reshape(-1)                                   # [T*k]
    tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)        # [T*k]
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = tok[order]
    # position within expert group = index - start_of_group
    counts = jnp.bincount(flat_e, length=num_experts)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[e_sorted].astype(jnp.int32)
    keep = pos < capacity
    slot = jnp.where(keep, e_sorted * capacity + pos, num_experts * capacity)  # overflow row
    buf = jnp.zeros((num_experts * capacity + 1, x2d.shape[-1]), x2d.dtype)
    buf = buf.at[slot].set(x2d[tok_sorted], mode="drop")
    # invert the sort so dest/tok align with the original [T*k] assignment order
    dest = jnp.zeros((t * k,), jnp.int32).at[order].set(jnp.where(keep, slot, -1))
    return buf[:-1].reshape(num_experts, capacity, -1), dest, tok


def moe_sorted(
    p: Params, mcfg: MoEConfig, x2d: jax.Array, capacity: Optional[int] = None
) -> Tuple[jax.Array, Aux]:
    """x2d [T, D] -> [T, D] via sort-based dispatch on a single device."""
    t, d = x2d.shape
    e, k = mcfg.storage_experts, mcfg.top_k
    cap = capacity or max(
        k, int(math.ceil(t * k / mcfg.num_experts * mcfg.capacity_factor))
    )
    logits = router_logits(p, x2d)
    ids, weights, aux = topk_route(logits, mcfg)
    buf, dest, tok = sorted_dispatch(x2d, ids, e, cap)
    out = _expert_ffn(p["experts"], buf)                       # [E,C,D]
    flat_out = out.reshape(e * cap, d)
    w_flat = weights.reshape(-1)
    valid = dest >= 0
    contrib = flat_out[jnp.where(valid, dest, 0)] * (
        w_flat * valid.astype(jnp.float32)
    )[:, None].astype(out.dtype)
    y = jnp.zeros((t, d), jnp.float32).at[tok].add(contrib.astype(jnp.float32))
    y = y.astype(x2d.dtype)
    if mcfg.num_shared_experts > 0:
        y = y + _shared_ffn(p, x2d)
    aux["dropped_frac"] = 1.0 - valid.mean()
    return y, aux


# ---------------------------------------------------------------------------
# epsum: expert parallelism under shard_map (AG tokens -> local sorted -> RS)
# ---------------------------------------------------------------------------
def moe_epsum_local(
    p_local: Params, mcfg: MoEConfig, x_local: jax.Array, *, ep_axis: str, ep_size: int
) -> Tuple[jax.Array, Aux]:
    """Per-device body under shard_map. x_local [T, D] = this data-row's tokens,
    REPLICATED across the EP axis; experts sharded on E.

    Every EP peer routes the row's tokens identically (router weights are
    replicated — the [T,E] GEMM is cheap), runs sorted dispatch restricted to
    its local experts, and the partial expert outputs are summed with ONE
    all-reduce over the EP axis per layer. No token all-to-all, no duplicated
    expert compute: each token's expert FLOPs happen exactly once, on the
    expert's owner.
    """
    e, k = mcfg.num_experts, mcfg.top_k
    e_loc = p_local["experts"]["w_up"].shape[0]   # storage_experts / ep_size
    my = jax.lax.axis_index(ep_axis)
    t, d = x_local.shape
    logits = router_logits(p_local, x_local)
    ids, weights, aux = topk_route(logits, mcfg)
    # map global (storage-space) expert -> local index (or E_loc => not mine)
    lo = my * e_loc
    local_ids = jnp.where((ids >= lo) & (ids < lo + e_loc), ids - lo, e_loc)
    cap = max(k, int(math.ceil(t * k / e * mcfg.capacity_factor)))
    buf, dest, tok = sorted_dispatch(x_local, local_ids, e_loc + 1, cap)
    out = _expert_ffn(p_local["experts"], buf[:e_loc])                  # [E_loc,C,D]
    flat_out = out.reshape(e_loc * cap, d)
    w_flat = weights.reshape(-1)
    valid = (dest >= 0) & (dest < e_loc * cap)
    contrib = flat_out[jnp.where(valid, dest, 0)] * (
        w_flat * valid.astype(jnp.float32)
    )[:, None].astype(out.dtype)
    y_partial = jnp.zeros((t, d), jnp.float32).at[tok].add(contrib.astype(jnp.float32))
    y = jax.lax.psum(y_partial.astype(x_local.dtype), ep_axis)
    if mcfg.num_shared_experts > 0:
        y = y + _shared_ffn(p_local, x_local)   # shared experts replicated over EP
    return y, aux


# ---------------------------------------------------------------------------
# gathered decode: per-token expert weights, optionally through the slot LUT
# ---------------------------------------------------------------------------
def moe_apply_routed(
    p: Params,
    x2d: jax.Array,
    ids: jax.Array,                       # [T, k] int32 (precomputed routing)
    weights: jax.Array,                   # [T, k] f32
    *,
    slot_buffer: Optional[Params] = None,
    lut: Optional[jax.Array] = None,
    include_shared: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Apply already-routed experts via gathered weights (engine path).

    Same compute as ``moe_gathered`` but routing is supplied by the caller so the
    rotary engine can resolve the LUT / issue blocking loads BEFORE compute.
    Returns (y [T,D], miss [T,k]).
    """
    if slot_buffer is not None:
        assert lut is not None
        num_slots = slot_buffer["w_up"].shape[0] - 1
        slots = lut[ids]
        miss = slots >= num_slots
        src = slot_buffer
        gidx = jnp.where(miss, num_slots, slots)
    else:
        miss = jnp.zeros(ids.shape, bool)
        src = p["experts"]
        gidx = ids
    wq = jnp.take(src["w_up"], gidx, axis=0)
    wd = jnp.take(src["w_down"], gidx, axis=0)
    if "w_gate" in src:
        wg = jnp.take(src["w_gate"], gidx, axis=0)
        h = jax.nn.silu(jnp.einsum("td,tkdf->tkf", x2d, wg)) * jnp.einsum(
            "td,tkdf->tkf", x2d, wq
        )
    else:
        h = jax.nn.gelu(jnp.einsum("td,tkdf->tkf", x2d, wq))
    outs = jnp.einsum("tkf,tkfd->tkd", h, wd)
    w_eff = weights * (~miss).astype(jnp.float32)
    y = jnp.einsum("tkd,tk->td", outs.astype(jnp.float32), w_eff).astype(x2d.dtype)
    if include_shared and "shared" in p:
        y = y + _shared_ffn(p, x2d)
    return y, miss


def moe_gathered(
    p: Params,
    mcfg: MoEConfig,
    x2d: jax.Array,
    *,
    slot_buffer: Optional[Params] = None,
    lut: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, Aux]:
    """Decode-path MoE: gather each routed expert's weights and apply as GEMVs.

    ``slot_buffer``: stacked expert weights restricted to resident slots
      ({w_gate/w_up/w_down} with leading dim num_slots+1; the trailing slot is a
      zero "miss" slot). ``lut`` [E] int32 maps expert id -> slot (missing ->
      num_slots). When both are None, gathers from the full expert store.

    Returns (y [T,D], miss_mask [T,k] bool — which routed experts were NOT
    resident; weight mass of misses is dropped here and corrected by the engine).
    """
    logits = router_logits(p, x2d)
    ids, weights, aux = topk_route(logits, mcfg)
    y, miss = moe_apply_routed(p, x2d, ids, weights, slot_buffer=slot_buffer, lut=lut)
    return y, miss, aux


def moe_epsum_decode_local(
    p_local: Params,
    mcfg: MoEConfig,
    x_local: jax.Array,          # [T, D] this data-row's decode tokens (replicated over EP)
    ids: jax.Array,              # [T, k] routing (computed outside; router replicated)
    weights: jax.Array,          # [T, k]
    *,
    ep_axis: str,
) -> jax.Array:
    """EP decode without gathering expert weights (§Perf iteration 1).

    Each EP peer applies only its LOCAL experts to the routed tokens via the
    gathered per-token path (T is tiny in decode), partials summed with one
    [T, D] psum — wire bytes per layer drop from O(E·D·F) weight gathers to
    O(T·D).
    """
    e_loc = p_local["experts"]["w_up"].shape[0]
    my = jax.lax.axis_index(ep_axis)
    lo = my * e_loc
    # combine weight per (token, local expert): sum over the k routed picks
    mine = (ids >= lo) & (ids < lo + e_loc)                      # [T, k]
    onehot = jax.nn.one_hot(
        jnp.where(mine, ids - lo, e_loc), e_loc + 1, dtype=jnp.float32
    )[..., :e_loc]                                                # [T, k, E_loc]
    w_mask = jnp.einsum("tke,tk->te", onehot, weights)            # [T, E_loc]
    # dense over local experts: every local expert's weights stream HBM->MXU
    # exactly once per step (decode's true lower bound when >=1 token routes
    # to it); T x E_loc is tiny so the extra FLOPs are noise next to that
    src = p_local["experts"]
    def mm(a, w, eq):
        return jnp.einsum(eq, a, w,
                          preferred_element_type=jnp.float32).astype(a.dtype)
    if "w_gate" in src:
        h = jax.nn.silu(mm(x_local, src["w_gate"], "td,edf->tef")) * mm(
            x_local, src["w_up"], "td,edf->tef")
    else:
        h = jax.nn.gelu(mm(x_local, src["w_up"], "td,edf->tef"))
    outs = mm(h, src["w_down"], "tef,efd->ted")                   # [T, E_loc, D]
    y_partial = jnp.einsum("ted,te->td", outs.astype(jnp.float32), w_mask)
    y = jax.lax.psum(y_partial.astype(x_local.dtype), ep_axis)
    if mcfg.num_shared_experts > 0:
        y = y + _shared_ffn(p_local, x_local)
    return y


def moe_forward(
    p: Params,
    mcfg: MoEConfig,
    x: jax.Array,
    *,
    impl: str = "dense",
    ep_axis: Optional[str] = None,
    ep_size: int = 1,
) -> Tuple[jax.Array, Aux]:
    """Shape-polymorphic entry: x [B,S,D] -> [B,S,D]."""
    b, s, d = x.shape
    if impl == "dense":
        return moe_dense(p, mcfg, x)
    if impl == "sorted":
        y, aux = moe_sorted(p, mcfg, x.reshape(-1, d))
        return y.reshape(b, s, d), aux
    if impl == "epsum":
        assert ep_axis is not None
        y, aux = moe_epsum_local(p, mcfg, x.reshape(-1, d), ep_axis=ep_axis, ep_size=ep_size)
        return y.reshape(b, s, d), aux
    raise ValueError(f"unknown moe impl {impl!r}")
