"""On-device sampling primitives for temperature > 0 decode.

The speculative window (:func:`repro.models.transformer.decode_window`) and the
engines' between-window draws share EXACTLY these functions, so a token drawn
inside a K-position window is bit-identical to the same token drawn by a
size-1 window or by the host-side standalone sampler — the property the
seeded-stream-equivalence tests pin.

PRNG protocol (stateless, position-keyed)
-----------------------------------------
Every draw is keyed by ``fold_in(row_key, n)`` where ``n`` is the CACHE
position whose logits are being sampled (the ``cur_len`` the decode step ran
at). Nothing is consumed from a sequential stream, so:

* spec-K and single-token decode use identical keys per position — full
  acceptance (self-drafting: draft dist == verify dist) yields bit-identical
  token streams;
* a REJECTED position (residency miss truncation) re-draws with the SAME key
  when it is re-decoded — PRNG state "commits" exactly like residency does:
  only accepted positions advance anything, and replay/relaunch/CB-rejoin all
  reproduce the draw;
* a serving request's stream depends only on (its seed, its own lengths), not
  on batch composition — the same request samples the same tokens alone or
  mid-flight in a continuous-batching window.

``SampleParams`` is a hashable static: jitted programs specialize per
(temperature, top_k, top_p), mirroring how they specialize per window size.
Logit warping matches the host reference (:class:`repro.serving.sampler
.Sampler`) bitwise-on-support: top-k keeps the ``lax.top_k`` candidates (ties
broken toward lower index), top-p sorts descending with a STABLE sort and
keeps tokens while the cumulative mass before them is < p.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SampleParams(NamedTuple):
    """Static warp parameters (hashable — keys jit caches)."""
    temperature: float = 1.0
    top_k: int = 0                  # 0 = off
    top_p: float = 1.0


def row_keys(seed: int, rows: int) -> jnp.ndarray:
    """[rows, 2] uint32 base keys: one independent stream per batch row."""
    base = jax.random.PRNGKey(seed)
    return jax.vmap(lambda r: jax.random.fold_in(base, r))(
        jnp.arange(rows, dtype=jnp.int32)
    )


def request_key(seed: int) -> jnp.ndarray:
    """[2] uint32 base key for one serving request (batch-independent)."""
    return jax.random.PRNGKey(seed)


def warp_probs(logits: jax.Array, sp: SampleParams) -> jax.Array:
    """Temperature / top-k / top-p warped probabilities, [B, V] f32.

    Off-support entries are exactly 0. Matches the host ``Sampler`` kept set:
    top-k via ``lax.top_k`` (lowest index wins ties), top-p via a stable
    descending sort keeping tokens with ``cum - p < top_p``.
    """
    x = logits.astype(jnp.float32) / sp.temperature
    v = x.shape[-1]
    if 0 < sp.top_k < v:
        _, idx = jax.lax.top_k(x, sp.top_k)                     # [B, k]
        keep = jnp.zeros(x.shape, bool)
        keep = jnp.put_along_axis(keep, idx, True, axis=-1, inplace=False)
        x = jnp.where(keep, x, -jnp.inf)
    p = jax.nn.softmax(x, axis=-1)
    if sp.top_p < 1.0:
        order = jnp.argsort(-p, axis=-1, stable=True)
        sp_sorted = jnp.take_along_axis(p, order, axis=-1)
        cum = jnp.cumsum(sp_sorted, axis=-1)
        keep_sorted = cum - sp_sorted < sp.top_p                # head always kept
        keep = jnp.zeros(p.shape, bool)
        keep = jnp.put_along_axis(keep, order, keep_sorted, axis=-1,
                                  inplace=False)
        p = jnp.where(keep, p, 0.0)
        p = p / p.sum(axis=-1, keepdims=True)
    return p


def position_keys(keys: jax.Array, pos: jax.Array) -> jax.Array:
    """Per-row draw keys: ``fold_in(row_key, pos_row)``.

    ``keys`` [B, 2] uint32 base keys; ``pos`` scalar or [B] int32 cache
    positions (broadcast per row).
    """
    b = keys.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    return jax.vmap(jax.random.fold_in)(keys, pos_b)


def draw(keys: jax.Array, probs: jax.Array) -> jax.Array:
    """One categorical token per row from warped ``probs`` [B, V] with
    per-row ``keys`` [B, 2] (Gumbel-max via ``jax.random.categorical``).
    Zero-probability tokens can never be drawn (log 0 = -inf)."""
    logp = jnp.where(probs > 0, jnp.log(probs), -jnp.inf)
    return jax.vmap(jax.random.categorical)(keys, logp).astype(jnp.int32)


def sample_step(logits: jax.Array, keys: jax.Array, pos: jax.Array,
                sp: SampleParams):
    """warp + fold + draw for one position: the shared in-window / standalone
    draw. Returns ``(tokens [B], probs [B, V], tok_probs [B])``."""
    p = warp_probs(logits, sp)
    nxt = draw(position_keys(keys, pos), p)
    p_tok = jnp.take_along_axis(p, nxt[:, None], axis=-1)[:, 0]
    return nxt, p, p_tok


def build_sample_fn(sp: SampleParams):
    """Jitted standalone ``fn(logits [B, V], keys [B, 2], pos) -> tokens [B]``
    — the engines' between-window draw, bit-identical to the in-window one
    (same ops, same key derivation)."""
    def fn(logits, keys, pos):
        return sample_step(logits, keys, pos, sp)[0]

    return jax.jit(fn)
