"""Grouped-query attention: training (chunked causal), prefill and decode paths.

The compiled path never materializes the full [S, S] score matrix: training and
prefill use a q-chunk x kv-chunk online-softmax scan (flash-attention dataflow in
pure jnp, memory O(q_chunk * kv_chunk)), with `lax.cond` block skipping so fully
masked blocks cost nothing at runtime. The Pallas kernel in
``repro.kernels.flash_attention`` implements the same dataflow with explicit VMEM
tiling for real TPUs; ``repro.kernels.ref`` reuses the functions here as oracles.

Decode is a static-shape single-token step against a fixed-capacity cache:
``cache_len`` positions are always addressed, with positions ``>= cur_len`` masked.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import AttentionConfig
from repro.models.layers import Params, apply_rope, dense_init, rms_norm_headdim, rope_angles

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def init_attention(key: jax.Array, d_model: int, acfg: AttentionConfig, dtype: Any) -> Params:
    kq, kk, kv, ko, _ = jax.random.split(key, 5)
    h, hkv, dh = acfg.num_heads, acfg.num_kv_heads, acfg.head_dim
    p: Params = {
        "wq": dense_init(kq, (d_model, h * dh), dtype),
        "wk": dense_init(kk, (d_model, hkv * dh), dtype),
        "wv": dense_init(kv, (d_model, hkv * dh), dtype),
        "wo": dense_init(ko, (h * dh, d_model), dtype, fan_in=h * dh),
    }
    if acfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def _project_qkv(
    p: Params, acfg: AttentionConfig, x: jax.Array, positions: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [B, S, D] -> q [B, S, H, dh], k/v [B, S, Hkv, dh] with RoPE + optional qk-norm."""
    b, s, _ = x.shape
    h, hkv, dh = acfg.num_heads, acfg.num_kv_heads, acfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, hkv, dh)
    v = (x @ p["wv"]).reshape(b, s, hkv, dh)
    if acfg.qk_norm:
        q = rms_norm_headdim(p["q_norm"], q)
        k = rms_norm_headdim(p["k_norm"], k)
    sin, cos = rope_angles(positions, dh, acfg.rope_theta)  # [B?, S, dh/2]
    sin = sin[..., None, :]  # broadcast over heads: [..., S, 1, dh/2]
    cos = cos[..., None, :]
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    return q, k, v


# ---------------------------------------------------------------------------
# Reference attention (small shapes only; used by tests as an oracle)
# ---------------------------------------------------------------------------
def reference_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    soft_cap: Optional[float] = None,
    q_offset: int = 0,
) -> jax.Array:
    """Naive O(S^2)-memory attention. q [B,Sq,H,dh], k/v [B,Skv,Hkv,dh] -> [B,Sq,H,dh]."""
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    logits = logits / math.sqrt(dh)
    if soft_cap is not None:
        logits = soft_cap * jnp.tanh(logits / soft_cap)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked (flash-dataflow) attention for train/prefill
# ---------------------------------------------------------------------------
def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    soft_cap: Optional[float] = None,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    q_offset: int = 0,
) -> jax.Array:
    """Online-softmax attention, O(q_chunk*kv_chunk) memory.

    q [B,Sq,H,dh]; k/v [B,Skv,Hkv,dh]. Fully masked (q_block, kv_block) pairs are
    skipped with lax.cond so causal/windowed compute is ~halved at runtime.
    """
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq, nk = sq // q_chunk, skv // kv_chunk
    if sq % q_chunk or skv % kv_chunk:
        raise ValueError(f"seq lens ({sq},{skv}) must divide chunks ({q_chunk},{kv_chunk})")
    scale = 1.0 / math.sqrt(dh)

    qc = q.reshape(b, nq, q_chunk, hkv, g, dh).transpose(1, 0, 2, 3, 4, 5)  # [nq,B,qc,hkv,g,dh]
    kc = k.reshape(b, nk, kv_chunk, hkv, dh).transpose(1, 0, 2, 3, 4)       # [nk,B,kc,hkv,dh]
    vc = v.reshape(b, nk, kv_chunk, hkv, dh).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_qblk):
        qi, qblk = qi_qblk
        q_start = qi * q_chunk + q_offset

        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, kblk, vblk = ki_kv
            k_start = ki * kv_chunk
            # block-level reachability (static dataflow, dynamic skip)
            reachable = jnp.array(True)
            if causal:
                reachable &= k_start <= q_start + q_chunk - 1
            if window is not None:
                reachable &= k_start + kv_chunk - 1 > q_start - window

            def compute(carry):
                m, l, acc = carry
                s = jnp.einsum(
                    "bqhgd,bkhd->bhgqk", qblk, kblk,
                    preferred_element_type=jnp.float32,
                ) * scale
                if soft_cap is not None:
                    s = soft_cap * jnp.tanh(s / soft_cap)
                qpos = q_start + jnp.arange(q_chunk)
                kpos = k_start + jnp.arange(kv_chunk)
                msk = jnp.ones((q_chunk, kv_chunk), bool)
                if causal:
                    msk &= kpos[None, :] <= qpos[:, None]
                if window is not None:
                    msk &= kpos[None, :] > qpos[:, None] - window
                s = jnp.where(msk[None, None, None], s, NEG_INF)
                m_new = jnp.maximum(m, s.max(axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + p.sum(axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                    preferred_element_type=jnp.float32,
                )
                return m_new, l_new, acc_new

            new_carry = jax.lax.cond(reachable, compute, lambda c: c, (m, l, acc))
            return new_carry, None

        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]        # [b,hkv,g,qc,dh]
        out = out.transpose(0, 3, 1, 2, 4)                   # [b,qc,hkv,g,dh]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qc))  # [nq,b,qc,hkv,g,dh]
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, dh)


# ---------------------------------------------------------------------------
# Block forward paths
# ---------------------------------------------------------------------------
def attention_train(
    p: Params,
    acfg: AttentionConfig,
    x: jax.Array,
    *,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    use_pallas: bool = False,
) -> jax.Array:
    """Full-sequence causal attention (training / prefill compute). x [B,S,D]."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, acfg, x, positions)
    if use_pallas:
        from repro.kernels import ops as kops

        ctx = kops.flash_attention(
            q, k, v, causal=True, window=acfg.window, soft_cap=acfg.logit_soft_cap
        )
    elif s <= max(q_chunk, 128):
        ctx = reference_attention(
            q, k, v, causal=True, window=acfg.window, soft_cap=acfg.logit_soft_cap
        )
    else:
        ctx = chunked_attention(
            q, k, v,
            causal=True, window=acfg.window, soft_cap=acfg.logit_soft_cap,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
    return ctx.reshape(b, s, -1) @ p["wo"]


def attention_prefill(
    p: Params,
    acfg: AttentionConfig,
    x: jax.Array,
    cache_len: int,
    *,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    use_pallas: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prefill: causal attention + emit a fixed-capacity KV cache of ``cache_len``.

    For local attention the cache capacity is min(window, cache_len) (ring buffer).
    """
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, acfg, x, positions)
    if use_pallas:
        from repro.kernels import ops as kops

        ctx = kops.flash_attention(
            q, k, v, causal=True, window=acfg.window, soft_cap=acfg.logit_soft_cap
        )
    elif s <= max(q_chunk, 128):
        ctx = reference_attention(
            q, k, v, causal=True, window=acfg.window, soft_cap=acfg.logit_soft_cap
        )
    else:
        ctx = chunked_attention(
            q, k, v,
            causal=True, window=acfg.window, soft_cap=acfg.logit_soft_cap,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
    y = ctx.reshape(b, s, -1) @ p["wo"]

    cap = _cache_capacity(acfg, cache_len)
    hkv, dh = acfg.num_kv_heads, acfg.head_dim
    ck = jnp.zeros((b, cap, hkv, dh), k.dtype)
    cv = jnp.zeros((b, cap, hkv, dh), v.dtype)
    if acfg.window is not None and s > cap:
        # keep the last `cap` positions, ring-indexed so slot = pos % cap
        tail_k, tail_v = k[:, -cap:], v[:, -cap:]
        start = s - cap
        slots = (start + jnp.arange(cap)) % cap
        ck = ck.at[:, slots].set(tail_k)
        cv = cv.at[:, slots].set(tail_v)
    else:
        ck = jax.lax.dynamic_update_slice(ck, k[:, : min(s, cap)], (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v[:, : min(s, cap)], (0, 0, 0, 0))
    cache = {"k": ck, "v": cv}
    return y, cache


def _cache_capacity(acfg: AttentionConfig, cache_len: int) -> int:
    if acfg.window is not None:
        return min(acfg.window, cache_len)
    return cache_len


def attention_prefill_chunk(
    p: Params,
    acfg: AttentionConfig,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    cur_len: jax.Array,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked prefill: append ``C`` positions to an existing cache and attend
    against everything cached so far (the chunk included).

    x [B, C, D]; cache k/v [B, cap, Hkv, dh]; ``cur_len`` = tokens already
    cached, scalar or per-row [B]. The chunk's KV is written at ring slots
    ``(cur_len + j) % cap``; every cache position is scored with a per-query
    validity mask (masked positions get exactly-zero probability mass), so
    the same static-shape program serves every chunk of the same length
    regardless of where it starts.

    Window-free caches score the post-write cache: slot index == absolute
    position — the same key layout the full-sequence path sees, so the
    context matches :func:`attention_prefill` up to appended exact-zero slots
    (bitwise in eager execution; the engine's binding bit-identity contract
    is between its two CHUNKED paths, which share this very function). Ring
    caches (sliding window) instead score the PRE-write cache concatenated
    with the chunk's own K/V, because a later chunk position may overwrite a
    previous-lap slot an earlier chunk query still needs; that path is exact
    in masking but not index-identical to the full-sequence layout. (Under
    the engine's current gating — prefill always starts at ``cur_len == 0``
    and is capped at the cache capacity — a chunk never wraps the ring, so
    the previous-lap reconstruction is defense-in-depth for future
    wrap-capable callers: continuation prefill at ``cur_len > 0``, or
    windowed prompts longer than the window.)
    """
    b, c, _ = x.shape
    h, hkv, dh = acfg.num_heads, acfg.num_kv_heads, acfg.head_dim
    g = h // hkv
    cl = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))        # [B]
    qpos = cl[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]        # [B, C]
    q, k_new, v_new = _project_qkv(p, acfg, x, qpos)

    cap = cache["k"].shape[1]
    assert c <= cap, f"prefill chunk ({c}) exceeds KV capacity ({cap})"
    slots = qpos % cap                                                  # [B, C]
    rows = jnp.arange(b)[:, None]
    ck = cache["k"].at[rows, slots].set(k_new)
    cv = cache["v"].at[rows, slots].set(v_new)
    qg = q.reshape(b, c, hkv, g, dh)
    # a single-query chunk would lower the QK/PV dots to the GEMV path, whose
    # reduction tree differs bitwise from the GEMM every other extent takes:
    # pad the QUERY side to extent 2 (zero row, discarded below) so a length-1
    # tail chunk scores through the same kernel as the full-sequence pass
    qpos_q = qpos                               # query-side positions [B, c_eff]
    c_eff = c
    if c == 1:
        qg = jnp.concatenate([qg, jnp.zeros_like(qg)], axis=1)
        qpos_q = jnp.concatenate([qpos, qpos], axis=1)
        c_eff = 2

    if acfg.window is None:
        # slot i holds position i (no wrap: the whole sequence fits cap); the
        # causal mask alone hides unwritten and future-chunk slots
        k_all, v_all = ck, cv
        kpos = jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32)[None, :],
                                (b, cap))                               # [B,cap]
    else:
        # ring: score pre-write cache + chunk K/V so previous-lap entries a
        # chunk write overwrote stay visible to earlier chunk queries. Slot i
        # pre-chunk holds the newest position < cur_len congruent to i mod
        # cap; never-written slots (and an empty cache) reconstruct negative
        k_all = jnp.concatenate([cache["k"], k_new], axis=1)   # [B, cap+C, ..]
        v_all = jnp.concatenate([cache["v"], v_new], axis=1)
        idx = jnp.arange(cap, dtype=jnp.int32)[None, :]
        end0 = cl[:, None] - 1                  # newest pre-chunk position [B,1]
        kpos = jnp.concatenate([end0 - (end0 - idx) % cap, qpos], axis=1)

    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_all,
                   preferred_element_type=jnp.float32)
    s = s / math.sqrt(dh)
    if acfg.logit_soft_cap is not None:
        s = acfg.logit_soft_cap * jnp.tanh(s / acfg.logit_soft_cap)
    valid = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= qpos_q[:, :, None])
    if acfg.window is not None:
        valid &= kpos[:, None, :] > qpos_q[:, :, None] - acfg.window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v_all.dtype), v_all,
                     preferred_element_type=jnp.float32)
    ctx = ctx[:, :c]                        # drop the GEMV-avoidance pad row
    y = ctx.reshape(b, c, h, dh).astype(x.dtype).reshape(b, c, -1) @ p["wo"]
    return y, {"k": ck, "v": cv}


def attention_decode(
    p: Params,
    acfg: AttentionConfig,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    cur_len: jax.Array,
    *,
    use_pallas: bool = False,
    page_table: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x [B,1,D]; cache k/v [B,cap,Hkv,dh].

    ``cur_len`` = tokens already cached, scalar OR per-row [B] (ragged batches
    from the continuous-batching scheduler). Static shapes: the new KV is
    written at slot ``cur_len % cap`` (ring semantics make full and windowed
    caches uniform); all cap positions are scored with invalid ones masked.

    ``page_table`` [B, cap // page_size] switches the cache to the serving
    engine's PAGED pool layout: k/v are SHARED planes [P, page_size, Hkv, dh]
    and each row's logical slot ``s`` lives at physical
    ``(page_table[b, s // page_size], s % page_size)``. The row's logical view
    is gathered back to the exact [B, cap, Hkv, dh] layout the contiguous
    path scores — identical einsum extents, identical masks — so paged decode
    is BITWISE equal to the contiguous cache holding the same logical KV.
    Stale contents of unallocated/recycled pages sit at masked positions:
    they soften to exactly 0.0 probability and contribute ±0.0 to the
    context sum (only finite values are ever written), so pages never need
    zeroing on alloc/free.
    """
    b = x.shape[0]
    h, hkv, dh = acfg.num_heads, acfg.num_kv_heads, acfg.head_dim
    g = h // hkv
    cl = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))        # [B]
    positions = cl[:, None]
    q, k_new, v_new = _project_qkv(p, acfg, x, positions)

    if page_table is None:
        cap = cache["k"].shape[1]
        slot = cl % cap
        rows = jnp.arange(b)
        ck = cache["k"].at[rows, slot].set(k_new[:, 0])
        cv = cache["v"].at[rows, slot].set(v_new[:, 0])
        ck_rows, cv_rows = ck, cv
    else:
        ps = cache["k"].shape[1]
        cap = page_table.shape[1] * ps
        slot = cl % cap
        page = jnp.take_along_axis(page_table, (slot // ps)[:, None], axis=1)[:, 0]
        off = slot % ps
        # pad rows (all-zero tables) write duplicate (0, off) coordinates into
        # the scratch page; the winner is arbitrary and never scored unmasked
        ck = cache["k"].at[page, off].set(k_new[:, 0])
        cv = cache["v"].at[page, off].set(v_new[:, 0])
        tail = cache["k"].shape[2:]
        ck_rows = ck[page_table].reshape((b, cap) + tail)
        cv_rows = cv[page_table].reshape((b, cap) + tail)

    if use_pallas:
        from repro.kernels import ops as kops

        ctx = kops.decode_attention(
            q, ck_rows, cv_rows, cur_len=cl, window=acfg.window,
            soft_cap=acfg.logit_soft_cap,
        )
    else:
        qg = q.reshape(b, 1, hkv, g, dh)
        # bf16 operands + f32 accumulation (MXU-native; avoids materializing
        # f32 copies of the KV cache — §Perf iteration 2)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck_rows,
                       preferred_element_type=jnp.float32)
        s = s / math.sqrt(dh)
        if acfg.logit_soft_cap is not None:
            s = acfg.logit_soft_cap * jnp.tanh(s / acfg.logit_soft_cap)
        # slot i holds absolute position: full cache -> i; ring cache -> reconstructed
        idx = jnp.arange(cap)[None, :]                                  # [1, cap]
        clb = cl[:, None]
        if acfg.window is None:
            kpos = jnp.broadcast_to(idx, (b, cap))
        else:
            # ring: slots ahead of the write head hold (older) positions from the
            # previous lap: pos = lap_base + i where lap_base depends on wrap
            lap = (clb // cap) * cap
            kpos = jnp.where(idx <= (clb % cap), lap + idx, lap - cap + idx)
        valid = (kpos <= clb) & (kpos >= 0)      # >=0: not-yet-written ring slots
        if acfg.window is not None:
            valid &= kpos > clb - acfg.window
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
        probs = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(cv_rows.dtype),
                         cv_rows, preferred_element_type=jnp.float32)
        ctx = ctx.reshape(b, 1, h, dh).astype(x.dtype)

    y = ctx.reshape(b, 1, -1) @ p["wo"]
    return y, {"k": ck, "v": cv}
