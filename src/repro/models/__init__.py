"""Model stack: layers, attention, MoE, recurrent blocks, and the assembler."""
from repro.models.transformer import (  # noqa: F401
    Runtime,
    decode_model,
    forward_train,
    init_params,
    lm_logits,
    lm_loss,
    prefill_model,
    zero_state,
)
from repro.models.params import analytic_params, count_params, model_flops, param_summary  # noqa: F401
