"""Shared layer primitives: norms, RoPE, MLPs, embeddings, init helpers."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype: Any, fan_in: Optional[int] = None) -> jax.Array:
    """Truncated-normal init scaled by 1/sqrt(fan_in)."""
    fan = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / jnp.sqrt(jnp.maximum(fan, 1)).astype(jnp.float32)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key: jax.Array, shape: Tuple[int, ...], dtype: Any) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms (computed in f32, cast back)
# ---------------------------------------------------------------------------
def init_norm(kind: str, dim: int, dtype: Any) -> Params:
    p: Params = {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def apply_norm(kind: str, p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    elif kind == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        raise ValueError(f"unknown norm {kind!r}")
    return out.astype(x.dtype)


def rms_norm_headdim(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """qk-norm: RMS-normalize the trailing head_dim."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions [...,] -> (sin, cos) each [..., head_dim//2], f32."""
    half = head_dim // 2
    freqs = jnp.exp(-jnp.log(theta) * (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., half]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x [..., T, heads..., head_dim]; sin/cos broadcastable to [..., T, 1, half]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out1 = xf1 * cos - xf2 * sin
    out2 = xf2 * cos + xf1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense MLPs
# ---------------------------------------------------------------------------
def init_mlp(kind: str, key: jax.Array, d_model: int, d_ff: int, dtype: Any) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": dense_init(k1, (d_model, d_ff), dtype),
            "w_up": dense_init(k2, (d_model, d_ff), dtype),
            "w_down": dense_init(k3, (d_ff, d_model), dtype, fan_in=d_ff),
        }
    if kind == "gelu_mlp":
        return {
            "w_up": dense_init(k1, (d_model, d_ff), dtype),
            "w_down": dense_init(k2, (d_ff, d_model), dtype, fan_in=d_ff),
        }
    raise ValueError(f"unknown mlp {kind!r}")


def apply_mlp(kind: str, p: Params, x: jax.Array) -> jax.Array:
    if kind == "swiglu":
        gate = jax.nn.silu(x @ p["w_gate"])
        return (gate * (x @ p["w_up"])) @ p["w_down"]
    if kind == "gelu_mlp":
        return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]
    raise ValueError(f"unknown mlp {kind!r}")


def swiglu_ffn(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down
