"""Composable decoder: assembles any ModelConfig's segment stack into
train / prefill / decode entry points.

Layer stack = ``cfg.segments``: each segment is a unit of block kinds scanned
``reps`` times with parameters stacked on axis 0, so HLO size is independent of
depth. Decode threads a per-layer state pytree (KV caches / recurrent states)
through the same scan. The MoE FFN implementation is selected by
``Runtime.sharding.moe_impl``; decode uses the gathered per-token path which is
also the compiled half of the rotary-residency technique (slot buffers + LUT).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import get_abstract_mesh, manual_axis_names, shard_map
from repro.config.base import ModelConfig, ShardingConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import sampling as sampling_mod
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    Params,
    apply_mlp,
    apply_norm,
    embed_init,
    init_mlp,
    init_norm,
)

Aux = Dict[str, jax.Array]


@dataclass(frozen=True)
class Runtime:
    """Execution context threaded through the model (sharding + kernel choices)."""

    sharding: ShardingConfig = field(default_factory=ShardingConfig)
    mesh: Optional[Mesh] = None
    cache_len: int = 2048
    q_chunk: int = 512
    kv_chunk: int = 512
    loss_chunk: int = 512

    @property
    def dp_spec(self) -> Tuple[str, ...]:
        return self.sharding.dp_axes

    def constrain(self, x: jax.Array, spec: P) -> jax.Array:
        if self.mesh is None:
            return x
        mesh, spec = _strip_manual(self.mesh, spec)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _manual_axes(am) -> set:
    return manual_axis_names(am)


def _strip_manual(mesh, spec: P):
    """Drop mesh axes that are Manual in the current shard_map context from a
    PartitionSpec (they are already fixed there); returns (mesh_to_use, spec)
    or (mesh, None) if nothing shardable remains."""
    am = get_abstract_mesh()
    manual = _manual_axes(am)
    if not manual:
        return mesh, spec
    entries = []
    for entry in spec:
        if entry is None:
            entries.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a not in manual)
            entries.append(kept if kept else None)
        else:
            entries.append(None if entry in manual else entry)
    if all(e is None for e in entries):
        return am, None
    return am, P(*entries)


# ===========================================================================
# Init
# ===========================================================================
def _init_block(key: jax.Array, kind: str, cfg: ModelConfig, dtype: Any) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind in ("attn_mlp", "local_attn"):
        return {
            "ln1": init_norm(cfg.norm, cfg.d_model, dtype),
            "attn": attn.init_attention(k1, cfg.d_model, cfg.attention, dtype),
            "ln2": init_norm(cfg.norm, cfg.d_model, dtype),
            "mlp": init_mlp(cfg.mlp, k2, cfg.d_model, cfg.d_ff, dtype),
        }
    if kind == "attn_moe":
        return {
            "ln1": init_norm(cfg.norm, cfg.d_model, dtype),
            "attn": attn.init_attention(k1, cfg.d_model, cfg.attention, dtype),
            "ln2": init_norm(cfg.norm, cfg.d_model, dtype),
            "moe": moe_mod.init_moe(k2, cfg.d_model, cfg.moe, cfg.mlp, dtype),
        }
    if kind == "mlstm":
        return {
            "ln": init_norm(cfg.norm, cfg.d_model, dtype),
            "cell": xlstm_mod.init_mlstm(k1, cfg.d_model, cfg.recurrent, dtype),
        }
    if kind == "slstm":
        return {
            "ln": init_norm(cfg.norm, cfg.d_model, dtype),
            "cell": xlstm_mod.init_slstm(k1, cfg.d_model, cfg.recurrent, dtype),
        }
    if kind == "rglru":
        return {
            "ln1": init_norm(cfg.norm, cfg.d_model, dtype),
            "rec": rglru_mod.init_rglru(k1, cfg.d_model, cfg.recurrent, dtype),
            "ln2": init_norm(cfg.norm, cfg.d_model, dtype),
            "mlp": init_mlp(cfg.mlp, k2, cfg.d_model, cfg.d_ff, dtype),
        }
    raise ValueError(f"unknown block kind {kind!r}")


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, len(cfg.segments) + 3)
    segments: List[Tuple[Params, ...]] = []
    for si, (unit, reps) in enumerate(cfg.segments):
        unit_params: List[Params] = []
        for pi, kind in enumerate(unit):
            pkeys = jax.random.split(jax.random.fold_in(keys[si], pi), reps)
            stacked = jax.vmap(lambda k: _init_block(k, kind, cfg, dtype))(pkeys)
            unit_params.append(stacked)
        segments.append(tuple(unit_params))
    p: Params = {
        "embed": embed_init(keys[-3], (cfg.vocab_size, cfg.d_model), dtype),
        "segments": tuple(segments),
        "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(keys[-2], (cfg.d_model, cfg.vocab_size), dtype)
    if cfg.frontend is not None and cfg.frontend_dim != cfg.d_model:
        p["frontend_proj"] = embed_init(keys[-1], (cfg.frontend_dim, cfg.d_model), dtype)
    return p


# ===========================================================================
# Per-layer states (decode)
# ===========================================================================
def zero_state(cfg: ModelConfig, batch: int, cache_len: int) -> Any:
    """State pytree mirroring ``segments``: per position, stacked over reps."""
    segs = []
    for unit, reps in cfg.segments:
        unit_states = []
        for kind in unit:
            st = _zero_block_state(cfg, kind, batch, cache_len)
            unit_states.append(jax.tree.map(lambda x: jnp.broadcast_to(x, (reps,) + x.shape), st))
        segs.append(tuple(unit_states))
    return tuple(segs)


def paged_zero_state(cfg: ModelConfig, num_pages: int, page_size: int) -> Any:
    """Decode state over the serving engine's PAGED KV pool: the same
    segments-mirroring pytree as :func:`zero_state`, but each KV leaf is a
    SHARED plane [reps, num_pages, page_size, Hkv, dh] addressed through
    per-row page tables (``attention_decode(page_table=...)``) instead of a
    per-row [B, cap, ...] cache. ``num_pages`` counts the scratch page the
    pool reserves at physical index 0. KV-cache-only stacks — a recurrent
    state is per-row by construction and cannot be paged."""
    dtype = jnp.dtype(cfg.dtype)
    a = cfg.attention
    segs = []
    for unit, reps in cfg.segments:
        unit_states = []
        for kind in unit:
            if kind not in ("attn_mlp", "attn_moe", "local_attn"):
                raise ValueError(
                    f"paged KV pool requires KV-cache blocks, got {kind!r}"
                )
            shape = (reps, num_pages, page_size, a.num_kv_heads, a.head_dim)
            unit_states.append(
                {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
            )
        segs.append(tuple(unit_states))
    return tuple(segs)


def _zero_block_state(cfg: ModelConfig, kind: str, batch: int, cache_len: int) -> Any:
    dtype = jnp.dtype(cfg.dtype)
    if kind in ("attn_mlp", "attn_moe", "local_attn"):
        a = cfg.attention
        cap = attn._cache_capacity(a, cache_len)
        shape = (batch, cap, a.num_kv_heads, a.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if kind == "mlstm":
        return xlstm_mod.mlstm_zero_state(batch, cfg.d_model, cfg.recurrent)
    if kind == "slstm":
        return xlstm_mod.slstm_zero_state(batch, cfg.d_model, cfg.recurrent)
    if kind == "rglru":
        return rglru_mod.rglru_zero_state(batch, cfg.d_model, cfg.recurrent)
    raise ValueError(kind)


# ===========================================================================
# Block application
# ===========================================================================
def _apply_block(
    kind: str,
    p: Params,
    cfg: ModelConfig,
    rt: Runtime,
    x: jax.Array,
    mode: str,                      # "train" | "prefill" | "chunk" | "decode"
    state: Any,
    cur_len: Optional[jax.Array],
    residency: Optional[Dict[str, jax.Array]],
    page_table: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Any, Aux]:
    b, s, d = x.shape
    aux: Aux = {}
    new_state = state
    if mode == "chunk" and kind not in ("attn_mlp", "attn_moe", "local_attn"):
        # a recurrent update consumes exactly one position of state per call;
        # chunked prefill threads a KV cache through multi-token appends
        raise ValueError(f"chunked prefill requires KV-cache blocks, got {kind!r}")
    if kind in ("attn_mlp", "attn_moe", "local_attn"):
        acfg = cfg.attention
        x_in = x                        # block input (decode telemetry: replay anchor)
        h = apply_norm(cfg.norm, p["ln1"], x)
        # §Perf iteration 3b: when head-TP is unavailable (heads don't divide
        # the model axis) shard the QUERY positions over it instead (SP) —
        # attention compute /tp with one K/V broadcast, vs 16x replication
        use_sp = (
            mode in ("train", "prefill")
            and rt.mesh is not None
            and acfg.num_heads % dict(rt.mesh.shape)[rt.sharding.tp_axis] != 0
            and x.shape[1] % dict(rt.mesh.shape)[rt.sharding.tp_axis] == 0
            and x.shape[1] >= 2048
        )
        if mode == "train":
            if use_sp:
                y = _sp_attention(p["attn"], acfg, cfg, rt, h, None)[0]
            else:
                y = attn.attention_train(
                    p["attn"], acfg, h,
                    q_chunk=rt.q_chunk, kv_chunk=rt.kv_chunk,
                    use_pallas=rt.sharding.use_pallas,
                )
        elif mode == "prefill":
            if use_sp:
                y, new_state = _sp_attention(p["attn"], acfg, cfg, rt, h, rt.cache_len)
            else:
                y, new_state = attn.attention_prefill(
                    p["attn"], acfg, h, rt.cache_len,
                    q_chunk=rt.q_chunk, kv_chunk=rt.kv_chunk,
                    use_pallas=rt.sharding.use_pallas,
                )
        elif mode == "chunk":
            y, new_state = attn.attention_prefill_chunk(
                p["attn"], acfg, h, state, cur_len
            )
        else:
            y, new_state = attn.attention_decode(
                p["attn"], acfg, h, state, cur_len,
                use_pallas=rt.sharding.use_pallas, page_table=page_table,
            )
        x = x + y
        h = apply_norm(cfg.norm, p["ln2"], x)
        if kind == "attn_moe":
            if mode in ("decode", "chunk"):
                slot_buffer = lut = None
                if residency is not None:
                    slot_buffer, lut = residency["slots"], residency["lut"]
                h2d = h.reshape(-1, d)
                logits = moe_mod.router_logits(p["moe"], h2d)
                ids, weights, moe_aux = moe_mod.topk_route(logits, cfg.moe)
                if (mode == "decode" and residency is None and rt.mesh is not None
                        and rt.sharding.moe_impl == "epsum"):
                    # §Perf: EP decode — local experts only + one [T,D] psum,
                    # instead of all-gathering the expert store per layer
                    am = get_abstract_mesh()
                    mesh_arg = am if (am is not None and am.axis_names) else rt.mesh
                    manual = _manual_axes(am)
                    dp_eff = tuple(a for a in rt.dp_spec if a not in manual) or None

                    def epdec_fn(p_moe, x2d, ids_, w_):
                        return moe_mod.moe_epsum_decode_local(
                            p_moe, cfg.moe, x2d, ids_, w_,
                            ep_axis=rt.sharding.tp_axis,
                        )

                    y2 = shard_map(
                        epdec_fn,
                        mesh=mesh_arg,
                        in_specs=(
                            _moe_param_specs(p["moe"], rt.sharding.tp_axis),
                            P(dp_eff, None), P(dp_eff, None), P(dp_eff, None),
                        ),
                        out_specs=P(dp_eff, None),
                        check_vma=False,
                    )(p["moe"], h2d, ids, weights)
                    miss = jnp.zeros(ids.shape, bool)
                else:
                    y2, miss = moe_mod.moe_apply_routed(
                        p["moe"], h2d, ids, weights,
                        slot_buffer=slot_buffer, lut=lut,
                    )
                aux["moe_miss"] = miss.sum()
                # routing telemetry for the rotary engine/predictor ("route_*"
                # keys are stacked per layer by the scan, not summed);
                # route_x anchors the engine's suffix replay at this block
                aux["route_ids"] = ids
                aux["route_weights"] = weights
                aux["route_miss"] = miss
                aux["route_h"] = h2d
                aux["route_x"] = x_in.reshape(-1, d)
                y2 = y2.reshape(b, s, d)
            else:
                impl = rt.sharding.moe_impl
                if impl == "epsum" and rt.mesh is None:
                    impl = "sorted"
                if impl == "epsum":
                    ep_size = rt.mesh.shape[rt.sharding.tp_axis]

                    def epsum_fn(p_moe, x2d):
                        return moe_mod.moe_epsum_local(
                            p_moe, cfg.moe, x2d,
                            ep_axis=rt.sharding.tp_axis, ep_size=ep_size,
                        )

                    # inside another shard_map (pod-compression) the concrete
                    # mesh is rejected and manual axes may not be mentioned —
                    # use the ambient abstract mesh and strip manual axes
                    am = get_abstract_mesh()
                    mesh_arg = am if (am is not None and am.axis_names) else rt.mesh
                    manual = _manual_axes(am)
                    dp_eff = tuple(a for a in rt.dp_spec if a not in manual) or None
                    fn = shard_map(
                        epsum_fn,
                        mesh=mesh_arg,
                        in_specs=(
                            _moe_param_specs(p["moe"], rt.sharding.tp_axis),
                            P(dp_eff, None),
                        ),
                        out_specs=(P(dp_eff, None), P()),
                        check_vma=False,
                    )
                    y2, moe_aux = fn(p["moe"], h.reshape(-1, d))
                    y2 = y2.reshape(b, s, d)
                else:
                    y2, moe_aux = moe_mod.moe_forward(p["moe"], cfg.moe, h, impl=impl)
            aux.update({f"moe_{k}": v for k, v in moe_aux.items()})
        else:
            y2 = apply_mlp(cfg.mlp, p["mlp"], h)
        return x + y2, new_state, aux
    if kind == "mlstm":
        h = apply_norm(cfg.norm, p["ln"], x)
        if mode == "train":
            y = xlstm_mod.mlstm_train(p["cell"], h, cfg.recurrent)
        elif mode == "prefill":
            y, new_state = xlstm_mod.mlstm_prefill(p["cell"], h, cfg.recurrent)
        else:
            y, new_state = xlstm_mod.mlstm_decode(p["cell"], h, state)
        return x + y, new_state, aux
    if kind == "slstm":
        h = apply_norm(cfg.norm, p["ln"], x)
        if mode == "train":
            y = xlstm_mod.slstm_train(p["cell"], h, cfg.recurrent)
        elif mode == "prefill":
            y, new_state = xlstm_mod.slstm_prefill(p["cell"], h, cfg.recurrent)
        else:
            y, new_state = xlstm_mod.slstm_decode(p["cell"], h, state)
        return x + y, new_state, aux
    if kind == "rglru":
        h = apply_norm(cfg.norm, p["ln1"], x)
        if mode == "train":
            y = rglru_mod.rglru_train(p["rec"], h, cfg.recurrent)
        elif mode == "prefill":
            y, new_state = rglru_mod.rglru_prefill(p["rec"], h, cfg.recurrent)
        else:
            y, new_state = rglru_mod.rglru_decode(p["rec"], h, state)
        x = x + y
        h = apply_norm(cfg.norm, p["ln2"], x)
        return x + apply_mlp(cfg.mlp, p["mlp"], h), new_state, aux
    raise ValueError(kind)


def _sp_attention(
    p: Params,
    acfg,
    cfg: ModelConfig,
    rt: Runtime,
    h: jax.Array,                       # [B, S, D] normed input
    cache_len: Optional[int],           # None -> train (no cache out)
):
    """Sequence-parallel attention under shard_map: each model-axis peer runs
    the flash-dataflow chunked attention for its S/tp query slice against the
    full K/V (q_offset keeps causal/window masks exact)."""
    b, s, d = h.shape
    tp = rt.sharding.tp_axis
    tp_size = dict(rt.mesh.shape)[tp]
    q, k, v = attn._project_qkv(p, acfg, h, jnp.arange(s)[None, :])
    am = get_abstract_mesh()
    mesh_arg = am if (am is not None and am.axis_names) else rt.mesh
    manual = _manual_axes(am)
    dp_eff = tuple(a for a in rt.dp_spec if a not in manual) or None
    s_loc = s // tp_size

    def local(qc, kf, vf):
        off = jax.lax.axis_index(tp) * s_loc
        return attn.chunked_attention(
            qc, kf, vf,
            causal=True, window=acfg.window, soft_cap=acfg.logit_soft_cap,
            q_chunk=min(rt.q_chunk, s_loc), kv_chunk=rt.kv_chunk, q_offset=off,
        )

    ctx = shard_map(
        local,
        mesh=mesh_arg,
        in_specs=(
            P(dp_eff, tp, None, None),
            P(dp_eff, None, None, None),
            P(dp_eff, None, None, None),
        ),
        out_specs=P(dp_eff, tp, None, None),
        check_vma=False,
    )(q, k, v)
    y = ctx.reshape(b, s, -1) @ p["wo"]
    if cache_len is None:
        return y, None
    cap = attn._cache_capacity(acfg, cache_len)
    ck = jnp.zeros((b, cap, acfg.num_kv_heads, acfg.head_dim), k.dtype)
    cv = jnp.zeros((b, cap, acfg.num_kv_heads, acfg.head_dim), v.dtype)
    if acfg.window is not None and s > cap:
        start = s - cap
        slots = (start + jnp.arange(cap)) % cap
        ck = ck.at[:, slots].set(k[:, -cap:])
        cv = cv.at[:, slots].set(v[:, -cap:])
    else:
        ck = jax.lax.dynamic_update_slice(ck, k[:, : min(s, cap)], (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v[:, : min(s, cap)], (0, 0, 0, 0))
    return y, {"k": ck, "v": cv}


def _moe_param_specs(p: Params, tp_axis: str) -> Any:
    """shard_map in_specs for MoE params: experts sharded on E, rest replicated."""
    specs = {"router": P(None, None)}
    specs["experts"] = {k: P(tp_axis, None, None) for k in p["experts"]}
    if "shared" in p:
        specs["shared"] = {k: P(None, None) for k in p["shared"]}
        specs["shared_gate"] = P(None, None)
    return specs


def _remat_policy(name: str):
    if name == "none":
        return None
    if name == "full":
        return jax.checkpoint_policies.nothing_saveable
    if name == "dots_saveable":
        return jax.checkpoint_policies.dots_saveable
    raise ValueError(f"unknown remat policy {name!r}")


# ===========================================================================
# Stack
# ===========================================================================
def _run_stack(
    cfg: ModelConfig,
    params: Params,
    rt: Runtime,
    x: jax.Array,
    mode: str,
    state: Optional[Any],
    cur_len: Optional[jax.Array],
    residency: Optional[Any],
    page_table: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Any, Aux]:
    """Scan the segment stack. residency: per-MoE-layer {slots, lut} stacked
    over reps; ``page_table`` [B, pages] switches decode-mode KV blocks to the
    paged pool layout (shared across layers — every layer's plane is carved
    identically, so one table addresses them all)."""
    aux_tot: Dict[str, jax.Array] = {}
    new_states: List[Any] = []
    for si, (unit, reps) in enumerate(cfg.segments):
        unit_params = params["segments"][si]
        # scan xs must be uniform pytrees: {} stands in for "no state"/"no residency"
        unit_state = state[si] if state is not None else tuple({} for _ in unit)
        unit_res = {}
        if residency is not None and any(k == "attn_moe" for k in unit):
            unit_res = residency[si]

        def unit_fn(x, per_rep, unit=unit):
            p_list, s_list, r = per_rep
            r = r if r else None
            new_s = []
            aux_u: Dict[str, jax.Array] = {}
            for pi, kind in enumerate(unit):
                st = s_list[pi] if s_list[pi] else None
                res_i = r if kind == "attn_moe" else None
                x, ns, aux_b = _apply_block(
                    kind, p_list[pi], cfg, rt, x, mode, st, cur_len, res_i,
                    page_table,
                )
                new_s.append(ns if ns is not None else {})
                for k, v in aux_b.items():
                    if k.startswith("route_"):
                        aux_u[k] = v            # passed through, stacked by scan
                    else:
                        aux_u[k] = aux_u.get(k, jnp.zeros(())) + v
            return x, (tuple(new_s), aux_u)

        policy = _remat_policy(rt.sharding.remat_policy)
        if mode == "train" and policy is not None:
            unit_fn = jax.checkpoint(unit_fn, policy=policy)

        x, (seg_states, seg_aux) = jax.lax.scan(
            unit_fn, x, (unit_params, unit_state, unit_res)
        )
        new_states.append(seg_states)
        for k, v in seg_aux.items():
            if k.startswith("route_"):
                aux_tot[f"{k}/seg{si}"] = v      # [reps, ...] per-layer telemetry
            else:
                aux_tot[k] = aux_tot.get(k, 0.0) + v.sum()
        x = rt.constrain(x, P(rt.dp_spec, None, None))
    return x, tuple(new_states), aux_tot


# ===========================================================================
# Embedding / head
# ===========================================================================
def embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embed"], tokens, axis=0)


def _prepend_frontend(
    cfg: ModelConfig, params: Params, x: jax.Array, frontend: Optional[jax.Array]
) -> jax.Array:
    if cfg.frontend is None:
        return x
    assert frontend is not None, f"{cfg.name} requires frontend embeddings"
    fe = frontend.astype(x.dtype)
    if "frontend_proj" in params:
        fe = fe @ params["frontend_proj"]
    return jnp.concatenate([fe, x], axis=1)


def lm_logits(cfg: ModelConfig, params: Params, h: jax.Array) -> jax.Array:
    h = apply_norm(cfg.norm, params["final_norm"], h)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ head


# ===========================================================================
# Entry points
# ===========================================================================
def forward_train(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    rt: Runtime,
    frontend: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Aux]:
    """tokens [B, S_tok] -> hidden [B, S_total, D] (pre-head), aux losses."""
    x = embed_tokens(cfg, params, tokens)
    x = _prepend_frontend(cfg, params, x, frontend)
    x = rt.constrain(x, P(rt.dp_spec, None, None))
    h, _, aux = _run_stack(cfg, params, rt, x, "train", None, None, None)
    return h, aux


def lm_loss(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    labels: jax.Array,
    rt: Runtime,
    frontend: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Aux]:
    """Next-token cross-entropy, chunked over sequence so [B,S,V] never
    materializes (matters at vocab 256k). labels [B, S_tok] with -1 = ignore."""
    h, aux = forward_train(cfg, params, tokens, rt, frontend)
    f = cfg.frontend_len if cfg.frontend is not None else 0
    if f > 0:
        pred_h = h[:, f - 1 : -1]            # predicts every token position
        tgt = labels
    else:
        pred_h = h[:, :-1]
        tgt = labels[:, 1:]
    b, s, d = pred_h.shape
    chunk = min(rt.loss_chunk, s)
    n = s // chunk
    rem = s - n * chunk
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    hn = apply_norm(cfg.norm, params["final_norm"], pred_h)

    def chunk_loss(hc, tc):
        logits = (hc @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(tc, 0)[..., None], axis=-1)[..., 0]
        valid = (tc >= 0).astype(jnp.float32)
        return ((logz - gold) * valid).sum(), valid.sum()

    def body(carry, xs):
        hc, tc = xs
        l, c = chunk_loss(hc, tc)
        return (carry[0] + l, carry[1] + c), None

    hc = hn[:, : n * chunk].reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    tc = tgt[:, : n * chunk].reshape(b, n, chunk).transpose(1, 0, 2)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hc, tc))
    if rem:
        l, c = chunk_loss(hn[:, n * chunk :], tgt[:, n * chunk :])
        tot, cnt = tot + l, cnt + c
    loss = tot / jnp.maximum(cnt, 1.0)
    if cfg.has_moe:
        m = cfg.moe
        loss = loss + m.router_aux_coef * aux.get("moe_load_balance", 0.0) / max(
            cfg.num_layers, 1
        )
        loss = loss + m.router_z_coef * aux.get("moe_router_z", 0.0) / max(cfg.num_layers, 1)
    aux["lm_loss"] = loss
    return loss, aux


def prefill_model(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,
    rt: Runtime,
    frontend: Optional[jax.Array] = None,
    last_index: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Any]:
    """Returns (last-position logits [B, V], decode state).

    ``last_index`` [B] selects each row's true last position (right-padded
    ragged prefill from the serving engine); default = final position.
    """
    x = embed_tokens(cfg, params, tokens)
    x = _prepend_frontend(cfg, params, x, frontend)
    x = rt.constrain(x, P(rt.dp_spec, None, None))
    state = zero_state(cfg, x.shape[0], rt.cache_len)
    h, state, _ = _run_stack(cfg, params, rt, x, "prefill", state, None, None)
    if last_index is None:
        hb = h[:, -1]
    else:
        hb = h[jnp.arange(h.shape[0]), last_index]
    logits = lm_logits(cfg, params, hb[:, None])[:, 0]
    return logits, state


def decode_model(
    cfg: ModelConfig,
    params: Params,
    token: jax.Array,            # [B] int32 current token
    state: Any,
    cur_len: jax.Array,          # scalar int32: number of tokens already in cache
    rt: Runtime,
    residency: Optional[Any] = None,
    page_table: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Any, Aux]:
    """One decode step: returns (logits [B, V], new state, aux incl. miss
    counts). ``page_table`` [B, pages]: ``state`` is the serving engine's
    paged pool (:func:`paged_zero_state`) instead of a per-row batch cache."""
    x = embed_tokens(cfg, params, token[:, None])
    x = rt.constrain(x, P(rt.dp_spec, None, None))
    h, state, aux = _run_stack(
        cfg, params, rt, x, "decode", state, cur_len, residency, page_table
    )
    logits = lm_logits(cfg, params, h[:, -1:])[:, 0]
    return logits, state, aux


def prefill_chunk_model(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,           # [B, C] int32: the chunk's token positions
    state: Any,
    cur_len: jax.Array,          # scalar or [B] int32: tokens already cached
    rt: Runtime,
    residency: Optional[Any] = None,
    with_head: bool = True,
) -> Tuple[Optional[jax.Array], Any, Aux]:
    """One prefill chunk: append ``C`` prompt positions to the decode state.

    The multi-token sibling of :func:`decode_model` — the same stacked scan,
    ``"chunk"`` mode blocks (:func:`attention_prefill_chunk` appends the
    chunk's KV; the MoE half runs the routed/gathered path over all B*C chunk
    tokens, optionally through the residency slot LUT, emitting the same
    ``route_*`` telemetry decode does). Requires KV-cache-only block kinds.

    Returns (logits [B, V] at the chunk's LAST position, new state, aux);
    ``with_head=False`` skips the lm-head GEMM and returns ``None`` logits —
    only a prompt's FINAL chunk needs the head, and at real vocab sizes the
    [D, V] GEMM plus the [B, V] pull is the dominant per-chunk waste.
    """
    x = embed_tokens(cfg, params, tokens)
    x = rt.constrain(x, P(rt.dp_spec, None, None))
    h, state, aux = _run_stack(cfg, params, rt, x, "chunk", state, cur_len, residency)
    if not with_head:
        return None, state, aux
    logits = lm_logits(cfg, params, h[:, -1:])[:, 0]
    return logits, state, aux


def decode_window(
    cfg: ModelConfig,
    params: Params,
    token: jax.Array,            # [B] int32 first token of the window
    state: Any,
    cur_len: jax.Array,          # scalar or [B] int32: tokens already in cache
    rt: Runtime,
    k_steps: int,
    residency: Optional[Any] = None,
    aux_fn: Optional[Any] = None,
    page_table: Optional[jax.Array] = None,
    sample: Optional[sampling_mod.SampleParams] = None,
    rng_keys: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, Any, Aux]:
    """``k_steps`` self-drafted decode steps in ONE traced program.

    A ``lax.scan`` over :func:`decode_model` threads (token, state, cur_len)
    through the window: each position runs the whole stack at its own
    ``cur_len`` (scalar engine or per-row [B] serving batches) and drafts the
    next token on-device — the self-drafting half of the speculative decode
    path. The residency pytree is a scan constant, so every window position
    gathers from the SAME residency snapshot (rotation is the caller's job, at
    window boundaries).

    Drafting is a plain argmax by default. With ``sample`` (a static
    :class:`repro.models.sampling.SampleParams`) and ``rng_keys`` ([B, 2]
    uint32 per-row base keys), position j instead draws from the warped
    distribution keyed by ``fold_in(row_key, cur_len_at_j)`` — the stateless
    position-keyed protocol that makes spec-K streams bit-identical to
    single-token ones — and the stacked aux gains ``sample_probs`` [K, B, V]
    (the warped per-position distributions, draft AND verifier for a
    self-drafting window) plus ``sample_p`` [K, B] (the drawn token's prob).

    Returns ``(draft [K, B], last_logits [B, V] f32, new_state, aux)`` where
    ``draft[j]`` is drafted from position j's logits (the token position j+1
    consumed) and every aux entry is stacked with a leading window axis [K, ...].
    ``aux_fn`` (optional) post-processes each position's aux dict before
    stacking (the engine's on-device demand GEMM). Logits are carried in f32 —
    a lossless upcast, so the caller's host argmax matches the single-token
    step bit-for-bit. ``page_table`` (scan constant, like residency) runs the
    window over the paged KV pool.
    """
    b = token.shape[0]
    logits0 = jnp.zeros((b, cfg.vocab_size), jnp.float32)

    def body(carry, _):
        tok, st, cl, _ = carry
        logits, st, aux = decode_model(
            cfg, params, tok, st, cl, rt, residency=residency,
            page_table=page_table,
        )
        if aux_fn is not None:
            aux = aux_fn(aux)
        if sample is None:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt, probs, p_tok = sampling_mod.sample_step(
                logits, rng_keys, cl, sample
            )
            aux = dict(aux)
            aux["sample_probs"] = probs
            aux["sample_p"] = p_tok
        return (nxt, st, cl + 1, logits.astype(jnp.float32)), (nxt, aux)

    init = (
        jnp.asarray(token, jnp.int32),
        state,
        jnp.asarray(cur_len, jnp.int32),
        logits0,
    )
    (_, state, _, logits), (draft, aux) = jax.lax.scan(
        body, init, None, length=k_steps
    )
    return draft, logits, state, aux


# ===========================================================================
# KV window snapshot / rollback (speculative decode truncation)
# ===========================================================================
_KV_KINDS = ("attn_mlp", "attn_moe", "local_attn")


def _kv_window_slots(
    cache: jax.Array, cur_len: jax.Array, k_steps: int
) -> Tuple[jax.Array, jax.Array]:
    """Row/slot index arrays for the ``k_steps`` cache slots a decode window
    starting at ``cur_len`` writes. cache [reps, B, cap, Hkv, dh]."""
    cap, b = cache.shape[2], cache.shape[1]
    assert k_steps <= cap, (
        f"speculative window ({k_steps}) exceeds KV capacity ({cap})"
    )
    cl = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    slots = (cl[:, None] + jnp.arange(k_steps, dtype=jnp.int32)[None, :]) % cap
    return jnp.arange(b)[:, None], slots                    # [B, 1], [B, K]


def _kv_window_slots_paged(
    cache: jax.Array, page_table: jax.Array, cur_len: jax.Array, k_steps: int
) -> Tuple[jax.Array, jax.Array]:
    """Physical (page, offset) index arrays for the ``k_steps`` PAGED cache
    positions a decode window starting at ``cur_len`` writes.
    cache [reps, P, page_size, Hkv, dh]; page_table [B, cap // page_size]."""
    ps = cache.shape[2]
    b = page_table.shape[0]
    cap = page_table.shape[1] * ps
    assert k_steps <= cap, (
        f"speculative window ({k_steps}) exceeds KV capacity ({cap})"
    )
    cl = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    slots = (cl[:, None] + jnp.arange(k_steps, dtype=jnp.int32)[None, :]) % cap
    pages = jnp.take_along_axis(page_table, slots // ps, axis=1)    # [B, K]
    return pages, slots % ps                                        # [B, K] x2


def snapshot_kv_window(cfg: ModelConfig, state: Any, cur_len: jax.Array,
                       k_steps: int,
                       page_table: Optional[jax.Array] = None) -> Any:
    """Pre-window copies of the KV slots the next ``k_steps`` decode positions
    overwrite — the substrate :func:`rollback_kv_window` restores from.

    Mirrors the stacked decode-state layout (segments x unit positions), with
    {} at non-KV positions; each KV leaf becomes [reps, B, K, Hkv, dh]. A tiny
    gather (K slots per layer), so speculation can truncate exactly: full
    caches get their zeros back, ring caches their previous-lap entries (which
    a rejected window's writes would otherwise destroy).

    ``page_table`` [B, pages]: ``state`` is the paged pool — the same [reps,
    B, K, Hkv, dh] saved layout, gathered through physical (page, offset)
    coordinates instead of per-row slots.
    """
    segs = []
    for si, (unit, reps) in enumerate(cfg.segments):
        unit_saved = []
        for pi, kind in enumerate(unit):
            if kind in _KV_KINDS:
                def take(c):
                    if page_table is None:
                        rows, slots = _kv_window_slots(c, cur_len, k_steps)
                        return c[:, rows, slots]
                    pages, poff = _kv_window_slots_paged(
                        c, page_table, cur_len, k_steps
                    )
                    return c[:, pages, poff]
                unit_saved.append(jax.tree.map(take, state[si][pi]))
            else:
                unit_saved.append({})
        segs.append(tuple(unit_saved))
    return tuple(segs)


def rollback_kv_window(
    cfg: ModelConfig,
    state: Any,
    saved: Any,
    cur_len: jax.Array,
    k_steps: int,
    keep: jax.Array,             # scalar or [B]: window positions to keep
    page_table: Optional[jax.Array] = None,
) -> Any:
    """KV truncate after a partially rejected speculative window.

    Restores the pre-window contents (``saved``, from
    :func:`snapshot_kv_window`) of every cache slot written by window offsets
    ``>= keep`` — per-row ``keep`` supports ragged serving batches — leaving
    offsets ``< keep`` (the accepted prefix) in place. Truncate-then-redecode
    is bit-identical to never having speculated: the restored state matches
    the one a sequential decode would hold at length ``cur_len + keep``.

    ``page_table`` [B, pages]: paged-pool variant (scatter through physical
    (page, offset) coordinates; pad rows' duplicate scratch-page writes are
    harmless — scratch contents are never scored unmasked).
    """
    offs = jnp.arange(k_steps, dtype=jnp.int32)
    segs = []
    for si, (unit, reps) in enumerate(cfg.segments):
        unit_new = []
        for pi, kind in enumerate(unit):
            st = state[si][pi]
            if kind in _KV_KINDS:
                def roll(c, s):
                    if page_table is None:
                        rows, slots = _kv_window_slots(c, cur_len, k_steps)
                        b = c.shape[1]
                    else:
                        rows, slots = _kv_window_slots_paged(
                            c, page_table, cur_len, k_steps
                        )
                        b = page_table.shape[0]
                    kp = jnp.broadcast_to(jnp.asarray(keep, jnp.int32), (b,))
                    mask = offs[None, :] >= kp[:, None]             # [B, K]
                    cur = c[:, rows, slots]
                    blended = jnp.where(mask[None, :, :, None, None], s, cur)
                    return c.at[:, rows, slots].set(blended)
                unit_new.append(jax.tree.map(roll, st, saved[si][pi]))
            else:
                unit_new.append(st)
        segs.append(tuple(unit_new))
    return tuple(segs)
