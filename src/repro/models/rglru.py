"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block: x -> [branch a: linear -> causal conv1d(w) -> RG-LRU] * [branch b: linear
-> gelu] -> linear out. The RG-LRU diagonal linear recurrence
``h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * (i_t * x_t)`` is evaluated with
``jax.lax.associative_scan`` for train/prefill (log-depth parallel over sequence)
and as a single fused step for decode. State is O(1) in sequence length.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import RecurrentConfig
from repro.models.layers import Params, dense_init

State = Dict[str, jax.Array]

_C = 8.0  # Griffin's fixed recurrence-sharpness constant


def init_rglru(key: jax.Array, d_model: int, rcfg: RecurrentConfig, dtype: Any) -> Params:
    w = rcfg.lru_width or d_model
    ka, kb, kx, kr, ki, kc, ko = jax.random.split(key, 7)
    # Lambda init so a = sigmoid(lam)^c spreads over (0.9, 0.999)
    u = jax.random.uniform(kr, (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / _C) / (1.0 - u ** (1.0 / _C)))
    return {
        "w_a": dense_init(ka, (d_model, w), dtype),            # branch a in-proj
        "w_b": dense_init(kb, (d_model, w), dtype),            # branch b (gate) in-proj
        "conv_w": (jax.random.normal(kc, (rcfg.conv_width, w), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_rg": dense_init(kx, (w, w), jnp.float32),           # recurrence gate r_t
        "w_ig": dense_init(ki, (w, w), jnp.float32),           # input gate i_t
        "lam": lam,
        "w_out": dense_init(ko, (w, d_model), dtype, fan_in=w),
    }


def rglru_zero_state(batch: int, d_model: int, rcfg: RecurrentConfig) -> State:
    w = rcfg.lru_width or d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, rcfg.conv_width - 1, w), jnp.float32),
    }


def _causal_conv(p: Params, x: jax.Array, conv_state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x [B,S,W]; conv_state [B,cw-1,W] holds the previous cw-1 inputs."""
    cw = p["conv_w"].shape[0]
    xf = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)  # [B, S+cw-1, W]
    out = sum(xf[:, i : i + x.shape[1]] * p["conv_w"][i] for i in range(cw))
    new_state = xf[:, -(cw - 1) :].astype(jnp.float32)
    return out + p["conv_b"], new_state


def _rglru_gates(p: Params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x [...,W] (post-conv) -> (a_t, gated input) in f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_rg"])
    i = jax.nn.sigmoid(xf @ p["w_ig"])
    log_a = -_C * r * jax.nn.softplus(p["lam"])     # log a_t  (a = sigmoid(lam)^(c*r))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * xf)


def _rglru_inner(p: Params, x: jax.Array, state: State) -> Tuple[jax.Array, State]:
    """x [B,S,D] -> (y [B,S,D], state)."""
    b, s, d = x.shape
    xa = x @ p["w_a"]
    xb = jax.nn.gelu(x @ p["w_b"])
    conv_out, conv_state = _causal_conv(p, xa, state["conv"])
    a, u = _rglru_gates(p, conv_out)                 # [B,S,W] each, f32

    # h_t = a_t h_{t-1} + u_t ; fold the incoming state into u_0
    u = u.at[:, 0].add(a[:, 0] * state["h"])

    def combine(l, r):
        al, ul = l
        ar, ur = r
        return al * ar, ar * ul + ur

    a_sc, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    new_state = {"h": h[:, -1], "conv": conv_state}
    y = (h.astype(x.dtype) * xb) @ p["w_out"]
    return y, new_state


def rglru_train(p: Params, x: jax.Array, rcfg: RecurrentConfig) -> jax.Array:
    state = rglru_zero_state(x.shape[0], x.shape[-1], rcfg)
    y, _ = _rglru_inner(p, x, state)
    return y


def rglru_prefill(p: Params, x: jax.Array, rcfg: RecurrentConfig) -> Tuple[jax.Array, State]:
    state = rglru_zero_state(x.shape[0], x.shape[-1], rcfg)
    return _rglru_inner(p, x, state)


def rglru_decode(p: Params, x: jax.Array, state: State) -> Tuple[jax.Array, State]:
    """x [B,1,D] single-step recurrence."""
    b, s, d = x.shape
    assert s == 1
    xa = x @ p["w_a"]
    xb = jax.nn.gelu(x @ p["w_b"])
    conv_out, conv_state = _causal_conv(p, xa, state["conv"])
    a, u = _rglru_gates(p, conv_out)
    h = a[:, 0] * state["h"] + u[:, 0]
    y = (h[:, None].astype(x.dtype) * xb) @ p["w_out"]
    return y, {"h": h, "conv": conv_state}
