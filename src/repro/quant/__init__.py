"""Grouped weight quantization for the rotating slot link (Q4_K_M analog).

The host link is the currency of the whole system: every rotation ships
expert weights host->HBM, and every byte saved is amortized over the K
tokens of a speculative window (``rotate_window_from_telemetry`` coalesces
uploads to the last write per slot, so a group transferred once serves a
whole window). This package packs experts as grouped 4-bit integers — two
nibbles per byte, per-group f16 scale + min over the reduction axis — and
provides the pure-JAX unpack/dequant reference mirrored by the in-kernel
dequant path of the Pallas ``moe_gmm`` kernel.

Bytes per weight element (what one expert costs on the link):

  ============  =====================  ==========  ============
  format        layout                 bytes/elem  vs f16
  ============  =====================  ==========  ============
  f16 / bf16    dense                  2.0         1.00x
  int8          + f32 scale [F]        ~1.0        ~0.50x
  int4 grouped  2 nibbles/byte + f16   0.5 + 4/G   0.281x (G=64)
                scale+min per group
  ============  =====================  ==========  ============

With the default group size G=64 an int4 expert moves ~0.28x the f16
bytes (the Q4_K_M operating point, ~4.5 bits/weight), so a rotation that
would ship 2 MB of bf16 ships ~0.56 MB — and under speculative windows
that transfer happens once per K committed tokens, not once per token.
"""
from repro.quant.int4 import (  # noqa: F401
    GROUP_SIZE_DEFAULT,
    bytes_per_element,
    dequantize_int4,
    effective_group,
    int4_tensor_bytes,
    quantize_int4,
    quantize_int4_batch,
    unpack_int4,
)
