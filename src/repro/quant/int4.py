"""Grouped int4 pack / unpack / dequant (the Q4_K_M-style slot format).

Layout
------
Weights quantize along axis ``-2`` — the reduction dim of every expert
matrix (``w_gate``/``w_up`` group over ``d_model`` rows, ``w_down`` over
``expert_d_ff`` rows) — in groups of ``group_size`` rows per output
column. Each group stores an asymmetric affine code::

    w  ~=  scale * q + mn,     q in [0, 15]

with ``scale``/``mn`` kept in f16 (quantization uses the f16-ROUNDED
values, so host dequant and in-kernel dequant agree bit-for-bit with what
the quantizer optimized). Two consecutive rows pack into one byte: byte
``i`` of the packed axis holds row ``2i`` in its low nibble and row
``2i+1`` in its high nibble, so the packed tensor is ``[.., D/2, F]``
uint8 next to ``[.., D/G, F]`` f16 scales and mins.

The batched variant is bit-equal to quantizing each expert alone (groups
never span the leading expert axis), which the upload path relies on:
one stacked scatter per tensor per rotation must produce exactly the
bytes N single-expert uploads would have.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax.numpy as jnp
import numpy as np

GROUP_SIZE_DEFAULT = 64

# keeps a flat group (mx == mn) from dividing by zero; f16-representable
_SCALE_EPS = 1e-6


def effective_group(rows: int, group_size: int) -> int:
    """Largest even divisor of ``rows`` that is <= ``group_size``.

    Real dims (2048, 1408, ...) keep the requested group; tiny reduced
    dims clamp so the group axis always tiles exactly.
    """
    assert rows % 2 == 0, f"int4 packing needs an even row count, got {rows}"
    assert group_size >= 2, f"group_size must be >= 2, got {group_size}"
    g = min(group_size, rows)
    while rows % g or g % 2:
        g -= 1
    return g


def quantize_int4(
    w: np.ndarray, group_size: int = GROUP_SIZE_DEFAULT
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """w [.., D, F] float -> (packed u8 [.., D/2, F], scale f16 [.., D/G, F],
    mn f16 [.., D/G, F]) with G = ``effective_group(D, group_size)``."""
    w = np.asarray(w, np.float32)
    d, f = w.shape[-2], w.shape[-1]
    g = effective_group(d, group_size)
    lead = w.shape[:-2]
    grp = w.reshape(lead + (d // g, g, f))
    mn = grp.min(axis=-2).astype(np.float16)
    mx = grp.max(axis=-2)
    scale = ((mx - mn.astype(np.float32)) / 15.0 + _SCALE_EPS).astype(np.float16)
    # quantize against the f16-ROUNDED affine so dequant is consistent
    s32 = scale.astype(np.float32)[..., None, :]
    m32 = mn.astype(np.float32)[..., None, :]
    q = np.clip(np.round((grp - m32) / s32), 0, 15).astype(np.uint8)
    q = q.reshape(lead + (d, f))
    packed = (q[..., 0::2, :] | (q[..., 1::2, :] << 4)).astype(np.uint8)
    return packed, scale, mn


def quantize_int4_batch(
    w: np.ndarray, group_size: int = GROUP_SIZE_DEFAULT
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """``quantize_int4`` over a leading expert axis: w [N, .., D, F] ->
    (packed [N, .., D/2, F], scale [N, .., D/G, F], mn [N, .., D/G, F])
    bit-equal to quantizing each expert alone (groups are per-expert, so
    the batched upload path matches the one-expert path byte-for-byte)."""
    assert w.ndim >= 3, "batched quantization expects a leading expert axis"
    return quantize_int4(w, group_size)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """packed u8 [.., P, F] -> q u8 [.., 2P, F] (row 2i = low nibble of
    byte i, row 2i+1 = high nibble)."""
    lo = packed & 0xF
    hi = packed >> 4
    q = jnp.stack([lo, hi], axis=-2)                   # [.., P, 2, F]
    return q.reshape(packed.shape[:-2] + (2 * packed.shape[-2], packed.shape[-1]))


def dequantize_int4(
    packed: jnp.ndarray,
    scale: jnp.ndarray,
    mn: jnp.ndarray,
    dtype: Any = jnp.float32,
) -> jnp.ndarray:
    """Pure-JAX unpack + affine dequant (the reference the Pallas kernel's
    in-VMEM dequant mirrors). Group size is inferred from the shapes."""
    q = unpack_int4(packed).astype(jnp.float32)
    rows = q.shape[-2]
    group = rows // scale.shape[-2]
    s = jnp.repeat(scale.astype(jnp.float32), group, axis=-2)
    m = jnp.repeat(mn.astype(jnp.float32), group, axis=-2)
    return (q * s + m).astype(dtype)


def int4_tensor_bytes(shape: Tuple[int, ...], group_size: int = GROUP_SIZE_DEFAULT) -> int:
    """Exact packed+scales+mins bytes of one [.., D, F] tensor."""
    d, f = shape[-2], shape[-1]
    lead = int(np.prod(shape[:-2])) if len(shape) > 2 else 1
    g = effective_group(d, group_size)
    return lead * ((d // 2) * f + 2 * (d // g) * f * 2)   # u8 + f16 scale + f16 mn


def bytes_per_element(
    quantization: str | None,
    dtype_bytes: int = 2,
    group_size: int = GROUP_SIZE_DEFAULT,
) -> float:
    """Approximate link bytes per weight element under ``quantization``
    (int8 counts its f32 per-channel scale as amortized-out, matching the
    feasibility model's 1 byte/elem)."""
    if quantization == "int8":
        return 1.0
    if quantization == "int4":
        return 0.5 + 4.0 / group_size
    return float(dtype_bytes)
