"""Token samplers and speculative-decode ACCEPT rules.

``Sampler`` is the HOST reference for the on-device warp in
``repro.models.sampling`` (same kept set: top-k ties break toward the lower
index like ``lax.top_k``, top-p uses a stable descending sort) — the group-tick
serving path still draws through it, and the differential tests in
``tests/test_sampler_properties.py`` hold the two implementations together.

``greedy_accept`` / ``stochastic_accept`` decide how many drafted tokens a
speculative window commits. ``stochastic_accept`` is the Leviathan et al.
leftover-distribution rejection rule and is what keeps the K-tokens-per-launch
shape *distributionally exact* at temperature > 0: accept drafted token t with
probability ``min(1, q(t)/p(t))`` (q = verifier distribution, p = draft
distribution) and resample the first rejection from ``normalize(max(q - p,
0))``. Self-drafting engines pass the same distributions for p and q, so
acceptance is certain and rejection comes only from residency misses — the
full rule is the plug point for a separate draft model, and its rejection path
is pinned by the distributional tests in ``tests/test_stochastic_decode.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


def greedy_accept(draft: np.ndarray, verify: np.ndarray) -> np.ndarray:
    """Greedy speculative accept rule: per-row length of the agreeing prefix.

    ``draft``/``verify`` are [K, B] token ids — the drafted window and the
    verifier's argmaxes for the same positions. A position commits only if it
    AND every earlier position agree (a disagreement invalidates everything
    drafted after it). Self-drafting with identical weights verifies against
    its own argmaxes, so this accepts the full window and rejection comes
    only from residency misses — the call is the plug point for a separate
    draft model. Returns accepted counts [B] in ``0..K``.
    """
    agree = np.cumprod(draft == verify, axis=0, dtype=np.int32)     # [K, B]
    return agree.sum(axis=0).astype(np.int32)


def stochastic_accept(
    draft: np.ndarray,          # [K, B] drafted token ids
    draft_probs: np.ndarray,    # [K, B, V] draft distributions p
    verify_probs: np.ndarray,   # [K, B, V] verifier distributions q
    rng: np.random.Generator,
) -> Tuple[np.ndarray, np.ndarray]:
    """Stochastic speculative acceptance (leftover-distribution rejection
    sampling, Leviathan et al.): accept drafted token t with probability
    ``min(1, q(t)/p(t))``; at the first rejection draw the replacement from
    ``normalize(max(q - p, 0))``.

    Returns ``(accepted [B], resampled [B])``: per-row accepted counts in
    ``0..K`` and, for rows with ``accepted < K``, the leftover-resampled
    replacement token at the first rejected position (``-1`` for rows that
    accepted the whole window). Committing ``accepted`` drafted tokens plus
    the replacement makes each emitted position exactly ``q``-distributed —
    the property the chi-squared tests verify.

    Self-drafting callers pass ``draft_probs is verify_probs``: every ratio is
    exactly 1, acceptance is certain, and the resample path is dormant (their
    rejections come from residency misses; the caller composes the two caps
    with a per-row ``min``).
    """
    k, b = draft.shape
    p = np.asarray(draft_probs, np.float64)                     # [K, B, V]
    q = np.asarray(verify_probs, np.float64)
    p_tok = np.take_along_axis(p, draft[..., None], axis=-1)[..., 0]   # [K, B]
    q_tok = np.take_along_axis(q, draft[..., None], axis=-1)[..., 0]
    # p(t) > 0 whenever t was genuinely drawn from p; guard anyway
    ratio = np.where(p_tok > 0, q_tok / np.maximum(p_tok, 1e-300), 0.0)
    u = rng.random((k, b))
    reject = u >= np.minimum(1.0, ratio)                        # [K, B]
    any_rej = reject.any(axis=0)
    accepted = np.where(any_rej, reject.argmax(axis=0), k).astype(np.int32)
    resampled = np.full((b,), -1, np.int32)
    rows = np.flatnonzero(any_rej)
    if rows.size:
        leftover = np.maximum(q[accepted[rows], rows] - p[accepted[rows], rows],
                              0.0)                              # [R, V]
        z = leftover.sum(axis=-1, keepdims=True)
        # z == 0 only if p >= q everywhere, i.e. p == q — then a rejection is
        # impossible up to float underflow; fall back to q itself
        leftover = np.where(z > 0, leftover, q[accepted[rows], rows])
        leftover /= leftover.sum(axis=-1, keepdims=True)
        cum = np.cumsum(leftover, axis=-1)
        u2 = rng.random((rows.size, 1))
        resampled[rows] = np.minimum(
            (cum < u2).sum(axis=-1), leftover.shape[-1] - 1
        ).astype(np.int32)
    return accepted, resampled


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0        # 0 = greedy
    top_k: int = 0                  # 0 = off
    top_p: float = 1.0
    seed: int = 0


class Sampler:
    def __init__(self, cfg: SamplerConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)

    def warp(self, logits: np.ndarray) -> np.ndarray:
        """logits [B, V] -> warped probabilities [B, V] (zeros off-support).

        The host reference for ``repro.models.sampling.warp_probs``: top-k
        keeps exactly ``top_k`` candidates with ties broken toward the LOWER
        index (the ``lax.top_k`` convention — a plain threshold mask would
        keep every tied candidate and sample a wider distribution than the
        device path), top-p keeps tokens while the cumulative mass before
        them is < p under a STABLE descending sort.
        """
        c = self.cfg
        x = logits.astype(np.float64) / c.temperature
        v = x.shape[-1]
        if 0 < c.top_k < v:
            order = np.argsort(-x, axis=-1, kind="stable")      # [B, V]
            keep = np.zeros_like(x, bool)
            np.put_along_axis(keep, order[:, : c.top_k], True, axis=-1)
            x = np.where(keep, x, -np.inf)
        p = np.exp(x - x.max(axis=-1, keepdims=True))
        p /= p.sum(axis=-1, keepdims=True)
        if c.top_p < 1.0:
            order = np.argsort(-p, axis=-1, kind="stable")
            sorted_p = np.take_along_axis(p, order, axis=-1)
            cum = np.cumsum(sorted_p, axis=-1)
            keep_sorted = cum - sorted_p < c.top_p
            keep = np.zeros_like(p, bool)
            np.put_along_axis(keep, order, keep_sorted, axis=-1)
            p = np.where(keep, p, 0.0)
            p /= p.sum(axis=-1, keepdims=True)
        return p

    def __call__(self, logits: np.ndarray) -> np.ndarray:
        """logits [B, V] -> tokens [B] (batched inverse-CDF draw: one uniform
        per row against the warped CDF — no per-row host loop)."""
        c = self.cfg
        if c.temperature <= 0.0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        p = self.warp(logits)
        cum = np.cumsum(p, axis=-1)
        u = self.rng.random((p.shape[0], 1))
        return np.minimum(
            (cum < u).sum(axis=-1), p.shape[-1] - 1
        ).astype(np.int32)
