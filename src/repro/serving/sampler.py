"""Token samplers: greedy / temperature / top-k / top-p, pure numpy (host-side
sampling keeps the compiled step deterministic and donation-friendly)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0        # 0 = greedy
    top_k: int = 0                  # 0 = off
    top_p: float = 1.0
    seed: int = 0


class Sampler:
    def __init__(self, cfg: SamplerConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)

    def __call__(self, logits: np.ndarray) -> np.ndarray:
        """logits [B, V] -> tokens [B]."""
        c = self.cfg
        if c.temperature <= 0.0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        x = logits.astype(np.float64) / c.temperature
        if c.top_k > 0:
            kth = np.partition(x, -c.top_k, axis=-1)[:, -c.top_k][:, None]
            x = np.where(x < kth, -np.inf, x)
        p = np.exp(x - x.max(axis=-1, keepdims=True))
        p /= p.sum(axis=-1, keepdims=True)
        if c.top_p < 1.0:
            order = np.argsort(-p, axis=-1)
            sorted_p = np.take_along_axis(p, order, axis=-1)
            cum = np.cumsum(sorted_p, axis=-1)
            keep_sorted = cum - sorted_p < c.top_p
            keep = np.zeros_like(p, bool)
            np.put_along_axis(keep, order, keep_sorted, axis=-1)
            p = np.where(keep, p, 0.0)
            p /= p.sum(axis=-1, keepdims=True)
        return np.array(
            [self.rng.choice(p.shape[-1], p=row) for row in p], np.int32
        )
