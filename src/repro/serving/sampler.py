"""Token samplers: greedy / temperature / top-k / top-p, pure numpy (host-side
sampling keeps the compiled step deterministic and donation-friendly), plus the
speculative-decode ACCEPT rules (how many drafted tokens commit per window)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


def greedy_accept(draft: np.ndarray, verify: np.ndarray) -> np.ndarray:
    """Greedy speculative accept rule: per-row length of the agreeing prefix.

    ``draft``/``verify`` are [K, B] token ids — the drafted window and the
    verifier's argmaxes for the same positions. A position commits only if it
    AND every earlier position agree (a disagreement invalidates everything
    drafted after it). Self-drafting with identical weights verifies against
    its own argmaxes, so this accepts the full window and rejection comes
    only from residency misses — the call is the plug point for a separate
    draft model. Returns accepted counts [B] in ``0..K``.
    """
    agree = np.cumprod(draft == verify, axis=0, dtype=np.int32)     # [K, B]
    return agree.sum(axis=0).astype(np.int32)


def stochastic_accept(
    draft: np.ndarray,          # [K, B] drafted token ids
    draft_probs: np.ndarray,    # [K, B] draft-time probability of each token
    verify_probs: np.ndarray,   # [K, B, V] verifier distributions
    rng: np.random.Generator,
) -> np.ndarray:
    """Hook for sampled speculative decoding (leftover-distribution rejection
    sampling, Leviathan et al.): accept token t with prob min(1, q(t)/p(t))
    and resample the first rejection from max(q - p, 0).

    The engines run the GREEDY rule for now — sampled decode falls back to
    single-token steps — but the signature is the committed interface so a
    temperature > 0 path only has to fill this in.
    """
    raise NotImplementedError(
        "stochastic speculative acceptance is a hook: engines currently "
        "speculate only under greedy sampling (see greedy_accept)"
    )


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0        # 0 = greedy
    top_k: int = 0                  # 0 = off
    top_p: float = 1.0
    seed: int = 0


class Sampler:
    def __init__(self, cfg: SamplerConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)

    def __call__(self, logits: np.ndarray) -> np.ndarray:
        """logits [B, V] -> tokens [B]."""
        c = self.cfg
        if c.temperature <= 0.0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        x = logits.astype(np.float64) / c.temperature
        if c.top_k > 0:
            kth = np.partition(x, -c.top_k, axis=-1)[:, -c.top_k][:, None]
            x = np.where(x < kth, -np.inf, x)
        p = np.exp(x - x.max(axis=-1, keepdims=True))
        p /= p.sum(axis=-1, keepdims=True)
        if c.top_p < 1.0:
            order = np.argsort(-p, axis=-1)
            sorted_p = np.take_along_axis(p, order, axis=-1)
            cum = np.cumsum(sorted_p, axis=-1)
            keep_sorted = cum - sorted_p < c.top_p
            keep = np.zeros_like(p, bool)
            np.put_along_axis(keep, order, keep_sorted, axis=-1)
            p = np.where(keep, p, 0.0)
            p /= p.sum(axis=-1, keepdims=True)
        return np.array(
            [self.rng.choice(p.shape[-1], p=row) for row in p], np.int32
        )
