"""Request scheduler: admission, continuous-batching slot assignment, deadlines.

Straggler mitigation (serving-side): every admission estimates completion time
from the engine's observed per-token latency; requests that cannot meet their
deadline are rejected up-front (or, if already running and past deadline,
truncated at the next step boundary) instead of dragging the whole batch — a
slow request in a synchronous decode batch is the serving analog of a straggler
node.

The scheduler also owns the per-ROW speculative-length policy: each slot's
draft accept rate (fed back by the engine after every window) adapts how far
that row may self-draft, so one misrouting row throttles only itself.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [S] int32
    max_new: int
    deadline_s: Optional[float] = None # relative to submission
    submitted_at: float = 0.0          # arrival
    # sampled serving: the request's PRNG stream seed (None = engine default).
    # Request-intrinsic — never derived from uid/slot — so the stream is
    # reproducible regardless of batching or admission order.
    seed: Optional[int] = None
    # filled by the engine
    output: List[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False
    truncated: bool = False
    reject_reason: str = ""            # why submit refused it (rejected only)
    # lifecycle timestamps (same clock as submitted_at): admission, first
    # emitted token (TTFT = first_token_at - submitted_at), every token
    # commit (inter-token latency percentiles), completion
    admitted_at: float = 0.0
    first_token_at: float = 0.0
    token_times: List[float] = field(default_factory=list)
    finished_at: float = 0.0


class Scheduler:
    def __init__(self, num_slots: int, *, est_tok_s: float = 20.0,
                 est_prefill_tok_s: Optional[float] = None,
                 spec_cap: int = 8, spec_low: float = 0.7,
                 spec_high: float = 0.95,
                 max_prompt_len: Optional[int] = None):
        self.num_slots = num_slots
        # prompts longer than the engine's KV capacity are rejected at
        # submit time (the prefill buckets clamp to the cache, so an
        # over-long prompt cannot be admitted without corrupting its row)
        self.max_prompt_len = max_prompt_len
        self.queue: List = []
        self.running: Dict[int, Request] = {}       # slot -> request
        self.free_slots = list(range(num_slots))
        self.est_tok_s = est_tok_s
        # separate prefill-rate estimate: admission used to assume prefill is
        # exactly 4x the decode rate, which the engine never corrected; the
        # serving engine now feeds measured prefill tok/s into this EMA. The
        # 4x prior survives only as the cold-start value.
        self.est_prefill_tok_s = (
            est_prefill_tok_s if est_prefill_tok_s is not None else 4 * est_tok_s
        )
        # per-ROW learned speculative lengths: each slot tracks an EMA of its
        # draft accept rate and adapts how far the engine may self-draft for
        # that row — rows whose routing keeps missing residency shrink toward
        # single-token decode, rows that accept everything grow toward the cap
        self.spec_cap = max(1, spec_cap)
        self.spec_low = spec_low
        self.spec_high = spec_high
        self._spec_len: Dict[int, int] = {}
        self._accept_ema: Dict[int, float] = {}
        self.rejected: List[Request] = []
        self.completed: List[Request] = []
        self._uid = itertools.count()

    def submit(self, prompt: np.ndarray, max_new: int, now: float,
               deadline_s: Optional[float] = None,
               seed: Optional[int] = None) -> Request:
        req = Request(next(self._uid), np.asarray(prompt, np.int32), max_new,
                      deadline_s, submitted_at=now, seed=seed)
        too_long = (
            self.max_prompt_len is not None
            and len(prompt) > self.max_prompt_len
        )
        est = len(prompt) / self.est_prefill_tok_s + max_new / self.est_tok_s
        if too_long or (deadline_s is not None and est > deadline_s):
            req.done = True
            req.truncated = True
            req.reject_reason = (
                f"prompt length {len(prompt)} exceeds KV capacity "
                f"{self.max_prompt_len}" if too_long
                else f"deadline {deadline_s}s infeasible (est {est:.3f}s)"
            )
            self.rejected.append(req)
            return req
        heapq.heappush(self.queue, (req.deadline_s or float("inf"), req.uid, req))
        return req

    def admit(self, now: float, pool=None) -> List[Request]:
        """Fill free slots from the queue (earliest deadline first).

        With a ``pool`` (`repro.serving.kv_pool.KVPagePool`), admission is
        driven by PAGE-POOL PRESSURE, not batch geometry: each admit reserves
        the worst case — pages for the prompt, the full declared output
        budget (the forecast the EMAs refine only tells us the *expected*
        finish; the reservation must cover the tail), plus ``spec_cap - 1``
        speculative headroom (a window writes all K drafted positions before
        per-row acceptance clamps to the budget) — and stops when the
        head-of-line request doesn't fit, preserving EDF order. Lazy physical
        allocation against that reservation can then never fail mid-window,
        and early finishes hand their unused pages to the next arrival."""
        admitted = []
        while self.free_slots and self.queue:
            _, _, req = self.queue[0]
            if pool is not None:
                need = pool.pages_for(
                    len(req.prompt) + req.max_new + self.spec_cap - 1
                )
                if not pool.reserve(req.uid, need):
                    break
            heapq.heappop(self.queue)
            req.slot = self.free_slots.pop(0)
            req.admitted_at = now
            # a never-seen slot joins at the group's learned drafting pace:
            # slots keep their per-row spec length across requests, but under
            # continuous batching a cold slot starting at 1 would drag the
            # whole window (K = min over live rows) back to single-token
            # decode on every join. Misrouting still halves it within a
            # window or two.
            if req.slot not in self._spec_len and self._spec_len:
                self._spec_len[req.slot] = max(self._spec_len.values())
            self.running[req.slot] = req
            admitted.append(req)
        return admitted

    def step_done(self, slot: int, token: int, now: float, eos: Optional[int] = None) -> None:
        req = self.running[slot]
        req.output.append(int(token))
        if not req.first_token_at:
            req.first_token_at = now
        req.token_times.append(now)
        over_deadline = (
            req.deadline_s is not None and now - req.submitted_at > req.deadline_s
        )
        if len(req.output) >= req.max_new or (eos is not None and token == eos) or over_deadline:
            req.done = True
            req.truncated = over_deadline and len(req.output) < req.max_new
            req.finished_at = now
            self.completed.append(req)
            del self.running[slot]
            self.free_slots.append(slot)
            self.free_slots.sort()

    def observe_rate(self, tok_s: float) -> None:
        self.est_tok_s = 0.9 * self.est_tok_s + 0.1 * tok_s

    def observe_prefill_rate(self, tok_s: float) -> None:
        """Measured prefill tokens/s feedback (engine calls this per prefill)."""
        self.est_prefill_tok_s = 0.9 * self.est_prefill_tok_s + 0.1 * tok_s

    @staticmethod
    def prefill_bucket(lengths: List[int], cache_len: int) -> int:
        """Admission bucket for one prefill group: the power-of-two length
        (min 16, clamped to the cache) covering every admitted prompt, so the
        whole group runs through ONE shared compiled prefill program instead
        of one batch-1 program launch per request. The scheduler owns the
        choice so the engine's compile cache is keyed purely on bucket."""
        m = max(lengths)
        return min(max(16, 1 << (m - 1).bit_length()), cache_len)

    # -- per-row speculative lengths --------------------------------------
    def spec_len(self, slot: int) -> int:
        """How far the engine may self-draft for this row (learned, >= 1)."""
        return self._spec_len.get(slot, 1)

    def observe_accept(self, slot: int, drafted: int, accepted: int) -> None:
        """Fold one window's accept outcome for ``slot`` into its EMA and
        adapt the row's speculative length: below ``spec_low`` the window
        halves (a misrouting row should stop wasting drafted compute and let
        rotation catch up every token), above ``spec_high`` it grows one step
        toward ``spec_cap``. Deterministic — no wall clock involved — so
        serving tests can drive it with a fake clock.
        """
        if drafted <= 0:
            return
        rate = accepted / drafted
        ema = self._accept_ema.get(slot)
        ema = rate if ema is None else 0.5 * ema + 0.5 * rate
        self._accept_ema[slot] = ema
        cur = self.spec_len(slot)
        if ema < self.spec_low:
            self._spec_len[slot] = max(1, cur // 2)
        elif ema > self.spec_high:
            self._spec_len[slot] = min(self.spec_cap, cur + 1)

    @property
    def idle(self) -> bool:
        return not self.running and not self.queue
