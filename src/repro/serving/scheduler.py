"""Request scheduler: admission, continuous-batching slot assignment, deadlines.

Straggler mitigation (serving-side): every admission estimates completion time
from the engine's observed per-token latency; requests that cannot meet their
deadline are rejected up-front (or, if already running and past deadline,
truncated at the next step boundary) instead of dragging the whole batch — a
slow request in a synchronous decode batch is the serving analog of a straggler
node.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [S] int32
    max_new: int
    deadline_s: Optional[float] = None # relative to submission
    submitted_at: float = 0.0
    # filled by the engine
    output: List[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False
    truncated: bool = False
    finished_at: float = 0.0


class Scheduler:
    def __init__(self, num_slots: int, *, est_tok_s: float = 20.0,
                 est_prefill_tok_s: Optional[float] = None):
        self.num_slots = num_slots
        self.queue: List = []
        self.running: Dict[int, Request] = {}       # slot -> request
        self.free_slots = list(range(num_slots))
        self.est_tok_s = est_tok_s
        # separate prefill-rate estimate: admission used to assume prefill is
        # exactly 4x the decode rate, which the engine never corrected; the
        # serving engine now feeds measured prefill tok/s into this EMA. The
        # 4x prior survives only as the cold-start value.
        self.est_prefill_tok_s = (
            est_prefill_tok_s if est_prefill_tok_s is not None else 4 * est_tok_s
        )
        self.rejected: List[Request] = []
        self.completed: List[Request] = []
        self._uid = itertools.count()

    def submit(self, prompt: np.ndarray, max_new: int, now: float,
               deadline_s: Optional[float] = None) -> Request:
        req = Request(next(self._uid), np.asarray(prompt, np.int32), max_new,
                      deadline_s, submitted_at=now)
        est = len(prompt) / self.est_prefill_tok_s + max_new / self.est_tok_s
        if deadline_s is not None and est > deadline_s:
            req.done = True
            req.truncated = True
            self.rejected.append(req)
            return req
        heapq.heappush(self.queue, (req.deadline_s or float("inf"), req.uid, req))
        return req

    def admit(self, now: float) -> List[Request]:
        """Fill free slots from the queue (earliest deadline first)."""
        admitted = []
        while self.free_slots and self.queue:
            _, _, req = heapq.heappop(self.queue)
            req.slot = self.free_slots.pop(0)
            self.running[req.slot] = req
            admitted.append(req)
        return admitted

    def step_done(self, slot: int, token: int, now: float, eos: Optional[int] = None) -> None:
        req = self.running[slot]
        req.output.append(int(token))
        over_deadline = (
            req.deadline_s is not None and now - req.submitted_at > req.deadline_s
        )
        if len(req.output) >= req.max_new or (eos is not None and token == eos) or over_deadline:
            req.done = True
            req.truncated = over_deadline and len(req.output) < req.max_new
            req.finished_at = now
            self.completed.append(req)
            del self.running[slot]
            self.free_slots.append(slot)
            self.free_slots.sort()

    def observe_rate(self, tok_s: float) -> None:
        self.est_tok_s = 0.9 * self.est_tok_s + 0.1 * tok_s

    def observe_prefill_rate(self, tok_s: float) -> None:
        """Measured prefill tokens/s feedback (engine calls this per prefill)."""
        self.est_prefill_tok_s = 0.9 * self.est_prefill_tok_s + 0.1 * tok_s

    @property
    def idle(self) -> bool:
        return not self.running and not self.queue
