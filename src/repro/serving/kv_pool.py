"""Paged ragged KV pool: the serving engine's page-granular KV allocator.

The contiguous per-slot KV batch (`zero_state(cfg, num_slots, cache_len)`)
ties a request's KV residency to a *batch row* for its whole lifetime: a
finished row's cache idles until the group drains, and admission is gated on
batch geometry. The pool replaces that with vLLM-style paging: the donated KV
planes are carved into fixed-size pages (`tfm.paged_zero_state` — one shared
[num_pages + 1, page_size, Hkv, dh] plane per layer per k/v), and each live
request owns an ordered *page table* mapping its logical cache slots
`0..cap-1` to physical pages. Rows join and leave a live decode window
between launches; a finishing request's pages return to the free list
immediately and the next queued request prefills into them.

This class is the HOST-side bookkeeping only — pure python, no jax. Device
addressing happens in `attention_decode(page_table=...)`, which gathers each
row's logical view from the shared planes (bitwise equal to the contiguous
layout — see `tests/test_serving_paged.py`).

Physical page 0 is the reserved scratch page: it is never handed out, pad
rows of a bucketed window carry all-zero page tables (their writes land in
scratch and their telemetry is masked with ``accepted=0``), and unallocated
page-table tail entries point at it. Stale contents of freed/unallocated
pages never need zeroing: every cache position beyond a row's true length is
masked to exact-zero attention probability, and only finite values are ever
written, so garbage contributes ±0.0 to the context sum — bit-identical to a
freshly zeroed cache.

Admission discipline (deadlock freedom): `reserve()` claims the WORST-CASE
page count for a request (prompt + full declared output budget, clamped to
the per-row capacity) before it is admitted; physical pages are then drawn
lazily by `ensure()` as the sequence grows, which therefore can never fail
mid-flight — no request ever stalls inside a window waiting for memory. The
continuous-batching win comes from early finishes (EOS / deadline): pages a
reservation never used return at `release()` and admit the next request
mid-stream rather than at a group boundary.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


class PagePoolError(RuntimeError):
    """An allocation invariant was violated (ensure past reservation/pool)."""


class KVPagePool:
    def __init__(self, num_pages: int, page_size: int, row_pages: int,
                 tracer=None):
        assert num_pages >= row_pages >= 1 and page_size >= 1
        # optional repro.obs.Tracer: reserve/ensure/release emit page-id
        # events the contract auditor replays for use-after-release checks
        self.tracer = tracer
        self.num_pages = num_pages          # allocatable pages (ids 1..num_pages)
        self.page_size = page_size
        self.row_pages = row_pages          # pages a full row spans (cap/page_size)
        self.row_capacity = row_pages * page_size
        # LIFO free list: freshly released pages are reused first, so stale
        # contents are recycled as aggressively as possible (the exactness
        # tests lean on this to exercise the garbage-is-masked contract)
        self._free: List[int] = list(range(num_pages, 0, -1))
        self._tables: Dict[int, List[int]] = {}      # uid -> ordered pages
        self._reserved: Dict[int, int] = {}          # uid -> reserved page count

    # -- accounting --------------------------------------------------------
    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return sum(len(t) for t in self._tables.values())

    @property
    def pages_reservable(self) -> int:
        """Free pages not yet spoken for by an admitted request's unallocated
        reservation remainder — what admission may promise to a NEW request."""
        backlog = sum(
            r - len(self._tables.get(uid, []))
            for uid, r in self._reserved.items()
        )
        return len(self._free) - backlog

    def pages_for(self, tokens: int) -> int:
        """Pages a sequence of ``tokens`` cache positions occupies (clamped to
        the per-row capacity — ring semantics wrap longer sequences)."""
        tokens = min(max(int(tokens), 0), self.row_capacity)
        return -(-tokens // self.page_size)

    # -- lifecycle ---------------------------------------------------------
    def reserve(self, uid: int, pages: int) -> bool:
        """Claim ``pages`` worst-case pages for ``uid`` (admission gate).
        Returns False — without admitting — when the unreserved remainder of
        the free list cannot cover it."""
        assert uid not in self._reserved, f"uid {uid} already reserved"
        if pages > self.pages_reservable:
            return False
        self._reserved[uid] = pages
        if self.tracer is not None:
            self.tracer.instant("kv_reserve", "kv_pool",
                                args={"uid": uid, "pages": pages})
        return True

    def ensure(self, uid: int, tokens: int) -> int:
        """Grow ``uid``'s page table to cover ``tokens`` cache positions;
        returns the number of pages newly allocated. Draws only from the
        request's reservation when one exists — admission sized it worst-case,
        so a reserved request can never fail here."""
        tbl = self._tables.setdefault(uid, [])
        target = self.pages_for(tokens)
        reserved = self._reserved.get(uid)
        if reserved is not None and target > reserved:
            raise PagePoolError(
                f"uid {uid} needs {target} pages but reserved only {reserved}"
            )
        grew = 0
        while len(tbl) < target:
            if not self._free:
                raise PagePoolError(f"page pool exhausted growing uid {uid}")
            tbl.append(self._free.pop())
            grew += 1
        if grew and self.tracer is not None:
            self.tracer.instant("kv_ensure", "kv_pool",
                                args={"uid": uid, "pages": tbl[-grew:]})
        return grew

    def release(self, uid: int) -> int:
        """Return ``uid``'s pages (and any unused reservation) to the pool;
        returns the number of pages freed. Freed pages are NOT zeroed — stale
        contents are masked exactly (module docstring)."""
        freed = self._tables.pop(uid, [])
        self._reserved.pop(uid, None)
        self._free.extend(reversed(freed))     # LIFO: newest-freed reused first
        if freed and self.tracer is not None:
            self.tracer.instant("kv_release", "kv_pool",
                                args={"uid": uid, "pages": list(freed)})
        return len(freed)

    # -- device view -------------------------------------------------------
    def table(self, uid: int) -> List[int]:
        return list(self._tables.get(uid, []))

    def table_array(self, uid: int) -> np.ndarray:
        """Fixed-shape [row_pages] int32 page table for one batch row;
        unallocated tail entries point at the scratch page 0."""
        out = np.zeros((self.row_pages,), np.int32)
        tbl = self._tables.get(uid, [])
        out[: len(tbl)] = tbl
        return out

    # -- invariants (property tests) ---------------------------------------
    def check(self) -> None:
        allocated = [p for t in self._tables.values() for p in t]
        assert len(allocated) == len(set(allocated)), "page double-allocated"
        free = set(self._free)
        assert len(free) == len(self._free), "free list duplicate"
        assert not (free & set(allocated)), "page both free and allocated"
        assert 0 not in free and 0 not in allocated, "scratch page leaked out"
        assert len(allocated) + len(self._free) == self.num_pages, "page leaked"
        for uid, r in self._reserved.items():
            assert len(self._tables.get(uid, [])) <= r, f"uid {uid} overdrew"
        assert self.pages_reservable >= 0, "reservations overcommit the pool"
