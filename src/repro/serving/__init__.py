"""Serving: request-level continuous batching over the shared compiled hot
paths.

``ServingEngine`` drives the live request set through the SAME fused
whole-stack step / speculative window programs the rotary engine compiles
(donated KV, ragged per-row lengths). On KV-cache-only stacks the KV lives
in a paged pool (``KVPagePool``): each request owns a page table into
shared per-layer planes, rows join/leave the window between launches, a
finishing request's pages free immediately and the next queued request
prefills into them — windows are bucketed to the power-of-two cover of the
live row count so the compile cache is keyed on geometry, not membership.
Recurrent stacks (and ``paged=False``) keep the legacy group-tick batch.
``Scheduler`` owns admission (page-pool pressure with worst-case
reservations, deadline feasibility from learned prefill/decode rates,
power-of-two prefill buckets), the per-request lifecycle timestamps behind
the TTFT / inter-token-latency percentiles, and the per-row
speculative-length policy. ``Sampler`` carries the speculative ACCEPT
rules (``greedy_accept``, ``stochastic_accept``) plus the host reference
warp/draw; at temperature > 0 the engine drafts ON DEVICE from the warped
distribution (``repro.models.sampling``) with per-request position-keyed
PRNG streams, and the host rule accepts/resamples per row — a request's
tokens depend only on its seed and lengths, never on batch composition.

Exactness contract: throughput serving drops missed experts in-step
(counted, rotation corrects the NEXT step) — it trades the rotary engine's
bit-exactness for zero replay stalls; everything else is exact: ragged
batching and KV splicing emit the same per-request tokens as running each
request alone, bucketed admission matches batch-1 prefills row for row, and
speculative ticks commit only tokens a sequential tick would have emitted
(per-row KV rollback). Telemetry→host transitions: the tick's ``route_*``
aux + on-device ``demand_next`` feed
``RotaryResidencyManager.rotate_from_telemetry`` (windows:
``rotate_window_from_telemetry`` with per-row accepted counts, so rejected
positions never pollute the predictor EMA or the hit/miss accounting);
measured prefill tok/s and accept rates feed the scheduler's admission and
spec-length EMAs.
"""
from repro.serving.engine import ServingEngine  # noqa: F401
from repro.serving.kv_pool import KVPagePool, PagePoolError  # noqa: F401
from repro.serving.sampler import Sampler, SamplerConfig  # noqa: F401
from repro.serving.scheduler import Request, Scheduler  # noqa: F401
