"""Serving: continuous batching over the shared compiled hot paths.

``ServingEngine`` drives a fixed decode batch through the SAME fused
whole-stack step / speculative window programs the rotary engine compiles
(donated KV, ragged per-row lengths); admission prefills whole groups
through one shared compiled bucketed program and splices rows into the live
batch KV. ``Scheduler`` owns admission (deadline feasibility from learned
prefill/decode rates, power-of-two prefill buckets) and the per-row
speculative-length policy. ``Sampler`` is host-side numpy (keeps the
compiled step deterministic and donation-friendly) and carries the
speculative ACCEPT rules.

Exactness contract: throughput serving drops missed experts in-step
(counted, rotation corrects the NEXT step) — it trades the rotary engine's
bit-exactness for zero replay stalls; everything else is exact: ragged
batching and KV splicing emit the same per-request tokens as running each
request alone, bucketed admission matches batch-1 prefills row for row, and
speculative ticks commit only tokens a sequential tick would have emitted
(per-row KV rollback). Telemetry→host transitions: the tick's ``route_*``
aux + on-device ``demand_next`` feed
``RotaryResidencyManager.rotate_from_telemetry`` (windows:
``rotate_window_from_telemetry`` with per-row accepted counts, so rejected
positions never pollute the predictor EMA or the hit/miss accounting);
measured prefill tok/s and accept rates feed the scheduler's admission and
spec-length EMAs.
"""
from repro.serving.engine import ServingEngine  # noqa: F401
from repro.serving.sampler import Sampler, SamplerConfig  # noqa: F401
from repro.serving.scheduler import Request, Scheduler  # noqa: F401
