"""Continuous-batching serving engine over the compiled whole-model step.

A fixed decode batch of ``num_slots`` rows runs one compiled ``decode_model``
step per tick; rows are claimed/freed by the scheduler as requests arrive and
finish (per-row ``lengths`` make the ragged batch exact). New requests are
prefilled as batch-1 at the next power-of-two length bucket and their KV rows
spliced into the live state.

Rotary residency in this path rotates slots BETWEEN steps from the previous
step's routing telemetry (route_* aux): the compiled step computes resident
experts via slot LUT; missed experts are dropped in-step, counted, and the
rotation corrects residency for the following step. The per-layer exact path
(host-corrected misses) lives in ``repro.core.engine`` — this engine is the
throughput-oriented compiled half.

Device-residency hot-path details shared with the rotary engine: the compiled
step IS the engine's fused whole-stack step (``build_fused_decode_step``) —
KV state donated, demand prediction on-device — the stacked residency pytree
handed to it is CACHED per segment (rebuilt only for segments whose slots/LUT
actually rotated — see ``RotaryResidencyManager.stacked_residency``), the
per-layer LUTs are persistent device arrays patched in place, the routing /
demand telemetry is pulled with async D2H copies issued before sampling, and
the between-step rotation is the manager's shared ``rotate_from_telemetry``
(one batched donated scatter per weight tensor per rotated layer).
"""
from __future__ import annotations

import math
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig, ResidencyConfig
from repro.core.engine import (
    build_fused_decode_step,
    concat_route_telemetry,
    moe_segments,
)
from repro.core.predictor import DemandPredictor
from repro.core.residency import RotaryResidencyManager
from repro.core.stats import EngineStats
from repro.models import transformer as tfm
from repro.models.transformer import Runtime
from repro.serving.sampler import Sampler, SamplerConfig
from repro.serving.scheduler import Request, Scheduler


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        rt: Optional[Runtime] = None,
        num_slots: int = 4,
        residency: Optional[ResidencyConfig] = None,
        sampler: Optional[SamplerConfig] = None,
        eos: Optional[int] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.rt = rt or Runtime(cache_len=1024)
        self.batch = num_slots
        self.eos = eos
        self.scheduler = Scheduler(num_slots)
        self.sampler = Sampler(sampler or SamplerConfig())
        self.stats = EngineStats()

        self.state = tfm.zero_state(cfg, self.batch, self.rt.cache_len)
        self.lengths = np.zeros((self.batch,), np.int32)
        self.next_token = np.zeros((self.batch,), np.int32)
        self.active = np.zeros((self.batch,), bool)

        # --- residency (MoE archs only) --------------------------------
        self.res_mgr: Optional[RotaryResidencyManager] = None
        self.predictor: Optional[DemandPredictor] = None
        if residency is not None and residency.mode != "full" and cfg.has_moe:
            host_experts, routers = [], []
            for si, (unit, reps) in enumerate(cfg.segments):
                for r in range(reps):
                    for pi, kind in enumerate(unit):
                        if kind != "attn_moe":
                            continue
                        p_l = jax.tree.map(
                            lambda a, r=r: a[r], params["segments"][si][pi]
                        )
                        host_experts.append(
                            {n: np.asarray(w, np.float32)
                             for n, w in p_l["moe"]["experts"].items()}
                        )
                        routers.append(np.asarray(p_l["moe"]["router"], np.float32))
            self.res_mgr = RotaryResidencyManager(
                cfg, residency, host_experts,
                batch=self.batch, cache_len=self.rt.cache_len, stats=self.stats,
            )
            self.predictor = DemandPredictor(routers, ema=residency.predictor_ema)
            for li in range(len(host_experts)):
                self.res_mgr.prepare_layer(li, self.predictor.smoothed[li])

        # --- compiled steps ---------------------------------------------
        # the tick shares the rotary engine's fused whole-stack step: KV state
        # donated (no per-tick cache copy), per-layer demand GEMM in-graph
        self._routers_next = None
        if self.res_mgr is not None:
            self.res_mgr.donate_buffers = True       # no snapshots span a tick
            self._routers_next = jnp.asarray(self.predictor.next_layer_routers())
        self._decode = build_fused_decode_step(
            cfg, self.rt, with_demand=self.res_mgr is not None, donate_state=True,
            keep_replay_anchor=False,     # no replay path: drop route_x outputs
        )
        self._moe_segs = moe_segments(cfg)
        self._prefill_cache: Dict[int, Any] = {}

    # ------------------------------------------------------------------
    def _prefill_one(self, prompt: np.ndarray) -> Any:
        """Batch-1 prefill at a power-of-two length bucket (right-padded;
        decode masks cache positions >= true length so pads never score).
        Recurrent archs use exact lengths — pads would pollute the state."""
        s = len(prompt)
        has_recurrence = any(
            k in ("mlstm", "slstm", "rglru") for k in self.cfg.layer_kinds
        )
        bucket = s if has_recurrence else min(
            max(16, 1 << (s - 1).bit_length()), self.rt.cache_len
        )
        cold = bucket not in self._prefill_cache
        if cold:
            def fn(params, tokens, last):
                return tfm.prefill_model(
                    self.cfg, params, tokens, self.rt, last_index=last
                )

            self._prefill_cache[bucket] = jax.jit(fn)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :s] = prompt
        t0 = time.perf_counter()
        logits, state = self._prefill_cache[bucket](
            self.params, jnp.asarray(padded), jnp.asarray([s - 1], jnp.int32)
        )
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        if not cold and dt > 0:
            # steady-state sample only — a cold bucket's wall time is
            # dominated by trace/compile and would poison the admission EMA
            self.scheduler.observe_prefill_rate(s / dt)
        return logits, state, s

    def _splice_row(self, slot: int, row_state: Any) -> None:
        """Insert a batch-1 prefill state into batch row ``slot``."""
        def splice(dst, src):
            return dst.at[:, slot].set(src[:, 0])

        self.state = jax.tree.map(splice, self.state, row_state)

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int,
               deadline_s: Optional[float] = None) -> Request:
        return self.scheduler.submit(prompt, max_new, time.perf_counter(), deadline_s)

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        """Drive until all submitted work completes. Returns completed requests."""
        ticks = 0
        t0 = time.perf_counter()
        while not self.scheduler.idle and ticks < max_ticks:
            now = time.perf_counter()
            for req in self.scheduler.admit(now):
                logits, row_state, true_len = self._prefill_one(req.prompt)
                self._splice_row(req.slot, row_state)
                self.lengths[req.slot] = true_len
                tok = int(self.sampler(np.asarray(logits))[0])
                self.next_token[req.slot] = tok
                self.active[req.slot] = True
                self.stats.tokens += len(req.prompt)
                # first sampled token may already finish the request
                self.scheduler.step_done(req.slot, tok, now, self.eos)
                if req.done:
                    self.active[req.slot] = False
            if not self.scheduler.running:
                ticks += 1
                continue
            residency = None
            if self.res_mgr is not None:
                residency = self.res_mgr.stacked_residency()
            logits, self.state, aux = self._decode(
                self.params,
                self._routers_next,
                jnp.asarray(self.next_token),
                self.state,
                jnp.asarray(self.lengths),
                residency,
            )
            self.stats.device_dispatches += 1
            if self.res_mgr is not None:
                # start D2H copies of the routing/demand telemetry now: they
                # complete while the host samples, so the between-step rotation
                # reads below never drain the device queue
                for k, v in aux.items():
                    if k.startswith("route_") or k == "demand_next":
                        v.copy_to_host_async()
                        self.stats.overlapped_pulls += 1
            logits_np = np.asarray(logits)
            self.stats.sync_pulls += 1
            self.lengths += self.active
            toks = self.sampler(logits_np)
            now = time.perf_counter()
            for slot in list(self.scheduler.running.keys()):
                self.next_token[slot] = toks[slot]
                self.scheduler.step_done(slot, toks[slot], now, self.eos)
                if slot in self.scheduler.free_slots:
                    self.active[slot] = False
            self.stats.steps += 1
            self.stats.tokens += int(self.active.sum())
            if self.res_mgr is not None:
                self._rotate_from_aux(aux)
            ticks += 1
        self.stats.wall_s += time.perf_counter() - t0
        if self.stats.wall_s > 0 and self.stats.steps:
            self.scheduler.observe_rate(self.stats.steps / self.stats.wall_s)
        return self.scheduler.completed

    # ------------------------------------------------------------------
    def _rotate_from_aux(self, aux: Dict[str, jax.Array]) -> None:
        """Between-step rotation from routing telemetry: assemble the step's
        [L, ...] arrays and hand off to the manager's shared helper (the
        demand GEMM already ran on device — ``aux["demand_next"]``)."""
        self.res_mgr.rotate_from_telemetry(
            self.predictor,
            concat_route_telemetry(aux, "ids", self._moe_segs),
            concat_route_telemetry(aux, "weights", self._moe_segs),
            concat_route_telemetry(aux, "miss", self._moe_segs),
            np.asarray(aux["demand_next"]),
        )
