"""Request-level continuous batching over a paged KV pool.

The serving engine runs every decode tick as ONE compiled window launch over
whatever requests are live *right now*: rows join and leave the window
BETWEEN launches. A finishing request frees its KV pages immediately
(`repro.serving.kv_pool.KVPagePool`); the next queued request prefills into
the freed pages and joins the very next window — no group drain, no idle KV.
Admission is driven by page-pool pressure (worst-case page reservations at
admit; lazy physical allocation that therefore never fails mid-flight), not
batch geometry.

KV lives in SHARED paged planes (`tfm.paged_zero_state`): per layer, one
[reps, num_pages + 1, page_size, Hkv, dh] plane addressed through per-row
page tables (physical page 0 is pad/scratch). `attention_decode(page_table=
...)` gathers each row's logical view back to the contiguous layout before
scoring, so paged decode is BITWISE equal to a contiguous cache holding the
same logical KV — the exactness contract (every request's tokens identical to
a batch-1 run of that request alone) survives the refactor, with rotation /
prediction telemetry masked per committed row (``accepted=[B]``) exactly as
the speculative window path does.

Compile-cache story: programs are keyed on WINDOW GEOMETRY, not live-row
count — the live rows pack into a power-of-two rows bucket (pad rows carry
all-zero page tables, write into the scratch page, and are masked everywhere
with ``accepted = 0``), so at most log2(num_slots)+1 row shapes exist per
window length K, however requests churn. Speculation, bucketed admission
prefill, per-row accept/rollback, and deadline handling all carry over; a
size-1 window IS the plain tick (same program family, same telemetry path).

Recurrent archs (and ``paged=False``) keep the previous group-tick path: a
fixed contiguous decode batch stepped via ``build_fused_decode_step``, rows
claimed/freed by the scheduler — recurrent state is per-row by construction
and cannot live in a shared page plane.

Device-residency hot-path details shared with the rotary engine: the
compiled window IS the engine's fused whole-stack program
(``build_fused_window_step``) — KV pool donated, demand prediction on-device
— the stacked residency pytree is CACHED per segment, per-layer LUTs are
persistent device arrays patched in place, routing / demand telemetry rides
async D2H copies issued before the draft pull, and the between-window
rotation is the manager's ``rotate_window_from_telemetry`` with per-row
accepted counts masking pad rows and rejected suffixes.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig, ResidencyConfig
from repro.core.engine import (
    build_fused_decode_step,
    build_window_fns,
    concat_route_telemetry,
    moe_segments,
)
from repro.core.predictor import DemandPredictor
from repro.core.residency import RotaryResidencyManager
from repro.core.stats import EngineStats
from repro.models import transformer as tfm
from repro.models import sampling as sampling_mod
from repro.models.sampling import SampleParams
from repro.models.transformer import Runtime
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import resolve_tracer
from repro.serving.kv_pool import KVPagePool
from repro.serving.sampler import Sampler, SamplerConfig, stochastic_accept
from repro.serving.scheduler import Request, Scheduler

_KV_ONLY_KINDS = ("attn_mlp", "attn_moe", "local_attn")


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        rt: Optional[Runtime] = None,
        num_slots: int = 4,
        residency: Optional[ResidencyConfig] = None,
        sampler: Optional[SamplerConfig] = None,
        eos: Optional[int] = None,
        spec_cap: int = 4,
        bucketed_prefill: bool = True,
        paged: Optional[bool] = None,
        kv_page_size: int = 16,
        kv_pages: Optional[int] = None,
        prefetch: bool = False,
        trace=None,
    ):
        """``spec_cap`` bounds per-row speculative decode: when sampling is
        greedy and the stack is KV-cache-only, windows self-draft up to the
        SCHEDULER's learned per-row speculative lengths (``spec_cap=1``
        disables speculation).

        ``bucketed_prefill`` routes each tick's admitted requests through ONE
        shared compiled prefill program at the scheduler-chosen power-of-two
        bucket (rows padded to the power-of-two cover of the group size,
        per-row ``last_index`` for the ragged lengths) instead of one batch-1
        program launch per request. Per-row outputs are identical to the
        batch-1 path. Recurrent archs need exact-length prefills and keep the
        batch-1 path regardless.

        ``paged`` selects the continuous-batching paged KV pool (module
        docstring); default: on for KV-cache-only stacks, off (group-tick
        path) for recurrent archs. ``kv_page_size`` is the positions-per-page
        granularity (clamped to the largest divisor of the per-row cache
        capacity); ``kv_pages`` overrides the pool size in pages (default
        ``num_slots`` full rows — the same KV memory the contiguous batch
        held, now fluid across requests).

        ``prefetch`` enables asynchronous predictive expert prefetch on the
        paged tick: while a window launch is in flight, the predicted next
        boundary's uploads land in the slot stores' SHADOW generation and the
        tick boundary becomes confirm/correct/flip
        (``RotaryResidencyManager.begin_prefetch`` / ``_commit_layer``).
        Unlike the rotary engine, serving enables it with steering margin 0:
        the paged tick has no replay path (a missed position commits with
        the expert dropped), so transitions must stay byte-identical to the
        synchronous baseline for outputs to stay byte-identical — only the
        overlap is bought. Requires the paged pool and a rotating residency
        manager.

        ``trace`` (a ``repro.obs.Tracer``) records launch/pull/rotation/
        prefetch spans plus one lane per request (queued → prefill → decode
        → finish) and the KV pool's page events; ``None``/disabled leaves
        every hot path untouched (all emission sites are guarded)."""
        self.cfg = cfg
        self.params = params
        self.rt = rt or Runtime(cache_len=1024)
        self.batch = num_slots
        self.eos = eos
        self.sampler = Sampler(sampler or SamplerConfig())
        self.stats = EngineStats()
        self._tr = resolve_tracer(trace)
        self.tracer = self._tr
        self.metrics = MetricsRegistry()
        kv_only = all(k in _KV_ONLY_KINDS for k in cfg.layer_kinds)
        if paged is None:
            paged = kv_only
        if paged and not kv_only:
            raise ValueError(
                "paged KV pool requires a KV-cache-only stack; recurrent "
                f"archs keep the group-tick path ({cfg.layer_kinds})"
            )
        self._paged = paged
        # sampled (temperature > 0) serving draws on-device with per-request
        # position-keyed PRNG streams (repro.models.sampling) on the paged
        # path; the group-tick path keeps the host Sampler
        self._sampled = self.sampler.cfg.temperature > 0.0
        self._sample_params = None
        self._sample_fn = None
        self._accept_rng = None
        self._req_keys: Dict[int, np.ndarray] = {}   # uid -> [2] uint32 base key
        if self._sampled:
            c = self.sampler.cfg
            self._sample_params = SampleParams(
                float(c.temperature), int(c.top_k), float(c.top_p)
            )
            self._sample_fn = sampling_mod.build_sample_fn(self._sample_params)
            self._accept_rng = np.random.default_rng(c.seed)
        # speculative windows need KV-only state (rollback restores cache
        # slots; a recurrent update is destructive). Sampled speculation runs
        # the stochastic accept rule over the window's sample_probs telemetry
        # — paged path only (the group tick draws through the host Sampler)
        self._spec_ok = (
            spec_cap > 1 and kv_only and (not self._sampled or paged)
        )
        from repro.models import attention as attn_mod

        cap = attn_mod._cache_capacity(cfg.attention, self.rt.cache_len)
        self._spec_cap_eff = 1
        if self._spec_ok:
            self._spec_cap_eff = max(1, min(spec_cap, cap))
            self._spec_ok = self._spec_cap_eff > 1
        self.scheduler = Scheduler(
            num_slots, spec_cap=self._spec_cap_eff,
            max_prompt_len=self.rt.cache_len,
        )

        self.lengths = np.zeros((self.batch,), np.int32)
        self.next_token = np.zeros((self.batch,), np.int32)
        self.active = np.zeros((self.batch,), bool)

        # --- KV: paged pool (continuous batching) or contiguous batch ----
        self.pool: Optional[KVPagePool] = None
        self.state = None                    # contiguous [B, cap, ...] caches
        self.pool_state = None               # shared paged planes
        if self._paged:
            page_size = max(1, min(kv_page_size, cap))
            while cap % page_size:
                page_size -= 1               # largest divisor <= kv_page_size
            row_pages = cap // page_size
            pages = kv_pages if kv_pages is not None else num_slots * row_pages
            if pages < row_pages:
                raise ValueError(
                    f"kv_pages={pages} cannot hold one full row "
                    f"({row_pages} pages of {page_size})"
                )
            self.pool = KVPagePool(pages, page_size, row_pages,
                                   tracer=self._tr)
            # physical plane index 0 is the scratch page pad rows write into
            self.pool_state = tfm.paged_zero_state(cfg, pages + 1, page_size)
        else:
            self.state = tfm.zero_state(cfg, self.batch, self.rt.cache_len)

        # --- residency (MoE archs only) --------------------------------
        self.res_mgr: Optional[RotaryResidencyManager] = None
        self.predictor: Optional[DemandPredictor] = None
        if residency is not None and residency.mode != "full" and cfg.has_moe:
            host_experts, routers = [], []
            for si, (unit, reps) in enumerate(cfg.segments):
                for r in range(reps):
                    for pi, kind in enumerate(unit):
                        if kind != "attn_moe":
                            continue
                        p_l = jax.tree.map(
                            lambda a, r=r: a[r], params["segments"][si][pi]
                        )
                        host_experts.append(
                            {n: np.asarray(w, np.float32)
                             for n, w in p_l["moe"]["experts"].items()}
                        )
                        routers.append(np.asarray(p_l["moe"]["router"], np.float32))
            # feasibility prices KV bytes: the pool holds pages-worth of KV,
            # not num_slots full rows, so report the pool-equivalent batch
            batch_eff = self.batch
            if self.pool is not None:
                batch_eff = max(
                    1, -(-self.pool.num_pages * self.pool.page_size // cap)
                )
            self.res_mgr = RotaryResidencyManager(
                cfg, residency, host_experts,
                batch=batch_eff, cache_len=self.rt.cache_len, stats=self.stats,
                tracer=self._tr, metrics=self.metrics,
            )
            self.predictor = DemandPredictor(routers, ema=residency.predictor_ema)
            for li in range(len(host_experts)):
                self.res_mgr.prepare_layer(li, self.predictor.smoothed[li])

        # --- compiled steps ---------------------------------------------
        # ticks share the rotary engine's fused whole-stack programs: KV state
        # donated (no per-tick cache copy), per-layer demand GEMM in-graph.
        # Paged mode runs EVERY tick through the window family (a plain tick
        # is a size-1 window), so the single-token step is only built for the
        # group-tick path.
        self._routers_next = None
        if self.res_mgr is not None:
            self.res_mgr.donate_buffers = True       # no snapshots span a tick
            self._routers_next = jnp.asarray(self.predictor.next_layer_routers())
        self.prefetch = bool(prefetch)
        if self.prefetch:
            if self.res_mgr is None:
                raise ValueError(
                    "prefetch=True needs a rotating residency manager: pass a "
                    "non-full ResidencyConfig on an MoE architecture (full "
                    "residency never rotates, so there is nothing to prefetch)"
                )
            if not self._paged:
                raise ValueError(
                    "prefetch=True rides the paged continuous-batching tick; "
                    "the group-tick path rotates synchronously"
                )
            if any(
                getattr(p, "needs_sync_resolve", False)
                for p in self.res_mgr.policies
            ):
                raise ValueError(
                    "prefetch=True is incompatible with reactive (LRU-style) "
                    "policies: their mid-step blocking loads leave no "
                    "boundary to flip at"
                )
            # margin 0: see the docstring — serving has no replay path, so
            # the transition SEQUENCE must match the synchronous baseline
            self.res_mgr.enable_prefetch(margin=0)
        self._decode = None
        if not self._paged:
            self._decode = build_fused_decode_step(
                cfg, self.rt, with_demand=self.res_mgr is not None,
                donate_state=True,
                keep_replay_anchor=False,  # no replay path: drop route_x outputs
            )
        self._moe_segs = moe_segments(cfg)
        self._prefill_cache: Dict[int, Any] = {}
        self._bucket_prefill_cache: Dict[int, Any] = {}
        self._window_cache: Dict[int, Any] = {}
        self._paged_splice_cache: Dict[int, Any] = {}
        self._has_recurrence = any(
            k in ("mlstm", "slstm", "rglru") for k in cfg.layer_kinds
        )
        self._bucketed_prefill = bucketed_prefill and not self._has_recurrence

    def _window_fns(self, k: int):
        """Compiled (window step, KV snapshot, KV rollback) for window size
        ``k`` — the rotary engine's speculative triple, minus the replay path
        (so the window drops the ``route_x`` anchors). Sampled engines bake
        their warp params into the window (drafting becomes an on-device
        position-keyed draw). Paged mode keys its whole compile cache here:
        (K, rows bucket) geometry, never live-row count."""
        fns = self._window_cache.get(k)
        if fns is None:
            fns = build_window_fns(
                self.cfg, self.rt, k,
                with_demand=self.res_mgr is not None,
                keep_replay_anchor=False,
                sample=self._sample_params,
            )
            self._window_cache[k] = fns
        return fns

    def _request_key(self, req: Request) -> np.ndarray:
        """[2] uint32 PRNG base key for one request — a pure function of the
        request's seed (uid/slot/batch-independent), so its sampled stream is
        identical alone, mid-CB-window, or across prefetch relaunches."""
        key = self._req_keys.get(req.uid)
        if key is None:
            seed = req.seed if req.seed is not None else self.sampler.cfg.seed
            key = np.asarray(sampling_mod.request_key(int(seed)))
            self._req_keys[req.uid] = key
        return key

    # ------------------------------------------------------------------
    def _prefill_one(self, prompt: np.ndarray) -> Any:
        """Batch-1 prefill at a power-of-two length bucket (right-padded;
        decode masks cache positions >= true length so pads never score).
        Recurrent archs use exact lengths — pads would pollute the state."""
        s = len(prompt)
        bucket = s if self._has_recurrence else Scheduler.prefill_bucket(
            [s], self.rt.cache_len
        )
        cold = bucket not in self._prefill_cache
        if cold:
            def fn(params, tokens, last):
                return tfm.prefill_model(
                    self.cfg, params, tokens, self.rt, last_index=last
                )

            self._prefill_cache[bucket] = jax.jit(fn)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :s] = prompt
        t0 = time.perf_counter()
        logits, state = self._prefill_cache[bucket](
            self.params, jnp.asarray(padded), jnp.asarray([s - 1], jnp.int32)
        )
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        if not cold and dt > 0:
            # steady-state sample only — a cold bucket's wall time is
            # dominated by trace/compile and would poison the admission EMA
            self.scheduler.observe_prefill_rate(s / dt)
        return logits, state, s

    def _prefill_bucketed(self, admitted: List[Request]) -> List[Any]:
        """Prefill one admission group through the SHARED compiled bucketed
        program: the scheduler picks the power-of-two bucket covering every
        admitted prompt, the rows pad to the power-of-two cover of the group
        size (compile cache keyed on (bucket, rows) — at most log2(batch)
        row shapes per bucket, and a single admission doesn't pay the whole
        batch's worth of pad-row prefill work or depress the admission-rate
        EMA), and ONE program launch scans every row through exactly the
        per-row computation ``_prefill_one`` runs — per-row outputs match
        the batch-1 splice-in path. Rows splice into the live KV (contiguous
        row or allocated pages) with the ragged machinery (per-row
        ``last_index`` / ``lengths``).

        Returns [(request, logits [1, V], row_state)] per admitted request.
        """
        lens = [len(r.prompt) for r in admitted]
        bucket = Scheduler.prefill_bucket(lens, self.rt.cache_len)
        rows = min(self.batch, 1 << (len(admitted) - 1).bit_length())
        key = (bucket, rows)
        cold = key not in self._bucket_prefill_cache
        if cold:
            def fn(params, tokens, last):          # [rows, bucket], [rows]
                def row(_, xs):
                    tok, li = xs
                    logits, state = tfm.prefill_model(
                        self.cfg, params, tok[None], self.rt,
                        last_index=li[None],
                    )
                    return None, (logits[0], state)

                _, (logits, states) = jax.lax.scan(row, None, (tokens, last))
                return logits, states

            self._bucket_prefill_cache[key] = jax.jit(fn)
        padded = np.zeros((rows, bucket), np.int32)
        last = np.zeros((rows,), np.int32)
        for i, req in enumerate(admitted):
            padded[i, : len(req.prompt)] = req.prompt
            last[i] = len(req.prompt) - 1
        t0 = time.perf_counter()
        logits, states = self._bucket_prefill_cache[key](
            self.params, jnp.asarray(padded), jnp.asarray(last)
        )
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        if not cold and dt > 0:
            # steady-state sample only — a cold bucket's wall time is
            # dominated by trace/compile and would poison the admission EMA
            self.scheduler.observe_prefill_rate(sum(lens) / dt)
        logits_np = np.asarray(logits)
        out = []
        for i, req in enumerate(admitted):
            row_state = jax.tree.map(lambda a, i=i: a[i], states)
            out.append((req, logits_np[i : i + 1], row_state))
        return out

    def _prefill_admitted(self, admitted: List[Request]) -> List[Any]:
        """Admission prefill: the shared bucketed program by default, batch-1
        programs for recurrent archs / ``bucketed_prefill=False``."""
        if not admitted:
            return []
        if self._bucketed_prefill:
            return self._prefill_bucketed(admitted)
        out = []
        for req in admitted:
            logits, row_state, _ = self._prefill_one(req.prompt)
            out.append((req, logits, row_state))
        return out

    def _splice_row(self, slot: int, row_state: Any) -> None:
        """Insert a batch-1 prefill state into contiguous batch row ``slot``."""
        def splice(dst, src):
            return dst.at[:, slot].set(src[:, 0])

        self.state = jax.tree.map(splice, self.state, row_state)

    def _paged_splice_fn(self, n: int):
        """Compiled ``n``-page join splice (cache keyed on page count —
        request lengths bucket to at most row_pages shapes)."""
        fn = self._paged_splice_cache.get(n)
        if fn is None:
            ps = self.pool.page_size

            def splice(pool_state, row_state, pg):
                def one(dst, src):
                    reps = src.shape[0]
                    blk = src[:, 0, : n * ps].reshape(
                        (reps, n, ps) + src.shape[3:]
                    )
                    return dst.at[:, pg].set(blk)

                return jax.tree.map(one, pool_state, row_state)

            fn = jax.jit(splice, donate_argnums=(0,))
            self._paged_splice_cache[n] = fn
        return fn

    def _splice_row_paged(self, uid: int, row_state: Any) -> None:
        """Insert a batch-1 prefill state's KV prefix into the pages request
        ``uid`` owns: ONE donated scatter over every pool plane per join."""
        pages = self.pool.table(uid)
        self.pool_state = self._paged_splice_fn(len(pages))(
            self.pool_state, row_state, jnp.asarray(pages, jnp.int32)
        )
        self.stats.device_dispatches += 1

    def _account_pages(self, grew: int) -> None:
        if grew:
            self.stats.kv_pages_allocated += grew
            self.stats.kv_pages_hwm = max(
                self.stats.kv_pages_hwm, self.pool.pages_in_use
            )

    def _release_request(self, req: Request) -> None:
        """A finished row leaves the window: its pages return to the pool NOW
        and the next queued request prefills into them at the next tick —
        the continuous-batching lever the group tick lacked."""
        tr = self._tr
        if tr is not None:
            # lane phase 3: first token -> finished (the decode stretch)
            t1 = req.finished_at or time.perf_counter()
            if req.first_token_at:
                tr.complete("decode", "request", req.first_token_at, t1,
                            lane=req.uid, args={"tokens": len(req.output)})
            tr.instant("finish", "request", lane=req.uid,
                       args={"tokens": len(req.output)})
        self._req_keys.pop(req.uid, None)
        if self.pool is not None:
            self.stats.kv_pages_released += self.pool.release(req.uid)

    # ------------------------------------------------------------------
    def warmup(self, max_prompt_len: int = 16) -> int:
        """Pre-compile the serving program family for a workload envelope
        (prompts up to ``max_prompt_len``): admission-prefill buckets x
        power-of-two group sizes, window K x rows buckets (paged) or the
        fixed-batch step/window family (group tick), and the paged splice
        programs for every reachable page count. Call BEFORE submitting
        traffic — first-request latency then measures serving, not tracing.

        Warmup launches write only throwaway positions (the paged programs
        write the scratch page; the group-tick programs touch row positions a
        request's splice fully overwrites) and touch no host bookkeeping or
        stats. Returns the number of programs compiled."""
        compiled = 0
        mp = max(1, min(max_prompt_len, self.rt.cache_len))
        # admission prefill: every power-of-two bucket the envelope reaches,
        # at every power-of-two admission group size (recurrent archs prefill
        # at exact lengths — nothing reusable to pre-compile)
        if not self._has_recurrence:
            buckets = sorted({
                Scheduler.prefill_bucket([l], self.rt.cache_len)
                for l in range(1, mp + 1)
            })
            if self._bucketed_prefill:
                g = 1
                while g <= self.batch:
                    for b in buckets:
                        if (b, g) not in self._bucket_prefill_cache:
                            self._prefill_bucketed([
                                Request(-1 - i, np.zeros((b,), np.int32), 0)
                                for i in range(g)
                            ])
                            compiled += 1
                    g *= 2
            else:
                for b in buckets:
                    if b not in self._prefill_cache:
                        self._prefill_one(np.zeros((b,), np.int32))
                        compiled += 1
        ks = range(1, self._spec_cap_eff + 1) if self._spec_ok else (1,)
        residency = None
        if self.res_mgr is not None:
            residency = self.res_mgr.stacked_residency()
        if self._paged:
            for k in ks:
                step_fn, snap_fn, roll_fn = self._window_fns(k)
                rows = 1
                while rows <= self.batch:
                    pt = jnp.zeros((rows, self.pool.row_pages), jnp.int32)
                    tok = jnp.zeros((rows,), jnp.int32)
                    lens = jnp.zeros((rows,), jnp.int32)
                    keep = jnp.zeros((rows,), jnp.int32)
                    saved = None
                    if self.res_mgr is not None:
                        saved = snap_fn(self.pool_state, lens, pt)
                        compiled += 1
                    out = step_fn(
                        self.params, self._routers_next, tok,
                        self.pool_state, lens, residency, pt,
                    )
                    self.pool_state = out[2]
                    compiled += 1
                    if saved is not None:
                        self.pool_state = roll_fn(
                            self.pool_state, saved, lens, keep, pt
                        )
                        compiled += 1
                    rows *= 2
            for n in sorted({self.pool.pages_for(l) for l in range(1, mp + 1)}):
                if n not in self._paged_splice_cache:
                    fn = self._paged_splice_fn(n)
                    self.pool_state = fn(
                        self.pool_state,
                        tfm.zero_state(self.cfg, 1, self.rt.cache_len),
                        jnp.zeros((n,), jnp.int32),
                    )
                    compiled += 1
            jax.block_until_ready(self.pool_state)
            return compiled
        tok = jnp.zeros((self.batch,), jnp.int32)
        lens = jnp.zeros((self.batch,), jnp.int32)
        keep = jnp.zeros((self.batch,), jnp.int32)
        out = self._decode(
            self.params, self._routers_next, tok, self.state, lens, residency
        )
        self.state = out[1]
        compiled += 1
        for k in ks:
            if k == 1:
                continue
            step_fn, snap_fn, roll_fn = self._window_fns(k)
            saved = None
            if self.res_mgr is not None:
                saved = snap_fn(self.state, lens)
                compiled += 1
            out = step_fn(
                self.params, self._routers_next, tok, self.state, lens,
                residency,
            )
            self.state = out[2]
            compiled += 1
            if saved is not None:
                self.state = roll_fn(self.state, saved, lens, keep)
                compiled += 1
        jax.block_until_ready(self.state)
        return compiled

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int,
               deadline_s: Optional[float] = None,
               seed: Optional[int] = None) -> Request:
        """``seed`` fixes this request's sampled PRNG stream (defaults to the
        engine sampler's seed); greedy engines ignore it."""
        prompt = np.asarray(prompt, np.int32)
        if self.pool is not None and len(prompt) > self.rt.cache_len:
            # up-front pool-capacity validation: this request could NEVER be
            # admitted, so fail loudly instead of queue-rejecting downstream
            raise ValueError(
                f"prompt length {len(prompt)} exceeds the per-request KV "
                f"capacity {self.rt.cache_len} "
                f"({self.pool.row_pages} pages x {self.pool.page_size} "
                f"positions at full residency)"
            )
        return self.scheduler.submit(
            prompt, max_new, time.perf_counter(), deadline_s, seed=seed
        )

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        """Drive until all submitted work completes. Returns completed requests."""
        ticks = 0
        t0 = time.perf_counter()
        while not self.scheduler.idle and ticks < max_ticks:
            self.tick()
            ticks += 1
        self.stats.wall_s += time.perf_counter() - t0
        if self.stats.wall_s > 0 and self.stats.steps:
            self.scheduler.observe_rate(self.stats.steps / self.stats.wall_s)
        return self.scheduler.completed

    def tick(self) -> None:
        """One serving iteration: request-level joins (admission against pool
        pressure, prefill into owned pages), then ONE decode launch over the
        live rows. Public so arrival-driven loops (``launch/serve.py
        --arrival-rate``, ``benchmarks/serving_load.py``) can interleave
        submissions with ticks on the wall clock."""
        now = time.perf_counter()
        tr = self._tr
        admitted = self.scheduler.admit(now, pool=self.pool)
        if tr is not None:
            for req in admitted:
                # lane phase 1: submission -> admission (queueing delay)
                tr.complete("queued", "request", req.submitted_at, now,
                            lane=req.uid, args={"prompt": len(req.prompt)})
        for req, logits, row_state in self._prefill_admitted(admitted):
            if self.pool is not None:
                self._account_pages(self.pool.ensure(req.uid, len(req.prompt)))
                self._splice_row_paged(req.uid, row_state)
            else:
                self._splice_row(req.slot, row_state)
            self.lengths[req.slot] = len(req.prompt)
            if self._sampled and self._paged:
                # per-request position-keyed device draw: the first token is
                # keyed at the last PROMPT position, so it is identical
                # whenever/wherever this request is admitted
                tok = int(np.asarray(self._sample_fn(
                    jnp.asarray(np.asarray(logits).reshape(1, -1)),
                    jnp.asarray(self._request_key(req))[None, :],
                    jnp.int32(len(req.prompt) - 1),
                ))[0])
            else:
                tok = int(self.sampler(np.asarray(logits))[0])
            self.next_token[req.slot] = tok
            self.active[req.slot] = True
            self.stats.tokens += len(req.prompt)
            # first sampled token may already finish the request
            self.scheduler.step_done(req.slot, tok, now, self.eos)
            if tr is not None:
                # lane phase 2: admission -> spliced + first token sampled
                tr.complete("prefill", "request", req.admitted_at,
                            time.perf_counter(), lane=req.uid,
                            args={"prompt": len(req.prompt)})
            if req.done:
                self.active[req.slot] = False
                self._release_request(req)
        if not self.scheduler.running:
            return
        if self._paged:
            self._tick_paged()
            return
        # group-tick path (recurrent archs / paged=False): per-row learned
        # speculative lengths — the tick self-drafts as far as the
        # slowest-adapting ACTIVE row allows (windows are batch-wide
        # programs; acceptance and KV rollback are per-row)
        k_tick = 1
        if self._spec_ok:
            k_tick = min(
                self.scheduler.spec_len(s) for s in self.scheduler.running
            )
            k_tick = max(1, min(k_tick, self._spec_cap_eff))
        if k_tick > 1:
            self._tick_window(k_tick)
        else:
            self._tick_single()

    # ------------------------------------------------------------------
    def _tick_paged(self) -> None:
        """One continuous-batching window over the paged pool.

        The live rows (whatever requests are running right now) pack into a
        power-of-two rows bucket and run ONE compiled window launch — pad
        rows carry all-zero page tables (writes land in the scratch page) and
        zero lengths/tokens, and are masked out of acceptance, rotation and
        the predictor EMA via ``accepted = 0``. Window length: 1 when
        speculation is off (a plain tick is a size-1 window; sampling at
        temperature > 0 draws from the window's f32 last-position logits,
        a lossless upcast), else the slowest live row's learned spec length.

        Per-row acceptance mirrors the group-tick window: commit up to (not
        past) the first residency miss, clamped >= 1 (serving drops missed
        experts in-step; no replay path); rejected suffixes roll the row's
        PAGES back via the paged snapshot/rollback and re-draft next window
        after rotation has corrected residency. Rows that finish mid-window
        release their pages before the next admission runs.
        """
        sch = self.scheduler
        live = [s for s in sorted(sch.running) if self.active[s]]
        if not live:
            return
        tr = self._tr
        t_tick = time.perf_counter()
        if tr is not None:
            tr.new_unit("tick")
        k = 1
        if self._spec_ok:
            k = min(sch.spec_len(s) for s in live)
            k = max(1, min(k, self._spec_cap_eff))
        # grow each live row's page table to cover the window's writes — the
        # admission reservation sized this worst-case, so ensure cannot fail
        for s in live:
            self._account_pages(
                self.pool.ensure(sch.running[s].uid, int(self.lengths[s]) + k)
            )
        rows = 1 << max(0, len(live) - 1).bit_length()   # pow2 bucket >= live
        pt = np.zeros((rows, self.pool.row_pages), np.int32)
        tok = np.zeros((rows,), np.int32)
        lens = np.zeros((rows,), np.int32)
        keys = None
        if self._sampled:
            keys_np = np.zeros((rows, 2), np.uint32)
        for i, s in enumerate(live):
            pt[i] = self.pool.table_array(sch.running[s].uid)
            tok[i] = self.next_token[s]
            lens[i] = self.lengths[s]
            if self._sampled:
                # request-intrinsic base keys: the row's draws depend only on
                # (its seed, its cache positions), never its slot or the
                # window's other occupants — CB streams == isolated streams
                keys_np[i] = self._request_key(sch.running[s])
        if self._sampled:
            keys = jnp.asarray(keys_np)
        if tr is not None:
            # every physical page this window will read/write, for the
            # auditor's use-after-release replay
            tr.instant("kv_use", "kv_pool", args={
                "pages": sorted({int(p) for row in pt[: len(live)]
                                 for p in row if p}),
                "rows": len(live),
            })
        step_fn, snap_fn, roll_fn = self._window_fns(k)
        residency = None
        if self.res_mgr is not None:
            residency = self.res_mgr.stacked_residency()
        pt_j = jnp.asarray(pt)
        lens_j = jnp.asarray(lens)
        saved = None
        if self.res_mgr is not None:
            # pre-window page contents: misses may reject per-row suffixes.
            # Dispatched BEFORE the donating window step, so it reads the
            # pre-window planes.
            saved = snap_fn(self.pool_state, lens_j, pt_j)
            self.stats.device_dispatches += 1
            if tr is not None:
                tr.instant("kv_snapshot", "kv_pool", args={"rows": len(live)})
        if tr is not None:
            t_launch = time.perf_counter()
        draft, last_logits, self.pool_state, aux = step_fn(
            self.params, self._routers_next, jnp.asarray(tok),
            self.pool_state, lens_j, residency, pt_j, rng_keys=keys,
        )
        if tr is not None:
            tr.complete("launch", "launch", t_launch, time.perf_counter(),
                        args={"rows": len(live), "k": k})
        self.stats.device_dispatches += 1
        self.stats.windows += 1
        if k > 1:
            self.stats.spec_windows += 1
        if self._sampled:
            # the per-position warped distributions (draft AND verifier for a
            # self-drafting window) ride the same async channel as the route
            # telemetry; the stochastic accept rule runs on them below
            aux["sample_probs"].copy_to_host_async()
            self.stats.overlapped_pulls += 1
        if self.res_mgr is not None:
            for key, v in aux.items():
                if key.startswith("route_") or key == "demand_next":
                    v.copy_to_host_async()
                    self.stats.overlapped_pulls += 1
            if self.prefetch:
                # window still in flight: ship the predicted boundary's
                # uploads into the shadow generation under it (request joins
                # between ticks just drift the shadow — the next commit's
                # catch-up copies reconcile it)
                self.res_mgr.begin_prefetch(self.predictor)
        if tr is not None:
            t_pull = time.perf_counter()
        # greedy AND sampled windows draft on-device: [K, rows], THE
        # queue-draining pull (sampled drafting happened in-graph from the
        # warped per-position distributions, keyed per request)
        draft_np = np.asarray(draft)
        if tr is not None:
            tr.complete("pull", "pull", t_pull, time.perf_counter(),
                        args={"rows": len(live), "k": k})
        self.stats.sync_pulls += 1
        accepted = np.zeros((rows,), np.int32)
        accepted[: len(live)] = k
        miss = None
        if self.res_mgr is not None:
            miss = concat_route_telemetry(aux, "miss", self._moe_segs, axis=1)
            step_row_miss = miss.any(axis=(1, 3))               # [K, rows]
            any_miss = step_row_miss.any(axis=0)
            first = np.where(any_miss, step_row_miss.argmax(axis=0), k)
            accepted[: len(live)] = np.maximum(first[: len(live)], 1)
            if tr is not None and bool(any_miss[: len(live)].any()):
                tr.instant("miss", "launch", args={
                    "rows": int(any_miss[: len(live)].sum()), "k": k,
                })
        if self._sampled:
            # stochastic accept over the pulled distributions. Self-drafting
            # passes the SAME array as p and q (ratio exactly 1), so the rule
            # accepts every position and the resample swap below is dormant —
            # it is the live plug point for a real p != q drafter, and it
            # composes with the miss cap by per-row min (a miss below the
            # first stochastic rejection wins, and then the swapped token is
            # never fed)
            probs = np.asarray(aux["sample_probs"])         # [K, rows, V]
            s_acc, resampled = stochastic_accept(
                draft_np, probs, probs, self._accept_rng
            )
            stoch = np.where(s_acc < k, s_acc + 1, k).astype(np.int32)
            rej = np.flatnonzero(s_acc < k)
            if rej.size:
                draft_np = draft_np.copy()      # device pull may be read-only
                draft_np[s_acc[rej], rej] = resampled[rej]
            accepted[: len(live)] = np.minimum(
                accepted[: len(live)], stoch[: len(live)]
            )
        # a finishing row commits only what it can still emit; ``offered`` =
        # drafts the row could have used (the accept-rate denominator, so
        # unused tail drafts don't read as rejections)
        offered: Dict[int, int] = {}
        for i, s in enumerate(live):
            req = sch.running[s]
            budget = req.max_new - len(req.output)
            offered[s] = min(k, budget)
            accepted[i] = min(int(accepted[i]), budget)
        if saved is not None and (accepted[: len(live)] < k).any():
            self.pool_state = roll_fn(
                self.pool_state, saved, lens_j, jnp.asarray(accepted), pt_j
            )
            self.stats.device_dispatches += 1
            if tr is not None:
                tr.instant("kv_rollback", "kv_pool", args={
                    "accepted": [int(a) for a in accepted[: len(live)]],
                })
        now = time.perf_counter()
        fed_total = 0
        k_committed = 0
        for i, s in enumerate(live):
            a = int(accepted[i])
            self.lengths[s] += a
            k_committed = max(k_committed, a)
            req = sch.running[s]
            fed = 0
            for j in range(a):
                t = int(draft_np[j, i])
                self.next_token[s] = t
                sch.step_done(s, t, now, self.eos)
                fed += 1
                if tr is not None:
                    tr.instant("token", "request", lane=req.uid,
                               args={"tok": t})
                if req.done:
                    self.active[s] = False
                    self._release_request(req)
                    break
            fed_total += fed
            sch.observe_accept(s, offered[s], fed)
            if k > 1:
                self.stats.drafted_tokens += offered[s]
                self.stats.accepted_tokens += fed
        # 'steps' = sequential decode positions the window committed
        self.stats.steps += k_committed
        self.stats.tokens += fed_total
        if self.res_mgr is not None:
            # pad rows and rejected suffixes are masked out of the hit/miss
            # accounting and the demand-predictor EMA by accepted=[rows]
            self.res_mgr.rotate_window_from_telemetry(
                self.predictor,
                concat_route_telemetry(aux, "ids", self._moe_segs, axis=1),
                concat_route_telemetry(aux, "weights", self._moe_segs, axis=1),
                miss,
                np.asarray(aux["demand_next"]),
                accepted=accepted,
            )
        self.metrics.histogram(
            "window_ms", "wall ms per serving window"
        ).observe((time.perf_counter() - t_tick) * 1e3)

    # ------------------------------------------------------------------
    def _tick_single(self) -> None:
        """Group-tick single-token decode (recurrent archs / ``paged=False``):
        one fused ``decode_model`` step over the fixed contiguous batch."""
        tr = self._tr
        if tr is not None:
            tr.new_unit("tick")
            t_launch = time.perf_counter()
        residency = None
        if self.res_mgr is not None:
            residency = self.res_mgr.stacked_residency()
        logits, self.state, aux = self._decode(
            self.params,
            self._routers_next,
            jnp.asarray(self.next_token),
            self.state,
            jnp.asarray(self.lengths),
            residency,
        )
        if tr is not None:
            tr.complete("launch", "launch", t_launch, time.perf_counter())
        self.stats.device_dispatches += 1
        if self.res_mgr is not None:
            # start D2H copies of the routing/demand telemetry now: they
            # complete while the host samples, so the between-step rotation
            # reads below never drain the device queue
            for k, v in aux.items():
                if k.startswith("route_") or k == "demand_next":
                    v.copy_to_host_async()
                    self.stats.overlapped_pulls += 1
        if tr is not None:
            t_pull = time.perf_counter()
        logits_np = np.asarray(logits)
        if tr is not None:
            tr.complete("pull", "pull", t_pull, time.perf_counter())
        self.stats.sync_pulls += 1
        self.lengths += self.active
        toks = self.sampler(logits_np)
        now = time.perf_counter()
        for slot in list(self.scheduler.running.keys()):
            self.next_token[slot] = toks[slot]
            self.scheduler.step_done(slot, toks[slot], now, self.eos)
            if slot in self.scheduler.free_slots:
                self.active[slot] = False
            if self._spec_ok:
                # a plain tick is a size-1 window that accepted its token:
                # feedback that lets a fresh row's spec length grow
                self.scheduler.observe_accept(slot, 1, 1)
        self.stats.steps += 1
        self.stats.tokens += int(self.active.sum())
        if self.res_mgr is not None:
            self._rotate_from_aux(aux)

    # ------------------------------------------------------------------
    def _tick_window(self, k: int) -> None:
        """One speculative group tick: ``k`` self-drafted positions for the
        whole contiguous batch in ONE compiled program.

        Per-row acceptance: a row commits drafted tokens up to (but not past)
        its first residency miss — clamped to >= 1, since position 0 is
        exactly what a plain tick would have computed (serving drops missed
        experts in-step; it has no replay path). Rejected positions roll the
        row's KV slots back (``tfm.rollback_kv_window`` takes per-row keep
        counts for the ragged batch) and re-draft next window, after rotation
        has had a chance to fix residency. Accept outcomes feed the
        scheduler's per-row speculative lengths.
        """
        tr = self._tr
        if tr is not None:
            tr.new_unit("tick")
        step_fn, snap_fn, roll_fn = self._window_fns(k)
        residency = None
        if self.res_mgr is not None:
            residency = self.res_mgr.stacked_residency()
        lengths = jnp.asarray(self.lengths)
        saved = None
        if self.res_mgr is not None:
            # pre-window KV slot contents: misses may reject per-row suffixes
            saved = snap_fn(self.state, lengths)
            self.stats.device_dispatches += 1
            if tr is not None:
                tr.instant("kv_snapshot", "kv_pool")
        if tr is not None:
            t_launch = time.perf_counter()
        draft, _logits, self.state, aux = step_fn(
            self.params, self._routers_next,
            jnp.asarray(self.next_token), self.state, lengths, residency,
        )
        if tr is not None:
            tr.complete("launch", "launch", t_launch, time.perf_counter(),
                        args={"k": k})
        self.stats.device_dispatches += 1
        self.stats.spec_windows += 1
        if self.res_mgr is not None:
            for key, v in aux.items():
                if key.startswith("route_") or key == "demand_next":
                    v.copy_to_host_async()
                    self.stats.overlapped_pulls += 1
        if tr is not None:
            t_pull = time.perf_counter()
        draft_np = np.asarray(draft)           # [K, B]: THE queue-draining pull
        if tr is not None:
            tr.complete("pull", "pull", t_pull, time.perf_counter(),
                        args={"k": k})
        self.stats.sync_pulls += 1
        accepted = np.where(self.active, k, 0).astype(np.int32)
        miss = None
        if self.res_mgr is not None:
            miss = concat_route_telemetry(aux, "miss", self._moe_segs, axis=1)
            step_row_miss = miss.any(axis=(1, 3))               # [K, B]
            any_miss = step_row_miss.any(axis=0)
            first = np.where(any_miss, step_row_miss.argmax(axis=0), k)
            accepted = np.where(
                self.active, np.maximum(first, 1), 0
            ).astype(np.int32)
            if tr is not None and bool((any_miss & self.active).any()):
                tr.instant("miss", "launch", args={
                    "rows": int((any_miss & self.active).sum()), "k": k,
                })
        # a finishing row commits only what it can still emit: drafting past
        # max_new must not advance lengths or count as accepted throughput.
        # ``offered`` = drafts the row could have used — the accept-rate
        # denominator, so unused tail drafts don't read as rejections
        offered: Dict[int, int] = {}
        for slot, req in self.scheduler.running.items():
            if self.active[slot]:
                budget = req.max_new - len(req.output)
                offered[slot] = min(k, budget)
                accepted[slot] = min(int(accepted[slot]), budget)
        if saved is not None and (accepted < k).any():
            self.state = roll_fn(
                self.state, saved, lengths, jnp.asarray(accepted)
            )
            self.stats.device_dispatches += 1
            if tr is not None:
                tr.instant("kv_rollback", "kv_pool")
        self.lengths += accepted
        now = time.perf_counter()
        fed_total = 0
        for slot in list(self.scheduler.running.keys()):
            if not self.active[slot]:
                continue
            a = int(accepted[slot])
            fed = 0
            for j in range(a):
                tok = int(draft_np[j, slot])
                self.next_token[slot] = tok
                self.scheduler.step_done(slot, tok, now, self.eos)
                fed += 1
                if slot in self.scheduler.free_slots:
                    self.active[slot] = False
                    break
            fed_total += fed
            self.scheduler.observe_accept(slot, offered[slot], fed)
            self.stats.drafted_tokens += offered[slot]
            self.stats.accepted_tokens += fed
        # 'steps' = sequential decode positions the batch committed (what the
        # scheduler's tokens-per-row admission rate is derived from), not the
        # k positions the program speculated over
        self.stats.steps += int(accepted.max(initial=0))
        self.stats.tokens += fed_total
        if self.res_mgr is not None:
            # rejected positions re-decode next window and are recorded THEN:
            # per-row accepted counts mask them out of the hit/miss accounting
            # and the demand-predictor EMA here
            self.res_mgr.rotate_window_from_telemetry(
                self.predictor,
                concat_route_telemetry(aux, "ids", self._moe_segs, axis=1),
                concat_route_telemetry(aux, "weights", self._moe_segs, axis=1),
                miss,
                np.asarray(aux["demand_next"]),
                accepted=accepted,
            )

    # ------------------------------------------------------------------
    def _rotate_from_aux(self, aux: Dict[str, jax.Array]) -> None:
        """Between-step rotation from routing telemetry: assemble the step's
        [L, ...] arrays and hand off to the manager's shared helper (the
        demand GEMM already ran on device — ``aux["demand_next"]``)."""
        self.res_mgr.rotate_from_telemetry(
            self.predictor,
            concat_route_telemetry(aux, "ids", self._moe_segs),
            concat_route_telemetry(aux, "weights", self._moe_segs),
            concat_route_telemetry(aux, "miss", self._moe_segs),
            np.asarray(aux["demand_next"]),
        )

    # ------------------------------------------------------------------
    def latency_summary(self) -> Dict[str, float]:
        """TTFT + inter-token latency percentiles over COMPLETED requests
        (the load-generator's goodput rows; wall-clock, so only meaningful
        when requests were submitted at their real arrival times).

        Backed by the metrics registry: the ``ttft_ms`` / ``itl_ms``
        histograms are rebuilt from the scheduler's completed set on every
        call (reset + re-observe keeps the call idempotent), then read back
        via :meth:`Histogram.percentile` — raw samples are retained, so the
        numbers match the legacy ``np.percentile`` output exactly. The same
        histograms feed the Prometheus exposition (``--metrics-port``)."""
        done = self.scheduler.completed
        ttft = self.metrics.histogram("ttft_ms", "time to first token (ms)")
        itl = self.metrics.histogram("itl_ms", "inter-token latency (ms)")
        ttft.reset()
        itl.reset()
        for r in done:
            if r.first_token_at:
                ttft.observe(1e3 * (r.first_token_at - r.submitted_at))
            ts = r.token_times
            for a, b in zip(ts, ts[1:]):
                itl.observe(1e3 * (b - a))
        return {
            "completed": len(done),
            "ttft_p50_ms": round(ttft.percentile(50), 3),
            "ttft_p99_ms": round(ttft.percentile(99), 3),
            "itl_p50_ms": round(itl.percentile(50), 3),
            "itl_p99_ms": round(itl.percentile(99), 3),
        }

    def summary(self) -> Dict[str, float]:
        """Engine stats + request-latency percentiles in one dict."""
        out = self.stats.summary()
        out.update(self.latency_summary())
        return out

    def metrics_registry(self) -> "MetricsRegistry":
        """Refresh and return the registry for Prometheus scrapes: rebuilds
        the latency histograms and mirrors the aggregate ``EngineStats``
        counters into ``engine_*`` gauges (called per scrape by
        ``serve.py --metrics-port``)."""
        self.latency_summary()
        self.metrics.set_from(self.stats.summary())
        return self.metrics
