"""Continuous-batching serving engine over the compiled whole-model step.

A fixed decode batch of ``num_slots`` rows runs one compiled ``decode_model``
step per tick; rows are claimed/freed by the scheduler as requests arrive and
finish (per-row ``lengths`` make the ragged batch exact). Each tick's
admitted requests prefill together through ONE shared compiled bucketed
program (the scheduler picks the power-of-two bucket, rows pad to the
power-of-two cover of the group size with per-row ``last_index``, and each
row's KV splices into the live state) — per-row outputs identical to batch-1
prefills; recurrent archs keep the exact-length batch-1 path.

Rotary residency in this path rotates slots BETWEEN steps from the previous
step's routing telemetry (route_* aux): the compiled step computes resident
experts via slot LUT; missed experts are dropped in-step, counted, and the
rotation corrects residency for the following step. The per-layer exact path
(host-corrected misses) lives in ``repro.core.engine`` — this engine is the
throughput-oriented compiled half.

Device-residency hot-path details shared with the rotary engine: the compiled
step IS the engine's fused whole-stack step (``build_fused_decode_step``) —
KV state donated, demand prediction on-device — the stacked residency pytree
handed to it is CACHED per segment (rebuilt only for segments whose slots/LUT
actually rotated — see ``RotaryResidencyManager.stacked_residency``), the
per-layer LUTs are persistent device arrays patched in place, the routing /
demand telemetry is pulled with async D2H copies issued before sampling, and
the between-step rotation is the manager's shared ``rotate_from_telemetry``
(one batched donated scatter per weight tensor per rotated layer).
"""
from __future__ import annotations

import math
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig, ResidencyConfig
from repro.core.engine import (
    build_fused_decode_step,
    build_window_fns,
    concat_route_telemetry,
    moe_segments,
)
from repro.core.predictor import DemandPredictor
from repro.core.residency import RotaryResidencyManager
from repro.core.stats import EngineStats
from repro.models import transformer as tfm
from repro.models.transformer import Runtime
from repro.serving.sampler import Sampler, SamplerConfig
from repro.serving.scheduler import Request, Scheduler


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        rt: Optional[Runtime] = None,
        num_slots: int = 4,
        residency: Optional[ResidencyConfig] = None,
        sampler: Optional[SamplerConfig] = None,
        eos: Optional[int] = None,
        spec_cap: int = 4,
        bucketed_prefill: bool = True,
    ):
        """``spec_cap`` bounds per-row speculative decode: when sampling is
        greedy and the stack is KV-cache-only, ticks run self-drafting windows
        through ``build_fused_window_step``, sized by the SCHEDULER's learned
        per-row speculative lengths (``spec_cap=1`` disables speculation).

        ``bucketed_prefill`` routes each tick's admitted requests through ONE
        shared compiled prefill program at the scheduler-chosen power-of-two
        bucket (rows padded to the power-of-two cover of the group size,
        per-row ``last_index`` for the ragged lengths, KV spliced into the
        live batch state) instead of one batch-1 program launch per request.
        Per-row outputs are identical
        to the batch-1 path — the program scans the rows through the very
        same per-row prefill computation. Recurrent archs need exact-length
        prefills and keep the batch-1 path regardless."""
        self.cfg = cfg
        self.params = params
        self.rt = rt or Runtime(cache_len=1024)
        self.batch = num_slots
        self.eos = eos
        self.sampler = Sampler(sampler or SamplerConfig())
        self.stats = EngineStats()
        # speculative windows need KV-only state (rollback restores cache
        # slots; a recurrent update is destructive) and greedy drafting (the
        # stochastic accept rule is still a hook — see repro.serving.sampler)
        kv_only = all(
            k in ("attn_mlp", "attn_moe", "local_attn") for k in cfg.layer_kinds
        )
        self._spec_ok = (
            spec_cap > 1 and kv_only and self.sampler.cfg.temperature <= 0.0
        )
        self._spec_cap_eff = 1
        if self._spec_ok:
            from repro.models import attention as attn_mod

            cap = attn_mod._cache_capacity(cfg.attention, self.rt.cache_len)
            self._spec_cap_eff = max(1, min(spec_cap, cap))
            self._spec_ok = self._spec_cap_eff > 1
        self.scheduler = Scheduler(
            num_slots, spec_cap=self._spec_cap_eff,
            max_prompt_len=self.rt.cache_len,
        )

        self.state = tfm.zero_state(cfg, self.batch, self.rt.cache_len)
        self.lengths = np.zeros((self.batch,), np.int32)
        self.next_token = np.zeros((self.batch,), np.int32)
        self.active = np.zeros((self.batch,), bool)

        # --- residency (MoE archs only) --------------------------------
        self.res_mgr: Optional[RotaryResidencyManager] = None
        self.predictor: Optional[DemandPredictor] = None
        if residency is not None and residency.mode != "full" and cfg.has_moe:
            host_experts, routers = [], []
            for si, (unit, reps) in enumerate(cfg.segments):
                for r in range(reps):
                    for pi, kind in enumerate(unit):
                        if kind != "attn_moe":
                            continue
                        p_l = jax.tree.map(
                            lambda a, r=r: a[r], params["segments"][si][pi]
                        )
                        host_experts.append(
                            {n: np.asarray(w, np.float32)
                             for n, w in p_l["moe"]["experts"].items()}
                        )
                        routers.append(np.asarray(p_l["moe"]["router"], np.float32))
            self.res_mgr = RotaryResidencyManager(
                cfg, residency, host_experts,
                batch=self.batch, cache_len=self.rt.cache_len, stats=self.stats,
            )
            self.predictor = DemandPredictor(routers, ema=residency.predictor_ema)
            for li in range(len(host_experts)):
                self.res_mgr.prepare_layer(li, self.predictor.smoothed[li])

        # --- compiled steps ---------------------------------------------
        # the tick shares the rotary engine's fused whole-stack step: KV state
        # donated (no per-tick cache copy), per-layer demand GEMM in-graph
        self._routers_next = None
        if self.res_mgr is not None:
            self.res_mgr.donate_buffers = True       # no snapshots span a tick
            self._routers_next = jnp.asarray(self.predictor.next_layer_routers())
        self._decode = build_fused_decode_step(
            cfg, self.rt, with_demand=self.res_mgr is not None, donate_state=True,
            keep_replay_anchor=False,     # no replay path: drop route_x outputs
        )
        self._moe_segs = moe_segments(cfg)
        self._prefill_cache: Dict[int, Any] = {}
        self._bucket_prefill_cache: Dict[int, Any] = {}
        self._window_cache: Dict[int, Any] = {}
        self._has_recurrence = any(
            k in ("mlstm", "slstm", "rglru") for k in cfg.layer_kinds
        )
        self._bucketed_prefill = bucketed_prefill and not self._has_recurrence

    def _window_fns(self, k: int):
        """Compiled (window step, KV snapshot, KV rollback) for window size
        ``k`` — the rotary engine's speculative triple, minus the replay path
        (so the window drops the ``route_x`` anchors)."""
        fns = self._window_cache.get(k)
        if fns is None:
            fns = build_window_fns(
                self.cfg, self.rt, k,
                with_demand=self.res_mgr is not None,
                keep_replay_anchor=False,
            )
            self._window_cache[k] = fns
        return fns

    # ------------------------------------------------------------------
    def _prefill_one(self, prompt: np.ndarray) -> Any:
        """Batch-1 prefill at a power-of-two length bucket (right-padded;
        decode masks cache positions >= true length so pads never score).
        Recurrent archs use exact lengths — pads would pollute the state."""
        s = len(prompt)
        bucket = s if self._has_recurrence else Scheduler.prefill_bucket(
            [s], self.rt.cache_len
        )
        cold = bucket not in self._prefill_cache
        if cold:
            def fn(params, tokens, last):
                return tfm.prefill_model(
                    self.cfg, params, tokens, self.rt, last_index=last
                )

            self._prefill_cache[bucket] = jax.jit(fn)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :s] = prompt
        t0 = time.perf_counter()
        logits, state = self._prefill_cache[bucket](
            self.params, jnp.asarray(padded), jnp.asarray([s - 1], jnp.int32)
        )
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        if not cold and dt > 0:
            # steady-state sample only — a cold bucket's wall time is
            # dominated by trace/compile and would poison the admission EMA
            self.scheduler.observe_prefill_rate(s / dt)
        return logits, state, s

    def _prefill_bucketed(self, admitted: List[Request]) -> List[Any]:
        """Prefill one admission group through the SHARED compiled bucketed
        program: the scheduler picks the power-of-two bucket covering every
        admitted prompt, the rows pad to the power-of-two cover of the group
        size (compile cache keyed on (bucket, rows) — at most log2(batch)
        row shapes per bucket, and a single admission doesn't pay the whole
        batch's worth of pad-row prefill work or depress the admission-rate
        EMA), and ONE program launch scans every row through exactly the
        per-row computation ``_prefill_one`` runs — per-row outputs match
        the batch-1 splice-in path. Rows splice into the live batch KV with
        the existing ragged machinery (per-row ``last_index`` / ``lengths``).

        Returns [(request, logits [1, V], row_state)] per admitted request.
        """
        lens = [len(r.prompt) for r in admitted]
        bucket = Scheduler.prefill_bucket(lens, self.rt.cache_len)
        rows = min(self.batch, 1 << (len(admitted) - 1).bit_length())
        key = (bucket, rows)
        cold = key not in self._bucket_prefill_cache
        if cold:
            def fn(params, tokens, last):          # [rows, bucket], [rows]
                def row(_, xs):
                    tok, li = xs
                    logits, state = tfm.prefill_model(
                        self.cfg, params, tok[None], self.rt,
                        last_index=li[None],
                    )
                    return None, (logits[0], state)

                _, (logits, states) = jax.lax.scan(row, None, (tokens, last))
                return logits, states

            self._bucket_prefill_cache[key] = jax.jit(fn)
        padded = np.zeros((rows, bucket), np.int32)
        last = np.zeros((rows,), np.int32)
        for i, req in enumerate(admitted):
            padded[i, : len(req.prompt)] = req.prompt
            last[i] = len(req.prompt) - 1
        t0 = time.perf_counter()
        logits, states = self._bucket_prefill_cache[key](
            self.params, jnp.asarray(padded), jnp.asarray(last)
        )
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        if not cold and dt > 0:
            # steady-state sample only — a cold bucket's wall time is
            # dominated by trace/compile and would poison the admission EMA
            self.scheduler.observe_prefill_rate(sum(lens) / dt)
        logits_np = np.asarray(logits)
        out = []
        for i, req in enumerate(admitted):
            row_state = jax.tree.map(lambda a, i=i: a[i], states)
            out.append((req, logits_np[i : i + 1], row_state))
        return out

    def _prefill_admitted(self, admitted: List[Request]) -> List[Any]:
        """Admission prefill: the shared bucketed program by default, batch-1
        programs for recurrent archs / ``bucketed_prefill=False``."""
        if not admitted:
            return []
        if self._bucketed_prefill:
            return self._prefill_bucketed(admitted)
        out = []
        for req in admitted:
            logits, row_state, _ = self._prefill_one(req.prompt)
            out.append((req, logits, row_state))
        return out

    def _splice_row(self, slot: int, row_state: Any) -> None:
        """Insert a batch-1 prefill state into batch row ``slot``."""
        def splice(dst, src):
            return dst.at[:, slot].set(src[:, 0])

        self.state = jax.tree.map(splice, self.state, row_state)

    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int,
               deadline_s: Optional[float] = None) -> Request:
        return self.scheduler.submit(prompt, max_new, time.perf_counter(), deadline_s)

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        """Drive until all submitted work completes. Returns completed requests."""
        ticks = 0
        t0 = time.perf_counter()
        while not self.scheduler.idle and ticks < max_ticks:
            now = time.perf_counter()
            for req, logits, row_state in self._prefill_admitted(
                self.scheduler.admit(now)
            ):
                self._splice_row(req.slot, row_state)
                self.lengths[req.slot] = len(req.prompt)
                tok = int(self.sampler(np.asarray(logits))[0])
                self.next_token[req.slot] = tok
                self.active[req.slot] = True
                self.stats.tokens += len(req.prompt)
                # first sampled token may already finish the request
                self.scheduler.step_done(req.slot, tok, now, self.eos)
                if req.done:
                    self.active[req.slot] = False
            if not self.scheduler.running:
                ticks += 1
                continue
            # per-row learned speculative lengths: the tick self-drafts as far
            # as the slowest-adapting ACTIVE row allows (windows are batch-wide
            # programs; acceptance and KV rollback are per-row)
            k_tick = 1
            if self._spec_ok:
                k_tick = min(
                    self.scheduler.spec_len(s) for s in self.scheduler.running
                )
                k_tick = max(1, min(k_tick, self._spec_cap_eff))
            if k_tick > 1:
                self._tick_window(k_tick)
                ticks += 1
                continue
            residency = None
            if self.res_mgr is not None:
                residency = self.res_mgr.stacked_residency()
            logits, self.state, aux = self._decode(
                self.params,
                self._routers_next,
                jnp.asarray(self.next_token),
                self.state,
                jnp.asarray(self.lengths),
                residency,
            )
            self.stats.device_dispatches += 1
            if self.res_mgr is not None:
                # start D2H copies of the routing/demand telemetry now: they
                # complete while the host samples, so the between-step rotation
                # reads below never drain the device queue
                for k, v in aux.items():
                    if k.startswith("route_") or k == "demand_next":
                        v.copy_to_host_async()
                        self.stats.overlapped_pulls += 1
            logits_np = np.asarray(logits)
            self.stats.sync_pulls += 1
            self.lengths += self.active
            toks = self.sampler(logits_np)
            now = time.perf_counter()
            for slot in list(self.scheduler.running.keys()):
                self.next_token[slot] = toks[slot]
                self.scheduler.step_done(slot, toks[slot], now, self.eos)
                if slot in self.scheduler.free_slots:
                    self.active[slot] = False
                if self._spec_ok:
                    # a plain tick is a size-1 window that accepted its token:
                    # feedback that lets a fresh row's spec length grow
                    self.scheduler.observe_accept(slot, 1, 1)
            self.stats.steps += 1
            self.stats.tokens += int(self.active.sum())
            if self.res_mgr is not None:
                self._rotate_from_aux(aux)
            ticks += 1
        self.stats.wall_s += time.perf_counter() - t0
        if self.stats.wall_s > 0 and self.stats.steps:
            self.scheduler.observe_rate(self.stats.steps / self.stats.wall_s)
        return self.scheduler.completed

    # ------------------------------------------------------------------
    def _tick_window(self, k: int) -> None:
        """One speculative serving tick: ``k`` self-drafted positions for the
        whole batch in ONE compiled program.

        Per-row acceptance: a row commits drafted tokens up to (but not past)
        its first residency miss — clamped to >= 1, since position 0 is
        exactly what a plain tick would have computed (serving drops missed
        experts in-step; it has no replay path). Rejected positions roll the
        row's KV slots back (``tfm.rollback_kv_window`` takes per-row keep
        counts for the ragged batch) and re-draft next window, after rotation
        has had a chance to fix residency. Accept outcomes feed the
        scheduler's per-row speculative lengths.
        """
        step_fn, snap_fn, roll_fn = self._window_fns(k)
        residency = None
        if self.res_mgr is not None:
            residency = self.res_mgr.stacked_residency()
        lengths = jnp.asarray(self.lengths)
        saved = None
        if self.res_mgr is not None:
            # pre-window KV slot contents: misses may reject per-row suffixes
            saved = snap_fn(self.state, lengths)
            self.stats.device_dispatches += 1
        draft, _logits, self.state, aux = step_fn(
            self.params, self._routers_next,
            jnp.asarray(self.next_token), self.state, lengths, residency,
        )
        self.stats.device_dispatches += 1
        self.stats.spec_windows += 1
        if self.res_mgr is not None:
            for key, v in aux.items():
                if key.startswith("route_") or key == "demand_next":
                    v.copy_to_host_async()
                    self.stats.overlapped_pulls += 1
        draft_np = np.asarray(draft)           # [K, B]: THE queue-draining pull
        self.stats.sync_pulls += 1
        accepted = np.where(self.active, k, 0).astype(np.int32)
        miss = None
        if self.res_mgr is not None:
            miss = concat_route_telemetry(aux, "miss", self._moe_segs, axis=1)
            step_row_miss = miss.any(axis=(1, 3))               # [K, B]
            any_miss = step_row_miss.any(axis=0)
            first = np.where(any_miss, step_row_miss.argmax(axis=0), k)
            accepted = np.where(
                self.active, np.maximum(first, 1), 0
            ).astype(np.int32)
        # a finishing row commits only what it can still emit: drafting past
        # max_new must not advance lengths or count as accepted throughput.
        # ``offered`` = drafts the row could have used — the accept-rate
        # denominator, so unused tail drafts don't read as rejections
        offered: Dict[int, int] = {}
        for slot, req in self.scheduler.running.items():
            if self.active[slot]:
                budget = req.max_new - len(req.output)
                offered[slot] = min(k, budget)
                accepted[slot] = min(int(accepted[slot]), budget)
        if saved is not None and (accepted < k).any():
            self.state = roll_fn(
                self.state, saved, lengths, jnp.asarray(accepted)
            )
            self.stats.device_dispatches += 1
        self.lengths += accepted
        now = time.perf_counter()
        fed_total = 0
        for slot in list(self.scheduler.running.keys()):
            if not self.active[slot]:
                continue
            a = int(accepted[slot])
            fed = 0
            for j in range(a):
                tok = int(draft_np[j, slot])
                self.next_token[slot] = tok
                self.scheduler.step_done(slot, tok, now, self.eos)
                fed += 1
                if slot in self.scheduler.free_slots:
                    self.active[slot] = False
                    break
            fed_total += fed
            self.scheduler.observe_accept(slot, offered[slot], fed)
            self.stats.drafted_tokens += offered[slot]
            self.stats.accepted_tokens += fed
        # 'steps' = sequential decode positions the batch committed (what the
        # scheduler's tokens-per-row admission rate is derived from), not the
        # k positions the program speculated over
        self.stats.steps += int(accepted.max(initial=0))
        self.stats.tokens += fed_total
        if self.res_mgr is not None:
            # rejected positions re-decode next window and are recorded THEN:
            # per-row accepted counts mask them out of the hit/miss accounting
            # and the demand-predictor EMA here
            self.res_mgr.rotate_window_from_telemetry(
                self.predictor,
                concat_route_telemetry(aux, "ids", self._moe_segs, axis=1),
                concat_route_telemetry(aux, "weights", self._moe_segs, axis=1),
                miss,
                np.asarray(aux["demand_next"]),
                accepted=accepted,
            )

    # ------------------------------------------------------------------
    def _rotate_from_aux(self, aux: Dict[str, jax.Array]) -> None:
        """Between-step rotation from routing telemetry: assemble the step's
        [L, ...] arrays and hand off to the manager's shared helper (the
        demand GEMM already ran on device — ``aux["demand_next"]``)."""
        self.res_mgr.rotate_from_telemetry(
            self.predictor,
            concat_route_telemetry(aux, "ids", self._moe_segs),
            concat_route_telemetry(aux, "weights", self._moe_segs),
            concat_route_telemetry(aux, "miss", self._moe_segs),
            np.asarray(aux["demand_next"]),
        )
