"""AdamW (pure JAX) with warmup+cosine schedule and global-norm clipping.

Moments are f32 regardless of param dtype. ZeRO-1 is expressed at the sharding
layer: ``repro.distributed.sharding.opt_spec`` assigns the moments dp-sharded
specs, and GSPMD's reduce-scatter pass turns the gradient all-reduce into
reduce-scatter + subsequent all-gather of updated params.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import RunConfig

OptState = Dict[str, Any]


def lr_at(run: RunConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(run.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - run.warmup_steps) / jnp.maximum(run.total_steps - run.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return run.learning_rate * warm * (0.1 + 0.9 * cos)


def adamw_init(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    params: Any, grads: Any, opt: OptState, run: RunConfig
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    step = opt["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, run.grad_clip / (gnorm + 1e-9))
    lr = lr_at(run, step)
    b1, b2 = run.beta1, run.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + 1e-8) + run.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    params = jax.tree.unflatten(tdef, new_p)
    new_opt = {
        "m": jax.tree.unflatten(tdef, new_m),
        "v": jax.tree.unflatten(tdef, new_v),
        "step": step,
    }
    return params, new_opt, {"grad_norm": gnorm, "lr": lr}
