"""Training step + loop: microbatched grad accumulation, AdamW, optional
cross-pod int8 gradient compression, checkpoint/restart hooks.

``make_train_step`` builds the jit-able step used both for real (reduced-model)
training and for the full-size dry-run lowering. Microbatching reshapes the
global batch [B, S] -> [n_micro, B/n_micro, S] and accumulates f32 grads in a
``lax.scan`` — the standard memory lever that keeps activation residency
bounded at `microbatch` rows regardless of global batch.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.config.base import ModelConfig, RunConfig
from repro.models.transformer import Runtime, lm_loss
from repro.training.optimizer import adamw_init, adamw_update
from repro.training import compression

TrainState = Dict[str, Any]


def init_train_state(
    cfg: ModelConfig, params: Any, sharding_cfg=None, pod_count: int = 2
) -> TrainState:
    state: TrainState = {"params": params, "opt": adamw_init(params)}
    if sharding_cfg is not None and sharding_cfg.grad_compression == "int8_ef":
        state["ef"] = compression.ef_init(params, pod_count)
    return state


def make_train_step(
    cfg: ModelConfig,
    rt: Runtime,
    run: RunConfig,
    *,
    num_micro: int = 1,
    pod_compression: bool = False,
    pod_count: int = 2,
) -> Callable:
    """Returns train_step(state, tokens, labels, frontend=None) -> (state, metrics)."""

    def loss_fn(params, tokens, labels, frontend):
        loss, aux = lm_loss(cfg, params, tokens, labels, rt, frontend)
        return loss, aux

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, tokens, labels, frontend):
        if num_micro <= 1:
            (loss, aux), grads = grad_fn(params, tokens, labels, frontend)
            return loss, grads
        b = tokens.shape[0]
        mb = b // num_micro
        tk = tokens.reshape(num_micro, mb, *tokens.shape[1:])
        lb = labels.reshape(num_micro, mb, *labels.shape[1:])
        fe = (
            frontend.reshape(num_micro, mb, *frontend.shape[1:])
            if frontend is not None else None
        )

        def micro(carry, xs):
            acc, loss_sum = carry
            if fe is not None:
                t, l, f = xs
            else:
                t, l = xs
                f = None
            (loss, _), grads = grad_fn(params, t, l, f)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / num_micro, acc, grads
            )
            return (acc, loss_sum + loss / num_micro), None

        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        xs = (tk, lb, fe) if fe is not None else (tk, lb)
        (grads, loss), _ = jax.lax.scan(micro, (acc0, jnp.zeros(())), xs)
        return loss, grads

    def compute_grads_pod_compressed(params, tokens, labels, frontend, ef):
        """Manual over "pod": each pod computes partial grads on its batch slice
        (data/model axes stay automatic/GSPMD inside), then the pod-axis
        reduction happens as an explicit int8 all-reduce with error feedback."""
        from jax.sharding import PartitionSpec as P

        def inner(params, tokens, labels, frontend, ef):
            loss, grads = compute_grads(params, tokens, labels, frontend)
            grads, new_ef = compression.compressed_psum_pod(
                grads, ef, axis="pod", pod_count=pod_count
            )
            return jax.lax.pmean(loss, "pod"), grads, new_ef

        fe_spec = P() if frontend is None else P("pod")
        fn = shard_map(
            inner,
            mesh=rt.mesh,
            in_specs=(P(), P("pod"), P("pod"), fe_spec, P("pod")),
            out_specs=(P(), P(), P("pod")),
            axis_names={"pod"},
            check_vma=False,
        )
        return fn(params, tokens, labels, frontend, ef)

    def train_step(state, tokens, labels, frontend=None):
        params = state["params"]
        new_state = dict(state)
        if pod_compression and "ef" in state:
            loss, grads, new_state["ef"] = compute_grads_pod_compressed(
                params, tokens, labels, frontend, state["ef"]
            )
        else:
            loss, grads = compute_grads(params, tokens, labels, frontend)
        new_params, new_opt, metrics = adamw_update(params, grads, state["opt"], run)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        metrics["loss"] = loss
        return new_state, metrics

    return train_step


def train_loop(
    cfg: ModelConfig,
    state: TrainState,
    step_fn: Callable,
    loader,
    run: RunConfig,
    *,
    num_steps: int,
    ckpt_manager=None,
    log: Optional[Callable[[int, Dict], None]] = None,
) -> Tuple[TrainState, Dict[str, float]]:
    last_metrics: Dict[str, float] = {}
    for _ in range(num_steps):
        step, tokens, labels = next(loader)
        state, metrics = step_fn(state, tokens, labels)
        last_metrics = {k: float(v) for k, v in metrics.items()}
        if log is not None and step % run.log_every == 0:
            log(step, last_metrics)
        if ckpt_manager is not None and (step + 1) % run.checkpoint_every == 0:
            ckpt_manager.save(step + 1, state)
    return state, last_metrics
