from repro.training.optimizer import adamw_init, adamw_update, lr_at  # noqa: F401
from repro.training.trainer import init_train_state, make_train_step, train_loop  # noqa: F401
