"""int8 gradient compression with error feedback for cross-pod reduction.

On a multi-pod mesh the slow links are pod-to-pod (DCN/optical), while in-pod
ICI is fast. The trainer therefore computes gradients with the batch sharded
over the in-pod "data" axis only (GSPMD reduces those on ICI) and performs the
pod-axis reduction explicitly here, int8 on the wire:

  residual-corrected g -> per-tensor scale (psum-max'd so all pods agree) ->
  int8 quantize -> **int8 all-reduce over "pod"** -> dequant -> new residual.

The int8 psum is what lands in the HLO (1 byte/element on the cross-pod link vs
4 for f32 — visible to the roofline parser). Error feedback keeps the quantizer
unbiased over time: the un-transmitted remainder is added to the next step's
gradient.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def ef_init(params: Any, pod_count: int = 2) -> Any:
    """Per-pod error-feedback state: leading [pod] dim (each pod owns its own
    quantization residual), bf16 storage (residuals are small corrections),
    dp-sharded within the pod by the sharding rules."""
    return jax.tree.map(
        lambda p: jnp.zeros((pod_count,) + p.shape, jnp.bfloat16), params
    )


def compressed_psum_pod(
    grads: Any, ef: Any, *, axis: str = "pod", pod_count: int = 2
) -> Tuple[Any, Any]:
    """Runs INSIDE shard_map (manual over ``axis``); ef arrives as this pod's
    [1, ...] slice. Returns (mean grads, new ef slice)."""

    def one(g: jax.Array, e: jax.Array) -> Tuple[jax.Array, jax.Array]:
        gf = g.astype(jnp.float32) + e[0].astype(jnp.float32)
        # all pods must agree on the scale -> psum-max
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis)
        scale = amax / 127.0 + 1e-12
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        # int8 on the cross-pod wire; int32 accumulate to avoid reducer overflow
        summed = jax.lax.psum(q.astype(jnp.int32), axis)
        out = (summed.astype(jnp.float32) * scale) / pod_count
        new_e = gf - q.astype(jnp.float32) * scale        # local quantization residual
        return out.astype(g.dtype), new_e[None].astype(jnp.bfloat16)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_ef = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return new_g, new_ef
