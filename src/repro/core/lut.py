"""Expert <-> slot lookup table (the patent's "lookup-table mapping structure").

The LUT is the indirection that lets compiled compute address the *rotating*
physical slot buffer: ``lut[expert] -> slot`` with ``MISS = num_slots`` pointing
at the trailing zero slot. The inverse map ``slot -> expert`` drives eviction
bookkeeping. Host-side numpy; the device copy is refreshed on rotation.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

import numpy as np


class SlotLUT:
    # device copies that sync incrementally off this table: the per-layer [E]
    # int32 array and the per-segment stacked LUT plane (one row per rep)
    _consumers: Tuple[str, ...] = ("device", "stacked")

    def __init__(self, num_experts: int, num_slots: int):
        self.num_experts = num_experts
        self.num_slots = num_slots
        self.miss = num_slots                       # sentinel: trailing zero slot
        self.e2s = np.full((num_experts,), self.miss, np.int32)
        self.s2e = np.full((num_slots,), -1, np.int32)
        # incremental-device-sync bookkeeping: ``version`` counts mutations;
        # per-CONSUMER dirty sets hold expert ids whose e2s entry changed since
        # that consumer's last ``take_dirty``. Two device copies track this LUT
        # independently — the per-layer [E] array (consumer "device") and the
        # per-segment stacked LUT plane (consumer "stacked") — so each patches
        # only the entries IT hasn't absorbed yet instead of re-uploading [E]
        # per layer per step.
        self.version = 0
        self._dirty: Dict[str, set] = {}

    # -- queries ----------------------------------------------------------
    def slot_of(self, expert: int) -> int:
        return int(self.e2s[expert])

    def expert_in(self, slot: int) -> int:
        return int(self.s2e[slot])

    def is_resident(self, expert: int) -> bool:
        return self.e2s[expert] != self.miss

    @property
    def resident_experts(self) -> np.ndarray:
        return np.flatnonzero(self.e2s != self.miss)

    @property
    def free_slots(self) -> List[int]:
        return [int(s) for s in np.flatnonzero(self.s2e < 0)]

    def as_array(self) -> np.ndarray:
        """Device-uploadable [E] int32 (missing experts -> miss sentinel)."""
        return self.e2s.copy()

    def dirty_count(self, consumer: str = "device") -> int:
        """Number of e2s entries mutated since ``consumer``'s last
        ``take_dirty`` — lets the residency manager pick patch vs full
        re-upload without consuming (or materializing) the dirty set."""
        return len(self._dirty.get(consumer, ()))

    def take_dirty(self, consumer: str = "device") -> np.ndarray:
        """Expert ids mutated since ``consumer``'s previous call (sorted, then
        cleared for that consumer only — the other device copies keep their
        own backlog)."""
        d = self._dirty.get(consumer)
        if not d:
            return np.empty((0,), np.int64)
        idx = np.fromiter(sorted(d), np.int64, len(d))
        d.clear()
        return idx

    def _mark_dirty(self, expert: int) -> None:
        for consumer in self._consumers:
            self._dirty.setdefault(consumer, set()).add(int(expert))

    def clone(self) -> "SlotLUT":
        """Mutation-isolated copy for transition SIMULATION (the prefetch
        predictor runs the next boundary's placement on a clone so speculative
        planning never touches the authoritative table or its dirty sets)."""
        c = SlotLUT(self.num_experts, self.num_slots)
        c.e2s = self.e2s.copy()
        c.s2e = self.s2e.copy()
        return c

    # -- updates ----------------------------------------------------------
    def assign(self, expert: int, slot: int) -> int:
        """Bind expert -> slot, evicting any previous occupant. Returns evicted
        expert id or -1."""
        if not (0 <= slot < self.num_slots):
            raise ValueError(f"slot {slot} out of range [0,{self.num_slots})")
        evicted = int(self.s2e[slot])
        if evicted >= 0:
            self.e2s[evicted] = self.miss
            self._mark_dirty(evicted)
        prev_slot = int(self.e2s[expert])
        if prev_slot != self.miss:
            self.s2e[prev_slot] = -1
        self.e2s[expert] = slot
        self.s2e[slot] = expert
        self._mark_dirty(expert)
        self.version += 1
        return evicted

    def evict(self, expert: int) -> None:
        slot = int(self.e2s[expert])
        if slot != self.miss:
            self.s2e[slot] = -1
            self.e2s[expert] = self.miss
            self._mark_dirty(expert)
            self.version += 1

    def check_consistent(self) -> None:
        """Invariant: e2s and s2e are mutually inverse partial bijections."""
        for s in range(self.num_slots):
            e = self.s2e[s]
            if e >= 0:
                assert self.e2s[e] == s, (s, e)
        for e in range(self.num_experts):
            s = self.e2s[e]
            if s != self.miss:
                assert self.s2e[s] == e, (e, s)
        res = self.e2s[self.e2s != self.miss]
        assert len(np.unique(res)) == len(res), "two experts share a slot"
