"""Cyclic rotation of the slot group (the patent's rotary transform).

Experts are arranged on a *ring* ordered by long-horizon demand (EMA). The
resident set is a contiguous window of ``num_slots`` ring positions. Residency
advances by bounded forward/reverse rotation of the window — in contrast to LRU,
whose eviction order is a one-way recency stream with no structured way back.

Cyclical return: the rotation state keeps snapshots of (demand vector, window
position); when current demand correlates with a stored snapshot above a
threshold, the window rotates back to that snapshot's position — the paper's
"recurring semantic context allows cyclical return to a prior slot set".
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Tuple

import numpy as np


def cosine(a: np.ndarray, b: np.ndarray) -> float:
    na, nb = float(np.linalg.norm(a)), float(np.linalg.norm(b))
    if na == 0.0 or nb == 0.0:
        return 0.0
    return float(a @ b) / (na * nb)


@dataclass
class RotationDecision:
    delta: int                     # signed ring rotation applied this step
    reverse_jump: bool             # True if a cyclical-return jump was taken
    window: np.ndarray             # expert ids now in the window


class RotaryRing:
    """Ring ordering + rotating window over experts of ONE layer."""

    def __init__(
        self,
        num_experts: int,
        num_slots: int,
        *,
        max_stride: int = 4,
        reverse_threshold: float = 0.85,
        snapshot_every: int = 16,
        max_snapshots: int = 32,
        rering_every: int = 64,
        seed: int = 0,
    ):
        if num_slots > num_experts:
            raise ValueError("window larger than ring")
        self.num_experts = num_experts
        self.num_slots = num_slots
        self.max_stride = max_stride
        self.reverse_threshold = reverse_threshold
        self.snapshot_every = snapshot_every
        self.rering_every = rering_every
        self.ring = np.arange(num_experts, dtype=np.int32)
        self.pos = 0
        self.step = 0
        self.ema = np.zeros((num_experts,), np.float64)
        self.snapshots: Deque[Tuple[np.ndarray, int]] = deque(maxlen=max_snapshots)
        self._rng = np.random.default_rng(seed)

    # -- window helpers -----------------------------------------------------
    def window_at(self, pos: int) -> np.ndarray:
        idx = (pos + np.arange(self.num_slots)) % self.num_experts
        return self.ring[idx]

    @property
    def window(self) -> np.ndarray:
        return self.window_at(self.pos)

    def _window_score(self, pos: int, demand: np.ndarray) -> float:
        return float(demand[self.window_at(pos)].sum())

    # -- the rotary transform -------------------------------------------------
    def rotate(self, demand: np.ndarray, ema_alpha: float = 0.8) -> RotationDecision:
        """One structured transition given the (predicted) demand vector [E].

        1. cyclical-return check against stored snapshots;
        2. otherwise bounded rotation: choose delta in [-max_stride, max_stride]
           maximizing window demand (ties prefer smaller |delta| — fewer loads).
        """
        self.step += 1
        self.ema = ema_alpha * self.ema + (1.0 - ema_alpha) * demand

        # (a) cyclical return on recurring context — jump only when the
        # remembered window actually serves the current demand better than the
        # present one (prevents ping-ponging between equal-demand snapshots)
        here = self._window_score(self.pos, demand)
        best_snap: Optional[Tuple[float, int]] = None
        for snap_demand, snap_pos in self.snapshots:
            c = cosine(demand, snap_demand)
            if c > self.reverse_threshold and (best_snap is None or c > best_snap[0]):
                if self._window_score(snap_pos, demand) > here + 1e-9:
                    best_snap = (c, snap_pos)
        if best_snap is not None and best_snap[1] != self.pos:
            delta = self._ring_delta(self.pos, best_snap[1], self.num_experts)
            self.pos = best_snap[1]
            return RotationDecision(delta=delta, reverse_jump=True, window=self.window)

        # (b) bounded forward/reverse rotation
        deltas = sorted(range(-self.max_stride, self.max_stride + 1), key=abs)
        best_delta, best_score = 0, -np.inf
        for d in deltas:
            s = self._window_score((self.pos + d) % self.num_experts, demand)
            if s > best_score + 1e-12:
                best_delta, best_score = d, s
        if best_delta == 0 and best_score <= 1e-12 < demand.max():
            # demand lies entirely outside local reach: drift toward the ring
            # position of the hottest expert (bounded by the stride)
            target = int(np.nonzero(self.ring == int(np.argmax(demand)))[0][0])
            dist = (target - self.pos) % self.num_experts
            if dist > self.num_experts // 2:
                best_delta = -min(self.max_stride, self.num_experts - dist)
            else:
                best_delta = min(self.max_stride, dist)
        self.pos = (self.pos + best_delta) % self.num_experts

        # (c) periodic maintenance: snapshot + re-ring by EMA
        if self.step % self.snapshot_every == 0:
            self.snapshots.append((demand.copy(), self.pos))
        if self.step % self.rering_every == 0:
            self._rering()
        return RotationDecision(delta=best_delta, reverse_jump=False, window=self.window)

    def clone(self) -> "RotaryRing":
        """Mutation-isolated copy for transition SIMULATION: prefetch runs the
        next boundary's rotate() on a clone so the speculative plan never
        advances the authoritative ring state (pos/step/EMA/snapshots)."""
        c = RotaryRing(
            self.num_experts,
            self.num_slots,
            max_stride=self.max_stride,
            reverse_threshold=self.reverse_threshold,
            snapshot_every=self.snapshot_every,
            max_snapshots=self.snapshots.maxlen or 32,
            rering_every=self.rering_every,
        )
        c.ring = self.ring.copy()
        c.pos = self.pos
        c.step = self.step
        c.ema = self.ema.copy()
        c.snapshots = deque(self.snapshots, maxlen=self.snapshots.maxlen)
        return c

    @staticmethod
    def _ring_delta(src: int, dst: int, num_experts: int) -> int:
        """Minimal signed rotation taking ``src`` to ``dst`` on the ring.

        A jump across the ring seam (e.g. pos 0 -> pos E-1) is one REVERSE
        step, not E-1 forward steps; ties at exactly half the ring prefer the
        forward direction.
        """
        d = (dst - src) % num_experts
        if d > num_experts // 2:
            d -= num_experts
        return d

    def _rering(self) -> None:
        """Re-sort the ring by demand EMA, keeping the current window's experts
        contiguous at the current position (so re-ringing itself forces no loads)."""
        current = self.window.copy()
        rest = np.setdiff1d(self.ring, current, assume_unique=False)
        rest = rest[np.argsort(-self.ema[rest], kind="stable")]
        new_ring = np.empty_like(self.ring)
        idx = (self.pos + np.arange(self.num_slots)) % self.num_experts
        new_ring[idx] = current
        other_idx = np.setdiff1d(np.arange(self.num_experts), idx, assume_unique=True)
        # place remaining experts clockwise after the window, best EMA first
        order = np.argsort((other_idx - (self.pos + self.num_slots)) % self.num_experts)
        new_ring[other_idx[order]] = rest
        self.ring = new_ring
        # snapshots reference window positions whose contents changed: drop them
        self.snapshots.clear()
