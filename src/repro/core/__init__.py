"""The paper's contribution: rotary accelerator-residency management.

Slots (device buffers) + LUT indirection + cyclic rotation + hidden-state-guided
prefetch + host-compute miss fallback, with LRU/static/full baselines.
"""
from repro.core.engine import RotaryEngine  # noqa: F401
from repro.core.lut import SlotLUT  # noqa: F401
from repro.core.policies import make_policy  # noqa: F401
from repro.core.predictor import DemandPredictor  # noqa: F401
from repro.core.residency import (  # noqa: F401
    FeasibilityReport,
    InitializationError,
    RotaryResidencyManager,
    check_feasibility,
)
from repro.core.rotation import RotaryRing  # noqa: F401
from repro.core.slots import (  # noqa: F401
    SlotStore,
    dequantize_int8,
    fake_quantized_batch,
    quantize_int8,
    quantized_expert_bytes,
)
from repro.quant import (  # noqa: F401
    dequantize_int4,
    quantize_int4,
    quantize_int4_batch,
)
from repro.core.stats import EngineStats  # noqa: F401
from repro.core.transfer import CostModel, TransferClock  # noqa: F401
