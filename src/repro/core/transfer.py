"""Host<->accelerator transfer engine and the analytic cost model.

The container is CPU-only, so transfer *times* are modeled from hardware
constants while transfer *behaviour* (double-buffered uploads between steps,
blocking loads on LRU misses) is executed for real against jax device buffers.

TPU adaptation of the paper's PCIe numbers (DESIGN.md §2): host->HBM DMA is
modeled at 32 GB/s per host link; device compute at 197 TFLOP/s bf16; host GEMM
for miss fallback at 100 GFLOP/s (i7-class, the paper's n-cpu-moe executor).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class CostModel:
    host_link_gbs: float = 32.0          # host->HBM DMA bandwidth
    link_latency_us: float = 20.0
    device_flops: float = 197e12         # bf16 peak per chip (TPU v5e)
    device_hbm_gbs: float = 819.0
    host_flops: float = 100e9            # host GEMM for miss fallback
    mxu_efficiency: float = 0.6          # achievable fraction of peak on GEMV-ish decode

    def transfer_s(self, nbytes: int) -> float:
        return self.link_latency_us * 1e-6 + nbytes / (self.host_link_gbs * 1e9)

    def compute_s(self, flops: float, bytes_touched: float = 0.0) -> float:
        """Roofline max of compute and HBM time for a device-side op."""
        t_c = flops / (self.device_flops * self.mxu_efficiency)
        t_m = bytes_touched / (self.device_hbm_gbs * 1e9)
        return max(t_c, t_m)

    def host_compute_s(self, flops: float) -> float:
        return flops / self.host_flops


class TransferClock:
    """Tracks modeled overlap between prefetch DMA and device compute.

    Usage per decode step: ``begin_step()``, then for every layer
    ``prefetch(nbytes)`` (async, issued before the layer) and
    ``compute(seconds)``; blocking loads call ``blocking(nbytes)``.
    ``stall_s`` accumulates DMA time that compute could not hide.
    """

    def __init__(self, cost: CostModel):
        self.cost = cost
        self.device_t = 0.0          # device busy-until
        self.dma_t = 0.0             # dma busy-until
        self.compute_s = 0.0
        self.transfer_s = 0.0
        self.stall_s = 0.0
        self.host_s = 0.0

    @property
    def hidden_s(self) -> float:
        """Modeled DMA seconds hidden behind device compute: total transfer
        time minus the portion compute had to wait on. Predictive prefetch
        exists to push this toward ``transfer_s`` (stall_s -> 0)."""
        return max(0.0, self.transfer_s - self.stall_s)

    def prefetch(self, nbytes: int) -> None:
        if nbytes <= 0:
            return
        t = self.cost.transfer_s(nbytes)
        self.transfer_s += t
        self.dma_t = max(self.dma_t, self.device_t) + t

    def compute(self, seconds: float, *, needs_dma: bool = True) -> None:
        """Run a layer; if its weights are still in flight, the device waits."""
        start = self.device_t
        if needs_dma and self.dma_t > start:
            self.stall_s += self.dma_t - start
            start = self.dma_t
        self.device_t = start + seconds
        self.compute_s += seconds

    def blocking(self, nbytes: int) -> None:
        """Critical-path load (LRU miss): device idles for the whole transfer."""
        t = self.cost.transfer_s(nbytes)
        self.transfer_s += t
        self.stall_s += t
        self.device_t = max(self.device_t, self.dma_t) + t
        self.dma_t = self.device_t

    def host(self, seconds: float) -> None:
        """Host-executed miss overlaps nothing (result needed before next layer)."""
        self.host_s += seconds
        self.device_t += seconds
