"""RotaryResidencyManager: per-MoE-layer slots + policy + LUT + accounting,
plus the startup feasibility check that reproduces the paper's Fig. 3 failure.

The manager owns host-side expert weights (the "warehouse" — full model in host
memory) and a ``SlotStore`` per MoE layer (the rotating accelerator-resident
subset). ``prepare_layer`` runs the policy's proactive transition and executes
the resulting uploads; ``resolve`` maps routed expert ids through the LUT and
classifies hits/misses.

Exactness invariant: residency state NEVER changes what an engine emits —
only where compute happens. Misses are classified (in-kernel on the hot
paths, via ``resolve`` on the walk) and corrected by the owning engine
(host GEMM + suffix replay / KV rollback), so outputs stay bit-identical to
full residency; under int8/int4 stores the correction runs against
dequant∘quant weights, keeping quantized serving exactness-clean within its
format.

Telemetry→transition map (the host half of each compiled step): the fused
engines hand one step's device-classified telemetry to
``rotate_from_telemetry`` (or a speculative window's to
``rotate_window_from_telemetry``, per-committed-step-equivalent with
uploads coalesced to the last write per slot): ``ids``/``weights`` fold into
the ``DemandPredictor`` EMA, ``miss`` + ``ids`` land in ``LayerStats`` via
``record_routing``, and ``demand_next`` (the pre-gating GEMM: on-device for
decode, the shared chunk-boundary program for chunked prefill) drives
``policy.prepare`` → ``RotaryRing`` transition → batched ``SlotStore``
uploads (one donated scatter per weight tensor per rotated layer) and
incremental device-LUT patches.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig, ResidencyConfig
from repro.core.policies import ResidencyPolicy, make_policy
from repro.core.slots import (
    SlotStore,
    quantized_expert_bytes,
    scatter_set,
    scatter_set_donated,
)
from repro.core.stats import EngineStats
from repro.core.transfer import CostModel, TransferClock
from repro.obs.metrics import BYTES_BUCKETS
from repro.obs.tracer import resolve_tracer


# Dirty-slot patches into the persistent stacked planes: one dispatch per
# weight tensor per rotated LAYER instead of a fresh jnp.stack over every rep
# in the segment. ``src`` ships whole (device gather beats a host slice) and
# the same program serves the [reps, E] LUT plane.
@functools.partial(jax.jit, donate_argnums=(0,))
def _plane_patch_rows_donated(plane, rep, idx, src):
    return plane.at[rep, idx].set(src[idx])


@jax.jit
def _plane_patch_rows(plane, rep, idx, src):
    return plane.at[rep, idx].set(src[idx])


# fused variant: ONE dispatch patches every weight-tensor plane of a layer's
# segment (pytree-mapped scatter) instead of one launch per tensor — the
# miss-relaunch path patches planes mid-step, so per-dispatch overhead is on
# the decode critical path, not just at rotation boundaries
@functools.partial(jax.jit, donate_argnums=(0,))
def _seg_patch_rows_donated(planes, rep, idx, src):
    return jax.tree_util.tree_map(
        lambda p, s: p.at[rep, idx].set(s[idx]), planes, src
    )


@jax.jit
def _seg_patch_rows(planes, rep, idx, src):
    return jax.tree_util.tree_map(
        lambda p, s: p.at[rep, idx].set(s[idx]), planes, src
    )


# write-through upload: ONE dispatch lands a rotation's host rows in the
# layer's store buffers AND the persistent stacked planes AND refreshes the
# stacked LUT row — the store scatter, the plane patch, and the LUT patch
# that used to be three separate launches. Only valid for unquantized stores
# (quantized planes hold the dequantized view, which the store must derive)
@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _write_through_donated(bufs, seg_slots, seg_lut, rep, idx, vals, e2s):
    bufs = jax.tree_util.tree_map(lambda b, v: b.at[idx].set(v), bufs, vals)
    seg_slots = jax.tree_util.tree_map(
        lambda p, v: p.at[rep, idx].set(v), seg_slots, vals
    )
    return bufs, seg_slots, seg_lut.at[rep].set(e2s)


@jax.jit
def _write_through(bufs, seg_slots, seg_lut, rep, idx, vals, e2s):
    bufs = jax.tree_util.tree_map(lambda b, v: b.at[idx].set(v), bufs, vals)
    seg_slots = jax.tree_util.tree_map(
        lambda p, v: p.at[rep, idx].set(v), seg_slots, vals
    )
    return bufs, seg_slots, seg_lut.at[rep].set(e2s)


# stacked-LUT row refresh: the per-layer LUT is a tiny [E] int32 vector, so a
# fixed-shape full-row set beats an index-specialized scatter (every distinct
# dirty count would compile its own program)
@functools.partial(jax.jit, donate_argnums=(0,))
def _lut_row_set_donated(plane, rep, src):
    return plane.at[rep].set(src)


@jax.jit
def _lut_row_set(plane, rep, src):
    return plane.at[rep].set(src)


def _bucket_rows(idx: np.ndarray, cap: int) -> np.ndarray:
    """Pad a row-index vector to the next power-of-two bucket (capped): row
    scatters/gathers shape-specialize on the index length, and duplicate
    indices write the same row twice (idempotent), so a handful of bucketed
    programs serve every dirty-set size instead of one compile per count."""
    n = int(idx.size)
    b = 1
    while b < n:
        b <<= 1
    b = min(b, cap) if n <= cap else n
    if n < b:
        idx = np.pad(idx, (0, b - n), mode="edge")
    return idx


class InitializationError(RuntimeError):
    """Startup failure (the paper's 'failed to initialize', Fig. 3 N36/4096)."""


@dataclass
class FeasibilityReport:
    ok: bool
    reason: str
    slot_bytes: int
    kv_bytes: int
    static_bytes: int            # non-MoE weights always resident
    activation_bytes: int
    total_bytes: int
    budget_bytes: Optional[int]
    min_slots: int


def _attention_static_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """Weights that always stay on-device: everything except routed experts."""
    from repro.models.params import analytic_params

    total = analytic_params(cfg, active_only=False)
    if cfg.has_moe:
        m = cfg.moe
        mats = 3 if cfg.mlp == "swiglu" else 2
        routed = sum(
            m.num_experts * mats * cfg.d_model * m.expert_d_ff
            for k in cfg.layer_kinds if k == "attn_moe"
        )
        total -= routed
    return total * dtype_bytes


def check_feasibility(
    cfg: ModelConfig,
    rescfg: ResidencyConfig,
    *,
    batch: int,
    cache_len: int,
    dtype_bytes: int = 2,
) -> FeasibilityReport:
    """Two-sided startup check:

    (1) capacity floor — ``num_slots >= top_k + prefetch_margin`` so one step's
        routed experts plus in-flight prefetch fit (the N36-analog violates it);
    (2) memory ceiling — slots + pinned shared + KV + static weights +
        activation bound must fit ``hbm_budget_bytes``.
    """
    m = cfg.moe
    moe_layers = sum(1 for k in cfg.layer_kinds if k == "attn_moe")
    mats = 3 if cfg.mlp == "swiglu" else 2
    # exact packed bytes per expert (int4 includes its group scale/min planes)
    shapes = {"w_up": (cfg.d_model, m.expert_d_ff), "w_down": (m.expert_d_ff, cfg.d_model)}
    if mats == 3:
        shapes["w_gate"] = (cfg.d_model, m.expert_d_ff)
    expert_bytes = quantized_expert_bytes(
        shapes, rescfg.quantization, dtype_bytes, rescfg.quant_group_size
    )
    slots = rescfg.num_slots or m.num_experts
    min_slots = m.top_k + rescfg.prefetch_margin
    slot_bytes = moe_layers * (slots + 1) * expert_bytes

    kv_bytes = 0
    if cfg.uses_kv_cache:
        a = cfg.attention
        for k in cfg.layer_kinds:
            if k in ("attn_mlp", "attn_moe", "local_attn"):
                cap = min(a.window, cache_len) if (k == "local_attn" and a.window) else cache_len
                kv_bytes += 2 * batch * cap * a.num_kv_heads * a.head_dim * dtype_bytes
    static_bytes = _attention_static_bytes(cfg, dtype_bytes)
    act_bytes = 8 * batch * cfg.d_model * dtype_bytes * 16
    total = slot_bytes + kv_bytes + static_bytes + act_bytes

    if rescfg.mode != "full" and slots < min_slots:
        return FeasibilityReport(
            False,
            f"num_slots={slots} < top_k({m.top_k}) + prefetch_margin"
            f"({rescfg.prefetch_margin}) = {min_slots}: no startup margin",
            slot_bytes, kv_bytes, static_bytes, act_bytes, total,
            rescfg.hbm_budget_bytes, min_slots,
        )
    if rescfg.hbm_budget_bytes is not None and total > rescfg.hbm_budget_bytes:
        return FeasibilityReport(
            False,
            f"resident bytes {total/2**30:.2f} GiB exceed budget "
            f"{rescfg.hbm_budget_bytes/2**30:.2f} GiB",
            slot_bytes, kv_bytes, static_bytes, act_bytes, total,
            rescfg.hbm_budget_bytes, min_slots,
        )
    return FeasibilityReport(
        True, "ok", slot_bytes, kv_bytes, static_bytes, act_bytes, total,
        rescfg.hbm_budget_bytes, min_slots,
    )


class RotaryResidencyManager:
    """Owns residency state for every MoE layer of one model instance."""

    def __init__(
        self,
        cfg: ModelConfig,
        rescfg: ResidencyConfig,
        host_experts: List[Dict[str, np.ndarray]],   # per MoE layer: {w_*: [E, ...]}
        *,
        batch: int,
        cache_len: int,
        cost: Optional[CostModel] = None,
        stats: Optional[EngineStats] = None,
        seed: int = 0,
        tracer=None,
        metrics=None,
    ):
        report = check_feasibility(cfg, rescfg, batch=batch, cache_len=cache_len)
        if not report.ok:
            raise InitializationError(report.reason)
        self.cfg = cfg
        self.rescfg = rescfg
        self.report = report
        self.cost = cost or CostModel()
        self.stats = stats or EngineStats()
        # optional observability handles threaded by the owning engine; both
        # default to None and every emission site is guarded, so the
        # untraced hot path is untouched
        self.tracer = resolve_tracer(tracer)
        self.metrics = metrics
        self.host_experts = host_experts
        m = cfg.moe
        slots = rescfg.num_slots or m.num_experts
        if rescfg.mode == "full":
            slots = m.num_experts
        self.num_slots = slots
        # batched uploads may donate the replaced device buffers; engines whose
        # decode path never holds residency snapshots across a rotation (the
        # fused whole-stack step, the serving tick) flip this on
        self.donate_buffers = False
        dtype = jnp.dtype(cfg.dtype)
        self.stores: List[SlotStore] = []
        self.policies: List[ResidencyPolicy] = []
        for li, hw in enumerate(host_experts):
            shapes = {name: tuple(w.shape[1:]) for name, w in hw.items()}
            store = SlotStore(
                slots, shapes, dtype, rescfg.quantization,
                group_size=rescfg.quant_group_size,
            )
            policy = make_policy(rescfg.mode, m.num_experts, slots, rescfg, seed=seed + li)
            # full policy: preload everything (identity LUT) in one batch
            if rescfg.mode == "full":
                self.stats.bytes_uploaded += store.write_batch(
                    list(range(m.num_experts)), dict(hw)
                )
            self.stores.append(store)
            self.policies.append(policy)
        # persistent device-resident LUT per layer (patched incrementally on
        # rotation; never re-materialized per decode layer)
        self._lut_dev: List[Optional[jnp.ndarray]] = [None] * len(host_experts)
        # ONE generation counter keys every stacked device copy (slot planes
        # AND the stacked LUT plane): bumped whenever live residency content
        # changes — a live upload, a shadow flip. ``stacked_residency`` returns
        # its persistent planes untouched while generations match, else
        # scatters only the dirty slots tracked per layer below.
        self.generation = 0
        self._planes: Optional[Tuple[Any, ...]] = None
        self._planes_gen = -1
        self._stacked_dirty: List[set] = [set() for _ in host_experts]
        # MoE layer -> (segment index, rep) once planes exist: the upload
        # write-through path patches the layer's plane rows in the same fused
        # dispatch as the store scatter
        self._seg_of_layer: Dict[int, Tuple[int, int]] = {}
        # -- predictive prefetch (double-buffered generations) --------------
        # Enabled by the owning engine via ``enable_prefetch``. While a window
        # computes, ``begin_prefetch`` ships the SIMULATED next transition's
        # uploads into each store's shadow generation; the boundary's
        # authoritative transition then confirms (pointer flip), corrects
        # (mispredicted slots re-uploaded into the shadow BEFORE the flip), or
        # catches up (device-to-device copy for slots the shadow merely lags
        # on). ``_pending`` holds the speculative plan between the two.
        self._prefetch_enabled = False
        self._pending: Optional[List[List[Tuple[int, int, bool]]]] = None
        self._live_contents: Optional[List[Dict[int, int]]] = None
        self._shadow_contents: Optional[List[Dict[int, int]]] = None
        # adaptive speculation cadence: a stale forecast on near-uniform
        # routing mostly simulates EMPTY plans, so consecutive empties back
        # the re-simulation interval off exponentially (any landed plan
        # resets it) — the planner's host cost then tracks its hit rate
        self._sim_backoff = 1
        self._sim_skip = 0

    # ------------------------------------------------------------------
    def _transition(
        self,
        layer: int,
        demand: np.ndarray,
        steer: Optional[np.ndarray] = None,
    ) -> List[Tuple[int, int]]:
        """Run the policy's proactive transition (ring move + LUT updates) and
        account its rotation decision; returns the loads WITHOUT executing
        them — the window rotation path coalesces loads across steps before
        uploading. ``steer`` is the fresh pre-gating sample predictive
        steering retargets slots on (ignored at margin 0, the sync baseline)."""
        policy = self.policies[layer]
        loads = policy.prepare(demand, steer)
        ls = self.stats.layer(layer)
        decision = getattr(policy, "last_decision", None)
        if decision is not None:
            if decision.reverse_jump:
                ls.reverse_rotations += 1
            elif decision.delta:
                ls.forward_rotations += 1
        return loads

    def prepare_layer(
        self,
        layer: int,
        demand: np.ndarray,
        clock: Optional[TransferClock] = None,
        steer: Optional[np.ndarray] = None,
    ) -> int:
        """Run the proactive policy transition; execute uploads. Returns bytes."""
        loads = self._transition(layer, demand, steer)
        moved = self._execute_loads(layer, loads)
        ls = self.stats.layer(layer)
        ls.loads += len(loads)
        ls.bytes_loaded += moved
        if clock is not None:
            clock.prefetch(moved)
        return moved

    def _execute_loads(
        self, layer: int, loads: List[Tuple[int, int]], *, shadow: bool = False
    ) -> int:
        """Upload ``loads`` as ONE stacked scatter per weight tensor (not one
        dispatch per expert); old buffers are donated when the owning engine
        marked it safe. ``shadow`` lands the bytes in the store's shadow
        generation (speculative prefetch: the in-flight launch keeps reading
        untouched live buffers) instead of the live one."""
        if not loads:
            return 0
        hw = self.host_experts[layer]
        store = self.stores[layer]
        experts = np.asarray([e for e, _ in loads], np.int64)
        slots = [s for _, s in loads]
        if (
            not shadow
            and self._planes is not None
            and store.quantization is None
            and layer in self._seg_of_layer
        ):
            moved = self._write_through_loads(layer, slots, experts)
        else:
            before = store.dispatches
            moved = store.write_batch(
                slots, {n: hw[n][experts] for n in hw},
                donate=self.donate_buffers, shadow=shadow,
            )
            self.stats.upload_dispatches += store.dispatches - before
            self.stats.device_dispatches += store.dispatches - before
            self.stats.bytes_uploaded += moved
            if not shadow:
                self._stacked_dirty[layer].update(int(s) for _, s in loads)
                self.generation += 1
        if self._live_contents is not None:
            tracked = self._shadow_contents if shadow else self._live_contents
            for e, s in loads:
                tracked[layer][int(s)] = int(e)
        tr = self.tracer
        if tr is not None:
            tr.instant("upload", "prefetch" if shadow else "rotation",
                       args={"layer": layer, "bytes": moved,
                             "n": len(loads), "shadow": shadow})
        if self.metrics is not None:
            self.metrics.histogram(
                "upload_bytes", "bytes per slot-upload dispatch",
                buckets=BYTES_BUCKETS,
            ).observe(moved)
        return moved

    def _write_through_loads(
        self, layer: int, slots: List[int], experts: np.ndarray
    ) -> int:
        """Live upload fused with the plane patch: one compiled dispatch lands
        the host rows in the layer's store buffers AND its stacked slot-plane
        rows AND refreshes the stacked LUT row, replacing the store scatter +
        deferred ``stacked_residency`` patch pair. Unquantized stores only —
        a quantized plane holds the dequantized view, which only the store's
        two-phase path derives. Bit-exactness: the plane rows receive exactly
        the bytes the deferred d2d patch would have gathered from the store."""
        store = self.stores[layer]
        hw = self.host_experts[layer]
        lut = self.policies[layer].lut
        si, rep = self._seg_of_layer[layer]
        seg = self._planes[si]
        idx_np = np.asarray(slots, np.int32)
        vals = {n: np.asarray(hw[n][experts], store.dtype) for n in hw}
        moved = sum(int(v.nbytes) for v in vals.values())
        pad = _bucket_rows(idx_np, lut.num_slots)
        if pad.size > idx_np.size:
            extra = pad.size - idx_np.size
            vals = {
                n: np.concatenate([v, np.repeat(v[-1:], extra, axis=0)])
                for n, v in vals.items()
            }
        fn = _write_through_donated if self.donate_buffers else _write_through
        store.buffers, seg["slots"], seg["lut"] = fn(
            store.buffers, seg["slots"], seg["lut"],
            jnp.int32(rep), jnp.asarray(pad), vals, jnp.asarray(lut.e2s),
        )
        store.version += 1
        store.dispatches += 1
        store.bytes_uploaded += moved
        lut.take_dirty("stacked")        # the fused row set absorbed it
        self.stats.upload_dispatches += 1
        self.stats.device_dispatches += 1
        self.stats.bytes_uploaded += moved
        self.generation += 1
        # the planes are current for THIS layer; they lag only if another
        # layer still holds a dirty backlog — keep the generation key honest
        if not any(self._stacked_dirty) and not any(
            p.lut.dirty_count("stacked") for p in self.policies
        ):
            self._planes_gen = self.generation
        return moved

    def resolve(
        self, layer: int, ids: np.ndarray, clock: Optional[TransferClock] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Routed ids [T, k] -> (lut array [E], miss mask [T, k]).

        LRU-style policies may answer a miss with a blocking load (charged to the
        clock as a stall); others leave misses to host compute.
        """
        policy = self.policies[layer]
        policy.touch(np.unique(ids))
        lut = policy.lut
        miss = lut.e2s[ids] == lut.miss
        if miss.any():
            for e in np.unique(ids[miss]):
                load = policy.on_miss(int(e))
                if load is not None:
                    moved = self._execute_loads(layer, [load])
                    ls = self.stats.layer(layer)
                    ls.loads += 1
                    ls.bytes_loaded += moved
                    if clock is not None:
                        clock.blocking(moved)
            miss = lut.e2s[ids] == lut.miss
        ls = self.stats.layer(layer)
        ls.hits += int((~miss).sum())
        ls.misses += int(miss.sum())
        return lut.as_array(), miss

    # ------------------------------------------------------------------
    def device_lut(self, layer: int) -> jnp.ndarray:
        """The persistent device copy of ``layer``'s LUT.

        First call uploads the full [E] int32 table; later calls patch only the
        entries the policy mutated since (``SlotLUT.take_dirty``), so steady-
        state rotation costs a handful of scattered int32 updates instead of a
        fresh host->device array per MoE layer per decode step.
        """
        lut = self.policies[layer].lut
        cached = self._lut_dev[layer]
        if cached is None:
            lut.take_dirty()
            cached = jnp.asarray(lut.as_array())
        elif lut.dirty_count():
            old = cached
            if lut.dirty_count() > lut.num_experts // 2:
                # full re-upload beats a near-total scatter; the replaced
                # device array is dropped eagerly instead of waiting for GC
                lut.take_dirty()
                cached = jnp.asarray(lut.as_array())
                if self.donate_buffers:
                    old.delete()
            else:
                idx = lut.take_dirty()
                patch = scatter_set_donated if self.donate_buffers else scatter_set
                cached = patch(
                    old, jnp.asarray(idx, jnp.int32), jnp.asarray(lut.e2s[idx])
                )
                self.stats.lut_patch_dispatches += 1
                self.stats.device_dispatches += 1
        self._lut_dev[layer] = cached
        return cached

    def record_routing(self, layer: int, ids: np.ndarray, miss: np.ndarray) -> None:
        """Hit/miss accounting + policy usage feedback for routing that was
        classified ON DEVICE (hot path) — the bookkeeping half of ``resolve``
        without the host-side LUT lookup or reactive loads."""
        self.policies[layer].touch(np.unique(ids))
        ls = self.stats.layer(layer)
        ls.hits += int((~miss).sum())
        ls.misses += int(miss.sum())

    def ensure_resident(
        self, layer: int, experts: np.ndarray, avoid: np.ndarray
    ) -> Optional[List[Tuple[int, int]]]:
        """Make ``experts`` resident NOW (miss-relaunch correction): assign
        each missing one a slot whose current occupant is not in ``avoid``
        (the step's full routed set — evicting one of those would convert a
        hit into a fresh miss), upload as one batched scatter, and leave the
        incremental plane/LUT patching to pick the rows up off the shared
        generation counter. Returns the loads, or None when the residency
        cannot cover (more distinct routed experts than slots) — the caller
        falls back to the host-corrected suffix replay."""
        policy = self.policies[layer]
        lut = policy.lut
        need = [int(e) for e in np.unique(experts) if not lut.is_resident(int(e))]
        if not need:
            return []
        avoid_set = set(int(e) for e in avoid)
        free = list(lut.free_slots)
        evictable = [
            s for s in range(lut.num_slots)
            if lut.s2e[s] >= 0 and int(lut.s2e[s]) not in avoid_set
        ]
        ring = getattr(policy, "ring", None)
        if ring is not None:
            # evict the long-horizon-coldest occupants first: the correction
            # is reactive, so the displaced expert should be the one least
            # likely to be routed (and re-uploaded) next step
            evictable.sort(key=lambda s: (ring.ema[int(lut.s2e[s])], s))
        if len(free) + len(evictable) < len(need):
            return None
        loads: List[Tuple[int, int]] = []
        for e in need:
            slot = free.pop(0) if free else evictable.pop(0)
            lut.assign(e, slot)
            loads.append((e, slot))
        moved = self._execute_loads(layer, loads)
        ls = self.stats.layer(layer)
        ls.loads += len(loads)
        ls.bytes_loaded += moved
        return loads

    # -- predictive prefetch over double-buffered generations ------------
    def enable_prefetch(self, margin: Optional[int] = None) -> None:
        """Switch the manager to double-buffered prefetch mode: materialize a
        shadow generation per store, start tracking slot contents of both
        generations, and hand every policy its steering margin
        (``ResidencyConfig.prefetch_margin`` unless overridden). Must never be
        called on the synchronous baseline — the margin changes which experts
        transitions target (hotter, off-ring ones), which is exactly what
        shrinks the miss rate prefetch needs to pay for itself."""
        if self._prefetch_enabled:
            return
        if margin is None:
            margin = self.rescfg.prefetch_margin
        for p in self.policies:
            p.prefetch_margin = int(margin)
        self._live_contents = [
            {int(s): int(e) for s, e in enumerate(p.lut.s2e) if e >= 0}
            for p in self.policies
        ]
        for store in self.stores:
            store.ensure_shadow()
        self._shadow_contents = [dict(d) for d in self._live_contents]
        self._prefetch_enabled = True

    def begin_prefetch(self, predictor, clock: Optional[TransferClock] = None) -> int:
        """Ship the predicted next transition's uploads into the shadow
        generation — called right after a window launch is dispatched (and its
        telemetry pulls queued), so every bit of this host work and every
        shadow scatter overlaps the in-flight device compute. The plan comes
        from ``simulate_prepare`` on policy clones fed the predictor's current
        EMA (the pre-fold forecast of what the boundary will fold), so the
        authoritative ring/LUT state never advances speculatively. Returns
        bytes shipped; the boundary's ``_commit_layer`` scores the plan."""
        if not self._prefetch_enabled or self._pending is not None:
            return 0
        if self._sim_skip > 0:
            self._sim_skip -= 1
            return 0
        t0 = time.perf_counter()
        pending: List[List[Tuple[int, int, bool]]] = []
        launched = 0
        total = 0
        for l in range(len(self.policies)):
            plan = self.policies[l].simulate_prepare(
                predictor.forecast(l), predictor.steer_signal(l)
            )
            shadow = self._shadow_contents[l]
            entries: List[Tuple[int, int, bool]] = []
            ship: List[Tuple[int, int]] = []
            for e, s in plan:
                shipped = shadow.get(int(s)) != int(e)
                if shipped:
                    ship.append((int(e), int(s)))
                entries.append((int(e), int(s), shipped))
            moved = self._execute_loads(l, ship, shadow=True)
            launched += len(ship)
            total += moved
            pending.append(entries)
            if clock is not None:
                clock.prefetch(moved)
        self._pending = pending
        if launched:
            self._sim_backoff = 1
        else:
            self._sim_skip = self._sim_backoff
            self._sim_backoff = min(self._sim_backoff * 2, 16)
        self.stats.prefetch_launched += launched
        t1 = time.perf_counter()
        # legacy wall-clock accounting; when tracing is on, the SAME window
        # is also recorded as a ``prefetch_ship`` span so ``overlap_ms`` can
        # be derived from the trace and cross-checked against this counter
        self.stats.overlap_ms += (t1 - t0) * 1e3
        tr = self.tracer
        if tr is not None:
            tr.complete("prefetch_ship", "prefetch", t0, t1,
                        args={"bytes": total, "launched": launched})
        return total

    def _commit_layer(
        self,
        layer: int,
        loads: List[Tuple[int, int]],
        clock: Optional[TransferClock] = None,
    ) -> int:
        """Boundary reconciliation for one layer: score the speculative plan
        against the authoritative coalesced ``loads``, fix every slot where
        the shadow generation disagrees with the required post-transition
        contents, then flip. Order matters for exactness — corrections and
        catch-up copies land BEFORE the flip, so the generation the next
        launch gathers from is bit-identical to what the synchronous path
        would have produced with plain live uploads."""
        store = self.stores[layer]
        live = self._live_contents[layer]
        shadow = self._shadow_contents[layer]
        required = dict(live)
        for e, s in loads:
            required[int(s)] = int(e)
        plan = self._pending[layer] if self._pending is not None else []
        hits = 0
        wasted = 0
        useful = 0
        for e, s, shipped in plan:
            if required.get(s) == e:
                hits += 1
                if shipped:
                    useful += 1
            elif shipped:
                wasted += 1
        self.stats.prefetch_hits += hits
        self.stats.prefetch_wasted_bytes += wasted * store.bytes_per_expert
        tr = self.tracer
        if not loads:
            # nothing rotated: keep the live generation, let the shadow drift
            # (any speculative writes become next boundary's catch-up slots)
            if tr is not None and plan:
                tr.instant("prefetch_commit", "prefetch",
                           args={"layer": layer, "hits": hits,
                                 "wasted": wasted, "outcome": "drift"})
            return 0
        if useful == 0:
            # the shadow holds no byte this transition can reuse: the flip
            # protocol (corrections + d2d catch-up + pointer swap) would cost
            # strictly more dispatches than the synchronous path for zero
            # saved upload — take the plain live upload and let the shadow
            # keep drifting until a speculative plan actually lands
            moved = self._execute_loads(layer, loads)
            ls = self.stats.layer(layer)
            ls.loads += len(loads)
            ls.bytes_loaded += moved
            if clock is not None:
                clock.prefetch(moved)
            if tr is not None:
                tr.instant("prefetch_commit", "prefetch",
                           args={"layer": layer, "hits": hits,
                                 "wasted": wasted,
                                 "outcome": "live_fallback"})
            return moved
        # (1) mispredicted / unpredicted load slots: host-upload corrections
        corrections = [(e, s) for e, s in loads if shadow.get(int(s)) != int(e)]
        moved = self._execute_loads(layer, corrections, shadow=True)
        # (2) slots the shadow lags on (stale from drift or wasted writes):
        # device-to-device copy from live — no host-link traffic
        stale = sorted(
            s for s in set(live) | set(shadow) if shadow.get(s) != required.get(s)
        )
        if stale:
            n = store.sync_shadow_slots(stale, donate=self.donate_buffers)
            self.stats.device_dispatches += n
            for s in stale:
                shadow[s] = required[s]
        # (3) pointer flip: corrected shadow becomes live
        store.flip()
        self._live_contents[layer] = required
        self._shadow_contents[layer] = live
        self._stacked_dirty[layer].update(int(s) for _, s in loads)
        self.generation += 1
        if tr is not None:
            tr.instant("prefetch_commit", "prefetch",
                       args={"layer": layer, "hits": hits, "wasted": wasted,
                             "corrections": len(corrections),
                             "stale": len(stale), "outcome": "flip"})
        ls = self.stats.layer(layer)
        ls.loads += len(loads)
        ls.bytes_loaded += moved
        if clock is not None:
            clock.prefetch(moved)
        return moved

    def rotate_from_telemetry(
        self,
        predictor,
        ids: np.ndarray,
        weights: np.ndarray,
        miss: np.ndarray,
        demand_next: np.ndarray,
        clock: Optional[TransferClock] = None,
        record: bool = True,
    ) -> None:
        tr = self.tracer
        if tr is None:
            return self._rotate_from_telemetry(
                predictor, ids, weights, miss, demand_next, clock, record)
        with tr.span("rotation", "rotation", args={"kind": "step"}):
            return self._rotate_from_telemetry(
                predictor, ids, weights, miss, demand_next, clock, record)

    def _rotate_from_telemetry(
        self,
        predictor,                       # DemandPredictor
        ids: np.ndarray,                 # [L, T, k] routed expert ids
        weights: np.ndarray,             # [L, T, k] routing weights
        miss: np.ndarray,                # [L, T, k] device-classified misses
        demand_next: np.ndarray,         # [L, E]; row l = demand of layer (l+1)%L
        clock: Optional[TransferClock] = None,
        record: bool = True,
    ) -> None:
        """Between-step rotation + predictor feedback from ONE compiled step's
        telemetry — the host-side bookkeeping shared by the fused RotaryEngine
        step and the ServingEngine tick.

        ``demand_next`` is the on-device pre-gating signal (layer l's hidden
        through layer l+1's router, already softmaxed and token-averaged); the
        host only folds it into the EMA and runs the ring transition. With
        ``record`` the device-classified hit/miss masks are also accounted
        (the fused engine's replay path records its own authoritative masks
        and passes ``record=False``).
        """
        n = len(self.policies)
        for l in range(n):
            if record:
                self.record_routing(l, ids[l], miss[l])
            predictor.observe(l, ids[l], weights[l])
        for l in range(n):
            nxt = (l + 1) % n
            raw = demand_next[l]
            demand = predictor.update(nxt, raw)
            if self._pending is not None:
                loads = self._coalesce_loads(
                    nxt, self._transition(nxt, demand, steer=raw)
                )
                self._commit_layer(nxt, loads, clock)
            else:
                self.prepare_layer(nxt, demand, clock, steer=raw)
        self._pending = None

    def _coalesce_loads(
        self, layer: int, loads: List[Tuple[int, int]]
    ) -> List[Tuple[int, int]]:
        """Collapse a window's worth of pending loads to the last write per
        slot, dropping writes the LUT no longer references (an expert loaded
        then rotated away within the window never needs to touch the link)."""
        lut = self.policies[layer].lut
        final: Dict[int, int] = {}
        for e, s in loads:
            final[s] = e
        return [(e, s) for s, e in final.items() if lut.s2e[s] == e]

    def rotate_window_from_telemetry(
        self,
        predictor,
        ids: np.ndarray,
        weights: np.ndarray,
        miss: np.ndarray,
        demand_next: np.ndarray,
        clock: Optional[TransferClock] = None,
        record: bool = True,
        accepted: Optional[np.ndarray] = None,
    ) -> None:
        tr = self.tracer
        if tr is None:
            return self._rotate_window_from_telemetry(
                predictor, ids, weights, miss, demand_next, clock, record,
                accepted)
        with tr.span("rotation", "rotation", args={"kind": "window"}):
            return self._rotate_window_from_telemetry(
                predictor, ids, weights, miss, demand_next, clock, record,
                accepted)

    def _rotate_window_from_telemetry(
        self,
        predictor,                       # DemandPredictor
        ids: np.ndarray,                 # [K, L, T, k] routed ids per window step
        weights: np.ndarray,             # [K, L, T, k]
        miss: np.ndarray,                # [K, L, T, k]
        demand_next: np.ndarray,         # [K, L, E]; [s, l] = step s's demand
                                         # for layer (l+1)%L
        clock: Optional[TransferClock] = None,
        record: bool = True,
        accepted: Optional[np.ndarray] = None,
    ) -> None:
        """Window-boundary rotation from a speculative window's telemetry.

        The HOST-side transitions (EMA folds, ring moves, LUT updates) run
        once per committed step in step order — residency after the window is
        bit-identical to feeding the same steps through
        :meth:`rotate_from_telemetry` one at a time (the property the
        rotation-equivalence tests pin). What the window amortizes is the
        LINK: slot uploads coalesce to the last write per slot and ship as
        ONE batched scatter per weight tensor per layer per window, and the
        device LUT is patched once per layer instead of once per step.

        ``accepted`` (optional, [B] per-row committed counts) supports the
        serving engine's ragged acceptance: step ``s`` contributes a row's
        routing to the hit/miss accounting and the predictor EMA only while
        ``s < accepted[row]`` — a rejected position re-decodes next window
        and is recorded THEN, never twice, and routing computed from wrong
        drafted inputs never pollutes prediction. (The rotary engine commits
        batch-uniformly and pre-slices instead, leaving ``accepted=None``.)

        Sampled decode keeps the same commit discipline on its PRNG streams:
        a draw's key is ``fold_in(row_key, position)``, so a rejected
        position re-draws with the SAME key when it re-decodes — the stream
        commits like residency, per accepted position, and the emitted
        tokens depend only on (seed, position), never on window boundaries
        or batch composition.
        """
        n = len(self.policies)
        if accepted is not None:
            accepted = np.asarray(accepted)
            k_eff = int(accepted.max(initial=0))
            if k_eff == 0:
                return
            ids, weights, miss, demand_next = (
                a[:k_eff] for a in (ids, weights, miss, demand_next)
            )
        k_steps = ids.shape[0]

        def rows(s: int):
            return slice(None) if accepted is None else accepted > s

        pending: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
        if record:
            for s in range(k_steps):
                for l in range(n):
                    self.record_routing(l, ids[s, l][rows(s)], miss[s, l][rows(s)])
        for l in range(n):
            nxt = (l + 1) % n
            if accepted is None:
                smoothed = predictor.fold_window(
                    nxt, ids[:, nxt], weights[:, nxt], demand_next[:, l]
                )
            else:
                smoothed = []
                for s in range(k_steps):
                    sel = rows(s)
                    predictor.observe(nxt, ids[s, nxt][sel], weights[s, nxt][sel])
                    smoothed.append(predictor.update(nxt, demand_next[s, l]))
            for s in range(k_steps):
                pending[nxt].extend(
                    self._transition(nxt, smoothed[s], steer=demand_next[s, l])
                )
        for l in range(n):
            loads = self._coalesce_loads(l, pending[l])
            if self._pending is not None:
                self._commit_layer(l, loads, clock)
                continue
            moved = self._execute_loads(l, loads)
            ls = self.stats.layer(l)
            ls.loads += len(loads)
            ls.bytes_loaded += moved
            if clock is not None:
                clock.prefetch(moved)
        self._pending = None

    # ------------------------------------------------------------------
    def layer_residency(self, layer: int) -> Dict[str, Any]:
        """{slots, lut} pytree for ``decode_model`` / ``_apply_block``."""
        return {
            "slots": self.stores[layer].as_pytree(),
            "lut": self.device_lut(layer),
        }

    def stacked_residency(self) -> Any:
        """Residency pytree stacked per segment (whole-model compiled path).

        PERSISTENT planes keyed on the manager's single ``generation`` counter
        (shared by the slot planes and the stacked LUT plane): the first call
        stacks full per-segment planes; every later call scatters only the
        slots that actually rotated since (``_stacked_dirty`` per layer, the
        LUT's "stacked" dirty backlog), donating the replaced plane when the
        owning engine marked donation safe. A boundary that rotated one layer
        costs a handful of row scatters instead of re-stacking whole segments.
        """
        if self._planes is not None and self._planes_gen == self.generation:
            return self._planes
        if self._planes is None:
            segs: List[Any] = []
            li = 0
            for unit, reps in self.cfg.segments:
                if not any(k == "attn_moe" for k in unit):
                    segs.append({})
                    continue
                per_rep = [self.layer_residency(li + r) for r in range(reps)]
                for r in range(reps):
                    # the full stack absorbs every backlog for these layers
                    self._stacked_dirty[li + r].clear()
                    self.policies[li + r].lut.take_dirty("stacked")
                    self._seg_of_layer[li + r] = (len(segs), r)
                li += reps
                segs.append({
                    "slots": {
                        n: jnp.stack([p["slots"][n] for p in per_rep])
                        for n in per_rep[0]["slots"]
                    },
                    "lut": jnp.stack([p["lut"] for p in per_rep]),
                })
            self._planes = tuple(segs)
            self._planes_gen = self.generation
            return self._planes
        patch = _seg_patch_rows_donated if self.donate_buffers else _seg_patch_rows
        lut_set = _lut_row_set_donated if self.donate_buffers else _lut_row_set
        li = 0
        for seg, (unit, reps) in zip(self._planes, self.cfg.segments):
            if not seg:
                continue
            for r in range(reps):
                l = li + r
                rep_i = jnp.int32(r)
                dirty = self._stacked_dirty[l]
                if dirty:
                    idx_np = _bucket_rows(
                        np.asarray(sorted(dirty), np.int32),
                        self.policies[l].lut.num_slots,
                    )
                    idx = jnp.asarray(idx_np)
                    dirty.clear()
                    src = self.stores[l].as_pytree()
                    seg["slots"] = patch(seg["slots"], rep_i, idx, src)
                    self.stats.device_dispatches += 1
                lut = self.policies[l].lut
                lidx = lut.take_dirty("stacked")
                if len(lidx):
                    seg["lut"] = lut_set(seg["lut"], rep_i, jnp.asarray(lut.e2s))
                    self.stats.lut_patch_dispatches += 1
                    self.stats.device_dispatches += 1
            li += reps
        self._planes_gen = self.generation
        return self._planes

    def host_expert_flops(self, tokens: int) -> float:
        m = self.cfg.moe
        mats = 3 if self.cfg.mlp == "swiglu" else 2
        return 2.0 * tokens * mats * self.cfg.d_model * m.expert_d_ff
