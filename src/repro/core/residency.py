"""RotaryResidencyManager: per-MoE-layer slots + policy + LUT + accounting,
plus the startup feasibility check that reproduces the paper's Fig. 3 failure.

The manager owns host-side expert weights (the "warehouse" — full model in host
memory) and a ``SlotStore`` per MoE layer (the rotating accelerator-resident
subset). ``prepare_layer`` runs the policy's proactive transition and executes
the resulting uploads; ``resolve`` maps routed expert ids through the LUT and
classifies hits/misses.

Exactness invariant: residency state NEVER changes what an engine emits —
only where compute happens. Misses are classified (in-kernel on the hot
paths, via ``resolve`` on the walk) and corrected by the owning engine
(host GEMM + suffix replay / KV rollback), so outputs stay bit-identical to
full residency; under int8/int4 stores the correction runs against
dequant∘quant weights, keeping quantized serving exactness-clean within its
format.

Telemetry→transition map (the host half of each compiled step): the fused
engines hand one step's device-classified telemetry to
``rotate_from_telemetry`` (or a speculative window's to
``rotate_window_from_telemetry``, per-committed-step-equivalent with
uploads coalesced to the last write per slot): ``ids``/``weights`` fold into
the ``DemandPredictor`` EMA, ``miss`` + ``ids`` land in ``LayerStats`` via
``record_routing``, and ``demand_next`` (the pre-gating GEMM: on-device for
decode, the shared chunk-boundary program for chunked prefill) drives
``policy.prepare`` → ``RotaryRing`` transition → batched ``SlotStore``
uploads (one donated scatter per weight tensor per rotated layer) and
incremental device-LUT patches.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig, ResidencyConfig
from repro.core.policies import ResidencyPolicy, make_policy
from repro.core.slots import (
    SlotStore,
    quantized_expert_bytes,
    scatter_set,
    scatter_set_donated,
)
from repro.core.stats import EngineStats
from repro.core.transfer import CostModel, TransferClock


class InitializationError(RuntimeError):
    """Startup failure (the paper's 'failed to initialize', Fig. 3 N36/4096)."""


@dataclass
class FeasibilityReport:
    ok: bool
    reason: str
    slot_bytes: int
    kv_bytes: int
    static_bytes: int            # non-MoE weights always resident
    activation_bytes: int
    total_bytes: int
    budget_bytes: Optional[int]
    min_slots: int


def _attention_static_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """Weights that always stay on-device: everything except routed experts."""
    from repro.models.params import analytic_params

    total = analytic_params(cfg, active_only=False)
    if cfg.has_moe:
        m = cfg.moe
        mats = 3 if cfg.mlp == "swiglu" else 2
        routed = sum(
            m.num_experts * mats * cfg.d_model * m.expert_d_ff
            for k in cfg.layer_kinds if k == "attn_moe"
        )
        total -= routed
    return total * dtype_bytes


def check_feasibility(
    cfg: ModelConfig,
    rescfg: ResidencyConfig,
    *,
    batch: int,
    cache_len: int,
    dtype_bytes: int = 2,
) -> FeasibilityReport:
    """Two-sided startup check:

    (1) capacity floor — ``num_slots >= top_k + prefetch_margin`` so one step's
        routed experts plus in-flight prefetch fit (the N36-analog violates it);
    (2) memory ceiling — slots + pinned shared + KV + static weights +
        activation bound must fit ``hbm_budget_bytes``.
    """
    m = cfg.moe
    moe_layers = sum(1 for k in cfg.layer_kinds if k == "attn_moe")
    mats = 3 if cfg.mlp == "swiglu" else 2
    # exact packed bytes per expert (int4 includes its group scale/min planes)
    shapes = {"w_up": (cfg.d_model, m.expert_d_ff), "w_down": (m.expert_d_ff, cfg.d_model)}
    if mats == 3:
        shapes["w_gate"] = (cfg.d_model, m.expert_d_ff)
    expert_bytes = quantized_expert_bytes(
        shapes, rescfg.quantization, dtype_bytes, rescfg.quant_group_size
    )
    slots = rescfg.num_slots or m.num_experts
    min_slots = m.top_k + rescfg.prefetch_margin
    slot_bytes = moe_layers * (slots + 1) * expert_bytes

    kv_bytes = 0
    if cfg.uses_kv_cache:
        a = cfg.attention
        for k in cfg.layer_kinds:
            if k in ("attn_mlp", "attn_moe", "local_attn"):
                cap = min(a.window, cache_len) if (k == "local_attn" and a.window) else cache_len
                kv_bytes += 2 * batch * cap * a.num_kv_heads * a.head_dim * dtype_bytes
    static_bytes = _attention_static_bytes(cfg, dtype_bytes)
    act_bytes = 8 * batch * cfg.d_model * dtype_bytes * 16
    total = slot_bytes + kv_bytes + static_bytes + act_bytes

    if rescfg.mode != "full" and slots < min_slots:
        return FeasibilityReport(
            False,
            f"num_slots={slots} < top_k({m.top_k}) + prefetch_margin"
            f"({rescfg.prefetch_margin}) = {min_slots}: no startup margin",
            slot_bytes, kv_bytes, static_bytes, act_bytes, total,
            rescfg.hbm_budget_bytes, min_slots,
        )
    if rescfg.hbm_budget_bytes is not None and total > rescfg.hbm_budget_bytes:
        return FeasibilityReport(
            False,
            f"resident bytes {total/2**30:.2f} GiB exceed budget "
            f"{rescfg.hbm_budget_bytes/2**30:.2f} GiB",
            slot_bytes, kv_bytes, static_bytes, act_bytes, total,
            rescfg.hbm_budget_bytes, min_slots,
        )
    return FeasibilityReport(
        True, "ok", slot_bytes, kv_bytes, static_bytes, act_bytes, total,
        rescfg.hbm_budget_bytes, min_slots,
    )


class RotaryResidencyManager:
    """Owns residency state for every MoE layer of one model instance."""

    def __init__(
        self,
        cfg: ModelConfig,
        rescfg: ResidencyConfig,
        host_experts: List[Dict[str, np.ndarray]],   # per MoE layer: {w_*: [E, ...]}
        *,
        batch: int,
        cache_len: int,
        cost: Optional[CostModel] = None,
        stats: Optional[EngineStats] = None,
        seed: int = 0,
    ):
        report = check_feasibility(cfg, rescfg, batch=batch, cache_len=cache_len)
        if not report.ok:
            raise InitializationError(report.reason)
        self.cfg = cfg
        self.rescfg = rescfg
        self.report = report
        self.cost = cost or CostModel()
        self.stats = stats or EngineStats()
        self.host_experts = host_experts
        m = cfg.moe
        slots = rescfg.num_slots or m.num_experts
        if rescfg.mode == "full":
            slots = m.num_experts
        self.num_slots = slots
        # batched uploads may donate the replaced device buffers; engines whose
        # decode path never holds residency snapshots across a rotation (the
        # fused whole-stack step, the serving tick) flip this on
        self.donate_buffers = False
        dtype = jnp.dtype(cfg.dtype)
        self.stores: List[SlotStore] = []
        self.policies: List[ResidencyPolicy] = []
        for li, hw in enumerate(host_experts):
            shapes = {name: tuple(w.shape[1:]) for name, w in hw.items()}
            store = SlotStore(
                slots, shapes, dtype, rescfg.quantization,
                group_size=rescfg.quant_group_size,
            )
            policy = make_policy(rescfg.mode, m.num_experts, slots, rescfg, seed=seed + li)
            # full policy: preload everything (identity LUT) in one batch
            if rescfg.mode == "full":
                self.stats.bytes_uploaded += store.write_batch(
                    list(range(m.num_experts)), dict(hw)
                )
            self.stores.append(store)
            self.policies.append(policy)
        # persistent device-resident LUT per layer (patched incrementally on
        # rotation; never re-materialized per decode layer) + stacked-tree cache
        self._lut_dev: List[Optional[jnp.ndarray]] = [None] * len(host_experts)
        self._seg_cache: Dict[int, Tuple[Tuple[int, ...], Any]] = {}

    # ------------------------------------------------------------------
    def _transition(self, layer: int, demand: np.ndarray) -> List[Tuple[int, int]]:
        """Run the policy's proactive transition (ring move + LUT updates) and
        account its rotation decision; returns the loads WITHOUT executing
        them — the window rotation path coalesces loads across steps before
        uploading."""
        policy = self.policies[layer]
        loads = policy.prepare(demand)
        ls = self.stats.layer(layer)
        decision = getattr(policy, "last_decision", None)
        if decision is not None:
            if decision.reverse_jump:
                ls.reverse_rotations += 1
            elif decision.delta:
                ls.forward_rotations += 1
        return loads

    def prepare_layer(self, layer: int, demand: np.ndarray, clock: Optional[TransferClock] = None) -> int:
        """Run the proactive policy transition; execute uploads. Returns bytes."""
        loads = self._transition(layer, demand)
        moved = self._execute_loads(layer, loads)
        ls = self.stats.layer(layer)
        ls.loads += len(loads)
        ls.bytes_loaded += moved
        if clock is not None:
            clock.prefetch(moved)
        return moved

    def _execute_loads(self, layer: int, loads: List[Tuple[int, int]]) -> int:
        """Upload ``loads`` as ONE stacked scatter per weight tensor (not one
        dispatch per expert); old buffers are donated when the owning engine
        marked it safe."""
        if not loads:
            return 0
        hw = self.host_experts[layer]
        store = self.stores[layer]
        experts = np.asarray([e for e, _ in loads], np.int64)
        slots = [s for _, s in loads]
        before = store.dispatches
        moved = store.write_batch(
            slots, {n: hw[n][experts] for n in hw}, donate=self.donate_buffers
        )
        self.stats.upload_dispatches += store.dispatches - before
        self.stats.device_dispatches += store.dispatches - before
        self.stats.bytes_uploaded += moved
        return moved

    def resolve(
        self, layer: int, ids: np.ndarray, clock: Optional[TransferClock] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Routed ids [T, k] -> (lut array [E], miss mask [T, k]).

        LRU-style policies may answer a miss with a blocking load (charged to the
        clock as a stall); others leave misses to host compute.
        """
        policy = self.policies[layer]
        policy.touch(np.unique(ids))
        lut = policy.lut
        miss = lut.e2s[ids] == lut.miss
        if miss.any():
            for e in np.unique(ids[miss]):
                load = policy.on_miss(int(e))
                if load is not None:
                    moved = self._execute_loads(layer, [load])
                    ls = self.stats.layer(layer)
                    ls.loads += 1
                    ls.bytes_loaded += moved
                    if clock is not None:
                        clock.blocking(moved)
            miss = lut.e2s[ids] == lut.miss
        ls = self.stats.layer(layer)
        ls.hits += int((~miss).sum())
        ls.misses += int(miss.sum())
        return lut.as_array(), miss

    # ------------------------------------------------------------------
    def device_lut(self, layer: int) -> jnp.ndarray:
        """The persistent device copy of ``layer``'s LUT.

        First call uploads the full [E] int32 table; later calls patch only the
        entries the policy mutated since (``SlotLUT.take_dirty``), so steady-
        state rotation costs a handful of scattered int32 updates instead of a
        fresh host->device array per MoE layer per decode step.
        """
        lut = self.policies[layer].lut
        cached = self._lut_dev[layer]
        if cached is None:
            lut.take_dirty()
            cached = jnp.asarray(lut.as_array())
        elif lut.dirty_count():
            old = cached
            if lut.dirty_count() > lut.num_experts // 2:
                # full re-upload beats a near-total scatter; the replaced
                # device array is dropped eagerly instead of waiting for GC
                lut.take_dirty()
                cached = jnp.asarray(lut.as_array())
                if self.donate_buffers:
                    old.delete()
            else:
                idx = lut.take_dirty()
                patch = scatter_set_donated if self.donate_buffers else scatter_set
                cached = patch(
                    old, jnp.asarray(idx, jnp.int32), jnp.asarray(lut.e2s[idx])
                )
                self.stats.lut_patch_dispatches += 1
                self.stats.device_dispatches += 1
        self._lut_dev[layer] = cached
        return cached

    def record_routing(self, layer: int, ids: np.ndarray, miss: np.ndarray) -> None:
        """Hit/miss accounting + policy usage feedback for routing that was
        classified ON DEVICE (hot path) — the bookkeeping half of ``resolve``
        without the host-side LUT lookup or reactive loads."""
        self.policies[layer].touch(np.unique(ids))
        ls = self.stats.layer(layer)
        ls.hits += int((~miss).sum())
        ls.misses += int(miss.sum())

    def rotate_from_telemetry(
        self,
        predictor,                       # DemandPredictor
        ids: np.ndarray,                 # [L, T, k] routed expert ids
        weights: np.ndarray,             # [L, T, k] routing weights
        miss: np.ndarray,                # [L, T, k] device-classified misses
        demand_next: np.ndarray,         # [L, E]; row l = demand of layer (l+1)%L
        clock: Optional[TransferClock] = None,
        record: bool = True,
    ) -> None:
        """Between-step rotation + predictor feedback from ONE compiled step's
        telemetry — the host-side bookkeeping shared by the fused RotaryEngine
        step and the ServingEngine tick.

        ``demand_next`` is the on-device pre-gating signal (layer l's hidden
        through layer l+1's router, already softmaxed and token-averaged); the
        host only folds it into the EMA and runs the ring transition. With
        ``record`` the device-classified hit/miss masks are also accounted
        (the fused engine's replay path records its own authoritative masks
        and passes ``record=False``).
        """
        n = len(self.policies)
        for l in range(n):
            if record:
                self.record_routing(l, ids[l], miss[l])
            predictor.observe(l, ids[l], weights[l])
        for l in range(n):
            nxt = (l + 1) % n
            self.prepare_layer(nxt, predictor.update(nxt, demand_next[l]), clock)

    def _coalesce_loads(
        self, layer: int, loads: List[Tuple[int, int]]
    ) -> List[Tuple[int, int]]:
        """Collapse a window's worth of pending loads to the last write per
        slot, dropping writes the LUT no longer references (an expert loaded
        then rotated away within the window never needs to touch the link)."""
        lut = self.policies[layer].lut
        final: Dict[int, int] = {}
        for e, s in loads:
            final[s] = e
        return [(e, s) for s, e in final.items() if lut.s2e[s] == e]

    def rotate_window_from_telemetry(
        self,
        predictor,                       # DemandPredictor
        ids: np.ndarray,                 # [K, L, T, k] routed ids per window step
        weights: np.ndarray,             # [K, L, T, k]
        miss: np.ndarray,                # [K, L, T, k]
        demand_next: np.ndarray,         # [K, L, E]; [s, l] = step s's demand
                                         # for layer (l+1)%L
        clock: Optional[TransferClock] = None,
        record: bool = True,
        accepted: Optional[np.ndarray] = None,
    ) -> None:
        """Window-boundary rotation from a speculative window's telemetry.

        The HOST-side transitions (EMA folds, ring moves, LUT updates) run
        once per committed step in step order — residency after the window is
        bit-identical to feeding the same steps through
        :meth:`rotate_from_telemetry` one at a time (the property the
        rotation-equivalence tests pin). What the window amortizes is the
        LINK: slot uploads coalesce to the last write per slot and ship as
        ONE batched scatter per weight tensor per layer per window, and the
        device LUT is patched once per layer instead of once per step.

        ``accepted`` (optional, [B] per-row committed counts) supports the
        serving engine's ragged acceptance: step ``s`` contributes a row's
        routing to the hit/miss accounting and the predictor EMA only while
        ``s < accepted[row]`` — a rejected position re-decodes next window
        and is recorded THEN, never twice, and routing computed from wrong
        drafted inputs never pollutes prediction. (The rotary engine commits
        batch-uniformly and pre-slices instead, leaving ``accepted=None``.)
        """
        n = len(self.policies)
        if accepted is not None:
            accepted = np.asarray(accepted)
            k_eff = int(accepted.max(initial=0))
            if k_eff == 0:
                return
            ids, weights, miss, demand_next = (
                a[:k_eff] for a in (ids, weights, miss, demand_next)
            )
        k_steps = ids.shape[0]

        def rows(s: int):
            return slice(None) if accepted is None else accepted > s

        pending: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
        if record:
            for s in range(k_steps):
                for l in range(n):
                    self.record_routing(l, ids[s, l][rows(s)], miss[s, l][rows(s)])
        for l in range(n):
            nxt = (l + 1) % n
            if accepted is None:
                smoothed = predictor.fold_window(
                    nxt, ids[:, nxt], weights[:, nxt], demand_next[:, l]
                )
            else:
                smoothed = []
                for s in range(k_steps):
                    sel = rows(s)
                    predictor.observe(nxt, ids[s, nxt][sel], weights[s, nxt][sel])
                    smoothed.append(predictor.update(nxt, demand_next[s, l]))
            for s in range(k_steps):
                pending[nxt].extend(self._transition(nxt, smoothed[s]))
        for l in range(n):
            loads = self._coalesce_loads(l, pending[l])
            moved = self._execute_loads(l, loads)
            ls = self.stats.layer(l)
            ls.loads += len(loads)
            ls.bytes_loaded += moved
            if clock is not None:
                clock.prefetch(moved)

    # ------------------------------------------------------------------
    def layer_residency(self, layer: int) -> Dict[str, Any]:
        """{slots, lut} pytree for ``decode_model`` / ``_apply_block``."""
        return {
            "slots": self.stores[layer].as_pytree(),
            "lut": self.device_lut(layer),
        }

    def stacked_residency(self) -> Any:
        """Residency pytree stacked per segment (whole-model compiled path).

        Cached per segment keyed on (store.version, lut.version) of every rep:
        a serving tick only rebuilds (and re-uploads) the segments whose slots
        actually rotated since the previous tick.
        """
        segs = []
        li = 0
        for si, (unit, reps) in enumerate(self.cfg.segments):
            if not any(k == "attn_moe" for k in unit):
                segs.append({})
                continue
            key = tuple(
                v
                for r in range(reps)
                for v in (self.stores[li + r].version, self.policies[li + r].lut.version)
            )
            hit = self._seg_cache.get(si)
            if hit is not None and hit[0] == key:
                segs.append(hit[1])
                li += reps
                continue
            per_rep = [self.layer_residency(li + r) for r in range(reps)]
            li += reps
            stacked = {
                "slots": {
                    n: jnp.stack([p["slots"][n] for p in per_rep])
                    for n in per_rep[0]["slots"]
                },
                "lut": jnp.stack([p["lut"] for p in per_rep]),
            }
            self._seg_cache[si] = (key, stacked)
            segs.append(stacked)
        return tuple(segs)

    def host_expert_flops(self, tokens: int) -> float:
        m = self.cfg.moe
        mats = 3 if self.cfg.mlp == "swiglu" else 2
        return 2.0 * tokens * mats * self.cfg.d_model * m.expert_d_ff
