"""Device slot buffers: the rotating accelerator-resident expert store.

One ``SlotStore`` per MoE layer holds ``num_slots + 1`` stacked expert weight
sets — the trailing slot is all-zeros and backs the LUT's MISS sentinel, so the
compiled gather path needs no branches. Writes go through
``jax.lax.dynamic_update_slice`` style ``.at[slot].set`` with donation, the
host->HBM DMA analog.

Quantized stores (``repro.quant`` has the bytes-per-expert table):

* ``int8`` — symmetric per-output-channel int8 + f32 scales (~0.5x f16 link
  bytes);
* ``int4`` — grouped two-nibbles-per-byte packing with per-group f16
  scale + min over the reduction axis (Q4_K_M analog, ~0.28x f16 bytes at
  the default group of 64).

The gather path dequantizes after the take on this CPU host — memoized per
write generation, so a store that didn't rotate never re-dequantizes — while
the Pallas ``moe_gmm`` kernel keeps packed weights in HBM/VMEM and
dequantizes in-register on real TPUs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant import (
    GROUP_SIZE_DEFAULT,
    dequantize_int4,
    int4_tensor_bytes,
    quantize_int4_batch,
)

Params = Dict[str, Any]

QUANTIZATIONS = (None, "int8", "int4")


def quantize_int8(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-output-channel (last-dim) int8. w [.., F] -> (q int8, scale f32)."""
    amax = np.max(np.abs(w), axis=tuple(range(w.ndim - 1)), keepdims=True)
    scale = (amax / 127.0 + 1e-12).astype(np.float32)
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return q, scale.reshape(w.shape[-1])


def quantize_int8_batch(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """``quantize_int8`` over a leading expert axis: w [N, .., F] ->
    (q int8 [N, .., F], scale f32 [N, F]) with per-expert scales identical to
    quantizing each expert alone (the batched upload path must be bit-equal to
    the one-expert path)."""
    amax = np.max(np.abs(w), axis=tuple(range(1, w.ndim - 1)), keepdims=True)
    scale = (amax / 127.0 + 1e-12).astype(np.float32)
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return q, scale.reshape(w.shape[0], w.shape[-1])


@functools.partial(jax.jit, donate_argnums=(0,))
def scatter_set_donated(buf: jax.Array, idx: jax.Array, vals: jax.Array) -> jax.Array:
    return buf.at[idx].set(vals)


@jax.jit
def scatter_set(buf: jax.Array, idx: jax.Array, vals: jax.Array) -> jax.Array:
    return buf.at[idx].set(vals)


@functools.partial(jax.jit, donate_argnums=(0,))
def scatter_copy_rows_donated(dst: jax.Array, src: jax.Array, idx: jax.Array) -> jax.Array:
    """Device-to-device row copy ``dst[idx] = src[idx]`` — the shadow
    generation's catch-up path (slots whose live content the shadow merely
    lags on never touch the host link)."""
    return dst.at[idx].set(src[idx])


@jax.jit
def scatter_copy_rows(dst: jax.Array, src: jax.Array, idx: jax.Array) -> jax.Array:
    return dst.at[idx].set(src[idx])


# pytree-fused upload: every weight-tensor component (and its quantization
# scale/min planes) of one rotation lands in a SINGLE compiled scatter, so a
# slot upload costs one program launch regardless of tensor count — the
# miss-relaunch path uploads on the decode critical path, where per-dispatch
# overhead was the dominant cost of the correction
@functools.partial(jax.jit, donate_argnums=(0,))
def scatter_set_tree_donated(planes, idx, vals):
    return jax.tree_util.tree_map(lambda p, v: p.at[idx].set(v), planes, vals)


@jax.jit
def scatter_set_tree(planes, idx, vals):
    return jax.tree_util.tree_map(lambda p, v: p.at[idx].set(v), planes, vals)


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype: Any) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


class SlotStore:
    """Rotating device-resident buffer for one MoE layer's routed experts."""

    def __init__(
        self,
        num_slots: int,
        weight_shapes: Dict[str, Tuple[int, ...]],   # e.g. w_gate: (D, F)
        dtype: Any,
        quantization: Optional[str] = None,
        group_size: int = GROUP_SIZE_DEFAULT,
    ):
        assert quantization in QUANTIZATIONS, quantization
        self.num_slots = num_slots
        self.dtype = jnp.dtype(dtype)
        self.quantization = quantization
        self.group_size = group_size
        self.version = 0                # bumped per write (stacked-cache key)
        self.dispatches = 0             # scatter launches issued (fused: ONE
                                        # per write_batch, covering every
                                        # tensor component and quant plane)
        self.bytes_uploaded = 0         # cumulative host->device upload bytes
        self.dequant_runs = 0           # lazy host dequantizations executed
        self._pytree_cache: Optional[Params] = None
        self._pytree_version = -1
        # shadow generation (double-buffered slot planes): predictive prefetch
        # writes land here while a compiled launch reads the live buffers; the
        # boundary corrects mispredictions and flips. None until the owning
        # manager first calls ensure_shadow (sync-rotation engines never pay
        # the second plane).
        self._shadow: Optional[Dict[str, Params]] = None
        if quantization == "int8":
            store_dtype = jnp.int8
        elif quantization == "int4":
            store_dtype = jnp.uint8
        else:
            store_dtype = self.dtype
        self.buffers: Params = {
            name: jnp.zeros(
                (num_slots + 1,)
                + (self._packed_shape(shape) if quantization == "int4" else shape),
                store_dtype,
            )
            for name, shape in weight_shapes.items()
        }
        self.scales: Params = {}
        self.mins: Params = {}
        if quantization == "int8":
            self.scales = {
                name: jnp.zeros((num_slots + 1, shape[-1]), jnp.float32)
                for name, shape in weight_shapes.items()
            }
        elif quantization == "int4":
            for name, shape in weight_shapes.items():
                gshape = self._group_shape(shape)
                self.scales[name] = jnp.zeros((num_slots + 1,) + gshape, jnp.float16)
                self.mins[name] = jnp.zeros((num_slots + 1,) + gshape, jnp.float16)

    def _packed_shape(self, shape: Tuple[int, ...]) -> Tuple[int, ...]:
        return shape[:-2] + (shape[-2] // 2, shape[-1])

    def _group_shape(self, shape: Tuple[int, ...]) -> Tuple[int, ...]:
        from repro.quant import effective_group

        g = effective_group(shape[-2], self.group_size)
        return shape[:-2] + (shape[-2] // g, shape[-1])

    @property
    def bytes_per_expert(self) -> int:
        per = 0
        for name, buf in self.buffers.items():
            per += int(np.prod(buf.shape[1:])) * buf.dtype.itemsize
        for tree in (self.scales, self.mins):
            for name, s in tree.items():
                per += int(np.prod(s.shape[1:])) * s.dtype.itemsize
        return per

    @property
    def total_bytes(self) -> int:
        return self.bytes_per_expert * (self.num_slots + 1)

    def write(self, slot: int, expert_weights: Dict[str, np.ndarray]) -> int:
        """Upload one expert into ``slot``. Returns bytes moved host->device."""
        return self.write_batch(
            [slot], {n: np.asarray(w)[None] for n, w in expert_weights.items()}
        )

    def write_batch(
        self,
        slots: Sequence[int],
        stacked_weights: Dict[str, np.ndarray],   # name -> [N, ...] host array
        *,
        donate: bool = False,
        shadow: bool = False,
    ) -> int:
        """Upload N experts in ONE stacked scatter per weight tensor component.

        A rotation that moves N experts costs one ``.at[idx].set`` dispatch per
        tensor component (3 tensors for swiglu; quantized stores add their
        scale/min planes) instead of N per tensor; ``donate`` additionally
        donates the old device buffer to the scatter so steady-state rotation
        allocates nothing (safe only when no snapshot of the buffer is live —
        the fused decode path rotates strictly after replay). ``shadow``
        targets the SHADOW generation instead: an in-flight launch (and the
        replay that may follow it) keeps reading the untouched live buffers,
        which is what lets predictive prefetch ship these bytes during
        compute. Returns bytes moved host->device.
        """
        if not len(slots):
            return 0
        for slot in slots:
            assert 0 <= slot < self.num_slots, f"slot {slot} out of range"
        if shadow:
            self.ensure_shadow()
            buffers, scales, mins = (
                self._shadow["buffers"], self._shadow["scales"], self._shadow["mins"]
            )
        else:
            buffers, scales, mins = self.buffers, self.scales, self.mins
            self.version += 1
        scatter = scatter_set_tree_donated if donate else scatter_set_tree
        idx = jnp.asarray(np.asarray(slots, np.int32))
        # quantize host-side per tensor, then land EVERY plane (packed bytes +
        # scale/min) of every tensor in ONE fused scatter dispatch
        target: Dict[str, Params] = {"q": {}, "s": {}, "m": {}}
        vals: Dict[str, Params] = {"q": {}, "s": {}, "m": {}}
        moved = 0
        for name, w in stacked_weights.items():
            w = np.asarray(w)
            if self.quantization == "int8":
                q, scale = quantize_int8_batch(w.astype(np.float32))
                target["q"][name], vals["q"][name] = buffers[name], q
                target["s"][name], vals["s"][name] = scales[name], scale
                moved += q.nbytes + scale.nbytes
            elif self.quantization == "int4":
                q, scale, mn = quantize_int4_batch(
                    w.astype(np.float32), self.group_size
                )
                target["q"][name], vals["q"][name] = buffers[name], q
                target["s"][name], vals["s"][name] = scales[name], scale
                target["m"][name], vals["m"][name] = mins[name], mn
                moved += q.nbytes + scale.nbytes + mn.nbytes
            else:
                target["q"][name] = buffers[name]
                vals["q"][name] = np.asarray(w, self.dtype)
                moved += int(np.prod(w.shape)) * self.dtype.itemsize
        out = scatter(target, idx, vals)
        self.dispatches += 1
        for name, b in out["q"].items():
            buffers[name] = b
        for name, s in out["s"].items():
            scales[name] = s
        for name, m in out["m"].items():
            mins[name] = m
        self.bytes_uploaded += moved
        return moved

    # -- double-buffered generations (predictive prefetch) -----------------
    @property
    def has_shadow(self) -> bool:
        return self._shadow is not None

    def ensure_shadow(self) -> None:
        """Materialize the shadow generation (a one-time copy of the live
        buffers, so the first flip's untouched slots are already correct)."""
        if self._shadow is not None:
            return
        self._shadow = {
            "buffers": {n: b.copy() for n, b in self.buffers.items()},
            "scales": {n: s.copy() for n, s in self.scales.items()},
            "mins": {n: m.copy() for n, m in self.mins.items()},
        }
        self.dispatches += len(self.buffers) + len(self.scales) + len(self.mins)

    def sync_shadow_slots(self, slots: Sequence[int], *, donate: bool = False) -> int:
        """Device-to-device catch-up: copy ``slots`` rows live -> shadow (slots
        the shadow merely lags on — no host-link traffic). Returns dispatches."""
        if not len(slots):
            return 0
        self.ensure_shadow()
        copy_rows = scatter_copy_rows_donated if donate else scatter_copy_rows
        # pad to a FIXED index length: duplicate rows copy the same value
        # twice (idempotent), and one compiled scatter then serves every flip
        # instead of shape-specializing per distinct stale-slot count
        idx_np = np.asarray(slots, np.int32)
        if idx_np.size < self.num_slots:
            idx_np = np.pad(idx_np, (0, self.num_slots - idx_np.size), mode="edge")
        idx = jnp.asarray(idx_np)
        n = 0
        for live_tree, key in (
            (self.buffers, "buffers"), (self.scales, "scales"), (self.mins, "mins")
        ):
            sh = self._shadow[key]
            for name, src in live_tree.items():
                sh[name] = copy_rows(sh[name], src, idx)
                n += 1
        self.dispatches += n
        return n

    def flip(self) -> None:
        """Generation flip: the corrected shadow becomes live (what the next
        launch gathers from); the previous live becomes the new, stale shadow."""
        assert self._shadow is not None, "flip() before any shadow write"
        self.buffers, self._shadow["buffers"] = self._shadow["buffers"], self.buffers
        self.scales, self._shadow["scales"] = self._shadow["scales"], self.scales
        self.mins, self._shadow["mins"] = self._shadow["mins"], self.mins
        self.version += 1

    def as_pytree(self) -> Params:
        """The {w_*} pytree ``moe_gathered`` consumes (dequantized view when
        quantized).

        Quantized note: on this CPU host we dequantize lazily, MEMOIZED per
        write generation — repeated calls between rotations return the cached
        tree, and any ``write_batch`` invalidates it (``self.version`` is the
        key). The Pallas kernel path keeps packed weights in HBM/VMEM and
        dequantizes in-register instead.
        """
        if self.quantization is None:
            return dict(self.buffers)
        if self._pytree_version == self.version and self._pytree_cache is not None:
            return self._pytree_cache
        out = {}
        if self.quantization == "int8":
            for name, buf in self.buffers.items():
                # scale [S+1, F] broadcasts over the middle dims of [S+1, .., F]
                scale = self.scales[name].reshape(
                    (buf.shape[0],) + (1,) * (buf.ndim - 2) + (buf.shape[-1],)
                )
                out[name] = dequantize_int8(buf, scale, self.dtype)
        else:
            for name, buf in self.buffers.items():
                out[name] = dequantize_int4(
                    buf, self.scales[name], self.mins[name], self.dtype
                )
        self.dequant_runs += 1
        self._pytree_cache = out
        self._pytree_version = self.version
        return out

    def raw_pytree(self) -> Params:
        """Packed view (what a real-TPU ``moe_slot_ffn`` consumes in HBM):
        buffers plus ``scale_*`` / ``min_*`` planes."""
        out = dict(self.buffers)
        for name, s in self.scales.items():
            out[f"scale_{name}"] = s
        for name, m in self.mins.items():
            out[f"min_{name}"] = m
        return out


def fake_quantized_batch(
    w: np.ndarray,
    quantization: str,
    dtype: Any,
    group_size: int = GROUP_SIZE_DEFAULT,
) -> np.ndarray:
    """dequant(quant(w)) for a stacked [E, .., F] host tensor, through the
    EXACT jnp ops ``as_pytree`` uses — f32 numpy out. The engine's host miss
    correction computes with this so a missed expert's host GEMM matches the
    device's dequantized slot compute bit-for-bit (exactness across residency
    modes under quantization)."""
    w = np.asarray(w, np.float32)
    if quantization == "int8":
        q, scale = quantize_int8_batch(w)
        scale_b = scale.reshape((w.shape[0],) + (1,) * (w.ndim - 2) + (w.shape[-1],))
        deq = dequantize_int8(jnp.asarray(q), jnp.asarray(scale_b), dtype)
    elif quantization == "int4":
        packed, scale, mn = quantize_int4_batch(w, group_size)
        deq = dequantize_int4(
            jnp.asarray(packed), jnp.asarray(scale), jnp.asarray(mn), dtype
        )
    else:
        raise ValueError(f"unknown quantization {quantization!r}")
    return np.asarray(deq, np.float32)


def quantized_expert_bytes(
    weight_shapes: Dict[str, Tuple[int, ...]],
    quantization: Optional[str],
    dtype_bytes: int = 2,
    group_size: int = GROUP_SIZE_DEFAULT,
) -> int:
    """Exact link bytes of ONE expert under ``quantization`` — the unit the
    feasibility check and the cost model price rotations in."""
    total = 0
    for shape in weight_shapes.values():
        n = int(np.prod(shape))
        if quantization == "int8":
            total += n + shape[-1] * 4
        elif quantization == "int4":
            total += int4_tensor_bytes(shape, group_size)
        else:
            total += n * dtype_bytes
    return total
