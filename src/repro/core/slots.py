"""Device slot buffers: the rotating accelerator-resident expert store.

One ``SlotStore`` per MoE layer holds ``num_slots + 1`` stacked expert weight
sets — the trailing slot is all-zeros and backs the LUT's MISS sentinel, so the
compiled gather path needs no branches. Writes go through
``jax.lax.dynamic_update_slice`` style ``.at[slot].set`` with donation, the
host->HBM DMA analog.

Optional int8 quantization (the Q4_K_M analog, DESIGN.md §2): experts are stored
as symmetric per-output-channel int8 + f32 scales; the gather path dequantizes
after the take (the Pallas ``moe_gmm`` kernel dequantizes in VMEM on real TPUs).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def quantize_int8(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-output-channel (last-dim) int8. w [.., F] -> (q int8, scale f32)."""
    amax = np.max(np.abs(w), axis=tuple(range(w.ndim - 1)), keepdims=True)
    scale = (amax / 127.0 + 1e-12).astype(np.float32)
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return q, scale.reshape(w.shape[-1])


def quantize_int8_batch(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """``quantize_int8`` over a leading expert axis: w [N, .., F] ->
    (q int8 [N, .., F], scale f32 [N, F]) with per-expert scales identical to
    quantizing each expert alone (the batched upload path must be bit-equal to
    the one-expert path)."""
    amax = np.max(np.abs(w), axis=tuple(range(1, w.ndim - 1)), keepdims=True)
    scale = (amax / 127.0 + 1e-12).astype(np.float32)
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return q, scale.reshape(w.shape[0], w.shape[-1])


@functools.partial(jax.jit, donate_argnums=(0,))
def scatter_set_donated(buf: jax.Array, idx: jax.Array, vals: jax.Array) -> jax.Array:
    return buf.at[idx].set(vals)


@jax.jit
def scatter_set(buf: jax.Array, idx: jax.Array, vals: jax.Array) -> jax.Array:
    return buf.at[idx].set(vals)


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype: Any) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


class SlotStore:
    """Rotating device-resident buffer for one MoE layer's routed experts."""

    def __init__(
        self,
        num_slots: int,
        weight_shapes: Dict[str, Tuple[int, ...]],   # e.g. w_gate: (D, F)
        dtype: Any,
        quantization: Optional[str] = None,
    ):
        self.num_slots = num_slots
        self.dtype = jnp.dtype(dtype)
        self.quantization = quantization
        self.version = 0                # bumped per write (stacked-cache key)
        self.dispatches = 0             # scatter launches issued (batched: one
                                        # per weight tensor per rotation)
        store_dtype = jnp.int8 if quantization == "int8" else self.dtype
        self.buffers: Params = {
            name: jnp.zeros((num_slots + 1,) + shape, store_dtype)
            for name, shape in weight_shapes.items()
        }
        if quantization == "int8":
            self.scales: Params = {
                name: jnp.zeros((num_slots + 1, shape[-1]), jnp.float32)
                for name, shape in weight_shapes.items()
            }
        else:
            self.scales = {}

    @property
    def bytes_per_expert(self) -> int:
        per = 0
        for name, buf in self.buffers.items():
            per += int(np.prod(buf.shape[1:])) * buf.dtype.itemsize
            if self.scales:
                per += int(np.prod(self.scales[name].shape[1:])) * 4
        return per

    @property
    def total_bytes(self) -> int:
        return self.bytes_per_expert * (self.num_slots + 1)

    def write(self, slot: int, expert_weights: Dict[str, np.ndarray]) -> int:
        """Upload one expert into ``slot``. Returns bytes moved host->device."""
        return self.write_batch(
            [slot], {n: np.asarray(w)[None] for n, w in expert_weights.items()}
        )

    def write_batch(
        self,
        slots: Sequence[int],
        stacked_weights: Dict[str, np.ndarray],   # name -> [N, ...] host array
        *,
        donate: bool = False,
    ) -> int:
        """Upload N experts in ONE stacked scatter per weight tensor.

        A rotation that moves N experts costs one ``.at[idx].set`` dispatch per
        tensor (3 for swiglu) instead of N per tensor; ``donate`` additionally
        donates the old device buffer to the scatter so steady-state rotation
        allocates nothing (safe only when no snapshot of the buffer is live —
        the fused decode path rotates strictly after replay).
        Returns bytes moved host->device.
        """
        if not len(slots):
            return 0
        for slot in slots:
            assert 0 <= slot < self.num_slots, f"slot {slot} out of range"
        scatter = scatter_set_donated if donate else scatter_set
        idx = jnp.asarray(np.asarray(slots, np.int32))
        self.version += 1
        moved = 0
        for name, w in stacked_weights.items():
            w = np.asarray(w)
            if self.quantization == "int8":
                q, scale = quantize_int8_batch(w.astype(np.float32))
                self.buffers[name] = scatter(self.buffers[name], idx, jnp.asarray(q))
                self.scales[name] = scatter(self.scales[name], idx, jnp.asarray(scale))
                self.dispatches += 2
                moved += q.nbytes + scale.nbytes
            else:
                self.buffers[name] = scatter(
                    self.buffers[name], idx, jnp.asarray(w, self.dtype)
                )
                self.dispatches += 1
                moved += int(np.prod(w.shape)) * self.dtype.itemsize
        return moved

    def as_pytree(self) -> Params:
        """The {w_*} pytree ``moe_gathered`` consumes (dequantized view if int8).

        int8 note: on this CPU host we dequantize lazily per call; the Pallas
        kernel path keeps int8 in HBM/VMEM and dequantizes in-register.
        """
        if self.quantization == "int8":
            out = {}
            for name, buf in self.buffers.items():
                # scale [S+1, F] broadcasts over the middle dims of [S+1, .., F]
                scale = self.scales[name].reshape(
                    (buf.shape[0],) + (1,) * (buf.ndim - 2) + (buf.shape[-1],)
                )
                out[name] = dequantize_int8(buf, scale, self.dtype)
            return out
        return dict(self.buffers)

    def raw_pytree(self) -> Params:
        out = dict(self.buffers)
        for name, s in self.scales.items():
            out[f"scale_{name}"] = s
        return out
