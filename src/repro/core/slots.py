"""Device slot buffers: the rotating accelerator-resident expert store.

One ``SlotStore`` per MoE layer holds ``num_slots + 1`` stacked expert weight
sets — the trailing slot is all-zeros and backs the LUT's MISS sentinel, so the
compiled gather path needs no branches. Writes go through
``jax.lax.dynamic_update_slice`` style ``.at[slot].set`` with donation, the
host->HBM DMA analog.

Optional int8 quantization (the Q4_K_M analog, DESIGN.md §2): experts are stored
as symmetric per-output-channel int8 + f32 scales; the gather path dequantizes
after the take (the Pallas ``moe_gmm`` kernel dequantizes in VMEM on real TPUs).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def quantize_int8(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-output-channel (last-dim) int8. w [.., F] -> (q int8, scale f32)."""
    amax = np.max(np.abs(w), axis=tuple(range(w.ndim - 1)), keepdims=True)
    scale = (amax / 127.0 + 1e-12).astype(np.float32)
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return q, scale.reshape(w.shape[-1])


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype: Any) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


class SlotStore:
    """Rotating device-resident buffer for one MoE layer's routed experts."""

    def __init__(
        self,
        num_slots: int,
        weight_shapes: Dict[str, Tuple[int, ...]],   # e.g. w_gate: (D, F)
        dtype: Any,
        quantization: Optional[str] = None,
    ):
        self.num_slots = num_slots
        self.dtype = jnp.dtype(dtype)
        self.quantization = quantization
        self.version = 0                # bumped per write (stacked-cache key)
        store_dtype = jnp.int8 if quantization == "int8" else self.dtype
        self.buffers: Params = {
            name: jnp.zeros((num_slots + 1,) + shape, store_dtype)
            for name, shape in weight_shapes.items()
        }
        if quantization == "int8":
            self.scales: Params = {
                name: jnp.zeros((num_slots + 1, shape[-1]), jnp.float32)
                for name, shape in weight_shapes.items()
            }
        else:
            self.scales = {}

    @property
    def bytes_per_expert(self) -> int:
        per = 0
        for name, buf in self.buffers.items():
            per += int(np.prod(buf.shape[1:])) * buf.dtype.itemsize
            if self.scales:
                per += int(np.prod(self.scales[name].shape[1:])) * 4
        return per

    @property
    def total_bytes(self) -> int:
        return self.bytes_per_expert * (self.num_slots + 1)

    def write(self, slot: int, expert_weights: Dict[str, np.ndarray]) -> int:
        """Upload one expert into ``slot``. Returns bytes moved host->device."""
        assert 0 <= slot < self.num_slots, f"slot {slot} out of range"
        self.version += 1
        moved = 0
        for name, w in expert_weights.items():
            w = np.asarray(w)
            if self.quantization == "int8":
                q, scale = quantize_int8(w.astype(np.float32))
                self.buffers[name] = self.buffers[name].at[slot].set(q)
                self.scales[name] = self.scales[name].at[slot].set(scale)
                moved += q.nbytes + scale.nbytes
            else:
                self.buffers[name] = self.buffers[name].at[slot].set(
                    jnp.asarray(w, self.dtype)
                )
                moved += int(np.prod(w.shape)) * self.dtype.itemsize
        return moved

    def as_pytree(self) -> Params:
        """The {w_*} pytree ``moe_gathered`` consumes (dequantized view if int8).

        int8 note: on this CPU host we dequantize lazily per call; the Pallas
        kernel path keeps int8 in HBM/VMEM and dequantizes in-register.
        """
        if self.quantization == "int8":
            out = {}
            for name, buf in self.buffers.items():
                # scale [S+1, F] broadcasts over the middle dims of [S+1, .., F]
                scale = self.scales[name].reshape(
                    (buf.shape[0],) + (1,) * (buf.ndim - 2) + (buf.shape[-1],)
                )
                out[name] = dequantize_int8(buf, scale, self.dtype)
            return out
        return dict(self.buffers)

    def raw_pytree(self) -> Params:
        out = dict(self.buffers)
        for name, s in self.scales.items():
            out[f"scale_{name}"] = s
        return out
