"""RotaryEngine: the paper-faithful per-layer decode engine.

Execution per decode step (the paper's §4 loop, DESIGN.md §2 "engine path"):

  embed -> for each layer:
    attn half (device jit) -> fused router top-k ON DEVICE (Pallas topk_gate on
    TPU/GPU, lax.top_k elsewhere) -> gathered slot compute against the
    persistent device LUT (misses classified in-kernel, dropped) ->
    pre-gating: layer l's hidden predicts layer l+1's demand; the manager
    rotates l+1's slots and issues uploads BEFORE l+1 executes (double-buffered
    prefetch — transfers hide behind layer l's compute in the clock model)
  -> lm head -> sample.

The full model weights live in host memory (numpy); only attention/static
weights plus each layer's slot group are device-resident, mirroring Figure 1.

Decode hot path (device-resident, default for non-LRU policies)
---------------------------------------------------------------
The per-layer walk never drains the device queue: routing happens inside the
jitted attention half, the slot LUT is a persistent device array patched in
place on rotation, and the small per-layer host reads (hidden state for the
demand predictor, routed ids/weights for EMA feedback) are issued as async
copies that overlap the already-queued MoE compute. The only queue-draining
device->host transfer per token is the final logits pull; miss masks ride the
same materialization and are inspected afterwards.

Exactness under misses is preserved by REPLAY: when the end-of-step miss masks
show a routed expert was not resident, the step is re-executed from its saved
input with the per-layer residency snapshots (functional jax arrays, so the
snapshots are free) and the seed-style host GEMM correction applied between
layers. Tokens are therefore identical to the per-layer sync path for every
policy; on miss-free steps the predictor/rotation/stats bookkeeping is
bit-identical too (on replayed steps the demand predictor saw the optimistic
hiddens — the mechanism is unchanged, only its input differs).

The legacy behaviour survives behind two switches: ``host_routing=True``
reproduces the seed engine (blocking logits pull + numpy softmax/top-k + LUT
re-upload per layer — kept as the benchmark baseline), and LRU residency
automatically uses the per-layer sync walk because its reactive blocking loads
need routed ids on host mid-step.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig, ResidencyConfig
from repro.core.predictor import DemandPredictor, host_topk_route
from repro.core.residency import RotaryResidencyManager
from repro.core.stats import EngineStats
from repro.core.transfer import CostModel, TransferClock
from repro.kernels.topk_gate import route_topk
from repro.models import transformer as tfm
from repro.models import moe as moe_mod
from repro.models.layers import apply_norm
from repro.models.transformer import Runtime


def _np_ffn(hw: Dict[str, np.ndarray], e: int, x: np.ndarray) -> np.ndarray:
    """Host expert GEMM (the paper's CPU-resident expert execution)."""
    xf = x.astype(np.float32)
    if "w_gate" in hw:
        g = xf @ hw["w_gate"][e].astype(np.float32)
        h = (g / (1.0 + np.exp(-g))) * (xf @ hw["w_up"][e].astype(np.float32))
    else:
        u = xf @ hw["w_up"][e].astype(np.float32)
        h = 0.5 * u * (1.0 + np.tanh(np.sqrt(2 / np.pi) * (u + 0.044715 * u**3)))
    return h @ hw["w_down"][e].astype(np.float32)


class RotaryEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        rescfg: ResidencyConfig,
        *,
        rt: Optional[Runtime] = None,
        cost: Optional[CostModel] = None,
        batch: int = 1,
        seed: int = 0,
        host_routing: bool = False,
    ):
        assert cfg.has_moe, "RotaryEngine requires an MoE architecture"
        self.cfg = cfg
        self.rescfg = rescfg
        self.rt = rt or Runtime(cache_len=1024)
        self.cost = cost or CostModel()
        self.batch = batch
        self.host_routing = host_routing
        self.stats = EngineStats()
        self.clock = TransferClock(self.cost)

        # ---- flatten the layer stack; slice per-layer params -------------
        self.layers: List[Tuple[str, Any]] = []       # (kind, params)
        self.moe_index: List[Optional[int]] = []      # per layer: MoE ordinal
        self.host_experts: List[Dict[str, np.ndarray]] = []
        routers: List[np.ndarray] = []
        moe_ct = 0
        for si, (unit, reps) in enumerate(cfg.segments):
            for r in range(reps):
                for pi, kind in enumerate(unit):
                    p_l = jax.tree.map(lambda a, r=r: a[r], params["segments"][si][pi])
                    if kind == "attn_moe":
                        hw = {
                            n: np.asarray(w, np.float32)
                            for n, w in p_l["moe"]["experts"].items()
                        }
                        self.host_experts.append(hw)
                        routers.append(np.asarray(p_l["moe"]["router"], np.float32))
                        self.moe_index.append(moe_ct)
                        moe_ct += 1
                        if rescfg.mode != "full":
                            # the warehouse stays in host memory: drop the full
                            # expert store from device-resident layer params
                            p_l = dict(p_l)
                            p_l["moe"] = {
                                k: v for k, v in p_l["moe"].items() if k != "experts"
                            }
                    else:
                        self.moe_index.append(None)
                    self.layers.append((kind, p_l))
        self.num_moe_layers = moe_ct
        self.embed_params = {
            k: params[k]
            for k in ("embed", "final_norm", "lm_head", "frontend_proj")
            if k in params
        }

        self.predictor = DemandPredictor(routers, ema=rescfg.predictor_ema)
        self.manager = RotaryResidencyManager(
            cfg, rescfg, self.host_experts,
            batch=batch, cache_len=self.rt.cache_len,
            cost=self.cost, stats=self.stats, seed=seed,
        )
        # LRU answers misses with reactive blocking loads mid-step: that needs
        # routed ids on host before the next layer, i.e. the sync walk
        self._hot_decode = not host_routing and not any(
            getattr(p, "needs_sync_resolve", False) for p in self.manager.policies
        )
        self._jits: Dict[Tuple, Callable] = {}
        self._head_jit = jax.jit(self._lm_head_impl)
        self._warm_start()

    # ------------------------------------------------------------------
    def _warm_start(self) -> None:
        """Initial residency: rotate every layer once on the uniform prior
        (cold start — 'GGUF load' analog)."""
        for li in range(self.num_moe_layers):
            self.manager.prepare_layer(li, self.predictor.smoothed[li])

    # ------------------------------------------------------------------
    # jitted pieces (one compile per (kind, mode, routed))
    # ------------------------------------------------------------------
    def _block_fn(self, kind: str, mode: str, routed: bool = True) -> Callable:
        key = (kind, mode, routed)
        if key in self._jits:
            return self._jits[key]
        cfg, rt = self.cfg, self.rt

        if kind == "attn_moe":
            m = cfg.moe

            def attn_half(p, x, state, cur_len):
                h = apply_norm(cfg.norm, p["ln1"], x)
                if mode == "decode":
                    y, new_state = tfm.attn.attention_decode(p["attn"], cfg.attention, h, state, cur_len)
                else:
                    y, new_state = tfm.attn.attention_prefill(
                        p["attn"], cfg.attention, h, rt.cache_len,
                        q_chunk=rt.q_chunk, kv_chunk=rt.kv_chunk)
                x_mid = x + y
                h2 = apply_norm(cfg.norm, p["ln2"], x_mid)
                logits = moe_mod.router_logits(p["moe"], h2.reshape(-1, x.shape[-1]))
                if routed:
                    # fused device routing: Pallas topk_gate on TPU/GPU,
                    # lax.top_k fallback elsewhere — no host round-trip
                    ids, weights = route_topk(
                        logits, m.top_k, normalize=m.norm_topk_prob
                    )
                    return x_mid, h2, ids, weights, new_state
                return x_mid, h2, logits, new_state

            def moe_half(p, x_mid, h2, ids, weights, slots, lut):
                t = ids.shape[0]
                y2, miss = moe_mod.moe_apply_routed(
                    p["moe"], h2.reshape(t, -1), ids, weights,
                    slot_buffer=slots, lut=lut)
                return x_mid + y2.reshape(x_mid.shape), miss

            fns = (jax.jit(attn_half), jax.jit(moe_half))
        else:
            def full_block(p, x, state, cur_len):
                y, new_state, _ = tfm._apply_block(
                    kind, p, cfg, rt, x, mode, state if state else None, cur_len, None)
                return y, new_state

            fns = (jax.jit(full_block),)
        self._jits[key] = fns
        return fns

    def _embed(self, tokens: jax.Array) -> jax.Array:
        return jnp.take(self.embed_params["embed"], tokens, axis=0)

    def _lm_head_impl(self, embed_params, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        hn = apply_norm(cfg.norm, embed_params["final_norm"], h)
        head = (
            embed_params["embed"].T
            if cfg.tie_embeddings
            else embed_params["lm_head"]
        )
        return hn @ head

    def _lm_head(self, h: jax.Array) -> jax.Array:
        return self._head_jit(self.embed_params, h)

    # ------------------------------------------------------------------
    # shared host-side pieces
    # ------------------------------------------------------------------
    def _host_correct(
        self,
        x: jax.Array,
        moe_li: int,
        h2: jax.Array,
        ids: np.ndarray,
        weights: np.ndarray,
        miss: np.ndarray,
    ) -> jax.Array:
        """Seed-style exact host GEMM correction for missed experts."""
        h2_np = np.asarray(h2, np.float32).reshape(ids.shape[0], -1)
        corr = np.zeros_like(h2_np)
        hw = self.host_experts[moe_li]
        n_host = 0
        for t_i, j in zip(*np.nonzero(miss)):
            e = int(ids[t_i, j])
            corr[t_i] += weights[t_i, j] * _np_ffn(hw, e, h2_np[t_i])
            n_host += 1
        x = x + jnp.asarray(corr, x.dtype).reshape(x.shape)
        self.stats.layer(moe_li).host_computed += n_host
        self.clock.host(
            self.cost.host_compute_s(self.manager.host_expert_flops(n_host))
        )
        return x

    # ------------------------------------------------------------------
    # per-layer sync walk (prefill; decode for LRU / host_routing baseline)
    # ------------------------------------------------------------------
    def _run_layers(self, x: jax.Array, mode: str, cur_len: int) -> jax.Array:
        cfg = self.cfg
        m = cfg.moe
        clock = self.clock
        cur = jnp.int32(cur_len)
        for li, (kind, p_l) in enumerate(self.layers):
            state = self.state[li]
            if kind == "attn_moe":
                moe_li = self.moe_index[li]
                # --- routing (host baseline or device-routed pull) --------
                if self.host_routing:
                    attn_half, moe_half = self._block_fn(kind, mode, routed=False)
                    x_mid, h2, logits_dev, new_state = attn_half(p_l, x, state, cur)
                    self.stats.sync_pulls += 1
                    logits = np.asarray(logits_dev, np.float32)
                    ids, weights = host_topk_route(
                        logits, m.top_k, normalize=m.norm_topk_prob
                    )
                else:
                    attn_half, moe_half = self._block_fn(kind, mode, routed=True)
                    x_mid, h2, ids_dev, w_dev, new_state = attn_half(p_l, x, state, cur)
                    self.stats.sync_pulls += 1
                    ids = np.asarray(ids_dev)
                    weights = np.asarray(w_dev)
                self.state[li] = new_state
                # --- LUT resolve (LRU may block-load here) ----------------
                _, miss = self.manager.resolve(moe_li, ids, clock)
                slots_tree = self.manager.stores[moe_li].as_pytree()
                lut_dev = self.manager.device_lut(moe_li)
                x, _ = moe_half(
                    p_l, x_mid, h2,
                    jnp.asarray(ids), jnp.asarray(weights),
                    slots_tree, lut_dev,
                )
                # --- host correction for misses ---------------------------
                if miss.any() and self.rescfg.host_compute_misses:
                    x = self._host_correct(x, moe_li, h2, ids, weights, miss)
                # --- modeled device time for this layer -------------------
                flops, byts = self._layer_cost(kind, x.shape, cur_len, hits=int((~miss).sum()))
                clock.compute(self.cost.compute_s(flops, byts))
                # --- pre-gate the NEXT MoE layer from THIS hidden ----------
                # (cyclic: the last layer pre-gates layer 0 of the next step)
                nxt = (moe_li + 1) % self.num_moe_layers
                demand = self.predictor.predict(nxt, np.asarray(h2).reshape(ids.shape[0], -1))
                self.manager.prepare_layer(nxt, demand, clock)
                self.predictor.observe(moe_li, ids, weights)
            else:
                (block,) = self._block_fn(kind, mode)
                x, new_state = block(p_l, x, state if state else {}, cur)
                self.state[li] = new_state
                flops, byts = self._layer_cost(kind, x.shape, cur_len, hits=0)
                clock.compute(self.cost.compute_s(flops, byts), needs_dma=False)
        return x

    # ------------------------------------------------------------------
    # device-resident decode hot path
    # ------------------------------------------------------------------
    def _decode_step_hot(self, tok: np.ndarray) -> np.ndarray:
        """One decode step with a single queue-draining device->host pull.

        Returns host logits [B, V]. See the module docstring for the design.
        """
        cur_len = self.cur_len
        cur = jnp.int32(cur_len)
        x = self._embed(jnp.asarray(tok)[:, None])
        states_before = list(self.state)
        x_ins: List[jax.Array] = []                         # per-layer input refs
        snaps: Dict[int, Tuple[Any, jax.Array, int]] = {}   # li -> (slots, lut, moved)
        pend: List[Tuple[int, int, np.ndarray, np.ndarray, jax.Array]] = []
        order: List[Tuple] = []                             # modeled-clock ops
        for li, (kind, p_l) in enumerate(self.layers):
            x_ins.append(x)
            state = self.state[li]
            if kind == "attn_moe":
                moe_li = self.moe_index[li]
                attn_half, moe_half = self._block_fn(kind, "decode", routed=True)
                x_mid, h2, ids_dev, w_dev, new_state = attn_half(p_l, x, state, cur)
                slots_tree = self.manager.stores[moe_li].as_pytree()
                lut_dev = self.manager.device_lut(moe_li)
                x, miss_dev = moe_half(p_l, x_mid, h2, ids_dev, w_dev, slots_tree, lut_dev)
                self.state[li] = new_state
                # async D2H copies: by the time the host consumes these, the
                # MoE half + next layer's slot uploads are already queued, so
                # the reads overlap device work instead of draining the queue
                for a in (h2, ids_dev, w_dev, miss_dev):
                    a.copy_to_host_async()
                ids = np.asarray(ids_dev)
                weights = np.asarray(w_dev)
                h2_np = np.asarray(h2, np.float32).reshape(ids.shape[0], -1)
                self.stats.overlapped_pulls += 4
                # --- pre-gate next layer + predictor feedback (seed order) --
                nxt = (moe_li + 1) % self.num_moe_layers
                demand = self.predictor.predict(nxt, h2_np)
                moved = self.manager.prepare_layer(nxt, demand, clock=None)
                self.predictor.observe(moe_li, ids, weights)
                snaps[li] = (slots_tree, lut_dev, moved)
                pend.append((li, moe_li, ids, weights, miss_dev))
                order.append(("moe", li, moe_li, x.shape, moved))
            else:
                (block,) = self._block_fn(kind, "decode")
                x, new_state = block(p_l, x, state if state else {}, cur)
                self.state[li] = new_state
                order.append(("plain", li, kind, x.shape))
        logits_dev = self._lm_head(x[:, -1:])[:, 0]
        logits = np.asarray(logits_dev)        # THE one queue-draining pull
        self.stats.sync_pulls += 1
        miss_by_li = {li: np.asarray(md) for (li, _, _, _, md) in pend}
        missed = [li for (li, _, _, _, _) in pend if miss_by_li[li].any()]
        start = (
            missed[0]
            if (missed and self.rescfg.host_compute_misses)
            else len(self.layers)
        )
        # account stats + modeled clock for the (authoritative) prefix in the
        # same sequence the sync walk would have used; layers before the first
        # miss are exact as computed, so only the suffix needs replay
        for (li, moe_li, ids, _, _) in pend:
            if li >= start:
                break
            self.manager.record_routing(moe_li, ids, miss_by_li[li])
        for op in order:
            if op[1] >= start:
                break
            if op[0] == "moe":
                _, li, moe_li, shape, moved = op
                hits = int((~miss_by_li[li]).sum())
                flops, byts = self._layer_cost("attn_moe", shape, cur_len, hits=hits)
                self.clock.compute(self.cost.compute_s(flops, byts))
                self.clock.prefetch(moved)
            else:
                _, li, kind, shape = op
                flops, byts = self._layer_cost(kind, shape, cur_len, hits=0)
                self.clock.compute(self.cost.compute_s(flops, byts), needs_dma=False)
        if start < len(self.layers):
            return self._replay_step(x_ins[start], states_before, snaps, start)
        return logits

    def _replay_step(
        self,
        x0: jax.Array,
        states_before: List[Any],
        snaps: Dict[int, Tuple[Any, jax.Array, int]],
        start: int,
    ) -> np.ndarray:
        """Exact re-execution of a decode-step SUFFIX after an observed miss.

        Layers before ``start`` (the first layer whose optimistic pass missed)
        saw exactly the inputs/residency the sync walk would have used, so
        their optimistic outputs and KV writes stand. From ``start`` on, the
        step re-executes with the per-layer residency SNAPSHOTS captured by
        the hot pass (the slot buffers / LUT each layer actually gathered
        from), re-deriving routing from the corrected activations and applying
        the host GEMM correction between layers exactly like the sync walk.
        Rotation / prefetch already happened in the hot pass and is not
        repeated; its modeled DMA time is charged here at the seed position in
        the sequence.
        """
        cur_len = self.cur_len
        cur = jnp.int32(cur_len)
        clock = self.clock
        x = x0
        for li in range(start, len(self.layers)):
            kind, p_l = self.layers[li]
            state = states_before[li]
            if kind == "attn_moe":
                moe_li = self.moe_index[li]
                attn_half, moe_half = self._block_fn(kind, "decode", routed=True)
                x_mid, h2, ids_dev, w_dev, new_state = attn_half(p_l, x, state, cur)
                self.state[li] = new_state
                slots_tree, lut_dev, moved = snaps[li]
                x, miss_dev = moe_half(p_l, x_mid, h2, ids_dev, w_dev, slots_tree, lut_dev)
                ids = np.asarray(ids_dev)
                weights = np.asarray(w_dev)
                miss = np.asarray(miss_dev)
                self.stats.sync_pulls += 1
                self.manager.record_routing(moe_li, ids, miss)
                if miss.any() and self.rescfg.host_compute_misses:
                    x = self._host_correct(x, moe_li, h2, ids, weights, miss)
                flops, byts = self._layer_cost(kind, x.shape, cur_len, hits=int((~miss).sum()))
                clock.compute(self.cost.compute_s(flops, byts))
                clock.prefetch(moved)
            else:
                (block,) = self._block_fn(kind, "decode")
                x, new_state = block(p_l, x, state if state else {}, cur)
                self.state[li] = new_state
                flops, byts = self._layer_cost(kind, x.shape, cur_len, hits=0)
                clock.compute(self.cost.compute_s(flops, byts), needs_dma=False)
        logits = np.asarray(self._lm_head(x[:, -1:])[:, 0])
        self.stats.sync_pulls += 1
        return logits

    def _layer_cost(self, kind: str, xshape, cur_len: int, hits: int) -> Tuple[float, float]:
        """(flops, bytes) estimate of one layer at current shapes (modeled clock)."""
        from repro.models.params import _block_params

        cfg = self.cfg
        tokens = int(np.prod(xshape[:-1]))
        n_static = _block_params(cfg, kind, active_only=True)
        if kind == "attn_moe":
            m = cfg.moe
            mats = 3 if cfg.mlp == "swiglu" else 2
            n_static -= m.top_k * mats * cfg.d_model * m.expert_d_ff
            expert_flops = 2.0 * hits * mats * cfg.d_model * m.expert_d_ff
            expert_bytes = hits * mats * cfg.d_model * m.expert_d_ff * 2
        else:
            expert_flops = expert_bytes = 0.0
        flops = 2.0 * tokens * n_static + expert_flops
        byts = 2.0 * n_static + expert_bytes
        if cfg.uses_kv_cache and kind in ("attn_mlp", "attn_moe", "local_attn"):
            a = cfg.attention
            ctx = min(cur_len + 1, self.rt.cache_len)
            if kind == "local_attn" and a.window:
                ctx = min(ctx, a.window)
            flops += 4.0 * tokens * ctx * a.num_heads * a.head_dim
            byts += 2.0 * xshape[0] * ctx * a.num_kv_heads * a.head_dim * 2
        return flops, byts

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def prefill(self, tokens: np.ndarray) -> np.ndarray:
        """tokens [B, S] -> logits [B, V]; builds the decode state."""
        b, s = tokens.shape
        assert b == self.batch
        self.state = []
        for si, (unit, reps) in enumerate(self.cfg.segments):
            for r in range(reps):
                for pi, kind in enumerate(unit):
                    self.state.append(
                        tfm._zero_block_state(self.cfg, kind, b, self.rt.cache_len)
                    )
        t0 = time.perf_counter()
        x = self._embed(jnp.asarray(tokens))
        x = self._run_layers(x, "prefill", cur_len=0)
        logits = self._lm_head(x[:, -1:])[:, 0]
        self.stats.wall_s += time.perf_counter() - t0
        self.cur_len = s
        self.stats.tokens += b * s
        return np.asarray(logits)

    def decode(
        self,
        last_logits: np.ndarray,
        steps: int,
        *,
        greedy: bool = True,
        seed: int = 0,
    ) -> np.ndarray:
        """Generate ``steps`` tokens. Returns [B, steps]."""
        from repro.core.predictor import softmax as np_softmax

        rng = np.random.default_rng(seed)
        out = np.zeros((self.batch, steps), np.int32)
        logits = last_logits
        t0 = time.perf_counter()
        for i in range(steps):
            if greedy:
                tok = np.argmax(logits, axis=-1).astype(np.int32)
            else:
                p = np_softmax(logits.astype(np.float64), axis=-1)
                tok = np.array(
                    [rng.choice(p.shape[-1], p=row) for row in p], np.int32
                )
            out[:, i] = tok
            if self._hot_decode:
                logits = self._decode_step_hot(tok)
            else:
                x = self._embed(jnp.asarray(tok)[:, None])
                x = self._run_layers(x, "decode", cur_len=self.cur_len)
                logits = np.asarray(self._lm_head(x[:, -1:])[:, 0])
                self.stats.sync_pulls += 1
            self.cur_len += 1
            self.stats.steps += 1
            self.stats.tokens += self.batch
        self.stats.wall_s += time.perf_counter() - t0
        self.stats.compute_s = self.clock.compute_s
        self.stats.transfer_s = self.clock.transfer_s
        self.stats.stall_s = self.clock.stall_s
        self.stats.host_compute_s = self.clock.host_s
        self.last_logits = logits          # resume point for chained decodes
        return out

    def generate(self, prompt: np.ndarray, max_new: int, **kw) -> np.ndarray:
        logits = self.prefill(prompt)
        return self.decode(logits, max_new, **kw)
