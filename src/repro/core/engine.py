"""RotaryEngine: the paper-faithful per-layer decode engine.

Execution per decode step (the paper's §4 loop, DESIGN.md §2 "engine path"):

  embed -> for each layer:
    attn half (device jit) -> fused router top-k ON DEVICE (Pallas topk_gate on
    TPU/GPU, lax.top_k elsewhere) -> gathered slot compute against the
    persistent device LUT (misses classified in-kernel, dropped) ->
    pre-gating: layer l's hidden predicts layer l+1's demand; the manager
    rotates l+1's slots and issues uploads BEFORE l+1 executes (double-buffered
    prefetch — transfers hide behind layer l's compute in the clock model)
  -> lm head -> sample.

The full model weights live in host memory (numpy); only attention/static
weights plus each layer's slot group are device-resident, mirroring Figure 1.

Fused decode hot path (default for non-LRU policies on KV-cache stacks)
-----------------------------------------------------------------------
One compiled whole-stack step per token: ``build_fused_decode_step`` wraps
``tfm.decode_model``'s ``lax.scan`` over the segment stack (embed -> every
layer -> lm head) in a single jit, consuming the manager's version-keyed
``stacked_residency()`` pytree. The KV state is DONATED to the step
(``donate_argnums``), so decode updates the caches in place instead of copying
them every token. Demand prediction runs on-device inside the same step: the
per-layer router matrices are stacked once (``predictor.next_layer_routers``)
and every layer's next-step demand (softmaxed, token-averaged) comes back as
one small ``demand_next`` [L, E] tensor. Routing / miss / demand telemetry is
pulled with async copies that overlap the queued compute; the only
queue-draining device->host transfer per token is the final logits pull, and
the only compiled-program launch per miss-free token is the step itself
(O(1) dispatches instead of O(layers)). The host's per-token work shrinks to
rotation bookkeeping: EMA fold, ring transition, and batched slot uploads
(one donated scatter per weight tensor per rotated layer).

Speculative multi-token decode (``spec_k > 1``)
-----------------------------------------------
Greedy decode can advance K tokens per launch: ``build_fused_window_step``
scans the fused step over a K-position self-drafting window (per-position
``cur_len``, donated KV state carried across positions, next token = on-device
argmax) against ONE residency snapshot, so a miss-free window costs one
compiled launch and one queue-draining pull for K tokens. Acceptance is
greedy (self-drafting with identical weights verifies against its own
argmaxes — ``serving.sampler.greedy_accept`` is the plug point for real
drafters; the stochastic rule is a hook): rejection comes only from residency
misses, which invalidate a position and everything drafted after it. The
first rejected position rolls the KV cache back (``tfm.rollback_kv_window``
restores the pre-window slot contents captured by ``tfm.snapshot_kv_window`` —
ring caches need real restoration, not just masking) and replays exactly like
a missed single-token step; rotation is deferred to window boundaries, where
``rotate_window_from_telemetry`` applies the committed steps' transitions
one-by-one-equivalently while coalescing uploads to one batched scatter per
layer per window.

Exactness under misses is preserved by REPLAY: the fused step is the
optimistic pass; when the end-of-step miss masks show a routed expert was not
resident, the suffix from the first missed layer re-executes with the
per-layer walk against the SAME residency the compiled step gathered from
(rotation happens strictly after replay), anchored on the per-layer block
inputs the step emits as telemetry (``route_x``). Re-running an attention
block overwrites the same KV slot, so the post-step donated state is a valid
replay substrate — which is why the fused path requires KV-cache-only block
kinds; MoE stacks with recurrent blocks fall back to the per-layer hot walk
below. Tokens match the per-layer sync path for every policy (on replayed
steps the demand predictor saw the optimistic hiddens — the mechanism is
unchanged, only its input differs).

Chunked prefill hot path (``prefill_chunk=C``)
----------------------------------------------
Prompts ingest in power-of-two chunks (``prefill_chunk_plan`` bounds the
compile cache): each chunk is ONE compiled whole-stack launch
(``build_fused_prefill_step`` wrapping ``tfm.prefill_chunk_model`` — chunk
attention appends to the donated KV state, the MoE half gathers all B*C
chunk tokens through the same ``stacked_residency()`` pytree decode uses)
plus ONE queue-draining pull and ONE coalesced rotation window at the chunk
boundary (the pre-gating demand GEMM over the chunk's stacked hiddens, EMA
fold, ring transition per layer, uploads batched to one scatter per weight
tensor per rotated layer). A missed chunk suffix-replays per layer from the
first missed layer's saved block input, exactly like decode. Per-layer
engines (host_routing / LRU / ``fused_decode=False``) walk the same chunks
layer-by-layer with the same boundary rotation — the benchmark baseline —
and because both paths drive rotation through the SAME compiled demand
program, residency (and therefore the miss pattern) evolves identically:
fused-chunk logits and post-prefill KV are bit-identical to the chunked
layer walk, including slot-starved and int8/int4 stores.

Per-layer hot walk (fallback) and legacy switches
-------------------------------------------------
The PR-1 per-layer hot path (jitted attention half + routed MoE half per
layer, async telemetry copies, one logits pull per token, saved-input replay)
survives for MoE stacks with recurrent state. ``host_routing=True``
reproduces the seed engine (blocking logits pull + numpy softmax/top-k + LUT
re-upload per layer — kept as the benchmark baseline), and LRU residency
automatically uses the per-layer sync walk because its reactive blocking
loads need routed ids on host mid-step.

Exactness invariant and telemetry→transition map
------------------------------------------------
THE contract every fast path in this module keeps: greedy outputs are
bit-identical to full residency — rotation, speculation, chunking and
quantized stores may change WHERE compute happens and WHAT moves over the
link, never what comes out (quantized stores are exactness-clean within
their format: the host correction GEMMs against dequant∘quant weights).
The mechanisms are suffix replay (fused decode, chunked prefill) and KV
rollback + replay (speculative windows); ``docs/ARCHITECTURE.md`` has the
full dispatch-count table. Telemetry consumers on the host:
``route_ids``/``route_weights`` feed ``DemandPredictor.observe`` and
hit/miss accounting; ``route_miss`` picks the replay start layer;
``demand_next`` (decode, on-device GEMM) and the chunk-boundary demand GEMM
(prefill) feed ``DemandPredictor.update`` → ``policy.prepare`` → ring
transition → ``SlotStore.write_batch``; ``route_x`` anchors replay;
``route_h`` is the prefill demand GEMM's input.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig, ResidencyConfig
from repro.core.predictor import DemandPredictor, host_topk_route
from repro.core.residency import RotaryResidencyManager
from repro.core.stats import EngineStats
from repro.core.transfer import CostModel, TransferClock
from repro.kernels.topk_gate import route_topk
from repro.models import transformer as tfm
from repro.models import moe as moe_mod
from repro.models import sampling as sampling_mod
from repro.models.sampling import SampleParams
from repro.models.layers import apply_norm
from repro.models.transformer import Runtime
from repro.obs.metrics import MetricsRegistry
from repro.obs.tracer import resolve_tracer


def _np_ffn(hw: Dict[str, np.ndarray], e: int, x: np.ndarray) -> np.ndarray:
    """Host expert GEMM (the paper's CPU-resident expert execution)."""
    xf = x.astype(np.float32)
    if "w_gate" in hw:
        g = xf @ hw["w_gate"][e].astype(np.float32)
        h = (g / (1.0 + np.exp(-g))) * (xf @ hw["w_up"][e].astype(np.float32))
    else:
        u = xf @ hw["w_up"][e].astype(np.float32)
        h = 0.5 * u * (1.0 + np.tanh(np.sqrt(2 / np.pi) * (u + 0.044715 * u**3)))
    return h @ hw["w_down"][e].astype(np.float32)


def moe_segments(cfg: ModelConfig) -> List[int]:
    """Indices of segments containing an ``attn_moe`` unit — the order the
    scan stacks per-layer ``route_*`` telemetry in (MoE-ordinal order)."""
    return [
        si for si, (unit, _) in enumerate(cfg.segments)
        if any(k == "attn_moe" for k in unit)
    ]


def concat_route_telemetry(
    aux: Dict[str, jax.Array], name: str, moe_segs: List[int], axis: int = 0
) -> np.ndarray:
    """Per-segment ``route_{name}/seg*`` aux -> one [L, ...] host array in
    MoE-ordinal order (shared by RotaryEngine and ServingEngine). Speculative
    windows stack a leading K axis, so their layer axis is ``axis=1``."""
    if len(moe_segs) == 1:
        return np.asarray(aux[f"route_{name}/seg{moe_segs[0]}"])
    return np.concatenate(
        [np.asarray(aux[f"route_{name}/seg{si}"]) for si in moe_segs], axis=axis
    )


def build_fused_decode_step(
    cfg: ModelConfig,
    rt: Runtime,
    *,
    with_demand: bool,
    donate_state: bool = True,
    keep_replay_anchor: bool = True,
) -> Callable:
    """ONE compiled whole-stack decode step, shared by ``RotaryEngine`` (fused
    hot path) and ``ServingEngine`` (continuous-batching tick).

    Returns a jitted ``fn(params, routers_next, token, state, cur_len,
    residency) -> (logits [B, V], new_state, aux)``. ``cur_len`` may be a
    scalar (engine) or per-row [B] (serving's ragged batches). ``state`` is
    DONATED: the KV caches update in place instead of being copied per token.

    ``aux`` carries the per-segment ``route_*`` telemetry from the scan; with
    ``with_demand`` the DemandPredictor GEMM also runs in-graph —
    ``aux["demand_next"]`` [L, E] holds layer (l+1)%L's softmaxed,
    token-averaged demand computed from layer l's post-attention hidden
    against ``routers_next`` [L, D, E] (``predictor.next_layer_routers()``) —
    and the bulky per-layer hiddens (``route_h``) are dropped from the outputs
    since the demand signal subsumes them. ``keep_replay_anchor=False``
    additionally drops the per-layer block inputs (``route_x``) for callers
    with no replay path (the serving tick), saving their device->host copy.
    """
    moe_segs = moe_segments(cfg)
    aux_fn = _demand_aux_fn(moe_segs, with_demand, keep_replay_anchor)

    def step(params, routers_next, token, state, cur_len, residency,
             page_table=None):
        # trailing page_table (serving's paged KV pool) keeps the 6-arg
        # call signature every existing caller compiled against
        logits, new_state, aux = tfm.decode_model(
            cfg, params, token, state, cur_len, rt, residency=residency,
            page_table=page_table,
        )
        return logits, new_state, aux_fn(aux, routers_next)

    return jax.jit(step, donate_argnums=(3,) if donate_state else ())


def _demand_aux_fn(
    moe_segs: List[int], with_demand: bool, keep_replay_anchor: bool
):
    """Per-position aux hook shared by the single-token fused step and the
    speculative window: in-graph demand GEMM + telemetry slimming."""

    def aux_fn(aux, routers_next):
        if with_demand:
            h_all = jnp.concatenate(
                [aux[f"route_h/seg{si}"] for si in moe_segs], axis=0
            )                                                       # [L, T, D]
            dl = jnp.einsum("ltd,lde->lte", h_all.astype(jnp.float32), routers_next)
            aux["demand_next"] = jax.nn.softmax(dl, axis=-1).mean(axis=1)
            for si in moe_segs:
                del aux[f"route_h/seg{si}"]
                if not keep_replay_anchor:
                    del aux[f"route_x/seg{si}"]
        return aux

    return aux_fn


def prefill_chunk_plan(s: int, chunk: int) -> List[int]:
    """Split a prompt of ``s`` tokens into power-of-two chunk lengths.

    ``chunk`` (itself a power of two) repeats while the remainder allows, then
    the tail decomposes into descending powers of two — so a prompt of any
    length compiles at most ``log2(chunk)`` distinct chunk shapes beyond the
    steady-state one, keeping the fused prefill step's compile cache bounded.
    """
    assert s >= 1, "empty prompt"
    assert chunk >= 1 and (chunk & (chunk - 1)) == 0, (
        f"prefill_chunk must be a power of two, got {chunk}"
    )
    plan = [chunk] * (s // chunk)
    rem, bit, bits = s - chunk * (s // chunk), 1, []
    while rem:
        if rem & 1:
            bits.append(bit)
        rem >>= 1
        bit <<= 1
    return plan + sorted(bits, reverse=True)


def build_fused_prefill_step(
    cfg: ModelConfig,
    rt: Runtime,
    *,
    with_demand: bool,
    donate_state: bool = True,
    keep_replay_anchor: bool = True,
    with_head: bool = True,
) -> Callable:
    """ONE compiled whole-stack prefill-CHUNK step: the prompt-ingestion
    sibling of :func:`build_fused_decode_step`.

    Returns a jitted ``fn(params, routers_next, tokens [B, C], state, cur_len,
    residency) -> (logits [B, V], new_state, aux)`` wrapping
    :func:`tfm.prefill_chunk_model`: the chunk's C positions run through the
    whole stack (embed -> every layer -> lm head) in one launch, appending to
    the DONATED KV state, gathering experts for all B*C chunk tokens from the
    same ``stacked_residency()`` pytree decode uses, and emitting the same
    ``route_*`` telemetry decode does. The engine calls this with
    ``with_demand=False`` so the raw per-layer hiddens (``route_h``) stay in
    the aux for the chunk-boundary demand GEMM (which must see
    replay-corrected hiddens — an in-graph demand would bake in the
    optimistic ones) and ``with_head=False`` for every chunk but a prompt's
    last (only the final chunk's logits are consumed; the rest would pay the
    [D, V] head GEMM and a [B, V] pull for nothing). The jit re-specializes
    per chunk length; power-of-two chunk plans (:func:`prefill_chunk_plan`)
    keep that cache bounded.
    """
    moe_segs = moe_segments(cfg)
    aux_fn = _demand_aux_fn(moe_segs, with_demand, keep_replay_anchor)

    def step(params, routers_next, tokens, state, cur_len, residency):
        logits, new_state, aux = tfm.prefill_chunk_model(
            cfg, params, tokens, state, cur_len, rt, residency=residency,
            with_head=with_head,
        )
        return logits, new_state, aux_fn(aux, routers_next)

    return jax.jit(step, donate_argnums=(3,) if donate_state else ())


def build_fused_window_step(
    cfg: ModelConfig,
    rt: Runtime,
    k_steps: int,
    *,
    with_demand: bool,
    donate_state: bool = True,
    keep_replay_anchor: bool = True,
    sample: Optional[SampleParams] = None,
) -> Callable:
    """ONE compiled program running ``k_steps`` self-drafted decode
    positions (the speculative window) — the multi-token sibling of
    :func:`build_fused_decode_step`, shared by ``RotaryEngine`` and
    ``ServingEngine``.

    Returns a jitted ``fn(params, routers_next, token, state, cur_len,
    residency) -> (draft [K, B], last_logits [B, V], new_state, aux)``. The
    window scans :func:`tfm.decode_window`: per-position ``cur_len``, KV state
    DONATED and carried across positions, the next position's token drafted
    on-device (argmax, or a categorical draw from the ``sample``-warped
    distribution keyed per position when ``sample`` is set — the trailing
    ``rng_keys`` [B, 2] argument threads the per-row base keys), and every
    position gathering from the SAME residency snapshot (rotation happens at
    window boundaries). Telemetry comes back with a leading window axis —
    ``route_*`` as [K, L, T, k] after :func:`concat_route_telemetry`,
    ``demand_next`` as [K, L, E], and when sampling ``sample_probs``
    [K, B, V] / ``sample_p`` [K, B] — so the caller can commit the accepted
    prefix and roll back the rest.
    """
    moe_segs = moe_segments(cfg)
    aux_fn = _demand_aux_fn(moe_segs, with_demand, keep_replay_anchor)

    def step(params, routers_next, token, state, cur_len, residency,
             page_table=None, rng_keys=None):
        return tfm.decode_window(
            cfg, params, token, state, cur_len, rt, k_steps,
            residency=residency,
            aux_fn=lambda aux: aux_fn(aux, routers_next),
            page_table=page_table,
            sample=sample, rng_keys=rng_keys,
        )

    return jax.jit(step, donate_argnums=(3,) if donate_state else ())


def build_window_fns(
    cfg: ModelConfig,
    rt: Runtime,
    k: int,
    *,
    with_demand: bool,
    keep_replay_anchor: bool = True,
    sample: Optional[SampleParams] = None,
) -> Tuple[Callable, Callable, Callable]:
    """The compiled speculative-window triple both engines cache per K
    (and per ``sample`` warp params when sampling):
    (window step, KV snapshot, KV rollback). Rollback donates the state it
    truncates; the snapshot is dispatched BEFORE the donating window, so it
    reads the pre-window buffers."""
    step = build_fused_window_step(
        cfg, rt, k, with_demand=with_demand, donate_state=True,
        keep_replay_anchor=keep_replay_anchor, sample=sample,
    )
    # trailing page_table: the serving engine passes its paged pool + per-row
    # page tables through the same triple; contiguous callers are unchanged
    snap = jax.jit(
        lambda state, cl, page_table=None: tfm.snapshot_kv_window(
            cfg, state, cl, k, page_table=page_table
        )
    )
    roll = jax.jit(
        lambda state, saved, cl, keep, page_table=None: tfm.rollback_kv_window(
            cfg, state, saved, cl, k, keep, page_table=page_table
        ),
        donate_argnums=(0,),
    )
    return step, snap, roll


class RotaryEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        rescfg: ResidencyConfig,
        *,
        rt: Optional[Runtime] = None,
        cost: Optional[CostModel] = None,
        batch: int = 1,
        seed: int = 0,
        host_routing: bool = False,
        fused_decode: Optional[bool] = None,
        spec_k: int = 1,
        prefill_chunk: Optional[int] = None,
        prefetch: bool = False,
        trace=None,
    ):
        """Decode-path switches (see module docstring for the mechanisms):

        * default (``host_routing=False, fused_decode=None``) — fused
          whole-stack step when the policy and block kinds allow it, else the
          per-layer hot walk (LRU / recurrent stacks), always exact via replay;
        * ``fused_decode=False`` — force the per-layer device-resident hot
          walk (kept as the fused step's benchmark comparison). Prefer this
          for SLOT-STARVED configurations (num_slots well below the routed
          working set): the fused step's between-step rotation gives up the
          walk's intra-step pre-gating, and when most steps miss, the
          whole-suffix replay makes fused decode slower than the walk — see
          the slot-starved rows of ``benchmarks/decode_hot_path.py``. The
          paper's operating point (prefetch covers routing) is miss-free,
          where fused wins by construction;
        * ``fused_decode=True``  — require the fused step (raises if the
          policy or stack cannot support it);
        * ``host_routing=True``  — seed-style engine: blocking per-layer
          logits pull + numpy softmax/top-k (benchmark baseline);
        * ``spec_k=K``  (K > 1) — speculative multi-token decode: greedy
          decode runs K-position self-drafting windows through ONE compiled
          program (``build_fused_window_step``); residency misses reject the
          window's suffix, which rolls the KV cache back
          (``tfm.rollback_kv_window``) and replays the first rejected
          position exactly like the single-token path replays a missed step.
          Requires the fused path; non-greedy decode falls back to
          single-token steps (the stochastic accept rule is a hook for now —
          see ``repro.serving.sampler``);
        * ``prefill_chunk=C`` — chunked prefill hot path: the prompt ingests
          in power-of-two chunks of at most C tokens
          (``prefill_chunk_plan``). Fused engines run each chunk through ONE
          compiled launch (``build_fused_prefill_step``) with ONE coalesced
          rotation window between chunks, pre-gated by the previous chunk's
          telemetry; per-layer engines (host_routing / LRU /
          ``fused_decode=False``) walk the same chunks layer-by-layer — the
          benchmark baseline. ``None`` keeps the legacy full-sequence
          layer-walk prefill. Requires KV-cache-only block kinds (recurrent
          stacks fall back to the legacy walk); the fused chunk replay
          additionally requires window-free attention (ring caches fall back
          to the chunked walk). The fused and walk chunked paths are
          bit-identical to each other (logits AND post-prefill KV, every
          residency mode and slot format), and greedy continuations match
          the legacy full-sequence walk token for token — misses
          host-correct in the walk and suffix-replay per chunk in the fused
          path, exactly like decode;
        * ``prefetch=True`` — asynchronous predictive expert prefetch over
          double-buffered slot planes: while a launch computes, the predicted
          next transition's uploads land in a shadow generation
          (``RotaryResidencyManager.begin_prefetch``), and the boundary
          becomes confirm/correct/flip instead of synchronous scatters; the
          policy additionally steers up to ``rescfg.prefetch_margin`` cold
          slots toward predicted-hot off-window experts, which is what cuts
          the miss (and replay) rate. Residency may EVOLVE differently from
          the synchronous baseline, but greedy tokens stay bit-identical —
          the exactness machinery (host correction + replay) is unchanged.
          Requires the fused hot path; ``prefetch=False`` (the default)
          keeps the synchronous rotation path as the exactness baseline.
        * ``trace=Tracer(...)`` — record launch/pull/rotation/prefetch spans
          into a host-side ring buffer and export Chrome trace-event JSON
          (``repro.obs``). ``None`` (and a disabled tracer) leave every hot
          path untouched: emission sites are guarded ``if tr is not None``.
        """
        assert cfg.has_moe, "RotaryEngine requires an MoE architecture"
        self.cfg = cfg
        self.rescfg = rescfg
        self.rt = rt or Runtime(cache_len=1024)
        self.cost = cost or CostModel()
        self.batch = batch
        self.host_routing = host_routing
        self.stats = EngineStats()
        self.clock = TransferClock(self.cost)
        self._tr = resolve_tracer(trace)
        self.tracer = self._tr
        self.metrics = MetricsRegistry()

        # ---- flatten the layer stack; slice per-layer params -------------
        self.layers: List[Tuple[str, Any]] = []       # (kind, params)
        self.moe_index: List[Optional[int]] = []      # per layer: MoE ordinal
        self._layer_pos: List[Tuple[int, int, int]] = []   # li -> (si, pi, r)
        self._moe_pos: List[Tuple[int, int]] = []     # MoE ordinal -> (si, r)
        self._moe_layer_li: List[int] = []            # MoE ordinal -> flat li
        self.host_experts: List[Dict[str, np.ndarray]] = []
        routers: List[np.ndarray] = []
        moe_ct = 0
        for si, (unit, reps) in enumerate(cfg.segments):
            for r in range(reps):
                for pi, kind in enumerate(unit):
                    self._layer_pos.append((si, pi, r))
                    p_l = jax.tree.map(lambda a, r=r: a[r], params["segments"][si][pi])
                    if kind == "attn_moe":
                        self._moe_pos.append((si, r))
                        self._moe_layer_li.append(len(self.layers))
                        hw = {
                            n: np.asarray(w, np.float32)
                            for n, w in p_l["moe"]["experts"].items()
                        }
                        self.host_experts.append(hw)
                        routers.append(np.asarray(p_l["moe"]["router"], np.float32))
                        self.moe_index.append(moe_ct)
                        moe_ct += 1
                        if rescfg.mode != "full":
                            # the warehouse stays in host memory: drop the full
                            # expert store from device-resident layer params
                            p_l = dict(p_l)
                            p_l["moe"] = {
                                k: v for k, v in p_l["moe"].items() if k != "experts"
                            }
                    else:
                        self.moe_index.append(None)
                    self.layers.append((kind, p_l))
        self.num_moe_layers = moe_ct
        self.embed_params = {
            k: params[k]
            for k in ("embed", "final_norm", "lm_head", "frontend_proj")
            if k in params
        }

        # per-layer cache for the quantized host-correction weights (built
        # lazily by _correction_weights on a layer's first miss)
        self._correct_cache: Dict[int, Dict[str, np.ndarray]] = {}
        self.predictor = DemandPredictor(routers, ema=rescfg.predictor_ema)
        self.manager = RotaryResidencyManager(
            cfg, rescfg, self.host_experts,
            batch=batch, cache_len=self.rt.cache_len,
            cost=self.cost, stats=self.stats, seed=seed,
            tracer=self._tr, metrics=self.metrics,
        )
        # LRU answers misses with reactive blocking loads mid-step: that needs
        # routed ids on host before the next layer, i.e. the sync walk
        self._hot_decode = not host_routing and not any(
            getattr(p, "needs_sync_resolve", False) for p in self.manager.policies
        )
        # fused whole-stack step: additionally requires replay-safe per-layer
        # state — re-running an attention block overwrites the same KV slot,
        # while a recurrent update is destructive (see module docstring)
        kv_only = all(
            kind in ("attn_moe", "attn_mlp", "local_attn")
            for kind, _ in self.layers
        )
        fused_ok = self._hot_decode and kv_only
        if prefill_chunk is not None:
            assert prefill_chunk >= 1 and (prefill_chunk & (prefill_chunk - 1)) == 0, (
                f"prefill_chunk must be a power of two, got {prefill_chunk}"
            )
        self.prefill_chunk = prefill_chunk
        # chunked prefill threads the KV cache through multi-token appends:
        # recurrent stacks (and frontend archs, whose prompt is not plain
        # tokens) keep the legacy full-sequence walk; the fused chunk path
        # additionally needs window-free attention, because its suffix replay
        # re-reads pre-chunk cache content that a ring overwrite destroys
        self._chunk_prefill_ok = kv_only and cfg.frontend is None
        self._chunk_prefill_fused_ok = (
            self._chunk_prefill_ok and cfg.attention.window is None
        )
        if fused_decode:
            assert fused_ok, (
                "fused decode requires device routing (no host_routing, no "
                "LRU) and KV-cache-only block kinds"
            )
        self._fused_decode = fused_ok if fused_decode is None else bool(fused_decode)
        assert spec_k >= 1, "spec_k is a window size (>= 1)"
        if spec_k > 1:
            assert self._fused_decode, (
                "speculative decode (spec_k > 1) rides the fused whole-stack "
                "step: it needs device routing (no host_routing, no LRU) and "
                "KV-cache-only block kinds"
            )
            from repro.models import attention as attn_mod

            cap = attn_mod._cache_capacity(cfg.attention, self.rt.cache_len)
            assert spec_k <= cap, (
                f"spec_k={spec_k} exceeds the KV cache capacity ({cap})"
            )
        self.spec_k = spec_k
        # asynchronous predictive prefetch rides the fused hot path: it hides
        # shadow uploads under an IN-FLIGHT compiled launch, which the
        # synchronous baselines don't have. Fail loudly on unsupported combos
        # rather than silently running synchronous.
        self.prefetch = bool(prefetch)
        if self.prefetch:
            if host_routing:
                raise ValueError(
                    "prefetch=True is incompatible with host_routing=True: the "
                    "host-routing baseline blocks on per-layer logits pulls, so "
                    "there is no in-flight launch to hide shadow uploads under"
                )
            if not self._fused_decode:
                raise ValueError(
                    "prefetch=True requires the fused whole-stack hot path "
                    "(no LRU / recurrent stacks, fused_decode not disabled): "
                    "synchronous per-layer walks rotate mid-step, so there is "
                    "nothing to overlap"
                )
            if rescfg.mode != "full":
                # full residency never rotates: accept the flag (benchmarks
                # sweep it uniformly) but skip the shadow plane. margin=0:
                # predictive slot steering measured NEGATIVE on this workload
                # (routing is too close to uniform for the one-step-stale
                # signal — both the EMA and the raw pre-gating sample raised
                # the steps-with-a-miss count), so the perf mechanism is the
                # miss-relaunch, which needs no prediction at all; steering
                # stays available through the manager for richer routers
                self.manager.enable_prefetch(margin=0)
        self._jits: Dict[Tuple, Callable] = {}
        self._head_jit = jax.jit(self._lm_head_impl)
        self._cost_cache: Dict[str, Tuple[float, float]] = {}
        # stacked next-layer routers [L, D, E] + the chunk-boundary demand GEMM
        # (softmax(h_l @ R_{l+1}), token-averaged): shared by EVERY chunked
        # prefill path — walk and fused compute the pre-gating signal through
        # the SAME jitted program on the same [L, T, D] stacked hiddens, so
        # the residency evolution (and with it the miss pattern) is
        # bit-identical between them, which is what makes slot-starved
        # chunked prefill outputs bitwise comparable across paths. Built only
        # for engines that can use it (the router stack is a real device
        # upload a seed-baseline engine should not pay)
        self._chunk_telem: List[Tuple] = []        # walk-path per-chunk buffer
        if self._fused_decode or prefill_chunk is not None:
            self._routers_next = jnp.asarray(self.predictor.next_layer_routers())

            def demand_all(h_all, routers):        # [L, T, D], [L, D, E]
                dl = jnp.einsum(
                    "ltd,lde->lte", h_all.astype(jnp.float32), routers
                )
                return jax.nn.softmax(dl, axis=-1).mean(axis=1)

            self._demand_all_jit = jax.jit(demand_all)
        if self._fused_decode:
            # rotation happens strictly after replay in the fused path, so no
            # residency snapshot outlives the buffers a rotation replaces
            self.manager.donate_buffers = True
            self._fused_step = build_fused_decode_step(
                cfg, self.rt, with_demand=True, donate_state=True
            )
            # chunked prefill hot path: one whole-stack launch per chunk (the
            # jit re-specializes per power-of-two chunk length). with_demand
            # is OFF: the step keeps the raw per-layer hiddens (route_h) so
            # the chunk-boundary demand GEMM above runs on authoritative
            # (replay-corrected) hiddens, exactly like the walk baseline.
            # Only a prompt's final chunk runs the lm head — the other
            # chunks' queue-draining pull is the routing telemetry
            self._fused_prefill_step = build_fused_prefill_step(
                cfg, self.rt, with_demand=False, donate_state=True
            )
            self._fused_prefill_step_nohead = build_fused_prefill_step(
                cfg, self.rt, with_demand=False, donate_state=True,
                with_head=False,
            )
            self._moe_segs = moe_segments(cfg)
            self._pull_keys = [
                f"route_{nm}/seg{si}"
                for si in self._moe_segs
                for nm in ("ids", "weights", "miss")
            ] + ["demand_next"]
            # the prefill step has no in-graph demand: its telemetry pulls are
            # the routing triple only (route_h stays device-side for the
            # chunk-boundary demand GEMM; route_x is read only on replay)
            self._prefill_pull_keys = [
                f"route_{nm}/seg{si}"
                for si in self._moe_segs
                for nm in ("ids", "weights", "miss")
            ]
            # stacked decode params: the expert warehouse never rides along —
            # the residency arg supplies expert weights in EVERY mode
            segs_p = []
            for si, (unit, reps) in enumerate(cfg.segments):
                unit_p = []
                for pi, kind in enumerate(unit):
                    p_u = params["segments"][si][pi]
                    if kind == "attn_moe" and "experts" in p_u["moe"]:
                        p_u = dict(p_u)
                        p_u["moe"] = {
                            k: v for k, v in p_u["moe"].items() if k != "experts"
                        }
                    unit_p.append(p_u)
                segs_p.append(tuple(unit_p))
            self._decode_params = {
                **{k: v for k, v in params.items() if k != "segments"},
                "segments": tuple(segs_p),
            }
            self._dstate = None          # stacked decode state (built by prefill)
            # speculative windows: compiled (window, snapshot, rollback) per
            # (K, sample params) — sampled windows draft with on-device draws
            self._fused_windows: Dict[Any, Tuple[Callable, Callable, Callable]] = {}
            # the snapshot exists to make rollback exact; when misses are
            # impossible (full residency) or never replayed, no window is ever
            # rejected and the pre-window gather is pure overhead
            self._spec_needs_rollback = (
                rescfg.mode != "full" and rescfg.host_compute_misses
            )
        # between-window standalone draws (cached per warp params): the SAME
        # ops/keys as the in-window draw, so sampled streams are bit-identical
        # whichever path derives a position's token
        self._sample_fns: Dict[SampleParams, Callable] = {}
        self._warm_start()

    # ------------------------------------------------------------------
    def _warm_start(self) -> None:
        """Initial residency: rotate every layer once on the uniform prior
        (cold start — 'GGUF load' analog)."""
        for li in range(self.num_moe_layers):
            self.manager.prepare_layer(li, self.predictor.smoothed[li])

    # ------------------------------------------------------------------
    # jitted pieces (one compile per (kind, mode, routed))
    # ------------------------------------------------------------------
    def _block_fn(self, kind: str, mode: str, routed: bool = True) -> Callable:
        key = (kind, mode, routed)
        if key in self._jits:
            return self._jits[key]
        cfg, rt = self.cfg, self.rt

        if kind == "attn_moe":
            m = cfg.moe

            def attn_half(p, x, state, cur_len):
                h = apply_norm(cfg.norm, p["ln1"], x)
                if mode == "decode":
                    y, new_state = tfm.attn.attention_decode(p["attn"], cfg.attention, h, state, cur_len)
                elif mode == "chunk":
                    y, new_state = tfm.attn.attention_prefill_chunk(
                        p["attn"], cfg.attention, h, state, cur_len)
                else:
                    y, new_state = tfm.attn.attention_prefill(
                        p["attn"], cfg.attention, h, rt.cache_len,
                        q_chunk=rt.q_chunk, kv_chunk=rt.kv_chunk)
                x_mid = x + y
                h2 = apply_norm(cfg.norm, p["ln2"], x_mid)
                logits = moe_mod.router_logits(p["moe"], h2.reshape(-1, x.shape[-1]))
                if routed:
                    # fused device routing: Pallas topk_gate on TPU/GPU,
                    # lax.top_k fallback elsewhere — no host round-trip
                    ids, weights = route_topk(
                        logits, m.top_k, normalize=m.norm_topk_prob
                    )
                    return x_mid, h2, ids, weights, new_state
                return x_mid, h2, logits, new_state

            def moe_half(p, x_mid, h2, ids, weights, slots, lut):
                t = ids.shape[0]
                y2, miss = moe_mod.moe_apply_routed(
                    p["moe"], h2.reshape(t, -1), ids, weights,
                    slot_buffer=slots, lut=lut)
                return x_mid + y2.reshape(x_mid.shape), miss

            fns = (jax.jit(attn_half), jax.jit(moe_half))
        else:
            def full_block(p, x, state, cur_len):
                y, new_state, _ = tfm._apply_block(
                    kind, p, cfg, rt, x, mode, state if state else None, cur_len, None)
                return y, new_state

            fns = (jax.jit(full_block),)
        self._jits[key] = fns
        return fns

    def _embed(self, tokens: jax.Array) -> jax.Array:
        self.stats.device_dispatches += 1
        return jnp.take(self.embed_params["embed"], tokens, axis=0)

    def _lm_head_impl(self, embed_params, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        hn = apply_norm(cfg.norm, embed_params["final_norm"], h)
        head = (
            embed_params["embed"].T
            if cfg.tie_embeddings
            else embed_params["lm_head"]
        )
        return hn @ head

    def _lm_head(self, h: jax.Array) -> jax.Array:
        self.stats.device_dispatches += 1
        return self._head_jit(self.embed_params, h)

    # ------------------------------------------------------------------
    # shared host-side pieces
    # ------------------------------------------------------------------
    def _correction_weights(self, moe_li: int) -> Dict[str, np.ndarray]:
        """Host weights the miss correction must GEMM against: the originals,
        or — under quantization — dequant(quant(w)) through the store's exact
        jnp ops, so the correction is bit-consistent with what a RESIDENT slot
        would have computed. Built lazily per layer on first miss (a covered
        or full-residency engine never pays the pass or the f32 copy)."""
        if self.rescfg.quantization is None:
            return self.host_experts[moe_li]
        hw = self._correct_cache.get(moe_li)
        if hw is None:
            from repro.core.slots import fake_quantized_batch

            dtype = jnp.dtype(self.cfg.dtype)
            hw = {
                n: fake_quantized_batch(
                    w, self.rescfg.quantization, dtype,
                    self.rescfg.quant_group_size,
                )
                for n, w in self.host_experts[moe_li].items()
            }
            self._correct_cache[moe_li] = hw
        return hw

    def _host_correct(
        self,
        x: jax.Array,
        moe_li: int,
        h2: jax.Array,
        ids: np.ndarray,
        weights: np.ndarray,
        miss: np.ndarray,
    ) -> jax.Array:
        """Seed-style exact host GEMM correction for missed experts (against
        the dequantized weights when the slots are quantized)."""
        h2_np = np.asarray(h2, np.float32).reshape(ids.shape[0], -1)
        corr = np.zeros_like(h2_np)
        hw = self._correction_weights(moe_li)
        n_host = 0
        for t_i, j in zip(*np.nonzero(miss)):
            e = int(ids[t_i, j])
            corr[t_i] += weights[t_i, j] * _np_ffn(hw, e, h2_np[t_i])
            n_host += 1
        x = x + jnp.asarray(corr, x.dtype).reshape(x.shape)
        self.stats.layer(moe_li).host_computed += n_host
        self.clock.host(
            self.cost.host_compute_s(self.manager.host_expert_flops(n_host))
        )
        return x

    # ------------------------------------------------------------------
    # per-layer sync walk (prefill; decode for LRU / host_routing baseline)
    # ------------------------------------------------------------------
    def _run_layers(self, x: jax.Array, mode: str, cur_len: int) -> jax.Array:
        cfg = self.cfg
        m = cfg.moe
        clock = self.clock
        cur = jnp.int32(cur_len)
        for li, (kind, p_l) in enumerate(self.layers):
            state = self.state[li]
            if kind == "attn_moe":
                moe_li = self.moe_index[li]
                # --- routing (host baseline or device-routed pull) --------
                if self.host_routing:
                    attn_half, moe_half = self._block_fn(kind, mode, routed=False)
                    x_mid, h2, logits_dev, new_state = attn_half(p_l, x, state, cur)
                    self.stats.sync_pulls += 1
                    self.stats.device_dispatches += 1
                    logits = np.asarray(logits_dev, np.float32)
                    ids, weights = host_topk_route(
                        logits, m.top_k, normalize=m.norm_topk_prob
                    )
                else:
                    attn_half, moe_half = self._block_fn(kind, mode, routed=True)
                    x_mid, h2, ids_dev, w_dev, new_state = attn_half(p_l, x, state, cur)
                    self.stats.sync_pulls += 1
                    self.stats.device_dispatches += 1
                    ids = np.asarray(ids_dev)
                    weights = np.asarray(w_dev)
                self.state[li] = new_state
                # --- LUT resolve (LRU may block-load here) ----------------
                _, miss = self.manager.resolve(moe_li, ids, clock)
                slots_tree = self.manager.stores[moe_li].as_pytree()
                lut_dev = self.manager.device_lut(moe_li)
                x, _ = moe_half(
                    p_l, x_mid, h2,
                    jnp.asarray(ids), jnp.asarray(weights),
                    slots_tree, lut_dev,
                )
                self.stats.device_dispatches += 1
                # --- host correction for misses ---------------------------
                if miss.any() and self.rescfg.host_compute_misses:
                    x = self._host_correct(x, moe_li, h2, ids, weights, miss)
                # --- modeled device time for this layer -------------------
                flops, byts = self._layer_cost(kind, x.shape, cur_len, hits=int((~miss).sum()))
                clock.compute(self.cost.compute_s(flops, byts))
                if mode == "chunk":
                    # chunked prefill defers rotation to the chunk boundary
                    # (mirrors the fused hot path — the boundary rotation runs
                    # the shared demand GEMM on this chunk's hiddens, so walk
                    # and fused see bit-identical residency evolution)
                    self._chunk_telem.append((ids, weights, miss, h2))
                else:
                    # --- pre-gate the NEXT MoE layer from THIS hidden ------
                    # (cyclic: the last layer pre-gates layer 0 of next step)
                    nxt = (moe_li + 1) % self.num_moe_layers
                    demand = self.predictor.predict(nxt, np.asarray(h2).reshape(ids.shape[0], -1))
                    self.manager.prepare_layer(nxt, demand, clock)
                    self.predictor.observe(moe_li, ids, weights)
            else:
                (block,) = self._block_fn(kind, mode)
                x, new_state = block(p_l, x, state if state else {}, cur)
                self.stats.device_dispatches += 1
                self.state[li] = new_state
                flops, byts = self._layer_cost(kind, x.shape, cur_len, hits=0)
                clock.compute(self.cost.compute_s(flops, byts), needs_dma=False)
        return x

    # ------------------------------------------------------------------
    # device-resident decode hot path
    # ------------------------------------------------------------------
    def _decode_step_hot(self, tok: np.ndarray) -> np.ndarray:
        """One decode step with a single queue-draining device->host pull.

        Returns host logits [B, V]. See the module docstring for the design.
        """
        cur_len = self.cur_len
        cur = jnp.int32(cur_len)
        x = self._embed(jnp.asarray(tok)[:, None])
        states_before = list(self.state)
        x_ins: List[jax.Array] = []                         # per-layer input refs
        snaps: Dict[int, Tuple[Any, jax.Array, int]] = {}   # li -> (slots, lut, moved)
        pend: List[Tuple[int, int, np.ndarray, np.ndarray, jax.Array]] = []
        order: List[Tuple] = []                             # modeled-clock ops
        for li, (kind, p_l) in enumerate(self.layers):
            x_ins.append(x)
            state = self.state[li]
            if kind == "attn_moe":
                moe_li = self.moe_index[li]
                attn_half, moe_half = self._block_fn(kind, "decode", routed=True)
                x_mid, h2, ids_dev, w_dev, new_state = attn_half(p_l, x, state, cur)
                slots_tree = self.manager.stores[moe_li].as_pytree()
                lut_dev = self.manager.device_lut(moe_li)
                x, miss_dev = moe_half(p_l, x_mid, h2, ids_dev, w_dev, slots_tree, lut_dev)
                self.stats.device_dispatches += 2
                self.state[li] = new_state
                # async D2H copies: by the time the host consumes these, the
                # MoE half + next layer's slot uploads are already queued, so
                # the reads overlap device work instead of draining the queue
                for a in (h2, ids_dev, w_dev, miss_dev):
                    a.copy_to_host_async()
                ids = np.asarray(ids_dev)
                weights = np.asarray(w_dev)
                h2_np = np.asarray(h2, np.float32).reshape(ids.shape[0], -1)
                self.stats.overlapped_pulls += 4
                # --- pre-gate next layer + predictor feedback (seed order) --
                nxt = (moe_li + 1) % self.num_moe_layers
                demand = self.predictor.predict(nxt, h2_np)
                moved = self.manager.prepare_layer(nxt, demand, clock=None)
                self.predictor.observe(moe_li, ids, weights)
                snaps[li] = (slots_tree, lut_dev, moved)
                pend.append((li, moe_li, ids, weights, miss_dev))
                order.append(("moe", li, moe_li, x.shape, moved))
            else:
                (block,) = self._block_fn(kind, "decode")
                x, new_state = block(p_l, x, state if state else {}, cur)
                self.stats.device_dispatches += 1
                self.state[li] = new_state
                order.append(("plain", li, kind, x.shape))
        logits_dev = self._lm_head(x[:, -1:])[:, 0]
        logits = np.asarray(logits_dev)        # THE one queue-draining pull
        self.stats.sync_pulls += 1
        miss_by_li = {li: np.asarray(md) for (li, _, _, _, md) in pend}
        missed = [li for (li, _, _, _, _) in pend if miss_by_li[li].any()]
        start = (
            missed[0]
            if (missed and self.rescfg.host_compute_misses)
            else len(self.layers)
        )
        # account stats + modeled clock for the (authoritative) prefix in the
        # same sequence the sync walk would have used; layers before the first
        # miss are exact as computed, so only the suffix needs replay
        for (li, moe_li, ids, _, _) in pend:
            if li >= start:
                break
            self.manager.record_routing(moe_li, ids, miss_by_li[li])
        for op in order:
            if op[1] >= start:
                break
            if op[0] == "moe":
                _, li, moe_li, shape, moved = op
                hits = int((~miss_by_li[li]).sum())
                flops, byts = self._layer_cost("attn_moe", shape, cur_len, hits=hits)
                self.clock.compute(self.cost.compute_s(flops, byts))
                self.clock.prefetch(moved)
            else:
                _, li, kind, shape = op
                flops, byts = self._layer_cost(kind, shape, cur_len, hits=0)
                self.clock.compute(self.cost.compute_s(flops, byts), needs_dma=False)
        if start < len(self.layers):
            return self._replay_step(x_ins[start], states_before, snaps, start)
        return logits

    def _replay_step(
        self,
        x0: jax.Array,
        states_before: List[Any],
        snaps: Dict[int, Tuple[Any, jax.Array, int]],
        start: int,
    ) -> np.ndarray:
        """Exact re-execution of a decode-step SUFFIX after an observed miss.

        Layers before ``start`` (the first layer whose optimistic pass missed)
        saw exactly the inputs/residency the sync walk would have used, so
        their optimistic outputs and KV writes stand. From ``start`` on, the
        step re-executes with the per-layer residency SNAPSHOTS captured by
        the hot pass (the slot buffers / LUT each layer actually gathered
        from), re-deriving routing from the corrected activations and applying
        the host GEMM correction between layers exactly like the sync walk.
        Rotation / prefetch already happened in the hot pass and is not
        repeated; its modeled DMA time is charged here at the seed position in
        the sequence.
        """
        cur_len = self.cur_len
        cur = jnp.int32(cur_len)
        clock = self.clock
        x = x0
        for li in range(start, len(self.layers)):
            kind, p_l = self.layers[li]
            state = states_before[li]
            if kind == "attn_moe":
                moe_li = self.moe_index[li]
                attn_half, moe_half = self._block_fn(kind, "decode", routed=True)
                x_mid, h2, ids_dev, w_dev, new_state = attn_half(p_l, x, state, cur)
                self.state[li] = new_state
                slots_tree, lut_dev, moved = snaps[li]
                x, miss_dev = moe_half(p_l, x_mid, h2, ids_dev, w_dev, slots_tree, lut_dev)
                ids = np.asarray(ids_dev)
                weights = np.asarray(w_dev)
                miss = np.asarray(miss_dev)
                self.stats.sync_pulls += 1
                self.manager.record_routing(moe_li, ids, miss)
                if miss.any() and self.rescfg.host_compute_misses:
                    x = self._host_correct(x, moe_li, h2, ids, weights, miss)
                flops, byts = self._layer_cost(kind, x.shape, cur_len, hits=int((~miss).sum()))
                clock.compute(self.cost.compute_s(flops, byts))
                clock.prefetch(moved)
            else:
                (block,) = self._block_fn(kind, "decode")
                x, new_state = block(p_l, x, state if state else {}, cur)
                self.state[li] = new_state
                flops, byts = self._layer_cost(kind, x.shape, cur_len, hits=0)
                clock.compute(self.cost.compute_s(flops, byts), needs_dma=False)
        logits = np.asarray(self._lm_head(x[:, -1:])[:, 0])
        self.stats.sync_pulls += 1
        return logits

    # ------------------------------------------------------------------
    # fused whole-stack decode (ONE compiled step per token)
    # ------------------------------------------------------------------
    def _stack_state(self, flat: List[Any]) -> Any:
        """Per-layer state list -> the stacked pytree ``decode_model`` scans
        (tuple over segments of tuples over unit positions, leading dim =
        reps). One-time cost after prefill; decode then threads the stacked
        state through the donated fused step without ever re-stacking."""
        segs: List[Tuple] = []
        base = 0
        for unit, reps in self.cfg.segments:
            unit_states = []
            for pi in range(len(unit)):
                per_rep = [
                    flat[base + r * len(unit) + pi] or {} for r in range(reps)
                ]
                unit_states.append(
                    jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep)
                )
            segs.append(tuple(unit_states))
            base += reps * len(unit)
        return tuple(segs)

    def _layer_state(self, li: int) -> Any:
        si, pi, r = self._layer_pos[li]
        return jax.tree.map(lambda a: a[r], self._dstate[si][pi])

    def _set_layer_state(self, li: int, new_state: Any) -> None:
        si, pi, r = self._layer_pos[li]
        segs = list(self._dstate)
        unit = list(segs[si])
        unit[pi] = jax.tree.map(
            lambda full, s: full.at[r].set(s), unit[pi], new_state
        )
        segs[si] = tuple(unit)
        self._dstate = tuple(segs)

    def _decode_step_fused(self, tok: np.ndarray) -> np.ndarray:
        """One decode step = ONE compiled program launch (plus the rotation's
        batched uploads). Returns host logits [B, V]; see module docstring."""
        cur_len = self.cur_len
        tr = self._tr
        if tr is not None:
            tr.new_unit("decode")
            t_trace = time.perf_counter()
        residency = self.manager.stacked_residency()
        logits_dev, self._dstate, aux = self._fused_step(
            self._decode_params, self._routers_next, jnp.asarray(tok),
            self._dstate, jnp.int32(cur_len), residency,
        )
        self.stats.device_dispatches += 1
        if tr is not None:
            tr.complete("launch", "launch", t_trace, time.perf_counter(),
                        args={"cur_len": cur_len})
        # async D2H: these complete while the logits pull below drains the
        # queue, so the rotation bookkeeping reads ready host buffers
        for k in self._pull_keys:
            aux[k].copy_to_host_async()
        self.stats.overlapped_pulls += len(self._pull_keys)
        if self.prefetch:
            # the launch above is still in flight: plan the predicted next
            # transition and ship its uploads into the SHADOW generation now,
            # so this host work + the scatters overlap the device compute the
            # blocking pull below waits on
            self.manager.begin_prefetch(self.predictor, self.clock)
        if tr is not None:
            t_trace = time.perf_counter()
        logits = np.asarray(logits_dev)        # THE one queue-draining pull
        self.stats.sync_pulls += 1
        if tr is not None:
            tr.complete("pull", "pull", t_trace, time.perf_counter(),
                        args={"cur_len": cur_len})
        ids = concat_route_telemetry(aux, "ids", self._moe_segs)      # [L, T, k]
        weights = concat_route_telemetry(aux, "weights", self._moe_segs)
        miss = concat_route_telemetry(aux, "miss", self._moe_segs)
        demand_next = np.asarray(aux["demand_next"])   # [L, E]
        missed = np.flatnonzero(miss.reshape(miss.shape[0], -1).any(axis=1))
        if tr is not None and missed.size:
            tr.instant("miss", "launch",
                       args={"first_moe": int(missed[0]),
                             "layers": int(missed.size)})
        start_moe = (
            int(missed[0])
            if (missed.size and self.rescfg.host_compute_misses)
            else self.num_moe_layers
        )
        start_li = (
            self._moe_layer_li[start_moe]
            if start_moe < self.num_moe_layers
            else len(self.layers)
        )
        # stats + modeled clock for the authoritative prefix in seed order
        # (layers before the first miss are exact as computed; the replay
        # charges the suffix itself)
        self._account_step_prefix(ids, miss, start_li, cur_len)
        if start_li < len(self.layers):
            # miss-relaunch (prefetch mode): upload the known-missed experts —
            # no prediction involved — let the incremental planes/LUT absorb
            # the patch off the shared generation counter, and re-run the ONE
            # compiled step. Far cheaper than the per-layer replay walk with
            # its sync pull per MoE layer; falls back to the replay when the
            # residency cannot cover the routed set.
            redo = (
                self._relaunch_fused(tok, cur_len, ids, start_moe, start_li)
                if self.prefetch else None
            )
            if redo is not None:
                logits, ids, weights, miss, demand_next = redo
            else:
                logits = self._replay_fused(aux, start_moe, start_li, cur_len)
        # between-step rotation: the pre-gating GEMM already ran on device;
        # host work is the EMA fold, the ring transition, and ONE batched
        # (donated) scatter per weight tensor per rotated layer
        self.manager.rotate_from_telemetry(
            self.predictor, ids, weights, miss, demand_next,
            clock=self.clock, record=False,
        )
        return logits

    def _account_step_prefix(
        self,
        ids: np.ndarray,
        miss: np.ndarray,
        stop_li: int,
        cur_len: int,
        tokens: int = 1,
        start_li: int = 0,
    ) -> None:
        """record_routing + modeled clock for layers ``[start_li, stop_li)`` of
        one authoritative step (ids/miss [L, T, k]), in seed order — shared by
        the fused decode step, every position of a speculative window, each
        fused prefill chunk (``tokens`` = positions the launch processed), and
        the miss-relaunch suffix."""
        xshape = (self.batch, tokens, self.cfg.d_model)
        for li, (kind, _) in enumerate(self.layers):
            if li >= stop_li:
                break
            if li < start_li:
                continue
            moe_li = self.moe_index[li]
            if moe_li is not None:
                self.manager.record_routing(moe_li, ids[moe_li], miss[moe_li])
                hits = int((~miss[moe_li]).sum())
                flops, byts = self._layer_cost(kind, xshape, cur_len, hits=hits)
                self.clock.compute(self.cost.compute_s(flops, byts))
            else:
                flops, byts = self._layer_cost(kind, xshape, cur_len, hits=0)
                self.clock.compute(self.cost.compute_s(flops, byts), needs_dma=False)

    # ------------------------------------------------------------------
    # speculative multi-token decode (ONE compiled window per K tokens)
    # ------------------------------------------------------------------
    def _window_fns(
        self, k: int, sample: Optional[SampleParams] = None
    ) -> Tuple[Callable, Callable, Callable]:
        """Compiled (window step, KV snapshot, KV rollback) triple for window
        size ``k`` (cached per (k, sample) — decode tails may need a smaller
        final window, and sampled windows are a distinct compiled family)."""
        fns = self._fused_windows.get((k, sample))
        if fns is None:
            fns = build_window_fns(
                self.cfg, self.rt, k, with_demand=True, sample=sample
            )
            self._fused_windows[(k, sample)] = fns
        return fns

    def _decode_window_fused(
        self, tok: np.ndarray, k: int,
        sample: Optional[SampleParams] = None,
        rng_keys: Optional[jax.Array] = None,
        sample_rng: Optional[np.random.Generator] = None,
    ) -> Tuple[np.ndarray, np.ndarray, int]:
        """One speculative window: ``k`` self-drafted positions through
        ONE compiled program, one queue-draining pull, acceptance by the
        accept rule + miss telemetry, KV rollback + suffix replay for the
        first rejected position, rotation at the window boundary.

        ``tok`` [B] is the position-0 token (already emitted by the caller).
        Returns ``(extra [committed-1, B], logits [B, V], committed)``:
        ``extra`` are the drafted tokens that committed beyond ``tok``, and
        ``logits`` continue the chain (the last committed position's —
        replay-corrected when that position missed). Exactness: positions
        before the first miss saw exactly the inputs and residency the
        single-token fused path would have used (the window defers rotation
        to its boundary, and a miss-free step's rotation cannot change its
        own output — only WHERE later steps' compute happens, which the
        replay machinery already corrects), so committed tokens are
        bit-identical to single-token decode.

        With ``sample``/``rng_keys`` the window drafts by on-device
        position-keyed draws and acceptance runs
        :func:`repro.serving.sampler.stochastic_accept` over the pulled
        ``sample_probs`` telemetry. Self-drafting passes the same
        distributions as p and q, so the stochastic rule accepts every
        position (its resample path is dormant — a rejected-suffix re-draw
        happens at the caller's loop top with the SAME position key, which
        is the exact q-draw) and rejection still comes only from residency
        misses; sampled committed tokens are bit-identical to single-token
        sampled decode under the shared PRNG protocol.
        """
        cur_len0 = self.cur_len
        tr = self._tr
        if tr is not None:
            tr.new_unit("window")
        residency = self.manager.stacked_residency()
        step_fn, snap_fn, roll_fn = self._window_fns(k, sample)
        saved = None
        if self._spec_needs_rollback:
            # gather the pre-window contents of the K slots the window will
            # write, BEFORE the window donates (and mutates) the state
            saved = snap_fn(self._dstate, jnp.int32(cur_len0))
            self.stats.device_dispatches += 1
            if tr is not None:
                tr.instant("kv_snapshot", "launch", args={"k": k})
        if tr is not None:
            t_trace = time.perf_counter()
        draft_dev, logits_dev, self._dstate, aux = step_fn(
            self._decode_params, self._routers_next, jnp.asarray(tok),
            self._dstate, jnp.int32(cur_len0), residency,
            rng_keys=rng_keys,
        )
        self.stats.device_dispatches += 1
        self.stats.spec_windows += 1
        if tr is not None:
            tr.complete("launch", "launch", t_trace, time.perf_counter(),
                        args={"cur_len": cur_len0, "k": k})
        pull_keys = self._pull_keys
        if sample is not None:
            pull_keys = pull_keys + ["sample_probs", "sample_p"]
        for key in pull_keys:
            aux[key].copy_to_host_async()
        draft_dev.copy_to_host_async()
        self.stats.overlapped_pulls += len(pull_keys) + 1
        if self.prefetch:
            # whole window still in flight: shadow-upload the predicted next
            # transition under it (committed at the boundary rotation below)
            self.manager.begin_prefetch(self.predictor, self.clock)
        if tr is not None:
            t_trace = time.perf_counter()
        logits = np.asarray(logits_dev)        # THE one queue-draining pull
        self.stats.sync_pulls += 1
        if tr is not None:
            tr.complete("pull", "pull", t_trace, time.perf_counter(),
                        args={"cur_len": cur_len0, "k": k})
        draft = np.asarray(draft_dev)                               # [K, B]
        ids = concat_route_telemetry(aux, "ids", self._moe_segs, axis=1)
        weights = concat_route_telemetry(aux, "weights", self._moe_segs, axis=1)
        miss = concat_route_telemetry(aux, "miss", self._moe_segs, axis=1)
        demand_next = np.asarray(aux["demand_next"])                # [K, L, E]
        # --- accept rule ------------------------------------------------
        # self-draft with identical weights: greedy verification argmaxes ARE
        # the drafted tokens, and the stochastic rule sees draft dist ==
        # verify dist (ratio exactly 1 -> certain acceptance) — so either way
        # the token-level rule accepts everything (the call is the plug point
        # for a separate drafter) and rejection comes only from residency
        # misses invalidating a position and everything drafted after it
        from repro.serving.sampler import greedy_accept, stochastic_accept

        if sample is None:
            accept = int(greedy_accept(draft, draft).min())
        else:
            probs = np.asarray(aux["sample_probs"])             # [K, B, V]
            s_acc, _ = stochastic_accept(draft, probs, probs, sample_rng)
            accept = int(s_acc.min())
        miss_steps = miss.reshape(k, -1).any(axis=1)                # [K]
        missed = np.flatnonzero(miss_steps)
        if tr is not None and missed.size:
            tr.instant("miss", "launch",
                       args={"first_step": int(missed[0]),
                             "steps": int(missed.size)})
        j_star = None
        if missed.size and self.rescfg.host_compute_misses:
            j_star = int(missed[0])
            accept = min(accept, j_star)
        if j_star is not None and self.prefetch:
            # miss-relaunch for the whole window: cover every layer's routed
            # union across the K positions and re-run the ONE compiled window
            # program (it rewrites all K KV slots itself, so no rollback is
            # needed on success). Positions before the first miss recompute
            # bit-identically; the rest become the exact corrected chain —
            # the whole window commits instead of rejecting the suffix.
            redo = self._relaunch_window(
                step_fn, tok, cur_len0, k, ids,
                sample=sample, rng_keys=rng_keys,
            )
            if redo is not None:
                draft, logits, ids, weights, miss, demand_next, probs = redo
                if sample is None:
                    accept = int(greedy_accept(draft, draft).min())
                else:
                    s_acc, _ = stochastic_accept(
                        draft, probs, probs, sample_rng
                    )
                    accept = int(s_acc.min())
                j_star = None
        self.stats.drafted_tokens += k
        self.stats.accepted_tokens += accept
        # --- stats + modeled clock for fully-accepted positions ---------
        for s in range(accept):
            self._account_step_prefix(
                ids[s], miss[s], len(self.layers), cur_len0 + s
            )
        committed = accept
        if j_star is not None:
            # reject the suffix: roll the KV cache back past position j*
            # (restore the pre-window slot contents the rejected positions
            # overwrote — ``tfm.rollback_kv_window``), then replay position
            # j* from its first missed layer exactly like a missed
            # single-token step
            miss_j = miss[j_star]
            start_moe = int(
                np.flatnonzero(
                    miss_j.reshape(miss_j.shape[0], -1).any(axis=1)
                )[0]
            )
            start_li = self._moe_layer_li[start_moe]
            self._dstate = roll_fn(
                self._dstate, saved, jnp.int32(cur_len0), jnp.int32(j_star + 1)
            )
            self.stats.device_dispatches += 1
            if tr is not None:
                tr.instant("kv_rollback", "launch", args={"j_star": j_star})
            self._account_step_prefix(
                ids[j_star], miss[j_star], start_li, cur_len0 + j_star
            )
            logits = self._replay_fused(
                aux, start_moe, start_li, cur_len0 + j_star, step=j_star
            )
            committed = j_star + 1
        # --- window-boundary rotation from committed telemetry ----------
        # host-side transitions run per committed step (residency evolves
        # exactly as one-token-at-a-time); uploads + LUT patches amortize to
        # one batched dispatch per layer per window
        self.manager.rotate_window_from_telemetry(
            self.predictor, ids[:committed], weights[:committed],
            miss[:committed], demand_next[:committed],
            clock=self.clock, record=False,
        )
        return draft[: committed - 1], logits, committed

    def _relaunch_fused(
        self,
        tok: np.ndarray,
        cur_len: int,
        ids0: np.ndarray,
        start_moe: int,
        start_li: int,
    ) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]]:
        """Miss correction by RE-LAUNCH (prefetch mode): the telemetry names
        the missed experts exactly, so upload them, patch the persistent
        planes/LUT incrementally, and re-run the whole compiled step at the
        SAME ``cur_len`` — the relaunch overwrites every KV slot the optimistic
        pass wrote, and a miss-free launch is bit-identical to the
        host-corrected replay (the full-vs-starved exactness invariant), so
        greedy tokens cannot move. One compiled launch + one pull replaces the
        per-layer suffix walk and its sync pull per MoE layer.

        Corrected routing can route to NEW experts (the suffix recomputes from
        corrected hiddens); one more covering relaunch is allowed before
        falling back. Returns ``(logits, ids, weights, miss, demand_next)``
        from the authoritative miss-free pass, or None when residency cannot
        cover a layer's routed set (caller replays) or misses persist."""
        ids_cur = ids0
        for _ in range(2):
            # feasibility first, BEFORE paying any upload: ensure_resident can
            # cover layer l iff |unique routed| <= num_slots (every occupant is
            # either routed — it stays — or evictable), so a doomed relaunch
            # costs nothing and falls straight back to the replay
            routed_all = [
                np.unique(ids_cur[m]) for m in range(start_moe, self.num_moe_layers)
            ]
            if any(
                r.size > self.manager.policies[start_moe + i].lut.num_slots
                for i, r in enumerate(routed_all)
            ):
                return None
            moved = 0
            for i, moe_li in enumerate(range(start_moe, self.num_moe_layers)):
                routed = routed_all[i]
                loads = self.manager.ensure_resident(moe_li, routed, routed)
                if loads is None:
                    return None
                moved += len(loads) * self.manager.stores[moe_li].bytes_per_expert
            if moved:
                self.clock.blocking(moved)
            tr = self._tr
            if tr is not None:
                t_trace = time.perf_counter()
            residency = self.manager.stacked_residency()
            logits_dev, self._dstate, aux = self._fused_step(
                self._decode_params, self._routers_next, jnp.asarray(tok),
                self._dstate, jnp.int32(cur_len), residency,
            )
            self.stats.device_dispatches += 1
            self.stats.relaunched_steps += 1
            if tr is not None:
                tr.complete("launch", "launch", t_trace, time.perf_counter(),
                            args={"kind": "relaunch"})
            for k in self._pull_keys:
                aux[k].copy_to_host_async()
            if tr is not None:
                t_trace = time.perf_counter()
            logits = np.asarray(logits_dev)
            self.stats.sync_pulls += 1
            if tr is not None:
                tr.complete("pull", "pull", t_trace, time.perf_counter(),
                            args={"kind": "relaunch"})
            ids = concat_route_telemetry(aux, "ids", self._moe_segs)
            weights = concat_route_telemetry(aux, "weights", self._moe_segs)
            miss = concat_route_telemetry(aux, "miss", self._moe_segs)
            demand_next = np.asarray(aux["demand_next"])
            if not miss.any():
                # suffix accounting: the caller charged layers < start_li from
                # the original launch; the relaunch is authoritative for the
                # rest (exactly the slice _replay_fused would have recorded)
                self._account_step_prefix(
                    ids, miss, len(self.layers), cur_len, start_li=start_li
                )
                return logits, ids, weights, miss, demand_next
            ids_cur = ids
        return None

    def _relaunch_window(
        self,
        step_fn: Callable,
        tok: np.ndarray,
        cur_len0: int,
        k: int,
        ids0: np.ndarray,
        sample: Optional[SampleParams] = None,
        rng_keys: Optional[jax.Array] = None,
    ) -> Optional[Tuple[np.ndarray, ...]]:
        """Window-sized miss relaunch: cover each layer's routed-expert union
        across all K positions (None when it exceeds the slot count — spec
        windows can route wider than a single step) and re-run the compiled
        window program. On success every position is exact, so the caller
        commits all K tokens; on persistent misses the caller falls back to
        the classic rollback + suffix replay against the ORIGINAL telemetry,
        which stays valid because positions before the first miss recompute
        bit-identically and the pre-window KV snapshot is untouched. Sampled
        windows relaunch with the SAME ``rng_keys`` — position keys are a
        pure function of cache position, so the corrected chain re-draws
        deterministically — and return the relaunched ``sample_probs`` (the
        trailing tuple slot, None for greedy) for the caller's re-run of the
        stochastic accept rule."""
        ids_cur = ids0                                     # [K, L, T, kk]
        for _ in range(2):
            # same zero-cost feasibility gate as the single-step relaunch —
            # crucial here, because a window's routed union across K positions
            # regularly exceeds the slot count and the fallback replay would
            # otherwise be paid ON TOP of wasted uploads and a wasted launch
            routed_all = [
                np.unique(ids_cur[:, m]) for m in range(self.num_moe_layers)
            ]
            if any(
                r.size > self.manager.policies[m].lut.num_slots
                for m, r in enumerate(routed_all)
            ):
                return None
            moved = 0
            for moe_li in range(self.num_moe_layers):
                routed = routed_all[moe_li]
                loads = self.manager.ensure_resident(moe_li, routed, routed)
                if loads is None:
                    return None
                moved += len(loads) * self.manager.stores[moe_li].bytes_per_expert
            if moved:
                self.clock.blocking(moved)
            tr = self._tr
            if tr is not None:
                t_trace = time.perf_counter()
            residency = self.manager.stacked_residency()
            draft_dev, logits_dev, self._dstate, aux = step_fn(
                self._decode_params, self._routers_next, jnp.asarray(tok),
                self._dstate, jnp.int32(cur_len0), residency,
                rng_keys=rng_keys,
            )
            self.stats.device_dispatches += 1
            self.stats.relaunched_steps += 1
            if tr is not None:
                tr.complete("launch", "launch", t_trace, time.perf_counter(),
                            args={"kind": "relaunch"})
            pull_keys = self._pull_keys
            if sample is not None:
                pull_keys = pull_keys + ["sample_probs", "sample_p"]
            for key in pull_keys:
                aux[key].copy_to_host_async()
            draft_dev.copy_to_host_async()
            if tr is not None:
                t_trace = time.perf_counter()
            logits = np.asarray(logits_dev)
            self.stats.sync_pulls += 1
            if tr is not None:
                tr.complete("pull", "pull", t_trace, time.perf_counter(),
                            args={"kind": "relaunch"})
            draft = np.asarray(draft_dev)
            ids = concat_route_telemetry(aux, "ids", self._moe_segs, axis=1)
            weights = concat_route_telemetry(aux, "weights", self._moe_segs, axis=1)
            miss = concat_route_telemetry(aux, "miss", self._moe_segs, axis=1)
            demand_next = np.asarray(aux["demand_next"])
            if not miss.any():
                probs = (
                    np.asarray(aux["sample_probs"]) if sample is not None
                    else None
                )
                return draft, logits, ids, weights, miss, demand_next, probs
            ids_cur = ids
        return None

    def _replay_fused(
        self,
        aux: Dict[str, jax.Array],
        start_moe: int,
        start_li: int,
        cur_len: int,
        step: Optional[int] = None,
    ) -> np.ndarray:
        """Exact re-execution of a fused-step SUFFIX after an observed miss.

        Same contract as ``_replay_step``: layers before ``start_li`` saw
        exactly the inputs/residency the sync walk would have used, so their
        outputs and KV writes stand. The suffix re-executes with the per-layer
        walk from the fused pass's saved block input (``route_x`` telemetry)
        against the SAME residency the compiled step gathered from — rotation
        runs strictly after this replay. Re-running an attention block
        overwrites the very KV slot the optimistic pass wrote, so the
        post-step donated state is a valid replay substrate.

        ``step`` indexes a speculative window's leading K axis (the rejected
        position being replayed at ``cur_len``); the window path rolls the KV
        cache back past ``step`` BEFORE calling this, so the cache the suffix
        reads holds no writes from rejected positions.
        """
        tr = self._tr
        t_trace = time.perf_counter() if tr is not None else 0.0
        si0, r0 = self._moe_pos[start_moe]
        x_anchor = aux[f"route_x/seg{si0}"]
        if step is not None:
            x_anchor = x_anchor[step]
        x = x_anchor[r0].reshape(self.batch, 1, -1)
        self.stats.device_dispatches += 1             # device-side slice
        cur = jnp.int32(cur_len)
        clock = self.clock
        for li in range(start_li, len(self.layers)):
            kind, p_l = self.layers[li]
            state = self._layer_state(li)
            if kind == "attn_moe":
                moe_li = self.moe_index[li]
                attn_half, moe_half = self._block_fn(kind, "decode", routed=True)
                x_mid, h2, ids_dev, w_dev, new_state = attn_half(p_l, x, state, cur)
                slots_tree = self.manager.stores[moe_li].as_pytree()
                lut_dev = self.manager.device_lut(moe_li)
                x, miss_dev = moe_half(
                    p_l, x_mid, h2, ids_dev, w_dev, slots_tree, lut_dev
                )
                self.stats.device_dispatches += 2
                ids = np.asarray(ids_dev)
                weights = np.asarray(w_dev)
                miss = np.asarray(miss_dev)
                self.stats.sync_pulls += 1
                self.stats.replay_pulls += 1
                self.manager.record_routing(moe_li, ids, miss)
                if miss.any() and self.rescfg.host_compute_misses:
                    x = self._host_correct(x, moe_li, h2, ids, weights, miss)
                flops, byts = self._layer_cost(
                    kind, x.shape, cur_len, hits=int((~miss).sum())
                )
                clock.compute(self.cost.compute_s(flops, byts))
            else:
                (block,) = self._block_fn(kind, "decode")
                x, new_state = block(p_l, x, state if state else {}, cur)
                self.stats.device_dispatches += 1
                flops, byts = self._layer_cost(kind, x.shape, cur_len, hits=0)
                clock.compute(self.cost.compute_s(flops, byts), needs_dma=False)
            self._set_layer_state(li, new_state)
        logits = np.asarray(self._lm_head(x[:, -1:])[:, 0])
        self.stats.sync_pulls += 1
        self.stats.replay_pulls += 1
        self.stats.replayed_steps += 1
        if tr is not None:
            tr.complete("replay", "launch", t_trace, time.perf_counter(),
                        args={"start_li": start_li, "step": step})
        return logits

    def _layer_cost(self, kind: str, xshape, cur_len: int, hits: int) -> Tuple[float, float]:
        """(flops, bytes) estimate of one layer at current shapes (modeled clock).

        The per-kind static parameter counts are computed once and cached —
        this runs per layer per decode step on the host and must stay off the
        critical path.
        """
        cfg = self.cfg
        cached = self._cost_cache.get(kind)
        if cached is None:
            from repro.models.params import _block_params

            n_static = float(_block_params(cfg, kind, active_only=True))
            per_hit = 0.0
            if kind == "attn_moe":
                m = cfg.moe
                mats = 3 if cfg.mlp == "swiglu" else 2
                n_static -= m.top_k * mats * cfg.d_model * m.expert_d_ff
                per_hit = float(mats * cfg.d_model * m.expert_d_ff)
            cached = (n_static, per_hit)
            self._cost_cache[kind] = cached
        n_static, per_hit = cached
        tokens = int(np.prod(xshape[:-1]))
        flops = 2.0 * tokens * n_static + 2.0 * hits * per_hit
        byts = 2.0 * n_static + 2.0 * hits * per_hit
        if cfg.uses_kv_cache and kind in ("attn_mlp", "attn_moe", "local_attn"):
            a = cfg.attention
            ctx = min(cur_len + 1, self.rt.cache_len)
            if kind == "local_attn" and a.window:
                ctx = min(ctx, a.window)
            flops += 4.0 * tokens * ctx * a.num_heads * a.head_dim
            byts += 2.0 * xshape[0] * ctx * a.num_kv_heads * a.head_dim * 2
        return flops, byts

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def prefill(self, tokens: np.ndarray) -> np.ndarray:
        """tokens [B, S] -> logits [B, V]; builds the decode state.

        With ``prefill_chunk=C`` (KV-only stacks) the prompt ingests in
        power-of-two chunks: fused engines launch ONE compiled program per
        chunk with one coalesced rotation window between chunks (misses
        suffix-replayed per chunk, exactly like decode); per-layer engines
        walk the same chunks layer-by-layer. Logits and post-prefill KV are
        bit-identical BETWEEN the two chunked paths (fused vs walk, any
        residency mode or slot format), and greedy continuations match the
        legacy full-sequence walk token for token. Prompts longer than the
        KV capacity fall back to the legacy walk: chunk appends would wrap
        the cache ring mid-prompt, silently corrupting attention, where the
        legacy path at least attends over the full prompt before truncating.
        """
        b, s = tokens.shape
        assert b == self.batch
        from repro.models.attention import _cache_capacity

        chunked = (
            self.prefill_chunk is not None
            and self._chunk_prefill_ok
            and s <= _cache_capacity(self.cfg.attention, self.rt.cache_len)
        )
        t0 = time.perf_counter()
        if chunked and self._fused_decode and self._chunk_prefill_fused_ok:
            logits = self._prefill_fused_chunked(tokens)
            self.state = None
        else:
            self.state = [
                tfm._zero_block_state(self.cfg, kind, b, self.rt.cache_len)
                for kind, _ in self.layers
            ]
            if chunked:
                logits = self._prefill_walk_chunked(tokens)
            else:
                x = self._embed(jnp.asarray(tokens))
                x = self._run_layers(x, "prefill", cur_len=0)
                logits = self._lm_head(x[:, -1:])[:, 0]
            if self._fused_decode:
                # one-time: stack the per-layer states into the scan layout
                # the fused step consumes (and donates back, updated in place)
                self._dstate = self._stack_state(self.state)
                self.state = None
        self.stats.wall_s += time.perf_counter() - t0
        self.cur_len = s
        self.stats.tokens += b * s
        return np.asarray(logits)

    def _rotate_chunk_boundary(
        self,
        ids: np.ndarray,                 # [L, T, k] the chunk's routing
        weights: np.ndarray,             # [L, T, k]
        miss: np.ndarray,                # [L, T, k]
        h_all: Optional[jax.Array] = None,   # [L, T, D] stacked MoE hiddens
        demand_dev: Optional[jax.Array] = None,  # pre-dispatched GEMM result
    ) -> None:
        """ONE coalesced rotation window at a chunk boundary, shared by the
        walk and fused chunked prefill paths: the pre-gating demand GEMM runs
        on device over the stacked per-layer hiddens (``_demand_all_jit`` —
        the same compiled program in both paths, so residency evolves
        bit-identically), then ``rotate_from_telemetry`` folds the EMA, runs
        each layer's ring transition once, and batches the uploads to one
        scatter per weight tensor per rotated layer. The fused path dispatches
        the GEMM under the still-in-flight chunk launch and passes the result
        as ``demand_dev``. Hit/miss accounting already happened (walk:
        ``resolve``; fused: prefix accounting + replay), hence
        ``record=False``."""
        if demand_dev is None:
            demand_dev = self._demand_all_jit(h_all, self._routers_next)
            self.stats.device_dispatches += 1
        demand = np.asarray(demand_dev)
        self.manager.rotate_from_telemetry(
            self.predictor, ids, weights, miss, demand,
            clock=self.clock, record=False,
        )

    def _prefill_walk_chunked(self, tokens: np.ndarray) -> jax.Array:
        """Per-layer chunked prefill (the layer-walk baseline, and the chunked
        path for host_routing / LRU / ``fused_decode=False`` engines): each
        chunk walks the stack with the same chunk-append attention the fused
        step uses — one host sync per MoE layer per chunk — then rotates once
        at the chunk boundary."""
        s = tokens.shape[1]
        d = self.cfg.d_model
        cur, x = 0, None
        for c in prefill_chunk_plan(s, self.prefill_chunk):
            self._chunk_telem = []
            x = self._embed(jnp.asarray(tokens[:, cur : cur + c]))
            x = self._run_layers(x, "chunk", cur_len=cur)
            self.stats.prefill_chunks += 1
            self._rotate_chunk_boundary(
                np.stack([t[0] for t in self._chunk_telem]),
                np.stack([t[1] for t in self._chunk_telem]),
                np.stack([t[2] for t in self._chunk_telem]),
                jnp.stack([t[3].reshape(-1, d) for t in self._chunk_telem]),
            )
            cur += c
        self._chunk_telem = []      # don't pin the last chunk's device hiddens
        return self._lm_head(x[:, -1:])[:, 0]

    def _prefill_fused_chunked(self, tokens: np.ndarray) -> np.ndarray:
        """Fused chunked prefill: ONE compiled whole-stack launch + one
        queue-draining pull + one coalesced rotation window per chunk.

        Per chunk: (1) launch the fused prefill-chunk step against the
        current ``stacked_residency()`` with donated KV; (2) exactness — if
        the optimistic pass missed, the chunk suffix replays from the first
        missed layer with the per-layer walk (``_replay_prefill_chunk``),
        host-correcting exactly like the walk baseline and patching the
        telemetry with the authoritative routing/hiddens; (3) rotate once at
        the boundary (``_rotate_chunk_boundary``: shared demand GEMM + EMA
        fold + ring transitions + batched uploads). The final chunk also
        rotates, so decode starts pre-gated the same way the walk leaves it.
        """
        b, s = tokens.shape
        self._dstate = tfm.zero_state(self.cfg, b, self.rt.cache_len)
        plan = prefill_chunk_plan(s, self.prefill_chunk)
        cur, logits = 0, None
        tr = self._tr
        for ci, c in enumerate(plan):
            last = ci == len(plan) - 1
            step_fn = (
                self._fused_prefill_step if last
                else self._fused_prefill_step_nohead
            )
            if tr is not None:
                tr.new_unit("chunk")
                t_trace = time.perf_counter()
            residency = self.manager.stacked_residency()
            logits_dev, self._dstate, aux = step_fn(
                self._decode_params, self._routers_next,
                jnp.asarray(tokens[:, cur : cur + c]), self._dstate,
                jnp.int32(cur), residency,
            )
            self.stats.device_dispatches += 1
            self.stats.prefill_chunks += 1
            if tr is not None:
                tr.complete("launch", "launch", t_trace, time.perf_counter(),
                            args={"chunk": c, "cur_len": cur})
            for k in self._prefill_pull_keys:
                aux[k].copy_to_host_async()
            self.stats.overlapped_pulls += len(self._prefill_pull_keys)
            # dispatch the boundary demand GEMM behind the in-flight launch:
            # its input is the step's own route_h output, so it is computed
            # by the time the blocking telemetry pulls below drain the queue
            # (only usable when no replay patches the hiddens — see below)
            segs = [aux[f"route_h/seg{si}"] for si in self._moe_segs]
            h_fast = segs[0] if len(segs) == 1 else jnp.concatenate(segs)
            demand_dev = self._demand_all_jit(h_fast, self._routers_next)
            self.stats.device_dispatches += 1
            if self.prefetch:
                # chunk launch in flight: shadow-upload the predicted next
                # chunk-boundary transition under it
                self.manager.begin_prefetch(self.predictor, self.clock)
            if tr is not None:
                t_trace = time.perf_counter()
            if last:
                logits = np.asarray(logits_dev)  # THE queue-draining pull
            self.stats.sync_pulls += 1
            # non-final chunks have no head output: the first telemetry read
            # below is their one queue-draining pull instead
            ids = concat_route_telemetry(aux, "ids", self._moe_segs)  # [L,T,k]
            if tr is not None:
                tr.complete("pull", "pull", t_trace, time.perf_counter(),
                            args={"chunk": c})
            weights = concat_route_telemetry(aux, "weights", self._moe_segs)
            miss = concat_route_telemetry(aux, "miss", self._moe_segs)
            missed = np.flatnonzero(miss.reshape(miss.shape[0], -1).any(axis=1))
            if tr is not None and missed.size:
                tr.instant("miss", "launch",
                           args={"first_moe": int(missed[0]),
                                 "layers": int(missed.size)})
            start_moe = (
                int(missed[0])
                if (missed.size and self.rescfg.host_compute_misses)
                else self.num_moe_layers
            )
            start_li = (
                self._moe_layer_li[start_moe]
                if start_moe < self.num_moe_layers
                else len(self.layers)
            )
            self._account_step_prefix(ids, miss, start_li, cur, tokens=c)
            if start_li < len(self.layers):
                # the replay patches authoritative rows in place; telemetry
                # views of device buffers are read-only, so copy first
                ids, weights, miss = (
                    np.array(a) for a in (ids, weights, miss)
                )
                h_rows = [
                    aux[f"route_h/seg{si}"][r]
                    for si, r in self._moe_pos
                ]                               # per MoE layer: [T, D] device
                replay_logits = self._replay_prefill_chunk(
                    aux, start_moe, start_li, cur, c,
                    ids, weights, miss, h_rows, with_head=last,
                )
                if last:
                    logits = replay_logits
                # the replay patched the hiddens — the optimistic GEMM read
                # stale rows; re-run it over the authoritative stack
                self._rotate_chunk_boundary(
                    ids, weights, miss, h_all=jnp.stack(h_rows)
                )
            else:
                self._rotate_chunk_boundary(
                    ids, weights, miss, demand_dev=demand_dev
                )
            cur += c
        return logits

    def _replay_prefill_chunk(
        self,
        aux: Dict[str, jax.Array],
        start_moe: int,
        start_li: int,
        cur_len: int,
        chunk: int,
        ids_all: np.ndarray,             # [L, T, k] — patched in place
        weights_all: np.ndarray,
        miss_all: np.ndarray,
        h_rows: List[jax.Array],         # per MoE layer [T, D] — patched too
        with_head: bool = True,
    ) -> Optional[np.ndarray]:
        """Exact re-execution of a prefill-chunk SUFFIX after an observed miss
        — :meth:`_replay_fused` at chunk width. Layers before ``start_li`` saw
        exactly what the layer walk would have computed, so their outputs and
        KV writes stand; the suffix re-runs per layer from the chunk's saved
        block input (``route_x`` [T, D] reshaped to [B, C, D]) against the
        same residency the launch gathered from, host-correcting between
        layers. Re-running a chunk's attention overwrites the very cache
        slots the optimistic pass wrote (window-free caches only — the fused
        gate), so the post-launch donated state is a valid replay substrate.

        The replayed layers' AUTHORITATIVE routing and hiddens are patched
        into the caller's telemetry arrays, so the boundary rotation consumes
        exactly what the walk baseline would have produced — residency after
        the chunk is bit-identical across paths. ``with_head=False`` (every
        chunk but the prompt's last) skips the lm-head GEMM and its logits
        pull — only the final chunk's logits are consumed.
        """
        tr = self._tr
        t_replay = time.perf_counter() if tr is not None else 0.0
        si0, r0 = self._moe_pos[start_moe]
        x = aux[f"route_x/seg{si0}"][r0].reshape(self.batch, chunk, -1)
        self.stats.device_dispatches += 1             # device-side slice
        cur = jnp.int32(cur_len)
        clock = self.clock
        for li in range(start_li, len(self.layers)):
            kind, p_l = self.layers[li]
            state = self._layer_state(li)
            if kind == "attn_moe":
                moe_li = self.moe_index[li]
                attn_half, moe_half = self._block_fn(kind, "chunk", routed=True)
                x_mid, h2, ids_dev, w_dev, new_state = attn_half(p_l, x, state, cur)
                slots_tree = self.manager.stores[moe_li].as_pytree()
                lut_dev = self.manager.device_lut(moe_li)
                x, miss_dev = moe_half(
                    p_l, x_mid, h2, ids_dev, w_dev, slots_tree, lut_dev
                )
                self.stats.device_dispatches += 2
                ids = np.asarray(ids_dev)
                weights = np.asarray(w_dev)
                miss = np.asarray(miss_dev)
                self.stats.sync_pulls += 1
                self.stats.replay_pulls += 1
                self.manager.record_routing(moe_li, ids, miss)
                if miss.any() and self.rescfg.host_compute_misses:
                    x = self._host_correct(x, moe_li, h2, ids, weights, miss)
                ids_all[moe_li] = ids
                weights_all[moe_li] = weights
                miss_all[moe_li] = miss
                h_rows[moe_li] = h2.reshape(-1, x.shape[-1])
                flops, byts = self._layer_cost(
                    kind, x.shape, cur_len, hits=int((~miss).sum())
                )
                clock.compute(self.cost.compute_s(flops, byts))
            else:
                (block,) = self._block_fn(kind, "chunk")
                x, new_state = block(p_l, x, state if state else {}, cur)
                self.stats.device_dispatches += 1
                flops, byts = self._layer_cost(kind, x.shape, cur_len, hits=0)
                clock.compute(self.cost.compute_s(flops, byts), needs_dma=False)
            self._set_layer_state(li, new_state)
        self.stats.prefill_replays += 1
        if tr is not None:
            tr.complete("replay", "launch", t_replay, time.perf_counter(),
                        args={"start_li": start_li, "chunk": chunk})
        if not with_head:
            return None
        logits = np.asarray(self._lm_head(x[:, -1:])[:, 0])
        self.stats.sync_pulls += 1
        self.stats.replay_pulls += 1
        return logits

    def decode(
        self,
        last_logits: np.ndarray,
        steps: int,
        *,
        greedy: bool = True,
        seed: int = 0,
        sampler: Optional[Any] = None,
    ) -> np.ndarray:
        """Generate ``steps`` tokens. Returns [B, steps].

        With ``spec_k > 1`` decode advances in speculative windows: each
        window emits up to ``spec_k`` tokens from ONE compiled program launch
        and one queue-draining pull (bit-identical to single-token decode —
        rejected positions are rolled back and replayed). This holds for
        SAMPLED decode too: pass ``sampler`` (a
        ``repro.serving.sampler.SamplerConfig``) or ``greedy=False`` (plain
        temperature-1.0 sampling seeded by ``seed``) and the fused path
        drafts on-device from the warped distribution with position-keyed
        draws, accepting via the stochastic rule — sampled fused decode
        always runs the scanned window family (size-1 windows when
        ``spec_k == 1``), so the spec-K and single-token streams are the
        same compiled program at different trip counts and match bitwise.
        """
        out = np.zeros((self.batch, steps), np.int32)
        logits = last_logits
        if sampler is None and not greedy:
            from repro.serving.sampler import SamplerConfig

            sampler = SamplerConfig(temperature=1.0, seed=seed)
        sampled = sampler is not None and sampler.temperature > 0.0
        sp = base_keys = sample_fn = sample_rng = None
        if sampled:
            sp = SampleParams(
                float(sampler.temperature), int(sampler.top_k),
                float(sampler.top_p),
            )
            base_keys = sampling_mod.row_keys(sampler.seed, self.batch)
            sample_fn = self._sample_fns.get(sp)
            if sample_fn is None:
                sample_fn = sampling_mod.build_sample_fn(sp)
                self._sample_fns[sp] = sample_fn
            sample_rng = np.random.default_rng(sampler.seed)
        spec = self._fused_decode and self.spec_k > 1
        t0 = time.perf_counter()
        i = 0
        while i < steps:
            if sampled:
                tok = np.asarray(sample_fn(
                    jnp.asarray(logits), base_keys,
                    jnp.int32(self.cur_len - 1),
                ))
                self.stats.sync_pulls += 1
            else:
                tok = np.argmax(logits, axis=-1).astype(np.int32)
            out[:, i] = tok
            t_win = time.perf_counter()
            k = min(self.spec_k, steps - i) if spec else 1
            if k > 1 or (sampled and self._fused_decode):
                extra, logits, committed = self._decode_window_fused(
                    tok, k, sample=sp, rng_keys=base_keys,
                    sample_rng=sample_rng,
                )
                if committed > 1:
                    out[:, i + 1 : i + committed] = extra.T
                advanced = committed
            else:
                if self._fused_decode:
                    logits = self._decode_step_fused(tok)
                elif self._hot_decode:
                    logits = self._decode_step_hot(tok)
                else:
                    x = self._embed(jnp.asarray(tok)[:, None])
                    x = self._run_layers(x, "decode", cur_len=self.cur_len)
                    logits = np.asarray(self._lm_head(x[:, -1:])[:, 0])
                    self.stats.sync_pulls += 1
                advanced = 1
            i += advanced
            self.cur_len += advanced
            self.stats.steps += advanced
            self.stats.tokens += self.batch * advanced
            self.metrics.histogram(
                "window_ms", "wall ms per decode step/window"
            ).observe((time.perf_counter() - t_win) * 1e3)
        self.stats.wall_s += time.perf_counter() - t0
        self.stats.compute_s = self.clock.compute_s
        self.stats.transfer_s = self.clock.transfer_s
        self.stats.stall_s = self.clock.stall_s
        self.stats.host_compute_s = self.clock.host_s
        self.last_logits = logits          # resume point for chained decodes
        return out

    def generate(self, prompt: np.ndarray, max_new: int, **kw) -> np.ndarray:
        logits = self.prefill(prompt)
        return self.decode(logits, max_new, **kw)
