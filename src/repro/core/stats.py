"""Residency accounting: hits/misses, bytes moved, modeled stall time.

All counters are plain python/numpy (host side) — they describe the engine's
externally-observable behaviour, mirroring the paper's Table 4 metrics.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List


@dataclass
class LayerStats:
    hits: int = 0
    misses: int = 0
    host_computed: int = 0          # misses executed on host (n-cpu-moe analog)
    loads: int = 0                  # expert uploads to device slots
    bytes_loaded: int = 0
    reverse_rotations: int = 0
    forward_rotations: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 1.0


@dataclass
class EngineStats:
    layers: Dict[int, LayerStats] = field(default_factory=dict)
    steps: int = 0
    tokens: int = 0
    compute_s: float = 0.0          # modeled device compute time
    transfer_s: float = 0.0         # modeled host->device transfer time
    stall_s: float = 0.0            # transfer time NOT hidden behind compute
    host_compute_s: float = 0.0     # modeled host GEMM time for misses
    wall_s: float = 0.0             # measured wall time (reduced model, CPU)
    sync_pulls: int = 0             # queue-draining device->host reads (the
                                    # hot decode path does exactly 1 per token)
    overlapped_pulls: int = 0       # pipelined reads that overlap queued compute
    device_dispatches: int = 0      # host->device program launches the engine
                                    # issues (fused decode: 1 per miss-free token)
    lut_patch_dispatches: int = 0   # incremental LUT patch launches (subset of
                                    # device_dispatches; <=1 per layer per step)
    upload_dispatches: int = 0      # slot-upload scatter launches (fused: ONE
                                    # per rotation covering all weight tensors
                                    # and quant planes, not per expert/tensor)
    bytes_uploaded: int = 0         # real host->device slot-upload bytes (packed
                                    # bytes under int8/int4 — the link traffic the
                                    # quantized store shrinks ~2x / ~4x)
    replayed_steps: int = 0         # decode steps suffix-replayed after a miss
    replay_pulls: int = 0           # sync_pulls issued BY replay (subset of
                                    # sync_pulls; lets the speculative window's
                                    # 1-pull-per-window bound be checked net of
                                    # the exactness machinery's own reads)
    prefill_chunks: int = 0         # chunked-prefill launches (fused: ONE
                                    # compiled launch + one queue-draining pull
                                    # per chunk; walk: one chunk of the layer walk)
    prefill_replays: int = 0        # prefill chunks suffix-replayed after a miss
    spec_windows: int = 0           # speculative windows launched
    drafted_tokens: int = 0         # tokens self-drafted inside spec windows
    accepted_tokens: int = 0        # drafted tokens that committed (greedy
                                    # self-draft: rejections come only from
                                    # residency misses, so accept-rate < 1 is
                                    # a KV-rollback / replay canary)
    windows: int = 0                # serving decode launches over the paged
                                    # pool (every continuous-batching tick is
                                    # a window launch, size-1 included — the
                                    # 1-launch + 1-pull contract is checked
                                    # against this)
    kv_pages_allocated: int = 0     # KV pool pages drawn from the free list
    kv_pages_released: int = 0      # KV pool pages returned on request finish
    kv_pages_hwm: int = 0           # peak pages simultaneously in use (the
                                    # pool-pressure admission high-water mark)
    prefetch_launched: int = 0      # speculative expert uploads shipped into the
                                    # shadow generation during window compute
    prefetch_hits: int = 0          # prefetched uploads the authoritative
                                    # transition confirmed (flip reuses the
                                    # bytes; no boundary upload needed)
    prefetch_wasted_bytes: int = 0  # shadow bytes the transition disagreed with
                                    # (mispredicted slots, overwritten before
                                    # the flip by the correction pass)
    overlap_ms: float = 0.0         # wall time the prefetch work spent hidden
                                    # under in-flight window compute (dispatch
                                    # happens between the launch and its
                                    # queue-draining pull)
    relaunched_steps: int = 0       # compiled re-launches that replaced the
                                    # per-layer suffix replay (prefetch mode:
                                    # missed experts uploaded, planes patched
                                    # incrementally, step re-run miss-free)

    def layer(self, idx: int) -> LayerStats:
        return self.layers.setdefault(idx, LayerStats())

    @property
    def hits(self) -> int:
        return sum(l.hits for l in self.layers.values())

    @property
    def misses(self) -> int:
        return sum(l.misses for l in self.layers.values())

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 1.0

    @property
    def bytes_loaded(self) -> int:
        return sum(l.bytes_loaded for l in self.layers.values())

    @property
    def accept_rate(self) -> float:
        """Accepted / drafted over all speculative windows (1.0 when no
        speculation ran — the non-speculative path 'accepts' every token)."""
        return (
            self.accepted_tokens / self.drafted_tokens
            if self.drafted_tokens
            else 1.0
        )

    def modeled_step_time(self) -> float:
        """Per-token modeled latency: compute + unhidden transfer + host misses."""
        if self.steps == 0:
            return 0.0
        return (self.compute_s + self.stall_s + self.host_compute_s) / self.steps

    def per_layer(self) -> List[Dict[str, float]]:
        """Per-layer residency table (one row per MoE layer, index order).

        Surfaces the rotation-direction counters ``LayerStats`` has always
        tracked but ``summary()`` aggregates away — a layer rotating
        backwards (reverse_rotations) or re-loading heavily is the first
        thing to look at when ``hit_rate`` regresses.
        """
        rows: List[Dict[str, float]] = []
        for idx in sorted(self.layers):
            l = self.layers[idx]
            rows.append({
                "layer": idx,
                "hit_rate": round(l.hit_rate, 4),
                "hits": l.hits,
                "misses": l.misses,
                "host_computed": l.host_computed,
                "loads": l.loads,
                "bytes_loaded_MB": round(l.bytes_loaded / 2**20, 3),
                "forward_rotations": l.forward_rotations,
                "reverse_rotations": l.reverse_rotations,
            })
        return rows

    def per_layer_table(self) -> str:
        """``per_layer()`` pretty-printed for the examples / CLI."""
        header = (f"{'layer':>5} {'hit_rate':>8} {'misses':>7} {'loads':>6} "
                  f"{'MB':>8} {'fwd_rot':>7} {'rev_rot':>7}")
        lines = [header]
        for r in self.per_layer():
            lines.append(
                f"{r['layer']:>5} {r['hit_rate']:>8.4f} {r['misses']:>7} "
                f"{r['loads']:>6} {r['bytes_loaded_MB']:>8.3f} "
                f"{r['forward_rotations']:>7} {r['reverse_rotations']:>7}"
            )
        return "\n".join(lines)

    def summary(self) -> Dict[str, float]:
        return {
            "steps": self.steps,
            "tokens": self.tokens,
            "hit_rate": round(self.hit_rate, 4),
            "misses": self.misses,
            "bytes_loaded_MB": round(self.bytes_loaded / 2**20, 2),
            "bytes_uploaded_MB": round(self.bytes_uploaded / 2**20, 2),
            "modeled_ms_per_token": round(1e3 * self.modeled_step_time(), 3),
            "modeled_tok_per_s": round(
                1.0 / self.modeled_step_time() if self.modeled_step_time() else 0.0, 2
            ),
            "measured_wall_s": round(self.wall_s, 3),
            "stall_s": round(self.stall_s, 4),
            "sync_pulls": self.sync_pulls,
            "overlapped_pulls": self.overlapped_pulls,
            "device_dispatches": self.device_dispatches,
            "lut_patch_dispatches": self.lut_patch_dispatches,
            "upload_dispatches": self.upload_dispatches,
            "replayed_steps": self.replayed_steps,
            "prefill_chunks": self.prefill_chunks,
            "prefill_replays": self.prefill_replays,
            "spec_windows": self.spec_windows,
            "drafted_tokens": self.drafted_tokens,
            "accepted_tokens": self.accepted_tokens,
            "accept_rate": round(self.accept_rate, 4),
            "windows": self.windows,
            "kv_pages_allocated": self.kv_pages_allocated,
            "kv_pages_released": self.kv_pages_released,
            "kv_pages_hwm": self.kv_pages_hwm,
            "prefetch_launched": self.prefetch_launched,
            "prefetch_hits": self.prefetch_hits,
            "prefetch_wasted_bytes": self.prefetch_wasted_bytes,
            "overlap_ms": round(self.overlap_ms, 3),
            "relaunched_steps": self.relaunched_steps,
        }
