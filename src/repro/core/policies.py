"""Residency policies: rotary (the paper) vs LRU / static / full baselines.

Interface per MoE layer:
  * ``prepare(demand)``   — proactive transition BEFORE the layer executes,
    driven by the (predicted) demand vector. Returns expert->slot loads to issue
    off the critical path (hidden behind compute when bandwidth allows).
  * ``on_miss(expert)``   — reactive handling when a routed expert is not
    resident: a blocking load (LRU) or None = leave to host compute (paper's
    n-cpu-moe path).
  * ``touch(experts)``    — usage feedback.
"""
from __future__ import annotations

import copy
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.lut import SlotLUT
from repro.core.rotation import RotaryRing

Load = Tuple[int, int]   # (expert, slot)


class ResidencyPolicy:
    name = "base"
    # True for policies whose miss handling mutates residency MID-step (they
    # need routed ids on host before the next layer runs, forcing the engine's
    # per-layer sync walk instead of the device-resident hot path)
    needs_sync_resolve = False
    # >0 enables predictive steering (see RotaryPolicy): up to this many of the
    # coldest resident slots may be retargeted to hot off-window experts per
    # transition. Set ONLY via the residency manager's prefetch mode — the
    # synchronous baseline keeps 0, so its transitions are byte-identical to
    # every prior PR.
    prefetch_margin = 0

    def __init__(self, num_experts: int, num_slots: int):
        self.lut = SlotLUT(num_experts, num_slots)

    def prepare(
        self, demand: np.ndarray, steer_demand: Optional[np.ndarray] = None
    ) -> List[Load]:
        return []

    def simulate_prepare(
        self, demand: np.ndarray, steer_demand: Optional[np.ndarray] = None
    ) -> List[Load]:
        """The loads the NEXT ``prepare(demand)`` would issue, WITHOUT mutating
        this policy — the prefetch planner runs it on clones so speculative
        uploads never advance the authoritative LUT/ring state."""
        sim = copy.copy(self)
        sim.lut = self.lut.clone()
        return sim.prepare(demand, steer_demand)

    def on_miss(self, expert: int) -> Optional[Load]:
        return None

    def touch(self, experts: np.ndarray) -> None:
        pass

    # helper: place `experts` into slots, evicting non-members of `keep`
    def _place(self, experts: List[int], keep: np.ndarray) -> List[Load]:
        loads: List[Load] = []
        keep_set = set(int(e) for e in keep)
        evictable = [
            s for s in range(self.lut.num_slots)
            if self.lut.s2e[s] >= 0 and int(self.lut.s2e[s]) not in keep_set
        ]
        free = self.lut.free_slots + evictable
        for e in experts:
            if self.lut.is_resident(e):
                continue
            if not free:
                break
            slot = free.pop(0)
            self.lut.assign(int(e), slot)
            loads.append((int(e), slot))
        return loads


class FullPolicy(ResidencyPolicy):
    """Everything resident (num_slots == num_experts): the paper's 'whole
    warehouse on the loading dock' strawman; also the EP-sharded pod default."""

    name = "full"

    def __init__(self, num_experts: int, num_slots: int):
        super().__init__(num_experts, num_experts)
        self.initial_loads = [(e, e) for e in range(num_experts)]
        for e, s in self.initial_loads:
            self.lut.assign(e, s)


class StaticPolicy(ResidencyPolicy):
    """Fixed top-demand resident set chosen at startup, never rotated."""

    name = "static"

    def __init__(self, num_experts: int, num_slots: int):
        super().__init__(num_experts, num_slots)
        self._initialized = False

    def prepare(
        self, demand: np.ndarray, steer_demand: Optional[np.ndarray] = None
    ) -> List[Load]:
        if self._initialized:
            return []
        self._initialized = True
        top = np.argsort(-demand)[: self.lut.num_slots]
        return self._place([int(e) for e in top], top)


class LruPolicy(ResidencyPolicy):
    """Classic one-directional eviction: no prefetch; a miss blocks on a load
    that replaces the least-recently-used slot."""

    name = "lru"
    needs_sync_resolve = True

    def __init__(self, num_experts: int, num_slots: int):
        super().__init__(num_experts, num_slots)
        self.clock = 0
        self.last_used = np.full((num_experts,), -1, np.int64)

    def touch(self, experts: np.ndarray) -> None:
        self.clock += 1
        self.last_used[np.asarray(experts, np.int64)] = self.clock

    def on_miss(self, expert: int) -> Optional[Load]:
        free = self.lut.free_slots
        if free:
            slot = free[0]
        else:
            res = self.lut.resident_experts
            victim = int(res[np.argmin(self.last_used[res])])
            slot = self.lut.slot_of(victim)
        self.lut.assign(expert, slot)
        self.touch(np.array([expert]))
        return (expert, slot)


class RotaryPolicy(ResidencyPolicy):
    """The paper's policy: ring-ordered experts, bounded cyclic window rotation,
    hidden-state-guided (demand-vector) transitions, cyclical return on
    recurring context. Misses fall through to host compute (prefetch exists to
    make them rare), keeping loads OFF the critical path."""

    name = "rotary"

    def __init__(
        self,
        num_experts: int,
        num_slots: int,
        *,
        rotation_stride: int = 4,
        reverse_threshold: float = 0.85,
        host_compute_misses: bool = True,
        seed: int = 0,
    ):
        super().__init__(num_experts, num_slots)
        self.ring = RotaryRing(
            num_experts,
            num_slots,
            max_stride=rotation_stride,
            reverse_threshold=reverse_threshold,
            seed=seed,
        )
        self.host_compute_misses = host_compute_misses
        self.last_decision = None

    def prepare(
        self, demand: np.ndarray, steer_demand: Optional[np.ndarray] = None
    ) -> List[Load]:
        decision = self.ring.rotate(demand)
        self.last_decision = decision
        # the ring rotates on the long-horizon EMA; steering retargets slots
        # on the FRESH pre-gating sample when one is supplied — replay is
        # billed per step-with-a-miss, so the steering signal must predict the
        # next step's routing, not the running average
        target = self._steer_window(
            decision.window, demand if steer_demand is None else steer_demand
        )
        return self._place([int(e) for e in target], target)

    def _steer_window(self, window: np.ndarray, demand: np.ndarray) -> np.ndarray:
        """Predictive steering (prefetch mode only): swap up to
        ``prefetch_margin`` of the window's coldest experts for strictly-hotter
        experts the bounded ring rotation cannot reach. This is what converts
        predicted misses into hits — the ring keeps hot experts CONTIGUOUS
        only in aggregate, and a miss costs a host GEMM + suffix replay, far
        more than the int4 upload a swap costs. Deterministic: stable argsort,
        expert-id tie-breaks. With margin 0 (the synchronous baseline) the ring
        window passes through untouched."""
        margin = self.prefetch_margin
        if margin <= 0:
            return window
        members = [int(e) for e in window]
        member_set = set(members)
        order = np.argsort(-demand, kind="stable")
        hot = [
            int(e) for e in order
            if int(e) not in member_set and demand[int(e)] > 0.0
        ][:margin]
        if not hot:
            return window
        cold = sorted(members, key=lambda e: (demand[e], e))
        swapped = list(members)
        ci = 0
        for h in hot:                        # hottest missing vs coldest held
            victim = cold[ci]
            if demand[victim] >= demand[h]:
                break
            swapped[swapped.index(victim)] = h
            ci += 1
        return np.asarray(swapped, np.int32)

    def simulate_prepare(
        self, demand: np.ndarray, steer_demand: Optional[np.ndarray] = None
    ) -> List[Load]:
        sim = copy.copy(self)
        sim.ring = self.ring.clone()
        sim.lut = self.lut.clone()
        return sim.prepare(demand, steer_demand)

    def on_miss(self, expert: int) -> Optional[Load]:
        if self.host_compute_misses:
            return None                      # host executes it (n-cpu-moe analog)
        free = self.lut.free_slots
        if not free:
            return None
        slot = free[0]
        self.lut.assign(expert, slot)
        return (expert, slot)


def make_policy(mode: str, num_experts: int, num_slots: int, rescfg=None, seed: int = 0
                ) -> ResidencyPolicy:
    if mode == "full":
        return FullPolicy(num_experts, num_experts)
    if mode == "static":
        return StaticPolicy(num_experts, num_slots)
    if mode == "lru":
        return LruPolicy(num_experts, num_slots)
    if mode == "rotary":
        kw: Dict = {}
        if rescfg is not None:
            kw = dict(
                rotation_stride=rescfg.rotation_stride,
                reverse_threshold=rescfg.reverse_threshold,
                host_compute_misses=rescfg.host_compute_misses,
            )
        return RotaryPolicy(num_experts, num_slots, seed=seed, **kw)
    raise ValueError(f"unknown residency mode {mode!r}")
