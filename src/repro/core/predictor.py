"""Hidden-state-guided residency prediction (pre-gating).

Layer l+1's demand is predicted *before* it executes by pushing layer l's
post-attention hidden state through layer l+1's router matrix — a cheap [D, E]
GEMV — and EMA-smoothing across steps. This is the natural reading of the
patent's "hidden-state-guided residency decisions": the signal is generated
during execution, not from static configuration.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np


def softmax(x: np.ndarray, axis: int = -1) -> np.ndarray:
    x = x - x.max(axis=axis, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=axis, keepdims=True)


def host_topk_route(
    logits: np.ndarray, k: int, *, normalize: bool = True
) -> "tuple[np.ndarray, np.ndarray]":
    """Host-side router: logits [T, E] -> (ids [T, k] int32, weights [T, k] f32).

    Tie-breaking is lowest-index-wins (``kind="stable"`` on the descending
    sort), matching ``jax.lax.top_k`` and the Pallas ``topk_gate`` kernel so the
    host and device routing paths pick identical experts on tied probabilities.
    """
    probs = softmax(np.asarray(logits, np.float32), axis=-1)
    ids = np.argsort(-probs, axis=-1, kind="stable")[:, :k].astype(np.int32)
    weights = np.take_along_axis(probs, ids, axis=-1)
    if normalize:
        weights = weights / np.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return ids, weights


class DemandPredictor:
    """Per-model predictor over ``num_layers`` MoE layers.

    ``routers`` holds each MoE layer's router matrix [D, E] (host copies).
    ``predict(l, h)`` estimates the demand of layer ``l`` from hidden state
    ``h`` [B, D] taken at the *previous* layer's output.
    """

    def __init__(self, routers: List[np.ndarray], ema: float = 0.8):
        self.routers = [np.asarray(r, np.float32) for r in routers]
        self.ema = ema
        e = self.routers[0].shape[1]
        self.smoothed = [np.full((e,), 1.0 / e, np.float64) for _ in self.routers]
        # freshest raw pre-gating sample per layer (pre-EMA): the prefetch
        # planner steers on this — one step stale, but far closer to the next
        # step's actual routing than the heavily damped EMA
        self.last_sample = [np.full((e,), 1.0 / e, np.float64) for _ in self.routers]

    @property
    def num_layers(self) -> int:
        return len(self.routers)

    def predict(self, layer: int, h: Optional[np.ndarray]) -> np.ndarray:
        """Demand vector [E] for ``layer``; h [B, D] or None (cold start)."""
        if h is None:
            return self.smoothed[layer].copy()
        logits = np.asarray(h, np.float32) @ self.routers[layer]      # [B, E]
        return self.update(layer, softmax(logits, axis=-1).mean(axis=0))

    def update(self, layer: int, demand: np.ndarray) -> np.ndarray:
        """EMA-fold an externally computed demand sample [E] (the fused decode
        step's on-device router GEMM) and return the smoothed demand — the
        host half of ``predict`` when the GEMM already ran on device."""
        demand = np.asarray(demand, np.float64)
        self.last_sample[layer] = demand.copy()
        self.smoothed[layer] = self.ema * self.smoothed[layer] + (1 - self.ema) * demand
        return self.smoothed[layer].copy()

    def fold_window(
        self,
        layer: int,
        ids: np.ndarray,         # [K, T, k] routed ids, one row per window step
        weights: np.ndarray,     # [K, T, k]
        demands: np.ndarray,     # [K, E] on-device demand samples per step
    ) -> np.ndarray:
        """Demand aggregated over a speculative window: fold every accepted
        step's (observed routing, predicted demand) pair into the EMA in step
        order, returning the smoothed demand AFTER each step [K, E].

        One call per layer per window replaces 2K ``observe``/``update`` calls
        while staying bit-identical to applying the same K steps one token at
        a time — the invariant the window-deferred-rotation property tests
        pin down (residency transitions consume row ``s`` exactly where a
        sequential engine would have used step ``s``'s smoothed demand).
        """
        out = np.empty((ids.shape[0], self.routers[layer].shape[1]), np.float64)
        for s in range(ids.shape[0]):
            self.observe(layer, ids[s], weights[s])
            out[s] = self.update(layer, demands[s])
        return out

    def next_layer_routers(self) -> np.ndarray:
        """Stacked router matrices [L, D, E] with R[l] = router of layer
        (l+1) % L, so ``softmax(h_l @ R[l])`` is layer l+1's demand predicted
        from layer l's hidden — uploaded once and consumed inside the fused
        decode step (pre-gating moved on-device)."""
        n = self.num_layers
        return np.stack([self.routers[(l + 1) % n] for l in range(n)])

    def observe(self, layer: int, ids: np.ndarray, weights: np.ndarray) -> None:
        """Fold actually-routed experts back into the smoothed demand (feedback
        for when pre-gating and true routing diverge)."""
        e = self.routers[layer].shape[1]
        actual = np.zeros((e,), np.float64)
        np.add.at(actual, ids.reshape(-1), weights.reshape(-1).astype(np.float64))
        s = actual.sum()
        if s > 0:
            actual /= s
            self.smoothed[layer] = 0.5 * self.smoothed[layer] + 0.5 * actual

    def forecast(self, layer: int) -> np.ndarray:
        """Current smoothed demand [E] — the prefetch planner's forecast of
        the NEXT boundary's transition input. The boundary will fold a fresh
        on-device sample into this EMA before transitioning; speculation uses
        the pre-fold value, which is why a prefetched slot can mispredict and
        why the commit pass re-checks every one."""
        return self.smoothed[layer].copy()

    def steer_signal(self, layer: int) -> np.ndarray:
        """Freshest raw pre-gating sample [E] for predictive slot steering."""
        return self.last_sample[layer].copy()

    def top_experts(self, layer: int, k: int) -> np.ndarray:
        return np.argsort(-self.smoothed[layer])[:k].astype(np.int32)
