"""Slot-LUT grouped matmul — the paper's compute hot-spot, TPU-native.

Expert FFN compute addressed *through the rotating slot buffer*: the kernel
receives per-expert token tiles, the slot weight store (HBM), and the
expert->slot LUT as a **scalar-prefetch** operand, so Mosaic can issue the slot
weight tile's HBM->VMEM DMA using ``lut[e]`` before the grid step runs. This is
the TPU embodiment of the patent's "lookup-table mapping structure": rotation
rewrites the LUT, compute never changes.

int8 slots: weights stored int8, per-output-channel f32 scales applied to the
MXU accumulator tile — dequantization costs one VPU multiply per output
element and the slot buffer's HBM footprint halves vs bf16.

int4 slots (Q4_K_M analog, ``repro.quant``): weights stored as two nibbles
per uint8 byte along the reduction axis with per-group f16 scale + min. The
kernel unpacks and dequantizes IN VMEM right after the slot tile's HBM->VMEM
DMA — the affine dequant must run before the dot (scales vary along the
contraction dim, unlike int8's output-channel scales), costing a few VPU ops
per element while the slot buffer's HBM footprint and the host->HBM upload
both shrink ~4x vs bf16. On this CPU host the same kernel body executes under
``interpret=True``.

Tiling: grid (E, C/bc, F/bf, D/bd), D innermost accumulating into a VMEM f32
scratch tile; (bc, bf, bd) default to 128 — MXU-aligned on all three dims.
int4 blocks additionally keep bd a multiple of the scale group so the packed
tile and its scale/min tiles stay aligned.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant import unpack_int4


def _gmm_kernel(lut_ref, x_ref, w_ref, o_ref, acc_ref):
    d = pl.program_id(3)

    @pl.when(d == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0].astype(jnp.float32),
        w_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(d == pl.num_programs(3) - 1)
    def _():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _gmm_kernel_int8(lut_ref, x_ref, w_ref, scale_ref, o_ref, acc_ref):
    d = pl.program_id(3)

    @pl.when(d == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0].astype(jnp.float32),
        w_ref[0].astype(jnp.float32),          # int8 -> f32 in VREG
        preferred_element_type=jnp.float32,
    )

    @pl.when(d == pl.num_programs(3) - 1)
    def _():
        # per-output-channel dequant on the accumulator tile
        o_ref[0] = (acc_ref[...] * scale_ref[0]).astype(o_ref.dtype)


def _gmm_kernel_int4(group: int):
    """Kernel factory: ``group`` rows share one f16 scale/min (static)."""

    def kernel(lut_ref, x_ref, w_ref, scale_ref, mn_ref, o_ref, acc_ref):
        d = pl.program_id(3)

        @pl.when(d == 0)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # unpack two nibbles per byte in VMEM (the packing invariant lives in
        # repro.quant; the kernel tile is its generic [.., P, F] case)
        q = unpack_int4(w_ref[0]).astype(jnp.float32)       # [bd, bf]
        # affine dequant BEFORE the dot: scales vary along the contraction
        # dim, so they cannot fold into the accumulator like int8's
        s = jnp.repeat(scale_ref[0].astype(jnp.float32), group, axis=0)
        m = jnp.repeat(mn_ref[0].astype(jnp.float32), group, axis=0)
        acc_ref[...] += jnp.dot(
            x_ref[0].astype(jnp.float32),
            q * s + m,
            preferred_element_type=jnp.float32,
        )

        @pl.when(d == pl.num_programs(3) - 1)
        def _():
            o_ref[0] = acc_ref[...].astype(o_ref.dtype)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_f", "block_d", "interpret")
)
def slot_gmm(
    x: jax.Array,                    # [E, C, D]
    w: jax.Array,                    # [S+1, D, F] (bf16/int8) or [S+1, D/2, F] (int4 packed)
    lut: jax.Array,                  # [E] int32
    scale: Optional[jax.Array] = None,   # [S+1, F] f32 (int8) | [S+1, D/G, F] f16 (int4)
    mn: Optional[jax.Array] = None,      # [S+1, D/G, F] f16 (int4 group mins)
    *,
    block_c: int = 128,
    block_f: int = 128,
    block_d: int = 128,
    interpret: bool = False,
) -> jax.Array:
    e, c, d = x.shape
    is_int4 = w.dtype == jnp.uint8
    s1, dw, f = w.shape
    if is_int4:
        dw *= 2
    assert dw == d, (dw, d)
    bc, bf, bd = min(block_c, c), min(block_f, f), min(block_d, d)
    if is_int4:
        assert scale is not None and mn is not None, (
            "int4 slots require per-group scales and mins"
        )
        group = d // scale.shape[1]
        # the packed tile and its scale/min tiles must stay aligned: bd spans
        # whole bytes AND whole scale groups, else take the full axis
        if bd % 2 or bd % group:
            bd = d
    assert c % bc == 0 and f % bf == 0 and d % bd == 0, (
        f"dims ({c},{f},{d}) must divide blocks ({bc},{bf},{bd})"
    )
    grid = (e, c // bc, f // bf, d // bd)
    out_dtype = jnp.float32 if w.dtype in (jnp.int8, jnp.uint8) else x.dtype

    in_specs = [
        pl.BlockSpec((1, bc, bd), lambda e, ci, fi, di, lut: (e, ci, di)),
        pl.BlockSpec((1, bd, bf), lambda e, ci, fi, di, lut: (lut[e], di, fi)),
    ]
    kernel = _gmm_kernel
    args = (lut, x, w)
    if is_int4:
        in_specs[1] = pl.BlockSpec(
            (1, bd // 2, bf), lambda e, ci, fi, di, lut: (lut[e], di, fi)
        )
        in_specs.append(pl.BlockSpec(
            (1, bd // group, bf), lambda e, ci, fi, di, lut: (lut[e], di, fi)
        ))
        in_specs.append(pl.BlockSpec(
            (1, bd // group, bf), lambda e, ci, fi, di, lut: (lut[e], di, fi)
        ))
        kernel = _gmm_kernel_int4(group)
        args = (lut, x, w, scale, mn)
    elif w.dtype == jnp.int8:
        assert scale is not None, "int8 slots require per-channel scales"
        in_specs.append(pl.BlockSpec((1, bf), lambda e, ci, fi, di, lut: (lut[e], fi)))
        kernel = _gmm_kernel_int8
        args = (lut, x, w, scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, ci, fi, di, lut: (e, ci, fi)),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((e, c, f), out_dtype),
        interpret=interpret,
        name="slot_gmm",
    )(*args)


def moe_slot_ffn(
    x: jax.Array,                    # [E, C, D] dispatched tokens
    slots: dict,                     # w_gate/w_up/w_down (+ scale_* / min_*)
    lut: jax.Array,
    *,
    interpret: bool = False,
    **blocks,
) -> jax.Array:
    """Full expert FFN through the slot store: three slot_gmm calls + gating."""
    def g(name, xx):
        return slot_gmm(
            xx, slots[name], lut, slots.get(f"scale_{name}"),
            slots.get(f"min_{name}"),
            interpret=interpret, **blocks,
        )

    if "w_gate" in slots:
        h = jax.nn.silu(g("w_gate", x)) * g("w_up", x)
    else:
        h = jax.nn.gelu(g("w_up", x))
    return g("w_down", h.astype(x.dtype))
