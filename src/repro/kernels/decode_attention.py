"""Flash-decode: single-token attention against a long KV cache.

Grid (B, Hkv, S/bk): each step loads one KV block into VMEM and updates the
online-softmax state for the ``g`` grouped query heads that share the kv head.
Per-row ``lengths`` arrive via scalar prefetch (SMEM) so invalid cache slots are
masked without a host round-trip — this also serves ragged continuous-batching
batches. KV is the dominant HBM traffic; the kernel reads it exactly once.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref,
    m_ref, l_ref, acc_ref,
    *, scale: float, soft_cap: Optional[float], block_kv: int, groups: int,
):
    b, ki = pl.program_id(0), pl.program_id(2)
    k_start = ki * block_kv
    length = len_ref[b]

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k_start < length)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)                 # [g, dh]
        k = k_ref[0, 0].astype(jnp.float32)                 # [bk, dh]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [g, bk]
        if soft_cap is not None:
            s = soft_cap * jnp.tanh(s / soft_cap)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            (l_ref[:, 0] * corr + p.sum(axis=-1))[:, None], l_ref.shape
        )
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        vb = v_ref[0, 0].astype(jnp.float32)                # [bk, dh]
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p, vb, preferred_element_type=jnp.float32
        )

    @pl.when(ki == pl.num_programs(2) - 1)
    def _():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("soft_cap", "block_kv", "interpret")
)
def decode_attention(
    q: jax.Array,                   # [B, H, dh] one new token per row
    k: jax.Array,                   # [B, S, Hkv, dh]
    v: jax.Array,
    lengths: jax.Array,             # [B] int32 valid positions (includes new token)
    *,
    soft_cap: Optional[float] = None,
    block_kv: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, h, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    bk = min(block_kv, s)
    assert s % bk == 0, f"cache len {s} must divide block_kv {bk}"
    qg = q.reshape(b, hkv, g, dh)
    kt = k.transpose(0, 2, 1, 3)                            # [B, Hkv, S, dh]
    vt = v.transpose(0, 2, 1, 3)
    grid = (b, hkv, s // bk)
    kernel = functools.partial(
        _decode_kernel,
        scale=1.0 / math.sqrt(dh), soft_cap=soft_cap, block_kv=bk, groups=g,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda b_, h_, ki, L: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h_, ki, L: (b_, h_, ki, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h_, ki, L: (b_, h_, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda b_, h_, ki, L: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, LANES), jnp.float32),
            pltpu.VMEM((g, LANES), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), q.dtype),
        interpret=interpret,
        name="decode_attention",
    )(lengths.astype(jnp.int32), qg, kt, vt)
    return out.reshape(b, h, dh)
