"""Flash attention (prefill/train) with explicit VMEM tiling.

Grid (B, H, Sq/bq, Skv/bk), kv innermost. Online softmax state (running max,
denominator, output accumulator) lives in VMEM scratch; m/l are stored
lane-replicated at width 128 to satisfy TPU tiling. GQA is handled in the index
map (q head h reads kv head h // group). Fully-masked blocks are skipped with
``pl.when`` — on TPU the weight DMAs still issue but the MXU work is skipped;
a production grid would prune them (see benchmarks/kernels_bench for the
counted-FLOP comparison vs the chunked-jnp path).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, window: Optional[int], soft_cap: Optional[float],
    block_q: int, block_kv: int,
):
    qi, ki = pl.program_id(2), pl.program_id(3)
    q_start = qi * block_q
    k_start = ki * block_kv

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level reachability (static per (qi, ki) at runtime)
    reachable = True
    if causal:
        reachable = k_start <= q_start + block_q - 1
    if window is not None:
        reachable = jnp.logical_and(reachable, k_start + block_kv - 1 > q_start - window)

    @pl.when(reachable)
    def _():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, dh]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if soft_cap is not None:
            s = soft_cap * jnp.tanh(s / soft_cap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                            # lane-replicated
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            (l_ref[:, 0] * corr + p.sum(axis=-1))[:, None], l_ref.shape
        )
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )

    @pl.when(ki == pl.num_programs(3) - 1)
    def _():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "soft_cap", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array,                   # [B, Sq, H, dh]
    k: jax.Array,                   # [B, Skv, Hkv, dh]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    soft_cap: Optional[float] = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    bq, bk = min(block_q, sq), min(block_kv, skv)
    assert sq % bq == 0 and skv % bk == 0, f"seq ({sq},{skv}) must divide blocks ({bq},{bk})"
    qt = q.transpose(0, 2, 1, 3)                        # [B, H, Sq, dh]
    kt = k.transpose(0, 2, 1, 3)                        # [B, Hkv, Skv, dh]
    vt = v.transpose(0, 2, 1, 3)
    grid = (b, h, sq // bq, skv // bk)
    kernel = functools.partial(
        _flash_kernel,
        scale=1.0 / math.sqrt(dh), causal=causal, window=window, soft_cap=soft_cap,
        block_q=bq, block_kv=bk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b_, h_, qi, ki: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),       # m (lane-replicated)
            pltpu.VMEM((bq, LANES), jnp.float32),       # l
            pltpu.VMEM((bq, dh), jnp.float32),          # acc
        ],
        interpret=interpret,
        name="flash_attention",
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)                    # [B, Sq, H, dh]
