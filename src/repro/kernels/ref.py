"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests).

No pallas imports here: these are the semantics, written for clarity not speed.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import reference_attention


def slot_gmm_ref(
    x: jax.Array,              # [E, C, D] per-expert token batches
    w: jax.Array,              # [S+1, D, F] slot weights ([S+1, D/2, F] u8 if int4)
    lut: jax.Array,            # [E] int32 expert -> slot
    scale: Optional[jax.Array] = None,   # int8: [S+1, F] f32 | int4: [S+1, D/G, F] f16
    mn: Optional[jax.Array] = None,      # int4: [S+1, D/G, F] f16 group mins
) -> jax.Array:
    if w.dtype == jnp.uint8:             # grouped int4: dequant BEFORE the dot
        from repro.quant import dequantize_int4

        wg = jnp.take(dequantize_int4(w, scale, mn), lut, axis=0)
        out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32), wg)
        return out.astype(jnp.float32)
    wg = jnp.take(w, lut, axis=0).astype(jnp.float32)            # [E, D, F]
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32), wg)
    if scale is not None:
        out = out * jnp.take(scale, lut, axis=0)[:, None, :]
    return out.astype(x.dtype if scale is None and w.dtype != jnp.int8 else jnp.float32)


def moe_slot_ffn_ref(
    x: jax.Array,              # [E, C, D]
    slots: dict,               # w_gate/w_up/w_down (+ scale_* / min_* when quantized)
    lut: jax.Array,
) -> jax.Array:
    def g(name, xx=x):
        return slot_gmm_ref(
            xx, slots[name], lut, slots.get(f"scale_{name}"), slots.get(f"min_{name}")
        )

    if "w_gate" in slots:
        h = jax.nn.silu(g("w_gate")) * g("w_up")
    else:
        h = jax.nn.gelu(g("w_up"))
    return g("w_down", h.astype(jnp.float32))


def flash_attention_ref(
    q: jax.Array,              # [B, Sq, H, dh]
    k: jax.Array,              # [B, Skv, Hkv, dh]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    soft_cap: Optional[float] = None,
) -> jax.Array:
    return reference_attention(q, k, v, causal=causal, window=window, soft_cap=soft_cap)


def decode_attention_ref(
    q: jax.Array,              # [B, H, dh]
    k: jax.Array,              # [B, S, Hkv, dh]
    v: jax.Array,
    lengths: jax.Array,        # [B] int32: valid cache positions per batch row
    *,
    soft_cap: Optional[float] = None,
) -> jax.Array:
    b, h, dh = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k.astype(jnp.float32)) / jnp.sqrt(dh)
    if soft_cap is not None:
        logits = soft_cap * jnp.tanh(logits / soft_cap)
    valid = jnp.arange(s)[None, :] < lengths[:, None]            # [B, S]
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, dh).astype(q.dtype)


def topk_gate_ref(logits: jax.Array, k: int, *, normalize: bool = True
                  ) -> Tuple[jax.Array, jax.Array]:
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, ids = jax.lax.top_k(probs, k)
    if normalize:
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return ids.astype(jnp.int32), weights
