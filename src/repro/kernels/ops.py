"""jit'd dispatch wrappers around the Pallas kernels.

On this CPU container kernels run in ``interpret=True`` (the kernel body
executes in Python — correctness only); on a TPU backend the same calls lower
through Mosaic. Callers use these wrappers, never the kernels directly, so the
backend switch is one place.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import moe_gmm as _gmm
from repro.kernels import topk_gate as _tk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    soft_cap: Optional[float] = None,
    block_q: int = 128,
    block_kv: int = 128,
) -> jax.Array:
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, soft_cap=soft_cap,
        block_q=block_q, block_kv=block_kv, interpret=_interpret(),
    )


def decode_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    cur_len: jax.Array,
    window: Optional[int] = None,
    soft_cap: Optional[float] = None,
    block_kv: int = 256,
) -> jax.Array:
    """Adapter for the model's decode path: q [B,1,H,dh], cache k/v [B,S,Hkv,dh].

    ``cur_len`` (scalar or per-row [B]) is the number of tokens BEFORE this one;
    the new token was already written, so valid length is cur_len+1. Sliding
    windows fall back to the jnp path in the caller (ring-position masking is
    cache-layout specific).
    """
    b = q.shape[0]
    lengths = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,)) + 1
    out = _dec.decode_attention(
        q[:, 0], k, v, lengths, soft_cap=soft_cap,
        block_kv=block_kv, interpret=_interpret(),
    )
    return out[:, None]                                    # [B,1,H,dh]


def moe_slot_ffn(x: jax.Array, slots: dict, lut: jax.Array, **blocks) -> jax.Array:
    return _gmm.moe_slot_ffn(x, slots, lut, interpret=_interpret(), **blocks)


def slot_gmm(
    x: jax.Array, w: jax.Array, lut: jax.Array,
    scale: Optional[jax.Array] = None, mn: Optional[jax.Array] = None, **blocks
) -> jax.Array:
    return _gmm.slot_gmm(x, w, lut, scale, mn, interpret=_interpret(), **blocks)


def topk_gate(logits: jax.Array, k: int, *, normalize: bool = True
              ) -> Tuple[jax.Array, jax.Array]:
    return _tk.topk_gate(logits, k, normalize=normalize, interpret=_interpret())
