"""Fused softmax + top-k router gate.

One VMEM pass over a [bt, E] logit tile produces ids + normalized weights:
softmax, then k iterations of (argmax, mask) — k is static and small, the loop
unrolls into VPU max-reductions, avoiding a full sort and a second HBM pass
over probabilities. Matches ``jax.lax.top_k`` on ties by lowest-index-wins.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _topk_kernel(x_ref, ids_ref, w_ref, *, k: int, normalize: bool):
    logits = x_ref[...].astype(jnp.float32)                 # [bt, E]
    bt, e = logits.shape
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    probs = p / p.sum(axis=-1, keepdims=True)

    work = probs
    cols = jax.lax.broadcasted_iota(jnp.int32, (bt, e), 1)
    ids = []
    ws = []
    for _ in range(k):
        w = work.max(axis=-1)
        # lowest index among maxima (matches lax.top_k tie-breaking)
        is_max = work >= w[:, None]
        idx = jnp.min(jnp.where(is_max, cols, e), axis=-1)
        ids.append(idx)
        ws.append(w)
        work = jnp.where(cols == idx[:, None], -1.0, work)
    ids_arr = jnp.stack(ids, axis=-1).astype(jnp.int32)     # [bt, k]
    w_arr = jnp.stack(ws, axis=-1)
    if normalize:
        w_arr = w_arr / jnp.maximum(w_arr.sum(-1, keepdims=True), 1e-9)
    ids_ref[...] = ids_arr
    w_ref[...] = w_arr


def route_topk(
    logits: jax.Array,              # [T, E]
    k: int,
    *,
    normalize: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Backend-dispatching router gate for compiled decode/prefill paths.

    On TPU/GPU this lowers the fused Pallas ``topk_gate`` (one VMEM pass, no
    full sort); elsewhere it falls back to ``jax.lax.top_k`` over a softmax —
    interpret-mode Pallas inside a jitted hot loop would be pure overhead on
    CPU. Both paths break ties lowest-index-first, so routing is
    backend-independent. Traceable (safe to call inside jit).
    """
    if jax.default_backend() in ("tpu", "gpu"):
        t, e = logits.shape
        bt = min(256, t)
        pad = (-t) % bt
        if pad:
            logits = jnp.concatenate(
                [logits, jnp.full((pad, e), NEG_INF, logits.dtype)], axis=0
            )
        ids, w = topk_gate(logits, k, normalize=normalize)
        return ids[:t], w[:t]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, ids = jax.lax.top_k(probs, k)
    if normalize:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return ids.astype(jnp.int32), w


@functools.partial(
    jax.jit, static_argnames=("k", "normalize", "block_t", "interpret")
)
def topk_gate(
    logits: jax.Array,              # [T, E]
    k: int,
    *,
    normalize: bool = True,
    block_t: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    t, e = logits.shape
    bt = min(block_t, t)
    assert t % bt == 0, f"T={t} must divide block_t={bt}"
    kernel = functools.partial(_topk_kernel, k=k, normalize=normalize)
    ids, w = pl.pallas_call(
        kernel,
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, k), jnp.int32),
            jax.ShapeDtypeStruct((t, k), jnp.float32),
        ],
        interpret=interpret,
        name="topk_gate",
    )(logits)
    return ids, w
