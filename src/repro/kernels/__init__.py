"""Pallas TPU kernels for the perf-critical layers (validated in interpret mode
on CPU; Mosaic-lowered on TPU): slot-LUT grouped matmul (the paper's hot spot),
flash attention (prefill), flash-decode, fused top-k gate."""
