"""Elastic resharding: restore a checkpoint onto a *different* mesh.

Checkpoints are mesh-agnostic host arrays (serializer.py); restoring = deciding
a sharding per leaf for the *target* mesh and ``jax.device_put``-ing each array
with it. A job that loses a pod restarts on the smaller mesh with the same
bytes; scale-up works symmetrically — the paper-era "elastic scaling" feature.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def reshard_tree(
    tree: Any,
    mesh: Optional[Mesh],
    spec_fn: Optional[Callable[[tuple, Any], P]] = None,
) -> Any:
    """device_put every leaf with its target-mesh sharding.

    ``spec_fn(path, leaf) -> PartitionSpec``; defaults to replicated.
    """
    if mesh is None:
        return jax.tree.map(jax.numpy.asarray, tree)

    def put(path, leaf):
        spec = spec_fn(path, leaf) if spec_fn is not None else P()
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(put, tree)


def restore_elastic(
    manager,
    template: Any,
    mesh: Optional[Mesh],
    spec_fn: Optional[Callable] = None,
):
    """restore_latest + reshard onto ``mesh``. Returns (step, state, meta) or None."""
    got = manager.restore_latest(template)
    if got is None:
        return None
    step, state, meta = got
    return step, reshard_tree(state, mesh, spec_fn), meta
