"""Pytree <-> disk serialization (numpy .npz + JSON manifest).

Arrays are pulled to host as numpy (mesh-agnostic), keyed by their flattened
tree path, with dtypes preserved (bf16 stored as uint16-with-tag since npz has
no bfloat16). Restoring never touches device placement — ``elastic.restore``
decides shardings, which is what makes cross-mesh (elastic) resume work.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_BF16_TAG = "__bf16__"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_to_arrays(tree: Any) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out: Dict[str, np.ndarray] = {}
    for path, leaf in flat:
        key = _path_str(path)
        # np.array(copy=True): a SNAPSHOT, so async writers are immune to the
        # caller mutating host arrays after save() returns
        arr = np.array(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            out[key + _BF16_TAG] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


def arrays_to_tree(template: Any, arrays: Dict[str, np.ndarray]) -> Any:
    flat, tdef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = _path_str(path)
        if key in arrays:
            arr = arrays[key]
        elif key + _BF16_TAG in arrays:
            arr = arrays[key + _BF16_TAG].view(jnp.bfloat16)
        else:
            raise KeyError(f"checkpoint missing {key!r}")
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"{key}: shape {arr.shape} != template {np.shape(leaf)}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(tdef, leaves)


def save_tree(path: str, tree: Any, meta: Dict[str, Any]) -> None:
    os.makedirs(path, exist_ok=True)
    arrays = tree_to_arrays(tree)
    # atomic write: temp file then rename (suffix must be .npz or numpy appends)
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".npz")
    os.close(fd)
    np.savez(tmp, **arrays)
    os.replace(tmp, os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)


def load_tree(path: str, template: Any) -> Tuple[Any, Dict[str, Any]]:
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return arrays_to_tree(template, arrays), meta
