from repro.checkpoint.elastic import reshard_tree, restore_elastic  # noqa: F401
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
from repro.checkpoint.serializer import load_tree, save_tree  # noqa: F401
