"""Checkpoint manager: async save, retention, latest-resume.

Saves run on a worker thread (device->host copy happens on the caller thread so
the step's arrays are snapshotted consistently; disk IO overlaps training).
Directory layout: ``{dir}/step_{N}/{arrays.npz, meta.json}`` plus a ``COMMIT``
marker written last — a crash mid-save leaves no COMMIT and the restore path
skips the partial directory (fault-tolerance property test).
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.checkpoint.serializer import load_tree, save_tree, tree_to_arrays


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def existing_steps(self) -> List[int]:
        steps = []
        if not os.path.isdir(self.directory):
            return steps
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.directory, name, "COMMIT")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, meta: Optional[Dict] = None) -> None:
        self.wait()
        # snapshot to host NOW (consistent view), write on worker thread
        arrays = tree_to_arrays(state)
        meta = dict(meta or {})
        meta["step"] = step

        def _write():
            import numpy as np

            path = self._step_dir(step)
            os.makedirs(path, exist_ok=True)
            np.savez(os.path.join(path, "arrays.npz"), **arrays)
            import json

            with open(os.path.join(path, "meta.json"), "w") as f:
                json.dump(meta, f, indent=2)
            with open(os.path.join(path, "COMMIT"), "w") as f:
                f.write("ok")
            self._gc()

        if self.async_save:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = self.existing_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore_latest(self, template: Any) -> Optional[Tuple[int, Any, Dict]]:
        steps = self.existing_steps()
        if not steps:
            return None
        step = steps[-1]
        state, meta = load_tree(self._step_dir(step), template)
        return step, state, meta
