"""JAX version compatibility shims (single home for all version probing).

The codebase targets the modern JAX API surface (``jax.shard_map`` with
``check_vma=``/``axis_names=``, ``jax.sharding.get_abstract_mesh``,
``jax.sharding.AxisType``); this container pins jax 0.4.37 where shard_map
still lives in ``jax.experimental.shard_map`` with the older
``check_rep=``/``auto=`` spelling and there is no abstract-mesh query. Every
call site imports from here so the fallback logic exists exactly once.
"""
from __future__ import annotations

from typing import Any, Optional, Set

import jax

_HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")

if not _HAS_NATIVE_SHARD_MAP:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map


def shard_map(
    f,
    *,
    mesh: Any,
    in_specs: Any,
    out_specs: Any,
    check_vma: bool = True,
    axis_names: Optional[Set[str]] = None,
):
    """``jax.shard_map`` when available, else the 0.4.x experimental one.

    Translations for the legacy API:
      * ``check_vma``   -> ``check_rep`` (same meaning, renamed upstream)
      * ``axis_names``  -> ``auto = mesh axes NOT named`` (the legacy API
        names the automatic axes instead of the manual ones)
    """
    if _HAS_NATIVE_SHARD_MAP:
        kw: dict = {"check_vma": check_vma}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )
    kw = {"check_rep": check_vma}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _legacy_shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
    )


def get_abstract_mesh() -> Optional[Any]:
    """Ambient abstract mesh, or None when the running JAX cannot report one
    (callers treat None as "no manual axes in scope")."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        return None
    return fn()


def manual_axis_names(am: Any) -> Set[str]:
    """Names of mesh axes that are Manual in the ambient shard_map context."""
    if am is None or not getattr(am, "axis_names", None):
        return set()
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None or not hasattr(am, "axis_types"):
        return set()
    return {
        n for n, t in zip(am.axis_names, am.axis_types) if t == axis_type.Manual
    }
