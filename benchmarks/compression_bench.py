"""int8+EF cross-pod gradient compression: standalone lowering + quality check.

The full-train pod-compression lowering trips an XLA SPMD partitioner CHECK on
this build (EXPERIMENTS.md §Perf, refuted-hypothesis log), so the collective
evidence comes from a standalone grads-only module: the HLO must contain an
s8 all-reduce over the pod axis (1 byte/elem on the cross-pod wire vs 4 for
f32), and error feedback must keep the long-run compressed-gradient average
unbiased.
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def run() -> Dict:
    import os

    # a tiny private mesh is enough to lower the collective pattern
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.training.compression import compressed_psum_pod

    devs = jax.local_device_count()
    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray(np.linspace(-1, 1, 4096).reshape(64, 64), jnp.float32)}
    ef = {"w": jnp.zeros((1, 64, 64), jnp.bfloat16)}

    def step(g_, ef_):
        f = shard_map(
            lambda gg, ee: compressed_psum_pod(gg, ee, axis="pod", pod_count=1),
            mesh=mesh, in_specs=(P(), P("pod")), out_specs=(P(), P("pod")),
            check_vma=False,
        )
        return f(g_, ef_)

    lowered = jax.jit(step).lower(g, ef)
    txt = lowered.as_text()
    has_int8_wire = ("s8" in txt or "i8" in txt) and "all_reduce" in txt.replace("-", "_")
    comp = lowered.compile()

    # unbiasedness under error feedback
    acc = jnp.zeros((64, 64))
    cur = ef
    n = 25
    for _ in range(n):
        out, cur = step(g, cur)
        acc = acc + out["w"]
    bias = float(jnp.abs(acc / n - g["w"]).max())
    return {
        "int8_on_wire_in_hlo": bool(has_int8_wire),
        "ef_bias_after_25_steps": bias,
        "wire_bytes_ratio_vs_f32": 0.25,
        "note": "full-train lowering hits XLA spmd_partitioner_util.cc:504 "
                "CHECK on this build; logged as refuted in §Perf",
    }


def main() -> None:
    r = run()
    for k, v in r.items():
        print(f"  {k}: {v}")
    assert r["ef_bias_after_25_steps"] < 5e-3
    print("compression,ef_bias,%s" % r["ef_bias_after_25_steps"])


if __name__ == "__main__":
    main()
