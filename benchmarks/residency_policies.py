"""§4 rotary-vs-LRU claim: policy comparison on recurring-context workloads.

Replays a topic-cycling prompt stream (the paper's "recurring semantic
context") through the per-layer engine under each policy with the same slot
budget, reporting hit rate, bytes moved, modeled stall, and reverse-rotation
(cyclical-return) counts. Prefill and decode phases are reported separately
(paper §8.1 splits prompt-eval from decode throughput).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import numpy as np


def run(steps: int = 24, slots: int = 5) -> List[Dict]:
    from repro.config import ResidencyConfig, get_config
    from repro.configs import reduce_for_smoke
    from repro.core import RotaryEngine
    from repro.data import SyntheticSpec, batch_at_step
    from repro.models import init_params
    from repro.models.transformer import Runtime

    cfg = reduce_for_smoke(get_config("qwen36-35b-a3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    spec = SyntheticSpec(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2,
                         kind="topic", num_topics=3, topic_len=8, seed=11)
    prompt, _ = batch_at_step(spec, 0)
    rows = []
    for mode in ("full", "rotary", "lru", "static"):
        eng = RotaryEngine(
            cfg, params,
            ResidencyConfig(mode=mode, num_slots=slots),
            rt=Runtime(cache_len=64), batch=2,
        )
        eng.prefill(prompt.astype(np.int32))
        prefill_stats = {
            "hit_rate": eng.stats.hit_rate,
            "bytes_MB": eng.stats.bytes_loaded / 2**20,
        }
        logits = eng._lm_head(eng._embed(jax.numpy.asarray(prompt[:, -1:])))
        eng.decode(np.asarray(logits)[:, 0], steps)
        s = eng.stats
        rev = sum(l.reverse_rotations for l in s.layers.values())
        fwd = sum(l.forward_rotations for l in s.layers.values())
        rows.append({
            "policy": mode,
            "prefill_hit": round(prefill_stats["hit_rate"], 3),
            "total_hit": round(s.hit_rate, 3),
            "bytes_MB": round(s.bytes_loaded / 2**20, 2),
            "stall_ms": round(s.stall_s * 1e3, 3),
            "host_ms": round(s.host_compute_s * 1e3, 3),
            "fwd_rot": fwd,
            "rev_rot": rev,
            "modeled_tok_s": s.summary()["modeled_tok_per_s"],
        })
    return rows


def main() -> None:
    rows = run()
    hdr = list(rows[0])
    print("  " + " | ".join(f"{h:>12s}" for h in hdr))
    for r in rows:
        print("  " + " | ".join(f"{str(r[h]):>12s}" for h in hdr))
    rot = next(r for r in rows if r["policy"] == "rotary")
    lru = next(r for r in rows if r["policy"] == "lru")
    print(f"residency_policies,rotary_stall_vs_lru_ms,{rot['stall_ms']} vs {lru['stall_ms']}")


if __name__ == "__main__":
    main()
