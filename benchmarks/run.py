"""Benchmark harness: one module per paper table/figure + beyond-paper extras.

``python -m benchmarks.run`` executes everything and prints one
``name,key,value`` CSV line per benchmark (plus human-readable detail).
"""
import sys
import time
import traceback

MODULES = [
    ("table4_long_output", "Table 4: long-output generation under rotary residency"),
    ("table5_smoke", "Table 5: smoke-set completion"),
    ("fig3_configs", "Fig. 3: configuration feasibility sweep"),
    ("residency_policies", "§4: rotary vs LRU vs static vs full"),
    ("decode_hot_path", "decode hot path: device-resident step vs seed engine"),
    ("serving_load", "serving goodput: continuous batching vs group tick under Poisson load"),
    ("kernels_bench", "Pallas kernels vs references"),
    ("compression_bench", "int8+EF cross-pod gradient compression"),
]


def main() -> None:
    failures = 0
    for name, title in MODULES:
        print(f"\n=== {title} ({name}) ===", flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
            print(f"  [{time.time()-t0:.1f}s]", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
    print(f"\nbenchmarks done: {len(MODULES)-failures}/{len(MODULES)} ok")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
