"""Kernel micro-benchmarks (beyond paper): Pallas kernels vs pure-jnp references.

On this CPU container the kernels run in interpret mode, so wall-times compare
the REFERENCE implementations while the kernels are validated for correctness;
the roofline placement column reports the kernel's arithmetic intensity and the
v5e-bound term that dominates at the given shape.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

PEAK = 197e12
HBM = 819e9


def _time(fn, *args, reps=3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run() -> List[Dict]:
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    rows = []

    # slot-LUT grouped matmul
    e, c, d, f, s = 8, 64, 256, 512, 6
    x = jnp.asarray(rng.standard_normal((e, c, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((s + 1, d, f)), jnp.float32)
    lut = jnp.asarray(rng.integers(0, s + 1, e), jnp.int32)
    jit_ref = jax.jit(lambda x, w, l: ref.slot_gmm_ref(x, w, l))
    t_ref = _time(jit_ref, x, w, lut)
    out_k = ops.slot_gmm(x, w, lut, block_c=64, block_f=128, block_d=128)
    err = float(jnp.abs(out_k - jit_ref(x, w, lut)).max())
    flops = 2 * e * c * d * f
    bytes_ = (e * c * d + e * c * f) * 4 + (s + 1) * d * f * 4
    ai = flops / bytes_
    rows.append({
        "kernel": "slot_gmm", "ref_us": round(t_ref * 1e6, 1),
        "allclose_err": err, "arith_intensity": round(ai, 1),
        "v5e_bound": "compute" if ai > PEAK / HBM else "memory",
    })

    # flash attention
    b, sq, h, hkv, dh = 1, 512, 8, 2, 64
    q = jnp.asarray(rng.standard_normal((b, sq, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, sq, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, sq, hkv, dh)), jnp.float32)
    jit_ref2 = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    t_ref2 = _time(jit_ref2, q, k, v)
    out_k2 = ops.flash_attention(q, k, v, block_q=128, block_kv=128)
    err2 = float(jnp.abs(out_k2 - jit_ref2(q, k, v)).max())
    flops = 4 * b * h * sq * sq * dh / 2
    bytes_ = (b * sq * (h + 2 * hkv) * dh * 2) * 4
    rows.append({
        "kernel": "flash_attention", "ref_us": round(t_ref2 * 1e6, 1),
        "allclose_err": err2, "arith_intensity": round(flops / bytes_, 1),
        "v5e_bound": "compute" if flops / bytes_ > PEAK / HBM else "memory",
    })

    # decode attention
    b2, s2 = 8, 4096
    qd = jnp.asarray(rng.standard_normal((b2, h, dh)), jnp.float32)
    kd = jnp.asarray(rng.standard_normal((b2, s2, hkv, dh)), jnp.float32)
    vd = jnp.asarray(rng.standard_normal((b2, s2, hkv, dh)), jnp.float32)
    lengths = jnp.full((b2,), s2, jnp.int32)
    jit_ref3 = jax.jit(lambda q, k, v, l: ref.decode_attention_ref(q, k, v, l))
    t_ref3 = _time(jit_ref3, qd, kd, vd, lengths)
    from repro.kernels.decode_attention import decode_attention

    out_k3 = decode_attention(qd, kd, vd, lengths, block_kv=512, interpret=True)
    err3 = float(jnp.abs(out_k3 - jit_ref3(qd, kd, vd, lengths)).max())
    flops = 4 * b2 * h * s2 * dh
    bytes_ = 2 * b2 * s2 * hkv * dh * 4
    rows.append({
        "kernel": "decode_attention", "ref_us": round(t_ref3 * 1e6, 1),
        "allclose_err": err3, "arith_intensity": round(flops / bytes_, 2),
        "v5e_bound": "memory (KV stream)",
    })

    # topk gate (prefill shape)
    t4, e4, k4 = 4096, 128, 8
    logits = jnp.asarray(rng.standard_normal((t4, e4)), jnp.float32)
    jit_ref4 = jax.jit(lambda l: ref.topk_gate_ref(l, k4))
    t_ref4 = _time(jit_ref4, logits)
    ids_k, w_k = ops.topk_gate(logits, k4)
    ids_r, w_r = jit_ref4(logits)
    rows.append({
        "kernel": "topk_gate", "ref_us": round(t_ref4 * 1e6, 1),
        "allclose_err": float(jnp.abs(w_k - w_r).max()) + float((ids_k != ids_r).sum()),
        "arith_intensity": 0.1, "v5e_bound": "memory (one pass)",
    })

    # topk gate at DECODE shapes (the RotaryEngine hot path routes [B, E]
    # per MoE layer per token) + the backend-dispatching route_topk wrapper
    from repro.kernels.topk_gate import route_topk

    for tb in (1, 2, 8):
        logits_d = jnp.asarray(rng.standard_normal((tb, e4)), jnp.float32)
        jit_refd = jax.jit(lambda l: ref.topk_gate_ref(l, k4))
        t_refd = _time(jit_refd, logits_d)
        ids_k, w_k = ops.topk_gate(logits_d, k4)
        ids_a, w_a = jax.jit(lambda l: route_topk(l, k4))(logits_d)
        ids_r, w_r = jit_refd(logits_d)
        err = (
            float(jnp.abs(w_k - w_r).max()) + float((ids_k != ids_r).sum())
            + float(jnp.abs(w_a - w_r).max()) + float((ids_a != ids_r).sum())
        )
        rows.append({
            "kernel": f"topk_gate_decode_b{tb}", "ref_us": round(t_refd * 1e6, 1),
            "allclose_err": err,
            "arith_intensity": 0.1, "v5e_bound": "memory (one pass)",
        })
    return rows


def main() -> None:
    rows = run()
    for r in rows:
        print(f"  {r['kernel']:18s} ref={r['ref_us']:>9}us err={r['allclose_err']:.2e} "
              f"AI={r['arith_intensity']} bound={r['v5e_bound']}")
        assert r["allclose_err"] < 1e-2
    print("kernels_bench,all_validated,1")


if __name__ == "__main__":
    main()
