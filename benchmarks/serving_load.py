"""Serving load generator: continuous batching vs the group-tick baseline
under Poisson arrivals.

A seeded bursty Poisson trace (exponential inter-arrival gaps, mixed prompt
and output lengths) is replayed against TWO serving engines holding the SAME
KV memory on the reduced dense ``starcoder2_3b`` config:

* ``baseline`` — the group-tick path (``paged=False``): ``--slots`` fixed
  contiguous KV rows; a queued request waits for a whole row to free;
* ``cb``       — continuous batching over the paged KV pool: the same KV
  bytes as the baseline's rows, split into pages (``kv_pages = slots x
  row_pages``). Worst-case page reservations are sized per request, so
  short-output requests occupy a fraction of a row and MORE requests run
  concurrently in the same memory — rows join/leave the live window between
  launches, finishing rows free pages immediately.

Both engines decode greedily with speculative windows (``spec_cap=4``) and
see the identical trace, so per-request OUTPUTS must agree token-for-token
(both paths are exact) — the goodput comparison is pinned to bit-identical
work.

Goodput rows: tokens/s of committed output over the busy period, plus p50 /
p99 time-to-first-token and inter-token latency from the request lifecycle
timestamps. Acceptance gates (asserted): continuous batching achieves
>= 1.3x the baseline's goodput AND strictly lower p99 TTFT at the same
offered load.

Run directly (``python -m benchmarks.serving_load [--requests N]
[--arrival-rate R]``) or via ``python -m benchmarks.run`` / ``make bench``;
row data lands in ``BENCH_serving.json``.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

Trace = List[Tuple[float, np.ndarray, int]]     # (arrival_s, prompt, max_new)


def make_trace(n: int, rate: float, vocab: int, seed: int = 0) -> Trace:
    """Seeded Poisson arrivals with mixed prompt (4-10) and output (8/16/32)
    lengths — the bursty mixed-length workload where fixed rows idle most."""
    rng = np.random.default_rng(seed)
    at = np.cumsum(rng.exponential(1.0 / rate, size=n))
    at -= at[0]                                  # first request opens the run
    trace: Trace = []
    for i in range(n):
        plen = int(rng.integers(4, 11))
        prompt = rng.integers(0, vocab, (plen,)).astype(np.int32)
        max_new = int(rng.choice([8, 16, 32]))
        trace.append((float(at[i]), prompt, max_new))
    return trace


def drive(eng, trace: Trace) -> Dict:
    """Replay ``trace`` on the wall clock: submit each request at its arrival
    offset, tick the engine whenever work is live (request-level joins happen
    inside ``tick``), and measure the busy period end to end."""
    reqs = []
    i = 0
    t0 = time.perf_counter()
    while i < len(trace) or not eng.scheduler.idle:
        now = time.perf_counter() - t0
        while i < len(trace) and trace[i][0] <= now:
            _, prompt, max_new = trace[i]
            reqs.append(eng.submit(prompt, max_new))
            i += 1
        if not eng.scheduler.idle:
            eng.tick()
        elif i < len(trace):
            time.sleep(min(1e-3, max(0.0, trace[i][0] - now)))
    wall = time.perf_counter() - t0
    assert all(r.done and not r.truncated for r in reqs)
    ttft = [r.first_token_at - r.submitted_at for r in reqs]
    itl: List[float] = []
    for r in reqs:
        itl.extend(b - a for a, b in zip(r.token_times, r.token_times[1:]))

    def pct(xs: List[float], q: float) -> float:
        return float(np.percentile(np.asarray(xs), q))

    return {
        "requests": reqs,
        "outputs": [list(r.output) for r in reqs],
        "wall_s": wall,
        "goodput_tok_s": sum(len(r.output) for r in reqs) / wall,
        "ttft_p50_ms": 1e3 * pct(ttft, 50),
        "ttft_p99_ms": 1e3 * pct(ttft, 99),
        "itl_p50_ms": 1e3 * pct(itl, 50),
        "itl_p99_ms": 1e3 * pct(itl, 99),
    }


def run(n_requests: int = 64, rate: float = 400.0, slots: int = 2,
        cache_len: int = 64, page_size: int = 4, seed: int = 0) -> Dict:
    import jax

    from repro.config import get_config
    from repro.configs import reduce_for_smoke
    from repro.models import init_params
    from repro.models.transformer import Runtime
    from repro.serving import ServingEngine

    from repro.obs import Tracer

    cfg = reduce_for_smoke(get_config("starcoder2-3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = make_trace(n_requests, rate, cfg.vocab_size, seed)
    row_pages = cache_len // page_size

    def mk(paged: bool) -> ServingEngine:
        if paged:
            # the SAME KV bytes as the baseline's contiguous rows, split into
            # pages; worst-case reservations let short-output requests share
            # a row's worth of memory, so more slots become usable. The CB
            # engine runs TRACED (ring-buffer appends; <= 3% per the decode
            # benchmark's gate, and tracing only the CB side makes the
            # goodput gate below strictly harder): its trace feeds the
            # contract auditor, so every load run re-checks the serving
            # dispatch/KV invariants on real traffic
            return ServingEngine(
                cfg, params, rt=Runtime(cache_len=cache_len),
                num_slots=4 * slots, spec_cap=4, paged=True,
                kv_page_size=page_size, kv_pages=slots * row_pages,
                trace=Tracer(),
            )
        return ServingEngine(
            cfg, params, rt=Runtime(cache_len=cache_len),
            num_slots=slots, spec_cap=4, paged=False,
        )

    rows: Dict = {}
    max_plen = max(len(p) for _, p, _ in trace)
    for label, paged in (("baseline", False), ("cb", True)):
        # pre-compile the whole program family (prefill buckets x group
        # sizes, window K x rows buckets, splice page counts): the measured
        # replay times SERVING, not tracing, on both engines
        eng = mk(paged)
        eng.warmup(max_prompt_len=max_plen)
        r = drive(eng, trace)
        r["engine"] = eng
        rows[label] = r

    # both paths are exact: identical trace => identical per-request tokens
    assert rows["baseline"]["outputs"] == rows["cb"]["outputs"], (
        "continuous batching changed emitted tokens"
    )
    cb = rows["cb"]["engine"].stats
    assert cb.windows > 0 and cb.kv_pages_released == cb.kv_pages_allocated
    rows["goodput_ratio"] = (
        rows["cb"]["goodput_tok_s"] / rows["baseline"]["goodput_tok_s"]
    )
    # replay the CB engine's trace through the contract auditor: 1 launch +
    # 1 pull per tick, no KV page used after release, lanes well-formed
    from repro.obs import audit

    report = audit(rows["cb"]["engine"].tracer)
    report.raise_for_violations()
    rows["cb_audit"] = report.summary()
    return rows


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--arrival-rate", type=float, default=400.0,
                    help="Poisson arrival rate (req/s); high = bursty backlog")
    ap.add_argument("--slots", type=int, default=2,
                    help="baseline contiguous KV rows (the pool holds the "
                         "same KV bytes as this many rows)")
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    rows = run(args.requests, args.arrival_rate, args.slots,
               args.cache_len, seed=args.seed)
    for label in ("baseline", "cb"):
        r = rows[label]
        print(f"  {label:9s} goodput {r['goodput_tok_s']:7.2f} tok/s  "
              f"TTFT p50/p99 {r['ttft_p50_ms']:7.1f}/{r['ttft_p99_ms']:7.1f} ms  "
              f"ITL p50/p99 {r['itl_p50_ms']:6.1f}/{r['itl_p99_ms']:6.1f} ms  "
              f"wall {r['wall_s']:.2f}s")
        for key in ("goodput_tok_s", "ttft_p50_ms", "ttft_p99_ms",
                    "itl_p50_ms", "itl_p99_ms"):
            print(f"serving_load,{key}_{label},{r[key]:.3f}")
    cb = rows["cb"]["engine"].stats
    print(f"serving_load,goodput_ratio,{rows['goodput_ratio']:.3f}")
    print(f"serving_load,cb_windows,{cb.windows}")
    print(f"serving_load,cb_kv_pages_hwm,{cb.kv_pages_hwm}")
    print("serving_load,outputs_identical,1")
    print(f"serving_load,cb_audit_ok,{int(rows['cb_audit']['ok'])}")

    payload = {
        "config": "starcoder2_3b_reduced",
        "requests": args.requests,
        "arrival_rate": args.arrival_rate,
        "rows": {
            label: {
                k: rows[label][k]
                for k in ("wall_s", "goodput_tok_s", "ttft_p50_ms",
                          "ttft_p99_ms", "itl_p50_ms", "itl_p99_ms")
            }
            for label in ("baseline", "cb")
        },
        "goodput_ratio": rows["goodput_ratio"],
        "cb_stats": {
            "windows": cb.windows,
            "sync_pulls": cb.sync_pulls,
            "device_dispatches": cb.device_dispatches,
            "kv_pages_allocated": cb.kv_pages_allocated,
            "kv_pages_released": cb.kv_pages_released,
            "kv_pages_hwm": cb.kv_pages_hwm,
        },
        "outputs_identical": True,
        "cb_audit": rows["cb_audit"],
        # registry dump: window-ms distribution + exact TTFT/ITL histograms
        "cb_metrics": rows["cb"]["engine"].metrics_registry().summary(),
    }
    # machine-readable tier-1 pass-count trajectory (tools/tier1_delta.py):
    # embedded whenever a `make tier1` log exists next to this benchmark.
    # Loaded by explicit file path — tools/ is not a package, and mutating
    # sys.path would shadow any other module named tier1_delta process-wide
    import importlib.util
    import os

    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        "repro_tools_tier1_delta",
        os.path.join(repo_root, "tools", "tier1_delta.py"),
    )
    tier1_delta = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(tier1_delta)
    tier1 = tier1_delta.payload_from_files(
        os.path.join(repo_root, ".tier1.log"),
        os.path.join(repo_root, "CHANGES.md"),
    )
    if tier1 is not None:
        payload["tier1"] = tier1
        print(f"serving_load,tier1_passed,{tier1['passed']}")
    with open("BENCH_serving.json", "w") as f:
        json.dump(payload, f, indent=2)
    print("  wrote BENCH_serving.json")
    # acceptance: same KV memory, same offered load — continuous batching
    # must turn the idle row capacity into >= 1.3x goodput and strictly
    # lower tail time-to-first-token
    ratio = rows["goodput_ratio"]
    assert ratio >= 1.3, f"continuous batching goodput only {ratio:.2f}x"
    assert rows["cb"]["ttft_p99_ms"] < rows["baseline"]["ttft_p99_ms"], (
        rows["cb"]["ttft_p99_ms"], rows["baseline"]["ttft_p99_ms"]
    )


if __name__ == "__main__":
    main()
