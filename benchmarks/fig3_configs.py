"""Fig. 3 analog: configuration feasibility sweep.

The paper: N32/4096 succeeds (primary), N36/2048 succeeds (safety),
N36/4096 "failed to initialize". Our residency-budget feasibility check
(repro.core.residency.check_feasibility) reproduces the pattern: shrinking the
slot budget below top_k + prefetch_margin, or growing context until resident
bytes exceed the HBM budget, fails AT STARTUP — not mid-run.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import numpy as np


def run() -> List[Dict]:
    from repro.config import ResidencyConfig, get_config
    from repro.configs import reduce_for_smoke
    from repro.core import InitializationError, RotaryEngine, check_feasibility
    from repro.models import init_params
    from repro.models.transformer import Runtime

    cfg = reduce_for_smoke(get_config("qwen36-35b-a3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    rows = []
    # analog mapping: more CPU-resident experts == fewer device slots
    cases = [
        ("N32-analog (slots=5, ctx=96)", 5, 96, None),
        ("N36-analog (slots=4, ctx=48)", 4, 48, None),           # safety config
        ("N36-analog (slots=3, ctx=96)", 3, 96, None),           # paper's failure
        ("budget-bound (slots=6, tiny HBM)", 6, 96, 200_000),
    ]
    for name, slots, ctx, budget in cases:
        res = ResidencyConfig(mode="rotary", num_slots=slots, prefetch_margin=2,
                              hbm_budget_bytes=budget)
        rep = check_feasibility(cfg, res, batch=1, cache_len=ctx)
        status = "pre-check-fail: " + rep.reason if not rep.ok else None
        if rep.ok:
            try:
                eng = RotaryEngine(cfg, params, res, rt=Runtime(cache_len=ctx), batch=1)
                prompt = np.zeros((1, 8), np.int32)
                eng.generate(prompt, 4)
                status = "success"
            except InitializationError as e:
                status = f"failed to initialize: {e}"
        rows.append({"config": name, "result": status})
    return rows


def main() -> None:
    rows = run()
    for r in rows:
        print(f"  {r['config']:40s} -> {r['result']}")
    ok = sum(1 for r in rows if r["result"] == "success")
    print(f"fig3,success_configs,{ok}/4 (expected 2/4: the two margin/budget"
          f" violations must fail at startup)")


if __name__ == "__main__":
    main()
