"""Table 4 analog: long-output generation under rotary residency.

The paper: Qwen3.6-35B-A3B Q4_K_M on an 8 GB RTX 4060 laptop — 2048 tokens at
21.06 tok/s, ~6.3 GB VRAM. Here: (a) MEASURED decode on the reduced paper-arch
MoE through the per-layer rotary engine (real slot rotation, real hit/miss
accounting, host-GEMM misses), and (b) the FULL arch's modeled tok/s on the
TPU-v5e target from the CostModel with the measured hit rate — the
hardware-adapted Table 4 row.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import numpy as np


def run(tokens_out: int = 128, quant: str | None = "int8") -> Dict:
    from repro.config import ResidencyConfig, get_config
    from repro.configs import reduce_for_smoke
    from repro.core import CostModel, RotaryEngine
    from repro.models import init_params
    from repro.models.params import analytic_params
    from repro.models.transformer import Runtime

    full_cfg = get_config("qwen36-35b-a3b")
    cfg = reduce_for_smoke(full_cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))
    res = ResidencyConfig(mode="rotary", num_slots=5, quantization=quant)
    eng = RotaryEngine(cfg, params, res, rt=Runtime(cache_len=max(256, tokens_out + 32)),
                       batch=1)
    prompt = np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 16)).astype(np.int32)
    t0 = time.perf_counter()
    out = eng.generate(prompt, tokens_out)
    wall = time.perf_counter() - t0
    s = eng.stats.summary()

    # ---- full-arch modeled numbers on the TPU target -------------------
    cost = CostModel()
    from repro.quant import bytes_per_element

    m = full_cfg.moe
    mats = 3
    dtype_b = bytes_per_element(quant, 2, res.quant_group_size)
    active = analytic_params(full_cfg, active_only=True)
    static = active - m.top_k * mats * full_cfg.d_model * m.expert_d_ff
    expert_bytes = int(mats * full_cfg.d_model * m.expert_d_ff * dtype_b)
    hit = s["hit_rate"]
    # per token: static weights + resident expert reads on device; misses on host
    flops = 2.0 * active
    dev_bytes = 2 * static + m.top_k * hit * expert_bytes
    t_dev = cost.compute_s(flops * (static + m.top_k * hit * m.expert_d_ff * full_cfg.d_model * mats) / active, dev_bytes)
    t_host = cost.host_compute_s(2.0 * m.top_k * (1 - hit) * mats * full_cfg.d_model * m.expert_d_ff)
    # prefetch bytes per token from measured bytes/step scaled to full arch
    full_slot_bytes = expert_bytes
    loads_per_step = eng.stats.bytes_loaded / max(eng.stats.steps, 1) / max(
        eng.manager.stores[0].bytes_per_expert, 1
    )
    t_dma = cost.transfer_s(int(loads_per_step * full_slot_bytes))
    stall = max(0.0, t_dma - t_dev)
    tok_s = 1.0 / (t_dev + t_host + stall)
    # device-resident footprint at full scale: static (attention/embed/router)
    # weights + per-layer slot groups (+1 zero miss slot each)
    slots = eng.manager.num_slots
    resident_gb = (
        2 * static + full_cfg.num_layers * (slots + 1) * expert_bytes
    ) / 2**30
    return {
        "measured_tokens": int(out.shape[1]),
        "measured_wall_s": round(wall, 2),
        "measured_tok_s_reduced_cpu": round(out.shape[1] / wall, 2),
        "hit_rate": hit,
        "bytes_loaded_MB": s["bytes_loaded_MB"],
        "modeled_full_tok_s_v5e": round(tok_s, 2),
        "modeled_resident_GiB": round(resident_gb, 2),
        "paper_tok_s_rtx4060": 21.06,
        "paper_vram_GiB": 6.3,
    }


def main() -> None:
    r = run()
    for k, v in r.items():
        print(f"  {k}: {v}")
    print("table4,modeled_full_tok_s_v5e,%s" % r["modeled_full_tok_s_v5e"])


if __name__ == "__main__":
    main()
