"""Table 5 analog: smoke-set evaluation — 10 prompts through the serving
engine with rotary residency; completion rate + abnormal terminations."""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np


def run() -> Dict:
    from repro.config import ResidencyConfig, get_config
    from repro.configs import reduce_for_smoke
    from repro.models import init_params
    from repro.models.transformer import Runtime
    from repro.serving import ServingEngine

    cfg = reduce_for_smoke(get_config("qwen36-35b-a3b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(
        cfg, params, rt=Runtime(cache_len=96), num_slots=4,
        residency=ResidencyConfig(mode="rotary", num_slots=5),
    )
    rng = np.random.default_rng(7)
    total, ok, abnormal = 10, 0, 0
    reqs = []
    for i in range(total):
        plen = int(rng.integers(4, 20))
        reqs.append(eng.submit(rng.integers(0, cfg.vocab_size, plen), max_new=12))
    try:
        done = eng.run()
        for r in done:
            if len(r.output) == 12 and not r.truncated:
                ok += 1
    except Exception:                                   # noqa: BLE001
        abnormal += 1
    return {
        "total_items": total,
        "successful": ok,
        "completion_rate": ok / total,
        "abnormal_termination": abnormal,
        "paper": "10/10, 0 abnormal",
    }


def main() -> None:
    r = run()
    for k, v in r.items():
        print(f"  {k}: {v}")
    print("table5,completion_rate,%s" % r["completion_rate"])


if __name__ == "__main__":
    main()
