"""Decode hot-path micro-benchmark: device-resident step vs seed engine.

Acceptance check for the engine rework: on the ``qwen2_moe_a2_7b`` reduced
config the hot path must (a) produce IDENTICAL greedy tokens to the seed-style
per-layer engine (``host_routing=True``: blocking logits pull + numpy
softmax/top-k + per-layer LUT re-upload), (b) leave the residency accounting
mechanism intact (every counted miss host-corrected, same number of routed
assignments), and (c) reduce wall-clock per decode step, issuing exactly one
queue-draining device->host transfer per token on the miss-free path.

Run directly (``python -m benchmarks.decode_hot_path``) or via
``python -m benchmarks.run``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax
import numpy as np


def _run_engine(cfg, params, mode: str, slots: int, host_routing: bool,
                prompt: np.ndarray, steps: int) -> Dict:
    from repro.config import ResidencyConfig
    from repro.core import RotaryEngine
    from repro.models.transformer import Runtime

    eng = RotaryEngine(
        cfg, params, ResidencyConfig(mode=mode, num_slots=slots),
        rt=Runtime(cache_len=max(128, prompt.shape[1] + steps + 8)),
        batch=prompt.shape[0], host_routing=host_routing,
    )
    # warmup: populate the jit caches so the timed loop measures steady state
    logits = eng.prefill(prompt)
    eng.decode(logits, 2)
    pulls0 = eng.stats.sync_pulls
    t0 = time.perf_counter()
    out = eng.decode(eng.last_logits, steps)
    wall = time.perf_counter() - t0
    return {
        "engine": eng,
        "tokens": out,
        "s_per_step": wall / steps,
        "sync_pulls_per_step": (eng.stats.sync_pulls - pulls0) / steps,
    }


def run(steps: int = 16) -> Dict:
    from repro.config import get_config
    from repro.configs import reduce_for_smoke
    from repro.models import init_params

    # f32 so the host miss correction is bit-exact against device compute
    cfg = dataclasses.replace(
        reduce_for_smoke(get_config("qwen2-moe-a2.7b")), dtype="float32"
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, (2, 12)).astype(np.int32)

    rows = {}
    e = cfg.moe.num_experts
    for label, mode, slots, host_routing in (
        ("seed_rotary", "rotary", 6, True),      # slot-starved: misses common
        ("hot_rotary", "rotary", 6, False),
        ("seed_rotary_hi", "rotary", e, True),   # paper regime: prefetch covers
        ("hot_rotary_hi", "rotary", e, False),
        ("seed_full", "full", 0, True),
        ("hot_full", "full", 0, False),
    ):
        rows[label] = _run_engine(cfg, params, mode, slots, host_routing,
                                  prompt, steps)

    # (a) greedy tokens identical, seed vs hot, under every residency mode
    for pair in ("rotary", "rotary_hi", "full"):
        np.testing.assert_array_equal(rows[f"seed_{pair}"]["tokens"],
                                      rows[f"hot_{pair}"]["tokens"])
    # (b) accounting mechanism unchanged: all routed assignments counted and
    # every miss host-corrected, in both engines
    for label in ("seed_rotary", "hot_rotary"):
        s = rows[label]["engine"].stats
        assert s.hits + s.misses > 0
        assert sum(l.host_computed for l in s.layers.values()) == s.misses, label
    assert (rows["seed_rotary"]["engine"].stats.hits
            + rows["seed_rotary"]["engine"].stats.misses
            == rows["hot_rotary"]["engine"].stats.hits
            + rows["hot_rotary"]["engine"].stats.misses)
    # (c) miss-free hot decode: exactly ONE queue-draining pull per token
    assert rows["hot_full"]["sync_pulls_per_step"] == 1.0, rows["hot_full"]
    assert rows["hot_full"]["engine"].stats.misses == 0
    return rows


def main() -> None:
    steps = 16
    rows = run(steps)
    for label in ("seed_full", "hot_full", "seed_rotary_hi", "hot_rotary_hi",
                  "seed_rotary", "hot_rotary"):
        r = rows[label]
        print(f"  {label:15s} {r['s_per_step']*1e3:8.2f} ms/step  "
              f"sync_pulls/step={r['sync_pulls_per_step']:.1f}")
    base = rows["seed_full"]["s_per_step"]
    hot = rows["hot_full"]["s_per_step"]
    base_hi = rows["seed_rotary_hi"]["s_per_step"]
    hot_hi = rows["hot_rotary_hi"]["s_per_step"]
    print(f"  miss-free speedup (seed/hot): full {base / hot:.2f}x, "
          f"rotary-covered {base_hi / hot_hi:.2f}x")
    print("  (slot-starved rotary pays suffix replay per missed step; the "
          "prefetch-covered regime is the paper's operating point)")
    print(f"decode_hot_path,ms_per_step_hot_full,{hot*1e3:.3f}")
    print(f"decode_hot_path,ms_per_step_seed_full,{base*1e3:.3f}")
    print(f"decode_hot_path,speedup_full,{base / hot:.3f}")
    print(f"decode_hot_path,speedup_rotary_covered,{base_hi / hot_hi:.3f}")
    print(f"decode_hot_path,tokens_identical,1")
    # the hot path must not be slower on the miss-free steady state (5%
    # margin absorbs single-sample timing noise on a loaded host)
    assert hot <= base * 1.05, (hot, base)
    assert hot_hi <= base_hi * 1.05, (hot_hi, base_hi)


if __name__ == "__main__":
    main()
