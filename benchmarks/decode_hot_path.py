"""Decode hot-path micro-benchmark: fused whole-stack step vs per-layer paths.

Three decode paths of the SAME engine are compared on the ``qwen2_moe_a2_7b``
reduced config:

* ``seed``  — seed-style per-layer walk (``host_routing=True``: blocking
  logits pull + numpy softmax/top-k + per-layer LUT re-upload);
* ``layer`` — PR-1 device-resident per-layer hot path (``fused_decode=False``:
  2 jitted halves per MoE layer, async telemetry, one logits pull per token);
* ``fused`` — ONE compiled whole-stack step per token (donated KV state,
  on-device demand prediction, batched slot uploads).

Acceptance checks: (a) greedy tokens IDENTICAL across all three paths under
every residency mode (misses replay-corrected exactly), (b) accounting
mechanism intact (every counted miss host-corrected; same number of routed
assignments), (c) miss-free fused decode issues exactly ONE queue-draining
device->host pull AND one compiled-program launch per token (O(1) dispatches
vs the per-layer path's O(layers)), (d) the fused step beats the per-layer hot
path on per-step wall clock (target >= 1.3x miss-free).

Run directly (``python -m benchmarks.decode_hot_path``) or via
``python -m benchmarks.run`` / ``make bench-decode``; either way the row data
lands in ``BENCH_decode.json`` so the perf trajectory accumulates across PRs.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict

import jax
import numpy as np

PATHS = ("seed", "layer", "fused")


def _run_engine(cfg, params, mode: str, slots: int, path: str,
                prompt: np.ndarray, steps: int) -> Dict:
    from repro.config import ResidencyConfig
    from repro.core import RotaryEngine
    from repro.models.transformer import Runtime

    eng = RotaryEngine(
        cfg, params, ResidencyConfig(mode=mode, num_slots=slots),
        rt=Runtime(cache_len=max(128, prompt.shape[1] + steps + 8)),
        batch=prompt.shape[0],
        host_routing=(path == "seed"),
        fused_decode=None if path != "layer" else False,
    )
    if path == "fused":
        assert eng._fused_decode, "fused path unexpectedly unavailable"
    # warmup: populate the jit caches so the timed loop measures steady state
    logits = eng.prefill(prompt)
    eng.decode(logits, 2)
    pulls0 = eng.stats.sync_pulls
    disp0 = eng.stats.device_dispatches
    # best-of-3 timing: single 16-step samples are noisy on a shared host and
    # this benchmark gates a >=1.3x acceptance; tokens from every repeat still
    # feed the cross-path identity check
    outs, walls = [], []
    for _ in range(3):
        t0 = time.perf_counter()
        outs.append(eng.decode(eng.last_logits, steps))
        walls.append(time.perf_counter() - t0)
    timed = 3 * steps
    return {
        "engine": eng,
        "tokens": np.concatenate(outs, axis=1),
        "s_per_step": min(walls) / steps,
        "sync_pulls_per_step": (eng.stats.sync_pulls - pulls0) / timed,
        "dispatches_per_step": (eng.stats.device_dispatches - disp0) / timed,
    }


def run(steps: int = 16) -> Dict:
    from repro.config import get_config
    from repro.configs import reduce_for_smoke
    from repro.models import init_params

    # f32 so the host miss correction is bit-exact against device compute
    cfg = dataclasses.replace(
        reduce_for_smoke(get_config("qwen2-moe-a2.7b")), dtype="float32"
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, (2, 12)).astype(np.int32)

    rows = {}
    e = cfg.moe.num_experts
    for suffix, mode, slots in (
        ("rotary", "rotary", 6),       # slot-starved: misses common, replay paid
        ("rotary_hi", "rotary", e),    # paper regime: prefetch covers routing
        ("full", "full", 0),
    ):
        for path in PATHS:
            rows[f"{path}_{suffix}"] = _run_engine(
                cfg, params, mode, slots, path, prompt, steps
            )

    # (a) greedy tokens identical across all three paths, every residency mode
    for suffix in ("rotary", "rotary_hi", "full"):
        for path in ("layer", "fused"):
            np.testing.assert_array_equal(
                rows[f"seed_{suffix}"]["tokens"], rows[f"{path}_{suffix}"]["tokens"]
            )
    # (b) accounting mechanism unchanged: all routed assignments counted and
    # every miss host-corrected, in every path
    for path in PATHS:
        s = rows[f"{path}_rotary"]["engine"].stats
        assert s.hits + s.misses > 0
        assert sum(l.host_computed for l in s.layers.values()) == s.misses, path
        assert (s.hits + s.misses
                == rows["seed_rotary"]["engine"].stats.hits
                + rows["seed_rotary"]["engine"].stats.misses)
    # slot-starved fused decode actually exercised the replay machinery
    assert rows["fused_rotary"]["engine"].stats.replayed_steps > 0
    # (c) miss-free fused decode: ONE queue-draining pull and ONE compiled
    # program launch per token; the per-layer hot path stays O(layers)
    for suffix in ("full", "rotary_hi"):
        r = rows[f"fused_{suffix}"]
        assert r["sync_pulls_per_step"] == 1.0, r
        assert r["dispatches_per_step"] == 1.0, r
        assert r["engine"].stats.misses == 0
        assert rows[f"layer_{suffix}"]["dispatches_per_step"] >= 2 * cfg.num_layers
    return rows


def main() -> None:
    steps = 16
    rows = run(steps)
    order = [f"{p}_{s}" for s in ("full", "rotary_hi", "rotary") for p in PATHS]
    for label in order:
        r = rows[label]
        print(f"  {label:16s} {r['s_per_step']*1e3:8.2f} ms/step  "
              f"sync_pulls/step={r['sync_pulls_per_step']:.1f}  "
              f"dispatches/step={r['dispatches_per_step']:.1f}")
    speedups = {}
    for suffix in ("full", "rotary_hi"):
        layer = rows[f"layer_{suffix}"]["s_per_step"]
        fused = rows[f"fused_{suffix}"]["s_per_step"]
        seed = rows[f"seed_{suffix}"]["s_per_step"]
        speedups[suffix] = {
            "fused_vs_layer": layer / fused,
            "fused_vs_seed": seed / fused,
        }
        print(f"  miss-free {suffix}: fused vs per-layer {layer / fused:.2f}x, "
              f"fused vs seed {seed / fused:.2f}x")
    print("  (slot-starved rotary pays whole-suffix replay per missed step; "
          "the prefetch-covered regime is the paper's operating point)")
    for suffix, sp in speedups.items():
        print(f"decode_hot_path,speedup_fused_vs_layer_{suffix},{sp['fused_vs_layer']:.3f}")
        print(f"decode_hot_path,speedup_fused_vs_seed_{suffix},{sp['fused_vs_seed']:.3f}")
    print(f"decode_hot_path,ms_per_step_fused_full,{rows['fused_full']['s_per_step']*1e3:.3f}")
    print("decode_hot_path,tokens_identical,1")
    payload = {
        "config": "qwen2_moe_a2_7b_reduced_f32",
        "steps_timed": steps,
        "rows": {
            label: {
                "ms_per_step": rows[label]["s_per_step"] * 1e3,
                "sync_pulls_per_step": rows[label]["sync_pulls_per_step"],
                "dispatches_per_step": rows[label]["dispatches_per_step"],
                "misses": int(rows[label]["engine"].stats.misses),
                "replayed_steps": int(rows[label]["engine"].stats.replayed_steps),
            }
            for label in order
        },
        "speedups": speedups,
        "tokens_identical": True,
    }
    with open("BENCH_decode.json", "w") as f:
        json.dump(payload, f, indent=2)
    print("  wrote BENCH_decode.json")
    # acceptance: the fused step must beat the PR-1 per-layer hot path by
    # >= 1.3x on the miss-free steady state (best of the two covered regimes;
    # the other must at least not regress past timing noise)
    best = max(sp["fused_vs_layer"] for sp in speedups.values())
    worst = min(sp["fused_vs_layer"] for sp in speedups.values())
    assert best >= 1.3, speedups
    assert worst >= 1.05, speedups


if __name__ == "__main__":
    main()
