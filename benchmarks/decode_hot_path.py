"""Decode hot-path micro-benchmark: fused whole-stack step vs per-layer paths
vs speculative multi-token windows.

The decode paths of the SAME engine are compared on the ``qwen2_moe_a2_7b``
reduced config:

* ``seed``  — seed-style per-layer walk (``host_routing=True``: blocking
  logits pull + numpy softmax/top-k + per-layer LUT re-upload);
* ``layer`` — PR-1 device-resident per-layer hot path (``fused_decode=False``:
  2 jitted halves per MoE layer, async telemetry, one logits pull per token);
* ``fused`` — ONE compiled whole-stack step per token (donated KV state,
  on-device demand prediction, batched slot uploads);
* ``spec[K]`` — speculative self-drafting windows on the fused step: K tokens
  per compiled launch and per queue-draining pull, rotation at window
  boundaries (``--spec-k`` grows the row family);
* ``@int8 / @int4`` — quantized slot-store row family (``--quantization``):
  the fused and spec-4 paths re-run with int8 / grouped-int4 slots so the
  f16-vs-int8-vs-int4 link traffic (MB/token) is visible side by side;
* ``*_pf`` — asynchronous-prefetch row family (``prefetch=True``): the same
  fused / spec-4 rows with double-buffered slot planes — predicted uploads
  ship into a shadow generation while the live window computes, the boundary
  is a pointer flip plus a correction pass, and misses re-launch the ONE
  compiled step instead of paying the per-layer suffix replay;
* ``*_t`` — sampled row family (temperature 0.8, top-k 20, top-p 0.95): the
  fused single-token and spec-4 paths re-run drawing from the warped
  distribution with position-keyed PRNG streams and stochastic speculative
  acceptance — same compiled window family, exactness now distributional
  (and bitwise between the two rows, which share one seeded stream).

Acceptance checks: (a) greedy tokens IDENTICAL across all paths under every
residency mode (misses replay-corrected exactly; spec windows roll back +
replay), (b) accounting mechanism intact (every counted miss host-corrected;
same number of routed assignments), (c) miss-free fused decode issues exactly
ONE queue-draining device->host pull AND one compiled-program launch per token
— and miss-free spec-K decode exactly 1/K of each, (d) the fused step beats
the per-layer hot path >= 1.3x miss-free, and spec-4 beats the fused
single-token path >= 1.2x miss-free, (e) greedy self-drafting accepts every
drafted token miss-free (accept_rate >= 1.0 — the KV-rollback canary),
(f) quantized decode is exactness-clean WITHIN its format — greedy tokens
bit-identical between full residency, rotary, and rotary+spec-4 under int8
and int4 alike (host corrections run against the dequantized weights) — and
the int4 store moves <= 0.30x the f16 bytes per rotated expert,
(g) every prefetch row is bit-identical to its synchronous twin and the
miss-starved fused rotary row runs >= 1.5x faster with prefetch enabled,
with ``overlap_ms > 0`` recorded (uploads genuinely hid under compute),
(h) the sampled ``*_t`` rows emit bitwise-identical tokens (spec-4 sampled
== single-token sampled) with accept_rate on record, and sampled spec-4
beats sampled single-token >= 1.4x miss-free (the window also amortizes
the per-token host draw sync).

Run directly (``python -m benchmarks.decode_hot_path [--spec-k 2,4,8]
[--quantization int8,int4]``) or via ``python -m benchmarks.run`` /
``make bench-decode``; either way the row data lands in ``BENCH_decode.json``
so the perf trajectory accumulates across PRs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, Sequence, Tuple

import jax
import numpy as np

PATHS = ("seed", "layer", "fused")


def _run_engine(cfg, params, mode: str, slots: int, path: str,
                prompt: np.ndarray, steps: int,
                quant: str | None = None, prefetch: bool = False,
                sampler=None) -> Dict:
    from repro.config import ResidencyConfig
    from repro.core import RotaryEngine
    from repro.models.transformer import Runtime

    spec_k = int(path[4:]) if path.startswith("spec") else 1
    eng = RotaryEngine(
        cfg, params,
        ResidencyConfig(mode=mode, num_slots=slots, quantization=quant),
        rt=Runtime(cache_len=max(128, prompt.shape[1] + steps + 8)),
        batch=prompt.shape[0],
        host_routing=(path == "seed"),
        fused_decode=None if path != "layer" else False,
        spec_k=spec_k,
        prefetch=prefetch,
    )
    if path == "fused" or spec_k > 1:
        assert eng._fused_decode, "fused path unexpectedly unavailable"
    # warmup: populate the jit caches so the timed loop measures steady state
    logits = eng.prefill(prompt)
    eng.decode(logits, 2, sampler=sampler)
    pulls0 = eng.stats.sync_pulls
    disp0 = eng.stats.device_dispatches
    bytes0 = eng.stats.bytes_uploaded
    # best-of-3 timing: single 16-step samples are noisy on a shared host and
    # this benchmark gates a >=1.3x acceptance; tokens from every repeat still
    # feed the cross-path identity check
    outs, walls = [], []
    for _ in range(3):
        t0 = time.perf_counter()
        outs.append(eng.decode(eng.last_logits, steps, sampler=sampler))
        walls.append(time.perf_counter() - t0)
    timed = 3 * steps
    return {
        "engine": eng,
        "tokens": np.concatenate(outs, axis=1),
        "s_per_step": min(walls) / steps,
        "sync_pulls_per_step": (eng.stats.sync_pulls - pulls0) / timed,
        "dispatches_per_step": (eng.stats.device_dispatches - disp0) / timed,
        "mb_per_token": (eng.stats.bytes_uploaded - bytes0) / 2**20 / timed,
    }


def run(steps: int = 16, spec_ks: Sequence[int] = (2, 4, 8),
        quants: Sequence[str] = ("int8", "int4")) -> Dict:
    from repro.config import get_config
    from repro.configs import reduce_for_smoke
    from repro.models import init_params

    # f32 so the host miss correction is bit-exact against device compute
    cfg = dataclasses.replace(
        reduce_for_smoke(get_config("qwen2-moe-a2.7b")), dtype="float32"
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, (2, 12)).astype(np.int32)

    rows = {}
    e = cfg.moe.num_experts
    spec_paths = tuple(f"spec{k}" for k in spec_ks)
    for suffix, mode, slots in (
        ("rotary", "rotary", 6),       # slot-starved: misses common, replay paid
        ("rotary_hi", "rotary", e),    # paper regime: prefetch covers routing
        ("full", "full", 0),
    ):
        for path in PATHS + spec_paths:
            rows[f"{path}_{suffix}"] = _run_engine(
                cfg, params, mode, slots, path, prompt, steps
            )

    # (a) greedy tokens identical across all paths, every residency mode —
    # including every spec-K window size (rollback + replay keep exactness)
    for suffix in ("rotary", "rotary_hi", "full"):
        for path in ("layer", "fused") + spec_paths:
            np.testing.assert_array_equal(
                rows[f"seed_{suffix}"]["tokens"], rows[f"{path}_{suffix}"]["tokens"]
            )
    # (b) accounting mechanism unchanged: all routed assignments counted and
    # every miss host-corrected, in every path
    for path in PATHS + spec_paths:
        s = rows[f"{path}_rotary"]["engine"].stats
        assert s.hits + s.misses > 0
        assert sum(l.host_computed for l in s.layers.values()) == s.misses, path
        assert (s.hits + s.misses
                == rows["seed_rotary"]["engine"].stats.hits
                + rows["seed_rotary"]["engine"].stats.misses)
    # slot-starved fused decode actually exercised the replay machinery
    assert rows["fused_rotary"]["engine"].stats.replayed_steps > 0
    # (c) miss-free fused decode: ONE queue-draining pull and ONE compiled
    # program launch per token; the per-layer hot path stays O(layers)
    for suffix in ("full", "rotary_hi"):
        r = rows[f"fused_{suffix}"]
        assert r["sync_pulls_per_step"] == 1.0, r
        assert r["dispatches_per_step"] == 1.0, r
        assert r["engine"].stats.misses == 0
        assert rows[f"layer_{suffix}"]["dispatches_per_step"] >= 2 * cfg.num_layers
    # (c') miss-free spec-K decode: 1/K pulls per token, and on full residency
    # (no snapshot needed — misses impossible) 1/K launches per token
    for k in spec_ks:
        for suffix in ("full", "rotary_hi"):
            r = rows[f"spec{k}_{suffix}"]
            s = r["engine"].stats
            assert s.misses == 0
            assert r["sync_pulls_per_step"] == 1.0 / k, (k, suffix, r)
            # (e) greedy self-draft with identical weights must accept every
            # drafted token when miss-free — a KV-rollback bug canary
            assert s.drafted_tokens > 0
            assert s.accepted_tokens == s.drafted_tokens
            assert s.accept_rate >= 1.0
        assert rows[f"spec{k}_full"]["dispatches_per_step"] == 1.0 / k
        # slot-starved spec windows actually rolled back and replayed
        assert rows[f"spec{k}_rotary"]["engine"].stats.replayed_steps > 0

    # ---- quantized row family: link traffic + within-format exactness -----
    for quant in quants:
        for suffix, mode, slots in (
            ("rotary", "rotary", 6),
            ("rotary_hi", "rotary", e),
            ("full", "full", 0),
        ):
            rows[f"fused_{suffix}@{quant}"] = _run_engine(
                cfg, params, mode, slots, "fused", prompt, steps, quant=quant
            )
        rows[f"spec4_rotary_hi@{quant}"] = _run_engine(
            cfg, params, "rotary", e, "spec4", prompt, steps, quant=quant
        )
        rows[f"spec4_rotary@{quant}"] = _run_engine(
            cfg, params, "rotary", 6, "spec4", prompt, steps, quant=quant
        )
        # (f) quantized decode is exactness-clean WITHIN its format: full
        # residency, slot-starved rotary (host-corrected misses), prefetch-
        # covered rotary and rotary+spec-4 agree token-for-token
        base = rows[f"fused_full@{quant}"]["tokens"]
        for label in (f"fused_rotary@{quant}", f"fused_rotary_hi@{quant}",
                      f"spec4_rotary_hi@{quant}", f"spec4_rotary@{quant}"):
            np.testing.assert_array_equal(base, rows[label]["tokens"], err_msg=label)
        # the slot-starved quant row actually exercised quantized replay
        assert rows[f"fused_rotary@{quant}"]["engine"].stats.misses > 0
    if "int4" in quants:
        # (f) the int4 store ships <= 0.30x the f16 bytes per rotated expert
        # (packed nibbles + f16 group scale/min planes vs 2 bytes/element)
        from repro.core.slots import quantized_expert_bytes

        eng4 = rows["fused_rotary@int4"]["engine"]
        store = eng4.manager.stores[0]
        f16_bytes = quantized_expert_bytes(
            {n: w.shape[1:] for n, w in eng4.host_experts[0].items()},
            None, dtype_bytes=2,
        )
        ratio = store.bytes_per_expert / f16_bytes
        assert ratio <= 0.30, f"int4 bytes/expert {ratio:.3f}x f16 exceeds 0.30x"
        rows["int4_bytes_ratio_vs_f16"] = ratio

    # ---- asynchronous prefetch row family: double-buffered slot planes ----
    pf_defs = [
        ("fused_full_pf", "fused_full", "full", 0, "fused", None),
        ("fused_rotary_hi_pf", "fused_rotary_hi", "rotary", e, "fused", None),
        ("fused_rotary_pf", "fused_rotary", "rotary", 6, "fused", None),
    ]
    if 4 in spec_ks:
        pf_defs.append(
            ("spec4_rotary_pf", "spec4_rotary", "rotary", 6, "spec4", None))
    if "int4" in quants:
        pf_defs.append(("fused_rotary_pf@int4", "fused_rotary@int4",
                        "rotary", 6, "fused", "int4"))
    for label, twin, mode, slots, path, quant in pf_defs:
        rows[label] = _run_engine(cfg, params, mode, slots, path, prompt,
                                  steps, quant=quant, prefetch=True)
        # (g) the shadow-generation flip, the mispredict correction pass and
        # the miss relaunch are invisible in the output: greedy tokens
        # bit-identical to the synchronous-rotation twin row
        np.testing.assert_array_equal(
            rows[twin]["tokens"], rows[label]["tokens"], err_msg=label)
    # prefetch must not introduce misses where rotation already covered
    for label in ("fused_full_pf", "fused_rotary_hi_pf"):
        assert rows[label]["engine"].stats.misses == 0, label
    # the slot-starved row actually exercised the machinery: shadow uploads
    # launched during window compute, and missed steps resolved by uploading
    # the missed experts and re-launching the ONE compiled step (the suffix
    # replay remains only as the fallback for infeasible windows)
    spf = rows["fused_rotary_pf"]["engine"].stats
    assert spf.prefetch_launched > 0
    assert spf.overlap_ms > 0
    assert spf.relaunched_steps > 0

    # ---- sampled (temperature > 0) row family: the *_t rows ---------------
    # single-token sampled vs spec-4 sampled on the prefetch-covered regime:
    # both draft on-device from the warped distribution with position-keyed
    # draws, so the streams are bitwise-identical and the window's win is
    # pure launch/pull amortization (plus skipping the per-token host draw)
    from repro.serving.sampler import SamplerConfig

    smp = SamplerConfig(temperature=0.8, top_k=20, top_p=0.95, seed=11)
    for label, path in (("fused_rotary_hi_t", "fused"),
                        ("spec4_rotary_hi_t", "spec4")):
        rows[label] = _run_engine(
            cfg, params, "rotary", e, path, prompt, steps, sampler=smp
        )
    # (h) sampled spec-4 == sampled single-token bitwise (same seeded draws,
    # stochastic acceptance over identical draft/verify distributions), and
    # miss-free self-drafting still accepts everything (ratio exactly 1.0)
    np.testing.assert_array_equal(
        rows["fused_rotary_hi_t"]["tokens"], rows["spec4_rotary_hi_t"]["tokens"],
        err_msg="sampled spec-4 stream diverged from sampled single-token",
    )
    st4 = rows["spec4_rotary_hi_t"]["engine"].stats
    assert st4.misses == 0 and st4.drafted_tokens > 0
    assert st4.accept_rate >= 1.0, st4.summary()

    # the >=1.5x prefetch gate divides two rows the per-row harness timed
    # minutes apart; re-time the pair INTERLEAVED (round-robin, like the
    # prefill family's rounds) so host-load drift cannot land on one side
    # of the ratio — 4 rounds is what the 128-entry KV cache leaves room for
    import gc

    gc.collect()      # the row sweep above left garbage; not in a timed round
    pair = ("fused_rotary", "fused_rotary_pf")
    walls = {label: [] for label in pair}
    outs: Dict = {label: [] for label in pair}
    for _ in range(4):
        for label in pair:
            eng = rows[label]["engine"]
            t0 = time.perf_counter()
            outs[label].append(eng.decode(eng.last_logits, steps))
            walls[label].append(time.perf_counter() - t0)
    np.testing.assert_array_equal(       # the re-time rounds stay exact too
        np.concatenate(outs[pair[0]], axis=1),
        np.concatenate(outs[pair[1]], axis=1),
        err_msg="prefetch diverged from sync rotation in the re-time rounds",
    )
    for label in pair:
        rows[label]["s_per_step"] = min(walls[label]) / steps

    # the sampled >=1.4x gate gets the same interleaved treatment; both
    # engines sit at the same cur_len, so the re-time rounds must stay
    # bitwise-identical too (same position-keyed draws on both sides)
    gc.collect()
    pair_t = ("fused_rotary_hi_t", "spec4_rotary_hi_t")
    walls_t = {label: [] for label in pair_t}
    outs_t: Dict = {label: [] for label in pair_t}
    for _ in range(4):
        for label in pair_t:
            eng = rows[label]["engine"]
            t0 = time.perf_counter()
            outs_t[label].append(eng.decode(eng.last_logits, steps, sampler=smp))
            walls_t[label].append(time.perf_counter() - t0)
    np.testing.assert_array_equal(
        np.concatenate(outs_t[pair_t[0]], axis=1),
        np.concatenate(outs_t[pair_t[1]], axis=1),
        err_msg="sampled spec-4 diverged from single-token in re-time rounds",
    )
    for label in pair_t:
        rows[label]["s_per_step"] = min(walls_t[label]) / steps
    return rows


def run_prefill(prompt_len: int = 256, chunk: int = 32,
                decode_check: int = 4) -> Dict:
    """Prefill / time-to-first-token row family: chunked fused prefill vs the
    layer-walk paths on a long prompt.

    Rows (reduced ``qwen2_moe_a2_7b`` at 6 MoE layers — prefill's win is
    amortizing the per-layer host syncs, which scale with depth):

    * ``prefill_legacy`` — full-sequence layer walk (today's default,
      ``prefill_chunk=None``): one jitted attn+MoE pair per layer with a host
      sync per MoE layer, every distinct prompt length retraces and
      recompiles the whole stack;
    * ``prefill_walk``   — chunked layer walk (``fused_decode=False``): the
      same per-layer launches per chunk, rotation at chunk boundaries — the
      apples-to-apples baseline for the fused path;
    * ``prefill_fused``  — ONE compiled whole-stack launch + one
      queue-draining pull + one coalesced rotation window per chunk;
    * ``prefill_walk@int4`` / ``prefill_fused@int4`` — the chunked paths on
      grouped-int4 slots (within-format exactness pair).

    TTFT here = prefill wall time (the first token is a host argmax of the
    returned logits); ``ttft_new_len_s`` re-prefills at an UNSEEN prompt
    length — the serving-realistic admission case, where the legacy path
    pays a full whole-stack retrace + recompile and the chunked paths reuse
    their power-of-two chunk programs. Acceptance: fused beats the chunked
    layer walk >= 1.3x steady-state (prompts >= 256) and the legacy walk
    >= 2x on a new length; logits bit-identical between the chunked paths
    within each slot format; greedy continuations identical across paths;
    and the fused dispatch bound holds: exactly one whole-stack launch and
    one queue-draining pull per chunk, zero replays in the prefetch-covered
    regime.
    """
    import dataclasses as _dc

    from repro.config import ResidencyConfig, get_config
    from repro.configs import reduce_for_smoke
    from repro.core import RotaryEngine
    from repro.core.engine import prefill_chunk_plan
    from repro.models import init_params
    from repro.models.transformer import Runtime

    cfg = _dc.replace(
        reduce_for_smoke(get_config("qwen2-moe-a2.7b"), max_repeats=6),
        dtype="float32",
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, (1, prompt_len)).astype(np.int32)
    new_len = prompt_len + 2 * chunk       # unseen length, existing chunk shapes
    prompt_new = rng.integers(0, cfg.vocab_size, (1, new_len)).astype(np.int32)
    rt_len = max(128, new_len + decode_check + 8)
    e = cfg.moe.num_experts

    def mk(path: str, quant: str | None = None) -> RotaryEngine:
        return RotaryEngine(
            cfg, params,
            ResidencyConfig(mode="rotary", num_slots=e, quantization=quant),
            rt=Runtime(cache_len=rt_len), batch=1,
            fused_decode=False if path == "walk" else None,
            prefill_chunk=None if path == "legacy" else chunk,
        )

    labels = (
        ("prefill_legacy", "legacy", None),
        ("prefill_walk", "walk", None),
        ("prefill_fused", "fused", None),
        ("prefill_walk@int4", "walk", "int4"),
        ("prefill_fused@int4", "fused", "int4"),
    )
    reps = 5
    engines, snaps = {}, {}
    for label, path, quant in labels:
        eng = mk(path, quant)
        eng.prefill(prompt)                       # warmup: populate jit caches
        engines[label] = eng
        snaps[label] = (eng.stats.sync_pulls, eng.stats.prefill_chunks)
    # timing rounds are INTERLEAVED across rows (round-robin, best-of-N per
    # row): the speedup gates below are ratios, and timing the rows
    # back-to-back would let slow host-load drift land entirely on one row
    import gc

    gc.collect()      # don't let warmup garbage collect inside a timed round
    walls: Dict = {label: [] for label, _, _ in labels}
    logits: Dict = {}
    for _ in range(reps):
        for label, _, _ in labels:
            t0 = time.perf_counter()
            logits[label] = engines[label].prefill(prompt)
            walls[label].append(time.perf_counter() - t0)
    rows: Dict = {}
    for label, path, quant in labels:
        eng = engines[label]
        pulls0, chunks0 = snaps[label]
        tokens = eng.decode(logits[label], decode_check)
        chunks = (eng.stats.prefill_chunks - chunks0) // reps
        pulls = (eng.stats.sync_pulls - pulls0 - decode_check) / reps
        # admission at an unseen prompt length: chunked paths reuse their
        # power-of-two chunk programs, the legacy path recompiles the stack
        t0 = time.perf_counter()
        eng.prefill(prompt_new)
        ttft_new = time.perf_counter() - t0
        rows[label] = {
            "engine": eng,
            "logits": logits[label],
            "tokens": tokens,
            "ttft_s": min(walls[label]),
            "ttft_new_len_s": ttft_new,
            "chunks": chunks,
            "pulls_per_prefill": pulls,
        }

    n_chunks = len(prefill_chunk_plan(prompt_len, chunk))
    fused = rows["prefill_fused"]
    # (a) chunked paths bit-identical (logits) WITHIN each slot format;
    # greedy continuation identical across the f32 paths including the
    # legacy full-sequence walk (quantized rows are exactness-clean within
    # their format, not against the f32 store)
    np.testing.assert_array_equal(
        rows["prefill_walk"]["logits"], fused["logits"],
        err_msg="fused chunked prefill logits != chunked layer-walk logits",
    )
    np.testing.assert_array_equal(
        rows["prefill_walk@int4"]["logits"], rows["prefill_fused@int4"]["logits"],
        err_msg="int4 fused chunked prefill logits != int4 layer-walk logits",
    )
    for label in ("prefill_legacy", "prefill_walk"):
        np.testing.assert_array_equal(
            rows[label]["tokens"], fused["tokens"], err_msg=label
        )
    np.testing.assert_array_equal(
        rows["prefill_walk@int4"]["tokens"], rows["prefill_fused@int4"]["tokens"],
        err_msg="int4 chunked prefill decode tokens diverge across paths",
    )
    # (b) dispatch bound: ONE whole-stack launch and ONE queue-draining pull
    # per chunk, no replays in the prefetch-covered regime
    assert fused["chunks"] == n_chunks, (fused["chunks"], n_chunks)
    assert fused["pulls_per_prefill"] == n_chunks, fused["pulls_per_prefill"]
    assert fused["engine"].stats.prefill_replays == 0
    assert fused["engine"].stats.misses == 0
    # (c) the acceptance gates: fused >= 1.3x the chunked layer walk steady-
    # state, and >= 2x the legacy walk at an unseen prompt length (bounded
    # compile cache: the legacy path retraces the whole stack per length)
    speedup_walk = rows["prefill_walk"]["ttft_s"] / fused["ttft_s"]
    speedup_legacy = rows["prefill_legacy"]["ttft_s"] / fused["ttft_s"]
    speedup_new_len = (
        rows["prefill_legacy"]["ttft_new_len_s"] / fused["ttft_new_len_s"]
    )
    assert speedup_walk >= 1.3, (
        f"fused chunked prefill only {speedup_walk:.2f}x the layer walk"
    )
    assert speedup_new_len >= 2.0, (
        f"fused chunked prefill only {speedup_new_len:.2f}x the legacy walk "
        f"at an unseen prompt length"
    )
    rows["speedups"] = {
        "prefill_fused_vs_walk": speedup_walk,
        "prefill_fused_vs_legacy": speedup_legacy,
        "prefill_fused_vs_legacy_new_len": speedup_new_len,
    }
    rows["prompt_len"] = prompt_len
    rows["chunk"] = chunk
    return rows


def run_trace_overhead(steps: int = 16, rounds: int = 6) -> Dict:
    """Tracing-overhead row pair: the miss-starved fused+prefetch rotary
    workload with the event tracer ON vs OFF.

    Three engines over identical work: untraced (``trace=None``), traced
    (live :class:`repro.obs.Tracer`), and disabled (``Tracer(enabled=False)``).
    The disabled engine's overhead is asserted STRUCTURALLY, not by timing:
    the engine normalises a disabled tracer to no tracer reference at all
    (``eng._tr is None``), so its hot path executes exactly the instructions
    of the untraced one — unmeasurable by construction. The traced/untraced
    pair is timed interleaved (round-robin best-of-N, like the prefetch
    gate) and gated at <= 3% slowdown; the captured trace must pass the
    contract auditor.
    """
    import dataclasses as _dc
    import gc

    from repro.config import ResidencyConfig, get_config
    from repro.configs import reduce_for_smoke
    from repro.core import RotaryEngine
    from repro.models import init_params
    from repro.models.transformer import Runtime
    from repro.obs import Tracer, audit

    cfg = _dc.replace(
        reduce_for_smoke(get_config("qwen2-moe-a2.7b")), dtype="float32"
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = (np.random.default_rng(0)
              .integers(0, cfg.vocab_size, (2, 12)).astype(np.int32))

    def mk(trace):
        eng = RotaryEngine(
            cfg, params, ResidencyConfig(mode="rotary", num_slots=6),
            rt=Runtime(cache_len=max(128, prompt.shape[1] + steps + 8)),
            batch=2, prefetch=True, trace=trace,
        )
        logits = eng.prefill(prompt)
        eng.decode(logits, 2)                  # warmup: jit caches populated
        return eng

    tracer = Tracer()
    engines = {
        "untraced": mk(None),
        "traced": mk(tracer),
        "disabled": mk(Tracer(enabled=False)),
    }
    # the structural zero-overhead-when-off contract
    assert engines["disabled"]._tr is None
    assert engines["untraced"]._tr is None
    assert engines["traced"]._tr is tracer

    gc.collect()
    walls: Dict = {label: [] for label in engines}
    outs: Dict = {label: [] for label in engines}
    for _ in range(rounds):
        for label, eng in engines.items():
            t0 = time.perf_counter()
            outs[label].append(eng.decode(eng.last_logits, steps))
            walls[label].append(time.perf_counter() - t0)
    # identical work: greedy tokens bit-identical across all three engines
    base = np.concatenate(outs["untraced"], axis=1)
    for label in ("traced", "disabled"):
        np.testing.assert_array_equal(
            base, np.concatenate(outs[label], axis=1), err_msg=label)
    ratio = min(walls["traced"]) / min(walls["untraced"])
    # the captured trace passes the contract auditor, and its span-derived
    # prefetch overlap agrees with the legacy wall-clock accounting
    report = audit(tracer)
    report.raise_for_violations()
    stats_overlap = engines["traced"].stats.overlap_ms
    span_overlap = tracer.overlap_ms()
    assert abs(span_overlap - stats_overlap) <= max(1.0, 0.01 * stats_overlap), (
        span_overlap, stats_overlap)
    return {
        "ms_per_step_untraced": min(walls["untraced"]) / steps * 1e3,
        "ms_per_step_traced": min(walls["traced"]) / steps * 1e3,
        "traced_over_untraced": ratio,
        "disabled_is_noop": True,
        "events": len(tracer),
        "audit": report.summary(),
        "metrics": engines["traced"].metrics.summary(),
    }


def main(argv: Sequence[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec-k", default="2,4,8",
                    help="comma-separated speculative window sizes to row out")
    ap.add_argument("--quantization", default="int8,int4",
                    help="comma-separated slot formats for the quantized row "
                         "family (subset of int8,int4; empty disables)")
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--prefill-len", type=int, default=256,
                    help="prompt length for the prefill/TTFT row family "
                         "(0 disables the family)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="chunk length for the chunked-prefill rows "
                         "(power of two)")
    args = ap.parse_args(argv)
    spec_ks: Tuple[int, ...] = tuple(
        int(t) for t in args.spec_k.split(",") if t.strip()
    )
    assert 4 in spec_ks, "the >=1.2x acceptance gate is pinned at K=4"
    quants: Tuple[str, ...] = tuple(
        t for t in args.quantization.split(",") if t.strip() and t != "none"
    )
    assert all(q in ("int8", "int4") for q in quants), quants
    steps = args.steps
    rows = run(steps, spec_ks, quants)
    spec_paths = tuple(f"spec{k}" for k in spec_ks)
    order = [f"{p}_{s}" for s in ("full", "rotary_hi", "rotary")
             for p in PATHS + spec_paths]
    order += [f"fused_{s}@{q}" for q in quants
              for s in ("full", "rotary_hi", "rotary")]
    order += [f"spec4_{s}@{q}" for q in quants for s in ("rotary_hi", "rotary")]
    order += ["fused_full_pf", "fused_rotary_hi_pf", "fused_rotary_pf"]
    if 4 in spec_ks:
        order.append("spec4_rotary_pf")
    if "int4" in quants:
        order.append("fused_rotary_pf@int4")
    order += ["fused_rotary_hi_t", "spec4_rotary_hi_t"]
    for label in order:
        r = rows[label]
        print(f"  {label:22s} {r['s_per_step']*1e3:8.2f} ms/step  "
              f"sync_pulls/step={r['sync_pulls_per_step']:.1f}  "
              f"dispatches/step={r['dispatches_per_step']:.1f}  "
              f"MB/token={r['mb_per_token']:.3f}")
    speedups = {}
    for suffix in ("full", "rotary_hi"):
        layer = rows[f"layer_{suffix}"]["s_per_step"]
        fused = rows[f"fused_{suffix}"]["s_per_step"]
        seed = rows[f"seed_{suffix}"]["s_per_step"]
        speedups[suffix] = {
            "fused_vs_layer": layer / fused,
            "fused_vs_seed": seed / fused,
        }
        for k in spec_ks:
            spec = rows[f"spec{k}_{suffix}"]["s_per_step"]
            speedups[suffix][f"spec{k}_vs_fused"] = fused / spec
        print(f"  miss-free {suffix}: fused vs per-layer {layer / fused:.2f}x, "
              f"fused vs seed {seed / fused:.2f}x, "
              + ", ".join(
                  f"spec{k} vs fused {speedups[suffix][f'spec{k}_vs_fused']:.2f}x"
                  for k in spec_ks
              ))
    print("  (slot-starved rotary pays whole-suffix replay per missed step — "
          "spec windows additionally roll back and re-draft the rejected "
          "suffix; the prefetch-covered regime is the paper's operating point)")
    spf = rows["fused_rotary_pf"]["engine"].stats
    pf_speedup = (rows["fused_rotary"]["s_per_step"]
                  / rows["fused_rotary_pf"]["s_per_step"])
    print(f"  miss-starved rotary: prefetch vs sync rotation {pf_speedup:.2f}x  "
          f"(overlap {spf.overlap_ms:.1f} ms, "
          f"launched {spf.prefetch_launched}, hits {spf.prefetch_hits}, "
          f"relaunched {spf.relaunched_steps}, replayed {spf.replayed_steps})")
    for suffix, sp in speedups.items():
        print(f"decode_hot_path,speedup_fused_vs_layer_{suffix},{sp['fused_vs_layer']:.3f}")
        print(f"decode_hot_path,speedup_fused_vs_seed_{suffix},{sp['fused_vs_seed']:.3f}")
        for k in spec_ks:
            print(f"decode_hot_path,speedup_spec{k}_vs_fused_{suffix},"
                  f"{sp[f'spec{k}_vs_fused']:.3f}")
    print(f"decode_hot_path,speedup_prefetch_fused_rotary,{pf_speedup:.3f}")
    print(f"decode_hot_path,ms_per_step_fused_rotary_pf,"
          f"{rows['fused_rotary_pf']['s_per_step']*1e3:.3f}")
    print(f"decode_hot_path,overlap_ms_fused_rotary_pf,{spf.overlap_ms:.3f}")
    print("decode_hot_path,prefetch_tokens_identical,1")
    print(f"decode_hot_path,ms_per_step_fused_full,{rows['fused_full']['s_per_step']*1e3:.3f}")
    print(f"decode_hot_path,accept_rate_spec4_full,"
          f"{rows['spec4_full']['engine'].stats.accept_rate:.3f}")
    print("decode_hot_path,tokens_identical,1")
    # sampled *_t rows: spec-4 sampled vs single-token sampled, same stream
    sampled_speedup = (rows["fused_rotary_hi_t"]["s_per_step"]
                       / rows["spec4_rotary_hi_t"]["s_per_step"])
    print(f"  sampled (t=0.8) rotary_hi: spec4 vs single-token "
          f"{sampled_speedup:.2f}x  "
          f"(accept_rate {rows['spec4_rotary_hi_t']['engine'].stats.accept_rate:.3f}, "
          f"tokens bitwise-identical)")
    print(f"decode_hot_path,speedup_spec4_vs_fused_sampled_rotary_hi,"
          f"{sampled_speedup:.3f}")
    for label in ("fused_rotary_hi_t", "spec4_rotary_hi_t"):
        print(f"decode_hot_path,accept_rate_{label},"
              f"{rows[label]['engine'].stats.accept_rate:.3f}")
    print("decode_hot_path,sampled_tokens_identical,1")
    if quants:
        # link traffic: the slot-starved rotary workload (the regime that
        # actually rotates every window) priced in each slot format, MB per
        # decoded token — the f16-vs-int8-vs-int4 shrink in one column
        for q in quants:
            print(f"decode_hot_path,mb_per_token_fused_rotary_{q},"
                  f"{rows[f'fused_rotary@{q}']['mb_per_token']:.4f}")
        print(f"decode_hot_path,mb_per_token_fused_rotary_f32,"
              f"{rows['fused_rotary']['mb_per_token']:.4f}")
    if "int4" in quants:
        print(f"decode_hot_path,int4_bytes_ratio_vs_f16,"
              f"{rows['int4_bytes_ratio_vs_f16']:.4f}")
        print("decode_hot_path,int4_tokens_identical,1")
    # ---- prefill / time-to-first-token row family -------------------------
    prefill_rows = None
    if args.prefill_len:
        prefill_rows = run_prefill(args.prefill_len, args.prefill_chunk)
        for label in ("prefill_legacy", "prefill_walk", "prefill_fused",
                      "prefill_walk@int4", "prefill_fused@int4"):
            r = prefill_rows[label]
            print(f"  {label:22s} TTFT {r['ttft_s']*1e3:8.2f} ms  "
                  f"new-len {r['ttft_new_len_s']*1e3:8.2f} ms  "
                  f"chunks={r['chunks']}  pulls/prefill={r['pulls_per_prefill']:.1f}")
        for name, v in prefill_rows["speedups"].items():
            print(f"decode_hot_path,speedup_{name},{v:.3f}")
        print("decode_hot_path,prefill_tokens_identical,1")

    # ---- tracing-overhead row pair ----------------------------------------
    trace_rows = run_trace_overhead(steps)
    print(f"  tracing overhead (fused+prefetch rotary): "
          f"untraced {trace_rows['ms_per_step_untraced']:.2f} ms/step, "
          f"traced {trace_rows['ms_per_step_traced']:.2f} ms/step "
          f"({(trace_rows['traced_over_untraced'] - 1) * 100:+.1f}%), "
          f"{trace_rows['events']} events, "
          f"audit ok={trace_rows['audit']['ok']}")
    print(f"decode_hot_path,trace_overhead_ratio,"
          f"{trace_rows['traced_over_untraced']:.4f}")
    print("decode_hot_path,trace_audit_ok,1")

    payload = {
        "config": "qwen2_moe_a2_7b_reduced_f32",
        "steps_timed": steps,
        "rows": {
            label: {
                "ms_per_step": rows[label]["s_per_step"] * 1e3,
                "sync_pulls_per_step": rows[label]["sync_pulls_per_step"],
                "dispatches_per_step": rows[label]["dispatches_per_step"],
                "mb_per_token": rows[label]["mb_per_token"],
                "misses": int(rows[label]["engine"].stats.misses),
                "replayed_steps": int(rows[label]["engine"].stats.replayed_steps),
                "drafted_tokens": int(rows[label]["engine"].stats.drafted_tokens),
                "accepted_tokens": int(rows[label]["engine"].stats.accepted_tokens),
                "accept_rate": rows[label]["engine"].stats.accept_rate,
                "prefetch_launched": int(
                    rows[label]["engine"].stats.prefetch_launched),
                "prefetch_hits": int(rows[label]["engine"].stats.prefetch_hits),
                "prefetch_wasted_bytes": int(
                    rows[label]["engine"].stats.prefetch_wasted_bytes),
                "overlap_ms": rows[label]["engine"].stats.overlap_ms,
                "relaunched_steps": int(
                    rows[label]["engine"].stats.relaunched_steps),
            }
            for label in order
        },
        "speedups": speedups,
        "tokens_identical": True,
        "prefetch": {
            "speedup_fused_rotary": pf_speedup,
            "overlap_ms_fused_rotary_pf": spf.overlap_ms,
            "prefetch_launched": int(spf.prefetch_launched),
            "prefetch_hits": int(spf.prefetch_hits),
            "prefetch_wasted_bytes": int(spf.prefetch_wasted_bytes),
            "relaunched_steps": int(spf.relaunched_steps),
            "tokens_identical": True,
        },
    }
    payload["trace"] = trace_rows
    payload["sampled"] = {
        "speedup_spec4_vs_fused_rotary_hi": sampled_speedup,
        "accept_rate_spec4_rotary_hi_t":
            rows["spec4_rotary_hi_t"]["engine"].stats.accept_rate,
        "tokens_identical": True,
    }
    if "int4" in quants:
        payload["int4_bytes_ratio_vs_f16"] = rows["int4_bytes_ratio_vs_f16"]
        payload["int4_tokens_identical"] = True
    if prefill_rows is not None:
        payload["prefill"] = {
            "prompt_len": prefill_rows["prompt_len"],
            "chunk": prefill_rows["chunk"],
            "rows": {
                label: {
                    "ttft_ms": prefill_rows[label]["ttft_s"] * 1e3,
                    "ttft_new_len_ms": prefill_rows[label]["ttft_new_len_s"] * 1e3,
                    "chunks": prefill_rows[label]["chunks"],
                    "pulls_per_prefill": prefill_rows[label]["pulls_per_prefill"],
                    "prefill_replays": int(
                        prefill_rows[label]["engine"].stats.prefill_replays
                    ),
                    "misses": int(prefill_rows[label]["engine"].stats.misses),
                }
                for label in ("prefill_legacy", "prefill_walk", "prefill_fused",
                              "prefill_walk@int4", "prefill_fused@int4")
            },
            "speedups": prefill_rows["speedups"],
            "tokens_identical": True,
        }
    # machine-readable tier-1 pass-count trajectory (tools/tier1_delta.py):
    # embedded whenever a `make tier1` log exists next to this benchmark.
    # Loaded by explicit file path — tools/ is not a package, and mutating
    # sys.path would shadow any other module named tier1_delta process-wide
    import importlib.util
    import os

    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        "repro_tools_tier1_delta",
        os.path.join(repo_root, "tools", "tier1_delta.py"),
    )
    tier1_delta = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(tier1_delta)
    tier1 = tier1_delta.payload_from_files(
        os.path.join(repo_root, ".tier1.log"),
        os.path.join(repo_root, "CHANGES.md"),
    )
    if tier1 is not None:
        payload["tier1"] = tier1
        print(f"decode_hot_path,tier1_passed,{tier1['passed']}")
    with open("BENCH_decode.json", "w") as f:
        json.dump(payload, f, indent=2)
    print("  wrote BENCH_decode.json")
    # acceptance: the fused step must beat the PR-1 per-layer hot path by
    # >= 1.3x on the miss-free steady state (best of the two covered regimes;
    # the other must at least not regress past timing noise)
    best = max(sp["fused_vs_layer"] for sp in speedups.values())
    worst = min(sp["fused_vs_layer"] for sp in speedups.values())
    assert best >= 1.3, speedups
    assert worst >= 1.05, speedups
    # acceptance: speculative windows at K=4 must beat the fused single-token
    # path >= 1.2x miss-free (amortized launches + pulls + rotation), and not
    # regress past timing noise in the other covered regime
    best4 = max(sp["spec4_vs_fused"] for sp in speedups.values())
    worst4 = min(sp["spec4_vs_fused"] for sp in speedups.values())
    assert best4 >= 1.2, speedups
    assert worst4 >= 1.0, speedups
    # acceptance: sampled spec-4 must beat the sampled single-token fused
    # path >= 1.4x miss-free — the window amortizes the launch+pull AND the
    # per-token host draw sync, so its bar is higher than the greedy 1.2x
    assert sampled_speedup >= 1.4, (
        f"sampled spec4 only {sampled_speedup:.2f}x single-token sampled"
    )
    # acceptance: on the miss-starved fused rotary row, asynchronous prefetch
    # (shadow-generation uploads + compiled-step miss relaunch) must beat the
    # synchronous-rotation baseline >= 1.5x, with real overlap on record —
    # the prefetch engine cannot win by merely skipping work
    assert pf_speedup >= 1.5, (pf_speedup, spf.summary())
    assert spf.overlap_ms > 0, spf.summary()
    # acceptance: live tracing costs <= 3% on the miss-starved fused+prefetch
    # hot path (ring-buffer appends only), and a DISABLED tracer is a no-op
    # by construction (asserted structurally inside run_trace_overhead)
    assert trace_rows["traced_over_untraced"] <= 1.03, trace_rows
    assert trace_rows["disabled_is_noop"]


if __name__ == "__main__":
    main()
