"""Print the tier-1 pass-count delta vs the number recorded in CHANGES.md.

Usage: python tools/tier1_delta.py <pytest-log> <CHANGES.md>

The CHANGES.md convention is that each PR entry's tail records the tier-1
result as ``Tier-1: N passed``; ``make tier1`` tees the pytest output through
this script so every local run reports where the suite stands relative to the
last landed PR (a negative delta = regressions, a positive one = the new
coverage this PR adds).
"""
from __future__ import annotations

import re
import sys


def latest_passed(text: str) -> int:
    """Last ``N passed`` occurrence in a pytest summary (0 if none)."""
    hits = re.findall(r"(\d+) passed", text)
    return int(hits[-1]) if hits else 0


def recorded_passed(changes: str) -> int:
    """The most recent ``Tier-1: N passed`` recorded in CHANGES.md (its tail
    convention: newest entry first, so the first match wins)."""
    for line in changes.splitlines():
        m = re.search(r"Tier-1:\s*(\d+) passed", line)
        if m:
            return int(m.group(1))
    return 0


def main() -> None:
    if len(sys.argv) != 3:
        sys.exit(f"usage: {sys.argv[0]} <pytest-log> <CHANGES.md>")
    try:
        log = open(sys.argv[1]).read()
    except OSError as e:
        sys.exit(f"tier1_delta: cannot read pytest log: {e}")
    try:
        changes = open(sys.argv[2]).read()
    except OSError:
        changes = ""
    cur = latest_passed(log)
    prev = recorded_passed(changes)
    print(
        f"tier1: {cur} passed ({cur - prev:+d} vs the {prev} recorded in "
        f"CHANGES.md)"
    )


if __name__ == "__main__":
    main()
