"""Print the tier-1 pass-count delta vs the number recorded in CHANGES.md.

Usage: python tools/tier1_delta.py <pytest-log> <CHANGES.md>

The CHANGES.md convention is that each PR entry's tail records the tier-1
result as ``Tier-1: N passed``; ``make tier1`` tees the pytest output through
this script so every local run reports where the suite stands relative to the
last landed PR (a negative delta = regressions, a positive one = the new
coverage this PR adds).
"""
from __future__ import annotations

import re
import sys


def latest_passed(text: str) -> int:
    """Last ``N passed`` occurrence in a pytest summary (0 if none)."""
    hits = re.findall(r"(\d+) passed", text)
    return int(hits[-1]) if hits else 0


def recorded_passed(changes: str) -> int:
    """The most recent ``Tier-1: N passed`` recorded in CHANGES.md (its tail
    convention: newest entry first, so the first match wins)."""
    for line in changes.splitlines():
        m = re.search(r"Tier-1:\s*(\d+) passed", line)
        if m:
            return int(m.group(1))
    return 0


def delta_payload(log_text: str, changes_text: str) -> dict:
    """Machine-readable pass-count trajectory: what this run passed, what the
    last landed PR recorded, and the delta. Embedded into BENCH_decode.json by
    the decode hot-path benchmark so the trajectory is greppable per PR."""
    cur = latest_passed(log_text)
    prev = recorded_passed(changes_text)
    return {"passed": cur, "recorded": prev, "delta": cur - prev}


def payload_from_files(log_path: str, changes_path: str) -> "dict | None":
    """``delta_payload`` from file paths; None when no pytest log exists yet
    (callers embed the trajectory only when a tier-1 run has happened). The
    log's mtime is stamped in as ``log_time`` so a consumer can tell a fresh
    run from a stale log left over from before the benchmarked edit."""
    import datetime
    import os

    try:
        log = open(log_path).read()
        mtime = os.path.getmtime(log_path)
    except OSError:
        return None
    try:
        changes = open(changes_path).read()
    except OSError:
        changes = ""
    payload = delta_payload(log, changes)
    payload["log_time"] = datetime.datetime.fromtimestamp(mtime).isoformat(
        timespec="seconds"
    )
    return payload


def main() -> None:
    if len(sys.argv) != 3:
        sys.exit(f"usage: {sys.argv[0]} <pytest-log> <CHANGES.md>")
    payload = payload_from_files(sys.argv[1], sys.argv[2])
    if payload is None:
        sys.exit(f"tier1_delta: cannot read pytest log {sys.argv[1]!r}")
    print(
        f"tier1: {payload['passed']} passed ({payload['delta']:+d} vs the "
        f"{payload['recorded']} recorded in CHANGES.md)"
    )


if __name__ == "__main__":
    main()
