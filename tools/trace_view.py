"""Print the top-N slowest spans of a captured Chrome trace.

Usage: python tools/trace_view.py TRACE.json [--top N] [--track NAME]

Quick terminal triage for the traces ``serve.py --trace-out`` and the
benchmark drivers write: which launches/pulls/rotations dominated the run,
without opening Perfetto. One row per complete ("X") event, sorted by
duration; ``--track`` filters to one machine track (launch / pull /
rotation / prefetch / kv_pool) or the per-request lanes (request).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List


def slowest_spans(events: List[Dict[str, Any]], top: int,
                  track: str = "") -> List[Dict[str, Any]]:
    spans = [
        e for e in events
        if e.get("ph") == "X" and (not track or e.get("cat") == track)
    ]
    spans.sort(key=lambda e: -float(e.get("dur", 0.0)))
    return spans[:top]


def format_table(spans: List[Dict[str, Any]]) -> str:
    header = (f"{'dur_ms':>10} {'ts_ms':>12} {'track':>9} {'unit':>5} "
              f"{'lane':>5}  name")
    lines = [header]
    for e in spans:
        args = e.get("args") or {}
        unit = args.get("unit", "")
        lane = e["tid"] if e.get("pid") == 2 else ""
        extra = {k: v for k, v in args.items()
                 if k != "unit" and not isinstance(v, (list, dict))}
        tail = f"  {extra}" if extra else ""
        lines.append(
            f"{float(e.get('dur', 0.0)) / 1e3:>10.3f} "
            f"{float(e.get('ts', 0.0)) / 1e3:>12.3f} "
            f"{e.get('cat', ''):>9} {unit!s:>5} {lane!s:>5}  "
            f"{e.get('name', '')}{tail}"
        )
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--top", type=int, default=15,
                    help="number of spans to show (default 15)")
    ap.add_argument("--track", default="",
                    help="filter to one track (launch/pull/rotation/"
                         "prefetch/kv_pool/request)")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        data = json.load(f)
    events = data["traceEvents"] if isinstance(data, dict) else data
    spans = slowest_spans(events, args.top, args.track)
    n_spans = sum(1 for e in events if e.get("ph") == "X")
    print(f"{args.trace}: {len(events)} events, {n_spans} spans"
          + (f" (track={args.track})" if args.track else ""))
    print(format_table(spans))
    return 0


if __name__ == "__main__":
    sys.exit(main())
