"""Config registry + assigned-architecture parameter budgets."""
import pytest

from repro.config import get_config, list_archs
from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, reduce_for_smoke
from repro.configs.shapes import SHAPES, applicable_shapes, shape_applies
from repro.models.params import analytic_params, param_summary


def test_registry_complete():
    archs = list_archs()
    for a in ALL_ARCHS:
        assert a in archs


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_config_valid(arch):
    cfg = get_config(arch)
    assert cfg.num_layers > 0
    assert cfg.d_model > 0
    smoke = reduce_for_smoke(cfg)
    assert smoke.num_layers <= cfg.num_layers
    assert smoke.family == cfg.family
    # GQA class preserved
    if cfg.attention is not None:
        full_mha = cfg.attention.num_kv_heads == cfg.attention.num_heads
        smoke_mha = smoke.attention.num_kv_heads == smoke.attention.num_heads
        assert full_mha == smoke_mha


# Expected total parameter budgets (B), generous tolerance: configs are from
# public literature and our analytic count includes everything (embeddings...)
_EXPECTED_B = {
    "starcoder2-7b": (6.0, 8.5),
    "starcoder2-3b": (2.5, 3.6),
    "qwen3-4b": (3.2, 4.8),
    "phi3-mini-3.8b": (3.2, 4.4),
    "qwen2-moe-a2.7b": (12.0, 16.0),     # total (A2.7B = active)
    "dbrx-132b": (115.0, 140.0),
    "xlstm-350m": (0.25, 0.50),
    "recurrentgemma-2b": (2.2, 3.4),
    "pixtral-12b": (11.0, 13.5),
    "musicgen-large": (1.8, 2.8),
}


@pytest.mark.parametrize("arch", sorted(_EXPECTED_B))
def test_param_budget(arch):
    lo, hi = _EXPECTED_B[arch]
    n = analytic_params(get_config(arch)) / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_moe_active_params():
    cfg = get_config("qwen2-moe-a2.7b")
    s = param_summary(cfg)
    assert 1.8 <= s["active_params_B"] <= 3.5          # the A2.7B class
    assert s["active_params_B"] < s["total_params_B"] / 3


def test_paper_arch_class():
    cfg = get_config("qwen36-35b-a3b")
    s = param_summary(cfg)
    assert 25.0 <= s["total_params_B"] <= 40.0          # ~35B class
    assert 2.0 <= s["active_params_B"] <= 4.5           # ~A3B class


def test_shape_applicability():
    # long_500k only for sub-quadratic archs
    assert shape_applies(get_config("xlstm-350m"), SHAPES["long_500k"])
    assert shape_applies(get_config("recurrentgemma-2b"), SHAPES["long_500k"])
    assert not shape_applies(get_config("starcoder2-7b"), SHAPES["long_500k"])
    for arch in ASSIGNED_ARCHS:
        shapes = applicable_shapes(get_config(arch))
        assert len(shapes) in (3, 4)
