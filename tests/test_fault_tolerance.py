"""Fault tolerance: coordinator state machine + crash/resume bitwise training."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import params_for
from repro.config import RunConfig
from repro.data import SyntheticSpec, batch_at_step
from repro.distributed import FaultTolerantCoordinator, JobState
from repro.models.transformer import Runtime
from repro.training import init_train_state, make_train_step


def test_heartbeat_timeout_triggers_restart():
    c = FaultTolerantCoordinator(4, timeout_s=10.0, min_workers=3)
    for w in range(4):
        c.heartbeat(w, now=0.0)
    assert c.check(5.0) is JobState.RUNNING
    for w in range(3):
        c.heartbeat(w, now=20.0)          # worker 3 silent
    assert c.check(25.0) is JobState.RESTARTING
    assert c.alive_workers() == [0, 1, 2]
    assert c.try_resume(26.0)
    assert c.state is JobState.RUNNING


def test_straggler_detection():
    c = FaultTolerantCoordinator(4, timeout_s=1e9, straggler_factor=3.0,
                                 straggler_patience=2, min_workers=3)
    for t in range(6):
        now = float(t)
        for w in range(4):
            c.heartbeat(w, now, step_time=1.0 if w != 3 else 10.0)
        c.check(now)
        if c.state is JobState.RESTARTING:
            break
    assert c.state is JobState.RESTARTING
    assert any("straggler" in r["reason"] for r in c.restart_log)


def test_max_restarts_fails_job():
    c = FaultTolerantCoordinator(2, timeout_s=1.0, max_restarts=1, min_workers=1)
    c.heartbeat(0, 0.0); c.heartbeat(1, 0.0)
    c.check(10.0)                          # both time out -> restart 1
    c2 = FaultTolerantCoordinator(2, timeout_s=1.0, max_restarts=0, min_workers=1)
    c2.heartbeat(0, 0.0); c2.heartbeat(1, 0.0)
    assert c2.check(10.0) is JobState.FAILED


def test_backoff_grows():
    c = FaultTolerantCoordinator(2, timeout_s=1.0, max_restarts=5, min_workers=1)
    c.restarts = 1
    b1 = c.backoff_s()
    c.restarts = 3
    assert c.backoff_s() > b1


def test_crash_resume_bitwise(tmp_path):
    """Train 6 steps straight vs train 3 + crash + resume 3: identical params.
    (Deterministic data keyed by step + committed checkpoints.)"""
    from repro.checkpoint import CheckpointManager

    cfg, params = params_for("xlstm-350m")
    rt = Runtime()
    run = RunConfig(learning_rate=1e-3, warmup_steps=0)
    spec = SyntheticSpec(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    step_fn = jax.jit(make_train_step(cfg, rt, run))

    def run_steps(state, a, b):
        for i in range(a, b):
            t, l = batch_at_step(spec, i)
            state, _ = step_fn(state, jnp.asarray(t), jnp.asarray(l))
        return state

    s_straight = run_steps(init_train_state(cfg, params), 0, 6)

    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    s = run_steps(init_train_state(cfg, params), 0, 3)
    mgr.save(3, s)
    del s                                   # "crash"
    step, s2, _ = mgr.restore_latest(init_train_state(cfg, params))
    assert step == 3
    s2 = run_steps(s2, 3, 6)
    for a, b in zip(jax.tree.leaves(s_straight["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
