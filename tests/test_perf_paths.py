"""Correctness of the §Perf execution paths (SP attention, EP decode) against
their plain counterparts on a degenerate 1x1 mesh (shard_map semantics without
multi-device hardware; multi-device behaviour is covered by the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.config import AttentionConfig, MoEConfig, ShardingConfig
from repro.models import attention as A
from repro.models import moe as M


def test_sp_attention_offsets_match_full(rng):
    """chunked_attention with a traced q_offset (the SP building block) over
    sequence slices reproduces the full computation slice by slice."""
    b, s, h, hkv, dh = 1, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), jnp.float32)
    full = A.chunked_attention(q, k, v, q_chunk=16, kv_chunk=16)
    parts = []
    for i in range(4):                     # 4 "peers", 16 query positions each
        off = jnp.int32(i * 16)
        parts.append(
            A.chunked_attention(q[:, i * 16 : (i + 1) * 16], k, v,
                                q_chunk=16, kv_chunk=16, q_offset=off)
        )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(parts, axis=1)), np.asarray(full), atol=2e-5
    )


def test_sp_attention_model_path(rng):
    """_sp_attention under a (1,1) mesh == attention_train."""
    from repro.models.transformer import Runtime, _sp_attention

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    acfg = AttentionConfig(num_heads=3, num_kv_heads=1, head_dim=8)  # 3 % 1 == 0 but force path
    p = A.init_attention(jax.random.PRNGKey(0), 24, acfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 32, 24)), jnp.float32)
    rt = Runtime(sharding=ShardingConfig(), mesh=mesh, q_chunk=8, kv_chunk=8)
    y_sp, cache = jax.jit(
        lambda xx: _sp_attention(p, acfg, None, rt, xx, 32)
    )(x)
    y_ref, cache_ref = A.attention_prefill(p, acfg, x, 32, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(y_sp), np.asarray(y_ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(cache["k"]), np.asarray(cache_ref["k"]),
                               atol=1e-6)


def test_epsum_decode_matches_gathered(rng):
    """moe_epsum_decode_local on a size-1 EP axis == moe_apply_routed."""
    mcfg = MoEConfig(num_experts=8, top_k=2, expert_d_ff=16)
    p = M.init_moe(jax.random.PRNGKey(0), 12, mcfg, "swiglu", jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 12)), jnp.float32)
    logits = M.router_logits(p, x)
    ids, weights, _ = M.topk_route(logits, mcfg)
    y_ref, miss = M.moe_apply_routed(p, x, ids, weights)
    assert not bool(miss.any())
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    fn = shard_map(
        lambda pp, xx, ii, ww: M.moe_epsum_decode_local(
            pp, mcfg, xx, ii, ww, ep_axis="model"),
        mesh=mesh,
        in_specs=({"router": P(None, None),
                   "experts": {kk: P("model", None, None) for kk in p["experts"]}},
                  P("data", None), P("data", None), P("data", None)),
        out_specs=P("data", None),
        check_vma=False,
    )
    y_ep = jax.jit(fn)(p, x, ids, weights)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref), atol=1e-4)
