"""Recurrent blocks: train == prefill, and prefill+decode == longer prefill.
These are THE correctness properties for the sub-quadratic (long_500k) archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RecurrentConfig
from repro.models import rglru as R
from repro.models import xlstm as X


def _x(rng, b=2, s=12, d=16):
    return jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)


@pytest.mark.parametrize("mod,init,prefill,decode,train", [
    (X, X.init_mlstm, X.mlstm_prefill, X.mlstm_decode, X.mlstm_train),
    (X, X.init_slstm, X.slstm_prefill, X.slstm_decode, X.slstm_train),
    (R, R.init_rglru, R.rglru_prefill, R.rglru_decode, R.rglru_train),
])
def test_train_equals_prefill(rng, mod, init, prefill, decode, train):
    rcfg = RecurrentConfig(num_heads=2, lru_width=16, conv_width=4)
    p = init(jax.random.PRNGKey(0), 16, rcfg, jnp.float32)
    x = _x(rng)
    y_train = train(p, x, rcfg)
    y_pre, _ = prefill(p, x, rcfg)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_pre), atol=1e-5)


@pytest.mark.parametrize("init,prefill,decode", [
    (X.init_mlstm, X.mlstm_prefill, X.mlstm_decode),
    (X.init_slstm, X.slstm_prefill, X.slstm_decode),
    (R.init_rglru, R.rglru_prefill, R.rglru_decode),
])
def test_decode_continues_prefill(rng, init, prefill, decode):
    """prefill(x[:8]) then 4 decode steps == prefill(x[:12])."""
    rcfg = RecurrentConfig(num_heads=2, lru_width=16, conv_width=4)
    p = init(jax.random.PRNGKey(0), 16, rcfg, jnp.float32)
    x = _x(rng, s=12)
    y_full, state_full = prefill(p, x, rcfg)
    y_pre, state = prefill(p, x[:, :8], rcfg)
    outs = []
    for t in range(8, 12):
        y_t, state = decode(p, x[:, t : t + 1], state)
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, 8:]),
                               atol=2e-5)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state_full)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_rglru_state_is_o1(rng):
    """The recurrent state size is independent of sequence length — this is
    what makes long_500k feasible for the ssm/hybrid archs."""
    rcfg = RecurrentConfig(num_heads=2, lru_width=16, conv_width=4)
    p = R.init_rglru(jax.random.PRNGKey(0), 16, rcfg, jnp.float32)
    _, s1 = R.rglru_prefill(p, _x(rng, s=4), rcfg)
    _, s2 = R.rglru_prefill(p, _x(rng, s=64), rcfg)
    assert jax.tree.map(jnp.shape, s1) == jax.tree.map(jnp.shape, s2)


def test_rglru_forgetting(rng):
    """RG-LRU decay keeps the state bounded over long sequences."""
    rcfg = RecurrentConfig(num_heads=2, lru_width=16, conv_width=4)
    p = R.init_rglru(jax.random.PRNGKey(0), 16, rcfg, jnp.float32)
    x = _x(rng, b=1, s=256)
    _, st = R.rglru_prefill(p, x, rcfg)
    assert np.all(np.isfinite(np.asarray(st["h"])))
    assert np.abs(np.asarray(st["h"])).max() < 1e3


def test_mlstm_stabilizer_long_sequence(rng):
    """Exponential gating with the max-stabilizer must not overflow on long
    inputs with large gate pre-activations."""
    rcfg = RecurrentConfig(num_heads=2)
    p = X.init_mlstm(jax.random.PRNGKey(0), 16, rcfg, jnp.float32)
    x = 5.0 * _x(rng, b=1, s=128)
    y = X.mlstm_train(p, x, rcfg)
    assert np.all(np.isfinite(np.asarray(y)))
