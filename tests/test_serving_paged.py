"""Continuous batching over the paged KV pool.

Exactness contract: with CB on, every request's emitted tokens are
bit-identical to running that request alone at batch-1 — across residency
regimes, with speculative windows, through page recycling, and on quantized
slot formats. Plus pool accounting invariants, dispatch-count bounds, and
the request-lifecycle telemetry.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import params_for
from repro.config import ResidencyConfig
from repro.config.base import AttentionConfig
from repro.models import attention as attn
from repro.models import transformer as tfm
from repro.models.transformer import Runtime
from repro.serving import ServingEngine
from repro.serving.kv_pool import KVPagePool, PagePoolError
from repro.serving.scheduler import Scheduler


# ===========================================================================
# paged device layout: bitwise equality with the contiguous cache
# ===========================================================================
def test_paged_attention_bitwise_equals_contiguous(rng):
    """attention_decode through a PERMUTED page table over shared planes is
    bit-identical to the contiguous [B, cap, ...] cache holding the same
    logical KV — off-table pages hold huge garbage to prove masked positions
    contribute exactly +-0.0."""
    acfg = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=8)
    d_model = 32
    p = attn.init_attention(jax.random.PRNGKey(0), d_model, acfg, jnp.float32)
    b, cap, ps = 3, 16, 4
    n_pp = cap // ps
    P = 14                                     # physical pages incl. scratch 0
    cl = np.asarray([5, 9, 0], np.int32)       # ragged lengths, one empty row
    x = rng.standard_normal((b, 1, d_model)).astype(np.float32)
    ck = rng.standard_normal((b, cap, 2, 8)).astype(np.float32)
    cv = rng.standard_normal((b, cap, 2, 8)).astype(np.float32)
    y_ref, cache_ref = attn.attention_decode(
        p, acfg, jnp.asarray(x), {"k": jnp.asarray(ck), "v": jnp.asarray(cv)},
        jnp.asarray(cl),
    )
    perm = rng.permutation(np.arange(1, P))[: b * n_pp].reshape(b, n_pp)
    perm = perm.astype(np.int32)
    pk = rng.standard_normal((P, ps, 2, 8)).astype(np.float32) * 1e3
    pv = rng.standard_normal((P, ps, 2, 8)).astype(np.float32) * 1e3
    for i in range(b):
        for j in range(n_pp):
            pk[perm[i, j]] = ck[i, j * ps:(j + 1) * ps]
            pv[perm[i, j]] = cv[i, j * ps:(j + 1) * ps]
    y_pg, cache_pg = attn.attention_decode(
        p, acfg, jnp.asarray(x), {"k": jnp.asarray(pk), "v": jnp.asarray(pv)},
        jnp.asarray(cl), page_table=jnp.asarray(perm),
    )
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_pg))
    # the new KV landed at the right physical (page, offset) per row
    for i in range(b):
        s = cl[i] % cap
        pg, off = perm[i, s // ps], s % ps
        np.testing.assert_array_equal(
            np.asarray(cache_ref["k"])[i, s], np.asarray(cache_pg["k"])[pg, off]
        )
        np.testing.assert_array_equal(
            np.asarray(cache_ref["v"])[i, s], np.asarray(cache_pg["v"])[pg, off]
        )


def test_paged_snapshot_rollback_restores_pages(rng):
    """Paged KV snapshot/rollback: per-row keep counts restore exactly the
    rejected window slots at their page-table addresses."""
    class StubCfg:
        segments = ((("attn_moe",), 2), (("attn_mlp",), 1))

    cfg = StubCfg()
    b, cap, ps = 3, 16, 4
    n_pp = cap // ps
    P = 14
    k_steps = 3
    cl = np.asarray([5, 9, 0], np.int32)
    perm = rng.permutation(np.arange(1, P))[: b * n_pp].reshape(b, n_pp)
    pt = jnp.asarray(perm.astype(np.int32))

    def plane(reps):
        return {
            "k": jnp.asarray(rng.standard_normal((reps, P, ps, 2, 8)),
                             jnp.float32),
            "v": jnp.asarray(rng.standard_normal((reps, P, ps, 2, 8)),
                             jnp.float32),
        }

    state = ((plane(2),), (plane(1),))
    before = np.asarray(state[0][0]["k"])
    saved = tfm.snapshot_kv_window(cfg, state, jnp.asarray(cl), k_steps,
                                   page_table=pt)
    garbled = jax.tree.map(lambda c: c.at[:].add(7.0), state)
    keep = np.asarray([1, 0, 3], np.int32)
    rolled = tfm.rollback_kv_window(cfg, garbled, saved, jnp.asarray(cl),
                                    k_steps, jnp.asarray(keep), page_table=pt)
    after = np.asarray(rolled[0][0]["k"])
    garb = np.asarray(garbled[0][0]["k"])
    for i in range(b):
        for j in range(k_steps):
            s = (cl[i] + j) % cap
            pg, off = perm[i, s // ps], s % ps
            want = garb[:, pg, off] if j < keep[i] else before[:, pg, off]
            np.testing.assert_array_equal(after[:, pg, off], want)


# ===========================================================================
# pool accounting
# ===========================================================================
def test_kv_pool_reserve_ensure_release_invariants(rng):
    """Seeded random join/leave churn: no page is ever leaked, double-handed,
    or drawn past its reservation (tier-1 mirror of the hypothesis suite)."""
    pool = KVPagePool(num_pages=12, page_size=4, row_pages=4)
    live = {}
    uid = 0
    for _ in range(300):
        op = rng.integers(0, 3)
        if op == 0:                                     # admit
            need = int(rng.integers(1, pool.row_pages + 1))
            if pool.reserve(uid, need):
                live[uid] = need
                pool.ensure(uid, int(rng.integers(1, need * pool.page_size + 1)))
            else:
                assert need > pool.pages_reservable
            uid += 1
        elif op == 1 and live:                          # grow a live request
            u = int(rng.choice(list(live)))
            pool.ensure(u, int(rng.integers(1, live[u] * pool.page_size + 1)))
        elif op == 2 and live:                          # finish
            u = int(rng.choice(list(live)))
            freed = pool.release(u)
            assert freed <= live.pop(u)
        pool.check()
        assert pool.pages_in_use + pool.pages_free == pool.num_pages
    for u in list(live):
        pool.release(u)
    pool.check()
    assert pool.pages_free == pool.num_pages


def test_kv_pool_ensure_past_reservation_raises():
    pool = KVPagePool(num_pages=8, page_size=4, row_pages=4)
    assert pool.reserve(7, 2)
    with pytest.raises(PagePoolError):
        pool.ensure(7, 3 * pool.page_size)              # needs 3 > reserved 2
    # reservations gate admission, not the free list: 6 pages are still free
    # but only 8 - 2 = 6 ... of which the backlog holds 2
    assert pool.pages_free == 8 and pool.pages_reservable == 6
    assert not pool.reserve(8, 7)
    assert pool.reserve(8, 6)


# ===========================================================================
# continuous batching exactness (the PR contract)
# ===========================================================================
def _serve(cfg, params, prompts, *, num_slots, max_new=5, cache_len=32,
           rescfg=None, spec_cap=4, seeds=None, **kw):
    eng = ServingEngine(
        cfg, params, rt=Runtime(cache_len=cache_len), num_slots=num_slots,
        residency=rescfg, spec_cap=spec_cap, **kw,
    )
    seeds = seeds or [None] * len(prompts)
    reqs = [eng.submit(p, max_new=max_new, seed=s)
            for p, s in zip(prompts, seeds)]
    eng.run()
    return eng, [r.output for r in reqs]


@pytest.mark.parametrize("regime", ["full", "rotary_hi", "rotary_hi_int4"])
def test_cb_concurrent_matches_isolated(rng, regime):
    """Concurrent requests through the paged window == each request alone at
    batch-1, with spec windows on, under full residency, prefetch-covered
    rotary, and a quantized slot format (miss-free regimes: the residency
    trajectory is request-independent, so bit-identity must hold)."""
    cfg, params = params_for("qwen2-moe-a2.7b")
    e = cfg.moe.num_experts

    def mk_res():
        if regime == "full":
            return None
        quant = "int4" if regime.endswith("int4") else None
        return ResidencyConfig(mode="rotary", num_slots=e, quantization=quant)

    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 8, 11)]
    eng, outs = _serve(cfg, params, prompts, num_slots=3, rescfg=mk_res())
    assert eng.pool is not None and eng.stats.windows > 0
    if regime != "full":
        assert eng.stats.misses == 0                    # prefetch covers
    for i, p in enumerate(prompts):
        _, ref = _serve(cfg, params, [p], num_slots=1, rescfg=mk_res())
        assert outs[i] == ref[0], (regime, i)


@pytest.mark.parametrize("regime", ["full", "rotary_hi"])
def test_cb_sampled_matches_isolated(rng, regime):
    """Temperature > 0 serving: each request's PRNG stream is keyed on its
    OWN seed and position (never batch composition), so a sampled request
    under continuous batching emits the same tokens as running alone —
    including through speculative windows whose rejected drafts re-draw the
    same positions with the same fold_in keys. Scoped to the f32 miss-free
    regimes: int4 dequant differs sub-ULP across row-bucket batch shapes,
    which greedy argmax absorbs but a categorical draw can flip."""
    from repro.serving.sampler import SamplerConfig

    cfg, params = params_for("qwen2-moe-a2.7b")
    e = cfg.moe.num_experts
    mk_res = lambda: (None if regime == "full" else
                      ResidencyConfig(mode="rotary", num_slots=e))
    smp = lambda: SamplerConfig(temperature=0.8, top_k=20, top_p=0.95, seed=3)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 8, 11)]
    seeds = [11, 22, 33]
    eng, outs = _serve(cfg, params, prompts, num_slots=3, rescfg=mk_res(),
                       sampler=smp(), seeds=seeds)
    assert eng.stats.spec_windows > 0          # sampled serving still drafts
    for i, p in enumerate(prompts):
        _, ref = _serve(cfg, params, [p], num_slots=1, rescfg=mk_res(),
                        sampler=smp(), seeds=[seeds[i]])
        assert outs[i] == ref[0], (regime, i)
    # the stream is the seed's, not the slot's: re-serving concurrently with
    # the same seeds reproduces the outputs bitwise
    _, outs2 = _serve(cfg, params, prompts, num_slots=3, rescfg=mk_res(),
                      sampler=smp(), seeds=seeds)
    assert outs == outs2


def test_cb_sampled_slot_starved_single_request_exact(rng):
    """Sampled decode under a slot-starved rotary residency: a single request
    through the paged CB engine matches batch-1 bitwise even when stochastic
    rejection composes with residency-miss truncation on the same windows."""
    from repro.serving.sampler import SamplerConfig

    cfg, params = params_for("qwen2-moe-a2.7b")
    prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    res = lambda: ResidencyConfig(mode="rotary", num_slots=5)
    smp = lambda: SamplerConfig(temperature=0.9, seed=5)
    eng_cb, out_cb = _serve(cfg, params, [prompt], num_slots=4, rescfg=res(),
                            max_new=6, sampler=smp(), seeds=[17])
    _, out_iso = _serve(cfg, params, [prompt], num_slots=1, rescfg=res(),
                        max_new=6, sampler=smp(), seeds=[17])
    assert out_cb[0] == out_iso[0]
    assert eng_cb.stats.windows > 0


def test_cb_slot_starved_single_request_exact(rng):
    """Slot-starved rotary (misses are dropped in-step, so the residency
    trajectory is shared state between concurrent rows): a SINGLE request
    through the paged CB engine is still bit-identical to batch-1 — and to
    the pre-paging group-tick engine."""
    cfg, params = params_for("qwen2-moe-a2.7b")
    prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
    res = lambda: ResidencyConfig(mode="rotary", num_slots=5)
    eng_cb, out_cb = _serve(cfg, params, [prompt], num_slots=4, rescfg=res(),
                            max_new=6)
    _, out_iso = _serve(cfg, params, [prompt], num_slots=1, rescfg=res(),
                        max_new=6)
    _, out_legacy = _serve(cfg, params, [prompt], num_slots=1, rescfg=res(),
                           max_new=6, paged=False)
    assert out_cb[0] == out_iso[0] == out_legacy[0]
    assert eng_cb.stats.windows > 0


def test_cb_slot_starved_concurrent_completes(rng):
    """Concurrent slot-starved rotary can't be compared row-for-row against
    isolated runs (the rotation trajectory is shared), but every request must
    complete at full length with pages fully recycled and the drafted/accepted
    accounting consistent."""
    cfg, params = params_for("qwen2-moe-a2.7b")
    prompts = [rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(3)]
    eng, outs = _serve(cfg, params, prompts, num_slots=2,
                       rescfg=ResidencyConfig(mode="rotary", num_slots=5),
                       max_new=6)
    assert all(len(o) == 6 for o in outs)
    assert eng.stats.hits + eng.stats.misses > 0
    assert eng.stats.accepted_tokens <= eng.stats.drafted_tokens
    s = eng.stats
    assert s.kv_pages_released == s.kv_pages_allocated > 0


def test_cb_page_recycling_under_queueing_exact(rng):
    """A pool smaller than the request population forces queueing: later
    requests prefill into JUST-FREED garbage pages (LIFO reuse) and must
    still emit bit-identical tokens to running alone."""
    cfg, params = params_for("starcoder2-3b")
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 9, 12, 7)]
    # 8 pages of 4 positions = ONE row's worth of KV for four requests:
    # each needs pages_for(prompt + max_new + spec_cap - 1) ~ 4 pages
    eng, outs = _serve(cfg, params, prompts, num_slots=4, cache_len=32,
                       kv_page_size=4, kv_pages=8)
    s = eng.stats
    assert s.kv_pages_hwm <= 8
    assert s.kv_pages_released == s.kv_pages_allocated > 0
    for i, p in enumerate(prompts):
        _, ref = _serve(cfg, params, [p], num_slots=1, cache_len=32,
                        kv_page_size=4, kv_pages=8)
        assert outs[i] == ref[0], i


def test_cb_dispatch_counts_dense(rng):
    """The 1-launch + 1-queue-draining-pull-per-window contract: on a dense
    arch (no snapshot/rollback) every decode launch is a window, every window
    drains the queue exactly once, and the only other launches are the
    per-join page splices."""
    cfg, params = params_for("starcoder2-3b")
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 9)]
    eng, _ = _serve(cfg, params, prompts, num_slots=2, max_new=6)
    s = eng.stats
    assert s.windows > 0
    assert s.sync_pulls == s.windows
    assert s.device_dispatches == s.windows + len(prompts)


# ===========================================================================
# admission validation + request lifecycle telemetry
# ===========================================================================
def test_submit_validates_prompt_against_pool_capacity(rng):
    cfg, params = params_for("starcoder2-3b")
    eng = ServingEngine(cfg, params, rt=Runtime(cache_len=32), num_slots=2)
    with pytest.raises(ValueError, match="KV capacity"):
        eng.submit(rng.integers(0, cfg.vocab_size, 40), max_new=4)
    # queue-with-reason path: infeasible deadline is rejected with a reason
    r = eng.submit(rng.integers(0, cfg.vocab_size, 8), max_new=10_000,
                   deadline_s=1e-3)
    assert r.done and r.truncated and "infeasible" in r.reject_reason


def test_scheduler_pool_pressure_preserves_edf_order():
    """Admission stops at the first head-of-line request the pool cannot
    cover (no queue-jumping past EDF order), and resumes once pages free."""
    pool = KVPagePool(num_pages=4, page_size=4, row_pages=4)
    sch = Scheduler(num_slots=4, spec_cap=1)
    big = sch.submit(np.arange(12), max_new=4, now=0.0)     # needs 4 pages
    small = sch.submit(np.arange(2), max_new=2, now=0.0)    # needs 1 page
    assert sch.admit(0.0, pool=pool) == [big]
    assert sch.admit(0.0, pool=pool) == []                  # small must wait
    pool.ensure(big.uid, 12)
    for t in range(4):
        sch.step_done(big.slot, 1, now=float(t))
    pool.release(big.uid)
    assert sch.admit(5.0, pool=pool) == [small]
    assert small.admitted_at == 5.0


def test_request_lifecycle_timestamps_and_summary(rng):
    cfg, params = params_for("starcoder2-3b")
    eng = ServingEngine(cfg, params, rt=Runtime(cache_len=32), num_slots=2)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, 5), max_new=4)
            for _ in range(3)]
    eng.run()
    for r in reqs:
        assert r.submitted_at <= r.admitted_at <= r.first_token_at
        assert r.first_token_at <= r.finished_at
        assert len(r.token_times) == len(r.output) == 4
        assert all(a <= b for a, b in zip(r.token_times, r.token_times[1:]))
    summ = eng.summary()
    for key in ("ttft_p50_ms", "ttft_p99_ms", "itl_p50_ms", "itl_p99_ms",
                "windows", "kv_pages_hwm"):
        assert key in summ
    assert summ["completed"] == 3
    assert summ["ttft_p99_ms"] >= summ["ttft_p50_ms"] >= 0.0


def test_warmup_precompiles_without_changing_outputs(rng):
    cfg, params = params_for("starcoder2-3b")
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 9)]
    eng = ServingEngine(cfg, params, rt=Runtime(cache_len=32), num_slots=2)
    assert eng.warmup(max_prompt_len=9) > 0
    reqs = [eng.submit(p, max_new=4) for p in prompts]
    eng.run()
    _, ref = _serve(cfg, params, prompts, num_slots=2, max_new=4)
    assert [r.output for r in reqs] == ref


# ===========================================================================
# asynchronous prefetch on the CB tick: shadow generations over the pool
# ===========================================================================
def test_cb_prefetch_matches_sync(rng):
    """The paged CB tick with prefetch=True (shadow-generation uploads under
    the in-flight window, boundary confirm/correct/flip at margin 0) emits
    bit-identical tokens to the synchronous-rotation engine on the same
    trace — prefetch-covered AND slot-starved f32 (host corrections are
    bitwise against device compute at f32)."""
    import dataclasses

    from repro.models import init_params

    cfg, _ = params_for("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    e = cfg.moe.num_experts
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 9, 7)]
    starved = None
    for slots in (e, 5):
        res = lambda: ResidencyConfig(mode="rotary", num_slots=slots)
        _, ref = _serve(cfg, params, prompts, num_slots=3, rescfg=res())
        eng, got = _serve(cfg, params, prompts, num_slots=3, rescfg=res(),
                          prefetch=True)
        assert got == ref, slots
        starved = eng
    # the starved engine really rotated through the shadow protocol: slot
    # uploads happened and the boundary accounting ran
    assert starved.stats.hits + starved.stats.misses > 0
    assert starved.stats.bytes_uploaded > 0


def test_serving_prefetch_flag_validation(rng):
    """Loud errors for serving combos with nothing to prefetch."""
    cfg, params = params_for("qwen2-moe-a2.7b")
    e = cfg.moe.num_experts
    rt = lambda: Runtime(cache_len=32)
    with pytest.raises(ValueError, match="rotating"):
        ServingEngine(cfg, params, rt=rt(), num_slots=2, prefetch=True)
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg, params, rt=rt(), num_slots=2, paged=False,
                      residency=ResidencyConfig(mode="rotary", num_slots=e),
                      prefetch=True)
    with pytest.raises(ValueError, match="reactive"):
        ServingEngine(cfg, params, rt=rt(), num_slots=2,
                      residency=ResidencyConfig(mode="lru", num_slots=e),
                      prefetch=True)
