"""Docs/tooling drift checks: the commands ROADMAP.md documents must exist in
the Makefile with the shapes it claims, the architecture map must exist and be
linked, and the examples must demonstrate the current engine flags — so the
docs surface cannot silently rot as hot paths evolve."""
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _read(rel: str) -> str:
    return (ROOT / rel).read_text()


def test_makefile_targets_match_roadmap():
    """Every make target ROADMAP documents exists; the tier-1 invocation in
    the Makefile is the one ROADMAP pins; ci includes the smokes ROADMAP
    promises."""
    roadmap = _read("ROADMAP.md")
    makefile = _read("Makefile")
    for target in ("tier1", "ci", "bench", "bench-decode",
                   "smoke-int4", "smoke-prefill", "smoke-serve-cb",
                   "smoke-prefetch", "smoke-trace", "smoke-sample"):
        assert f"make {target}" in roadmap or f"`{target}`" in roadmap, (
            f"ROADMAP no longer documents the `{target}` make target"
        )
        assert re.search(rf"^{target}:", makefile, re.M), (
            f"ROADMAP documents `make {target}` but the Makefile has no "
            f"such target"
        )
    # the tier-1 gate is the plain pytest invocation ROADMAP pins
    assert "python -m pytest -x -q" in roadmap
    assert "pytest -x -q" in makefile
    assert "tier1_delta.py" in makefile          # the delta print ROADMAP cites
    # ci = dev-deps + tier1 + both smokes, as ROADMAP claims
    ci_line = re.search(r"^ci:\s*(.+?)(?:\s*##|$)", makefile, re.M).group(1)
    for dep in ("dev-deps", "tier1", "smoke-int4", "smoke-prefill",
                "smoke-serve-cb", "smoke-prefetch", "smoke-trace",
                "smoke-sample"):
        assert dep in ci_line, (dep, ci_line)
    # bench-decode rows ROADMAP/benchmarks README describe are actually passed
    assert "--spec-k" in makefile and "--quantization" in makefile


def test_architecture_doc_exists_and_is_linked():
    assert (ROOT / "docs" / "ARCHITECTURE.md").exists()
    roadmap = _read("ROADMAP.md")
    assert "docs/ARCHITECTURE.md" in roadmap
    arch = _read("docs/ARCHITECTURE.md")
    # the load-bearing sections: residency model, dispatch table, exactness,
    # quantized link, serving tick
    for needle in ("SlotStore", "SlotLUT", "DemandPredictor", "dispatch",
                   "int4", "replay", "ServingEngine", "prefill",
                   "KVPagePool", "page table", "continuous batching",
                   "shadow generation", "prefetch", "flip", "relaunch",
                   "write-through",
                   # the observability section: tracks/lanes map, the
                   # span->machine mapping, and the auditor invariant list
                   "Tracer", "Perfetto", "auditor", "prefetch_ship",
                   "kv_use", "MetricsRegistry", "Prometheus",
                   "one launch", "trace-out",
                   # sampled speculative serving: PRNG protocol, the accept
                   # rule, and the distributional-exactness story
                   "stochastic_accept", "fold_in", "warp_probs",
                   "chi-squared", "min(1, q(t)/p(t))", "smoke-sample"):
        assert needle.lower() in arch.lower(), needle


def test_benchmarks_readme_documents_the_json():
    readme = _read("benchmarks/README.md")
    for needle in ("BENCH_decode.json", "mb_per_token", "0.30",
                   "ttft", "prefill_fused", "tier1",
                   "BENCH_serving.json", "serving_load", "goodput",
                   "ttft_p99", "arrival",
                   "fused_rotary_pf", "overlap_ms", "relaunched_steps",
                   "prefetch_wasted_bytes", "1.5x",
                   # tracing/metrics flags + the tracing-overhead row
                   "--trace-out", "--metrics-port", "trace_overhead_ratio",
                   "repro.obs", "3%",
                   # the sampled *_t row family and its gate
                   "spec4_rotary_hi_t", "accept_rate", "1.4x"):
        assert needle.lower() in readme.lower(), needle


def test_examples_show_current_flags():
    """The examples demonstrate the flags the engines actually take today."""
    quick = _read("examples/quickstart.py")
    serve = _read("examples/serve_rotary.py")
    for needle in ("prefill_chunk", "spec_k", "int4", "per_layer_table"):
        assert needle in quick, needle
    for needle in ("spec_cap", "bucketed_prefill", "int4",
                   "kv_page_size", "ttft_p50_ms", "per_layer_table"):
        assert needle in serve, needle
    # and those kwargs really exist on the engines (drift in the other
    # direction: examples naming parameters that were renamed away)
    import inspect

    from repro.core import RotaryEngine
    from repro.serving import ServingEngine

    rotary_params = inspect.signature(RotaryEngine.__init__).parameters
    for kw in ("prefill_chunk", "spec_k", "host_routing", "fused_decode",
               "prefetch", "trace"):
        assert kw in rotary_params, kw
    serving_params = inspect.signature(ServingEngine.__init__).parameters
    for kw in ("spec_cap", "bucketed_prefill", "residency",
               "paged", "kv_pages", "kv_page_size", "prefetch", "trace"):
        assert kw in serving_params, kw


def test_serve_cli_flags_exist():
    """The CLI flags the docs/Makefile reference parse (smoke the argparse
    wiring without running a model)."""
    serve_src = _read("src/repro/launch/serve.py")
    for flag in ("--prefill-chunk", "--spec-k", "--spec-cap",
                 "--quantization", "--quant-group",
                 "--arrival-rate", "--kv-pages", "--kv-page-size",
                 "--prefetch", "--trace-out", "--metrics-port",
                 "--temperature", "--top-k", "--top-p", "--sample-seed"):
        assert flag in serve_src, flag
    makefile = _read("Makefile")
    assert "--prefill-chunk" in makefile          # smoke-prefill really uses it
    assert "--quantization int4" in makefile      # smoke-int4 really uses it
    assert "--arrival-rate" in makefile           # smoke-serve-cb really uses it
    assert "--prefetch" in makefile               # smoke-prefetch really uses it
    assert "--trace-out" in makefile              # smoke-trace really uses it
    assert "--metrics-port" in makefile           # smoke-trace scrapes it
    assert "repro.obs" in makefile                # the auditor runs on the artifact
    assert "trace_view.py" in makefile            # the top-N span table prints
    assert "--temperature 0.8" in makefile        # smoke-sample really samples
    assert "--sample-seed" in makefile            # ... with a pinned seed
    assert "accept_rate" in makefile              # ... and asserts telemetry
