"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.slots import quantize_int8
from repro.kernels import ops, ref


@pytest.mark.parametrize("e,c,d,f,s", [(4, 8, 16, 32, 3), (6, 16, 32, 16, 6),
                                       (2, 4, 8, 8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_slot_gmm_sweep(rng, e, c, d, f, s, dtype):
    x = jnp.asarray(rng.standard_normal((e, c, d)), dtype)
    w = jnp.asarray(rng.standard_normal((s + 1, d, f)), dtype)
    w = w.at[-1].set(0.0)
    lut = jnp.asarray(rng.integers(0, s + 1, e), jnp.int32)
    out = ops.slot_gmm(x, w, lut, block_c=4, block_f=8, block_d=8)
    r = ref.slot_gmm_ref(x, w, lut)
    atol = 1e-4 if dtype == jnp.float32 else 0.1
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=atol)


def test_slot_gmm_int8(rng):
    e, c, d, f, s = 4, 8, 16, 24, 3
    x = jnp.asarray(rng.standard_normal((e, c, d)), jnp.float32)
    wf = rng.standard_normal((s + 1, d, f)).astype(np.float32)
    q = np.zeros((s + 1, d, f), np.int8)
    sc = np.zeros((s + 1, f), np.float32)
    for i in range(s):
        q[i], sc[i] = quantize_int8(wf[i])
    lut = jnp.asarray([0, 2, 1, 3], jnp.int32)
    out = ops.slot_gmm(x, jnp.asarray(q), lut, jnp.asarray(sc),
                       block_c=4, block_f=8, block_d=8)
    r = ref.slot_gmm_ref(x, jnp.asarray(q), lut, jnp.asarray(sc))
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=1e-4)


def test_moe_slot_ffn_matches_ref(rng):
    e, c, d, f, s = 4, 8, 16, 24, 5
    x = jnp.asarray(rng.standard_normal((e, c, d)), jnp.float32)
    slots = {
        "w_gate": jnp.asarray(rng.standard_normal((s + 1, d, f)), jnp.float32),
        "w_up": jnp.asarray(rng.standard_normal((s + 1, d, f)), jnp.float32),
        "w_down": jnp.asarray(rng.standard_normal((s + 1, f, d)), jnp.float32),
    }
    lut = jnp.asarray(rng.integers(0, s + 1, e), jnp.int32)
    out = ops.moe_slot_ffn(x, slots, lut, block_c=4, block_f=8, block_d=8)
    r = ref.moe_slot_ffn_ref(x, slots, lut)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r, np.float32),
                               atol=2e-4)


@pytest.mark.parametrize("sq,skv,h,hkv,dh", [(32, 32, 4, 2, 16), (64, 64, 2, 1, 8),
                                             (16, 48, 4, 4, 32)])
@pytest.mark.parametrize("kw", [dict(causal=True), dict(causal=False),
                                dict(causal=True, window=16),
                                dict(causal=True, soft_cap=15.0)])
def test_flash_attention_sweep(rng, sq, skv, h, hkv, dh, kw):
    q = jnp.asarray(rng.standard_normal((2, sq, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, skv, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, skv, hkv, dh)), jnp.float32)
    out = ops.flash_attention(q, k, v, block_q=16, block_kv=16, **kw)
    r = ref.flash_attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=2e-3)


def test_flash_attention_bf16(rng):
    q = jnp.asarray(rng.standard_normal((1, 32, 2, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 32, 2, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 32, 2, 16)), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, block_q=16, block_kv=16)
    r = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=5e-2)


@pytest.mark.parametrize("s,h,hkv,dh,bk", [(64, 4, 2, 16, 16), (128, 2, 1, 32, 32),
                                           (32, 8, 8, 8, 8)])
def test_decode_attention_sweep(rng, s, h, hkv, dh, bk):
    b = 3
    q = jnp.asarray(rng.standard_normal((b, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, dh)), jnp.float32)
    lengths = jnp.asarray(rng.integers(1, s + 1, b), jnp.int32)
    from repro.kernels.decode_attention import decode_attention

    out = decode_attention(q, k, v, lengths, block_kv=bk, interpret=True)
    r = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=2e-3)


@pytest.mark.parametrize("t,e,k", [(32, 8, 2), (64, 16, 4), (16, 128, 8)])
@pytest.mark.parametrize("normalize", [True, False])
def test_topk_gate_sweep(rng, t, e, k, normalize):
    logits = jnp.asarray(rng.standard_normal((t, e)), jnp.float32)
    ids, w = ops.topk_gate(logits, k, normalize=normalize)
    ri, rw = ref.topk_gate_ref(logits, k, normalize=normalize)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(w), np.asarray(rw), atol=1e-5)


def test_attention_model_path_uses_pallas(rng):
    """use_pallas=True wires the model's attention through the kernels and
    matches the jnp path."""
    from repro.config import AttentionConfig, ShardingConfig
    from repro.models import attention as A
    from repro.models.transformer import Runtime

    acfg = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16)
    p = A.init_attention(jax.random.PRNGKey(0), 64, acfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 64, 64)), jnp.float32)
    y_ref = A.attention_train(p, acfg, x, q_chunk=16, kv_chunk=16)
    y_pal = A.attention_train(p, acfg, x, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref), atol=2e-3)


def test_routing_parity_on_ties(rng):
    """Host routing, the Pallas topk_gate, the lax.top_k fallback, and the
    model's topk_route must pick IDENTICAL experts on tied logits (lowest
    index wins) — residency accounting depends on the three agreeing."""
    from repro.config import MoEConfig
    from repro.core.predictor import host_topk_route
    from repro.kernels.topk_gate import route_topk
    from repro.models import moe as M

    t, e, k = 8, 16, 4
    logits = rng.standard_normal((t, e)).astype(np.float32)
    # manufacture exact ties, including a fully-constant row
    logits[:, 3] = logits[:, 7]
    logits[:, 11] = logits[:, 7]
    logits[0, :] = 0.5
    logits[5, :4] = logits[5, 4:8]
    lg = jnp.asarray(logits)

    ids_host, w_host = host_topk_route(logits, k)
    ids_auto, w_auto = route_topk(lg, k)                       # lax.top_k on CPU
    ids_pal, w_pal = ops.topk_gate(lg, k)                      # Pallas (interpret)
    ids_model, w_model, _ = M.topk_route(
        lg, MoEConfig(num_experts=e, top_k=k, expert_d_ff=8)
    )

    np.testing.assert_array_equal(ids_host, np.asarray(ids_auto))
    np.testing.assert_array_equal(ids_host, np.asarray(ids_pal))
    np.testing.assert_array_equal(ids_host, np.asarray(ids_model))
    np.testing.assert_allclose(w_host, np.asarray(w_auto), atol=1e-6)
    np.testing.assert_allclose(w_host, np.asarray(w_pal), atol=1e-6)
