"""Persistent stacked residency planes (the fused whole-stack step's gather
source): incremental dirty-slot patching — including the unquantized
write-through fast path — must be BITWISE identical to re-stacking the
per-layer residency from scratch, under every slot format, and the whole
surface must stay keyed on the manager's ONE shared generation counter."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ResidencyConfig, get_config
from repro.configs import reduce_for_smoke
from repro.core import RotaryResidencyManager

jax.config.update("jax_platform_name", "cpu")


def _mgr(slots=5, quant=None):
    cfg = reduce_for_smoke(get_config("qwen36-35b-a3b"))
    rng = np.random.default_rng(0)
    m = cfg.moe
    hw = [
        {
            "w_gate": rng.standard_normal(
                (m.num_experts, cfg.d_model, m.expert_d_ff)).astype(np.float32),
            "w_up": rng.standard_normal(
                (m.num_experts, cfg.d_model, m.expert_d_ff)).astype(np.float32),
            "w_down": rng.standard_normal(
                (m.num_experts, m.expert_d_ff, cfg.d_model)).astype(np.float32),
        }
        for _ in range(cfg.num_layers)
    ]
    rescfg = ResidencyConfig(mode="rotary", num_slots=slots, quantization=quant)
    return cfg, RotaryResidencyManager(cfg, rescfg, hw, batch=1, cache_len=64)


def _restack(cfg, mgr):
    """Ground truth: stack the per-layer residency from scratch."""
    segs, li = [], 0
    for seg, (unit, reps) in zip(mgr.stacked_residency(), cfg.segments):
        if not seg:
            segs.append({})
            continue
        per = [mgr.layer_residency(li + r) for r in range(reps)]
        segs.append({
            "slots": {n: jnp.stack([p["slots"][n] for p in per])
                      for n in per[0]["slots"]},
            "lut": jnp.stack([p["lut"] for p in per]),
        })
        li += reps
    return segs


@pytest.mark.parametrize("quant", [None, "int8", "int4"])
def test_stacked_incremental_equals_restack(quant):
    """Rotate several boundaries, patching the persistent planes
    incrementally each time; the result matches a from-scratch re-stack
    byte for byte — so the fused step may gather from long-lived donated
    planes at a handful of row scatters per boundary."""
    cfg, mgr = _mgr(quant=quant)
    e = cfg.moe.num_experts
    rng = np.random.default_rng(7)
    mgr.stacked_residency()                    # build the persistent planes
    gen0 = mgr.generation
    for _ in range(4):
        for l in range(len(mgr.policies)):
            mgr.prepare_layer(l, rng.random(e))
        mgr.stacked_residency()                # incremental patch path
    assert mgr.generation > gen0               # rotations actually happened
    got = mgr.stacked_residency()
    for seg, want in zip(got, _restack(cfg, mgr)):
        assert bool(seg) == bool(want)
        if not seg:
            continue
        for n in want["slots"]:
            np.testing.assert_array_equal(
                np.asarray(seg["slots"][n]), np.asarray(want["slots"][n]),
                err_msg=f"{quant} {n}",
            )
        np.testing.assert_array_equal(
            np.asarray(seg["lut"]), np.asarray(want["lut"]), err_msg=str(quant)
        )


def test_stacked_generation_cache():
    """ONE generation counter keys the planes: an unchanged manager returns
    the cached planes with zero new dispatches, slot uploads bump the shared
    counter, and the planes stay the same PERSISTENT tuple throughout —
    patched in place, never re-stacked."""
    cfg, mgr = _mgr()
    e = cfg.moe.num_experts
    a = mgr.stacked_residency()
    d0 = mgr.stats.device_dispatches
    assert mgr.stacked_residency() is a        # cache hit
    assert mgr.stats.device_dispatches == d0   # ... costs nothing
    rng = np.random.default_rng(3)
    g0 = mgr.generation
    for _ in range(6):
        for l in range(len(mgr.policies)):
            mgr.prepare_layer(l, rng.random(e))
    assert mgr.generation > g0                 # uploads bumped the one counter
    assert mgr.stacked_residency() is a        # persistent, patched in place
