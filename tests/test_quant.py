"""Grouped int4 quantization subsystem (repro.quant): tier-1 coverage.

Pack/unpack round-trip, batch-vs-single bit-equality (the upload path's
invariant), memoized lazy dequant, the in-kernel Pallas dequant path
(interpret mode on this host), link-bytes accounting, and end-to-end
exactness: int4 decode is exactness-clean WITHIN its format — greedy tokens
bit-identical across full residency, slot-starved rotary, and rotary+spec-K.

These are the tier-1 mirrors of the hypothesis properties in
``test_quant_properties.py`` (which skips without the dev deps).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import params_for
from repro.config import ResidencyConfig
from repro.core import RotaryEngine
from repro.core.residency import check_feasibility
from repro.core.slots import SlotStore, fake_quantized_batch, quantized_expert_bytes
from repro.models import init_params
from repro.models.transformer import Runtime
from repro.quant import (
    dequantize_int4,
    effective_group,
    int4_tensor_bytes,
    quantize_int4,
    quantize_int4_batch,
    unpack_int4,
)


# ===========================================================================
# pack / unpack / dequant
# ===========================================================================
def test_int4_roundtrip_error_bounded_by_group_scale(rng):
    """|dequant(quant(w)) - w| <= the group's scale step, everywhere."""
    for d, f, g in ((64, 48, 64), (48, 64, 64), (16, 8, 4), (6, 10, 64)):
        w = (rng.standard_normal((d, f)) * 3).astype(np.float32)
        packed, scale, mn = quantize_int4(w, g)
        back = np.asarray(
            dequantize_int4(jnp.asarray(packed), jnp.asarray(scale), jnp.asarray(mn))
        )
        step = np.repeat(scale.astype(np.float32), effective_group(d, g), axis=-2)
        assert (np.abs(back - w) <= step + 1e-6).all(), (d, f, g)


def test_int4_unpack_inverts_packing(rng):
    q = rng.integers(0, 16, (3, 12, 5)).astype(np.uint8)
    packed = (q[:, 0::2, :] | (q[:, 1::2, :] << 4)).astype(np.uint8)
    np.testing.assert_array_equal(np.asarray(unpack_int4(jnp.asarray(packed))), q)


def test_int4_batch_bit_equal_to_single(rng):
    """Quantizing N experts stacked must produce byte-identical packed
    buffers / scales / mins to quantizing each alone — the batched rotation
    upload relies on this (mirrors the int8 property)."""
    w = rng.standard_normal((5, 16, 12)).astype(np.float32)
    pb, sb, mb = quantize_int4_batch(w, 8)
    for i in range(5):
        p1, s1, m1 = quantize_int4(w[i], 8)
        np.testing.assert_array_equal(pb[i], p1)
        np.testing.assert_array_equal(sb[i], s1)
        np.testing.assert_array_equal(mb[i], m1)


def test_effective_group_clamps_to_axis():
    assert effective_group(2048, 64) == 64
    assert effective_group(48, 64) == 48
    assert effective_group(10, 4) == 2          # 4 doesn't divide 10
    with pytest.raises(AssertionError):
        effective_group(7, 4)                   # odd rows can't pack


# ===========================================================================
# SlotStore int4: bytes, memoized dequant, batched scatters
# ===========================================================================
def _shapes():
    return {"w_gate": (64, 48), "w_up": (64, 48), "w_down": (48, 64)}


def test_int4_store_bytes_le_030x_f16():
    """The acceptance ratio: packed nibbles + f16 group scale/min planes move
    <= 0.30x the bytes of an f16 slot per rotated expert."""
    shapes = _shapes()
    q4 = SlotStore(4, shapes, jnp.bfloat16, quantization="int4")
    fp = SlotStore(4, shapes, jnp.bfloat16)
    ratio = q4.bytes_per_expert / fp.bytes_per_expert
    assert ratio <= 0.30, ratio
    # analytic helper agrees with the store's real buffers
    assert q4.bytes_per_expert == sum(int4_tensor_bytes(s, 64) for s in shapes.values())
    assert quantized_expert_bytes(shapes, "int4", 2, 64) == q4.bytes_per_expert


def test_int4_write_batch_one_scatter_per_plane(rng):
    """A rotation moving N experts costs ONE fused scatter dispatch for all
    tensor planes together (packed + scale + min of every weight tensor),
    never one per expert or per plane."""
    store = SlotStore(4, _shapes(), jnp.float32, quantization="int4")
    w = {n: rng.standard_normal((3,) + s).astype(np.float32)
         for n, s in _shapes().items()}
    moved = store.write_batch([0, 1, 2], w)
    assert store.dispatches == 1
    assert moved == 3 * store.bytes_per_expert
    assert store.bytes_uploaded == moved


def test_int4_store_roundtrip_matches_host_dequant(rng):
    """What as_pytree returns for a written slot is exactly the host-side
    dequant of the quantized expert (the exactness contract)."""
    store = SlotStore(3, _shapes(), jnp.float32, quantization="int4")
    w = {n: rng.standard_normal((2,) + s).astype(np.float32)
         for n, s in _shapes().items()}
    store.write_batch([0, 2], w)
    tree = store.as_pytree()
    for n in _shapes():
        want = fake_quantized_batch(w[n], "int4", jnp.float32)
        np.testing.assert_array_equal(np.asarray(tree[n][0]), want[0])
        np.testing.assert_array_equal(np.asarray(tree[n][2]), want[1])
    raw = store.raw_pytree()
    assert {"min_w_gate", "scale_w_gate"} <= set(raw)


@pytest.mark.parametrize("quant", ["int8", "int4"])
def test_lazy_dequant_memoized_per_write_generation(rng, quant):
    """as_pytree dequantizes ONCE per write generation: repeated calls hit
    the cache, any write invalidates it."""
    store = SlotStore(4, _shapes(), jnp.float32, quantization=quant)
    w = {n: rng.standard_normal((1,) + s).astype(np.float32)
         for n, s in _shapes().items()}
    store.write_batch([0], w)
    t1 = store.as_pytree()
    for _ in range(3):
        assert store.as_pytree() is t1
    assert store.dequant_runs == 1
    store.write_batch([1], w)
    t2 = store.as_pytree()
    assert t2 is not t1
    assert store.as_pytree() is t2
    assert store.dequant_runs == 2


# ===========================================================================
# Pallas moe_gmm int4 path (interpret mode on this host)
# ===========================================================================
def test_slot_gmm_int4_matches_ref(rng):
    from repro.kernels import ops, ref

    e, c, d, f, s = 4, 8, 16, 24, 3
    x = jnp.asarray(rng.standard_normal((e, c, d)), jnp.float32)
    wf = rng.standard_normal((s + 1, d, f)).astype(np.float32)
    wf[-1] = 0.0
    packed, scale, mn = quantize_int4(wf, 8)
    lut = jnp.asarray([0, 2, 1, 3], jnp.int32)
    out = ops.slot_gmm(x, jnp.asarray(packed), lut, jnp.asarray(scale),
                       jnp.asarray(mn), block_c=4, block_f=8, block_d=8)
    r = ref.slot_gmm_ref(x, jnp.asarray(packed), lut, jnp.asarray(scale),
                         jnp.asarray(mn))
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=1e-4)


def test_moe_slot_ffn_int4_matches_ref(rng):
    from repro.kernels import ops, ref

    e, c, d, f, s = 4, 8, 16, 24, 5
    x = jnp.asarray(rng.standard_normal((e, c, d)), jnp.float32)
    slots = {}
    for name, shape in (("w_gate", (d, f)), ("w_up", (d, f)), ("w_down", (f, d))):
        wq = rng.standard_normal((s + 1,) + shape).astype(np.float32)
        p, sc, mn = quantize_int4(wq, 8)
        slots[name] = jnp.asarray(p)
        slots[f"scale_{name}"] = jnp.asarray(sc)
        slots[f"min_{name}"] = jnp.asarray(mn)
    lut = jnp.asarray(rng.integers(0, s + 1, e), jnp.int32)
    out = ops.moe_slot_ffn(x, slots, lut, block_c=4, block_f=8, block_d=8)
    r = ref.moe_slot_ffn_ref(x, slots, lut)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r), atol=2e-4)


# ===========================================================================
# end-to-end: int4 decode exactness + link accounting
# ===========================================================================
def _f32(arch="qwen2-moe-a2.7b"):
    cfg, _ = params_for(arch)
    cfg = dataclasses.replace(cfg, dtype="float32")
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _engine(cfg, params, mode, slots, **kw):
    return RotaryEngine(
        cfg, params,
        ResidencyConfig(mode=mode, num_slots=slots, prefetch_margin=2,
                        quantization="int4"),
        rt=Runtime(cache_len=64), batch=2, **kw,
    )


def test_int4_decode_exact_across_residency_modes(rng):
    """ACCEPTANCE: greedy tokens bit-identical between full residency,
    prefetch-covered rotary, slot-starved rotary (misses host-corrected
    against the dequantized weights), and rotary+spec-4 — all under
    quantization='int4'."""
    cfg, params = _f32()
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    T = 10
    full = _engine(cfg, params, "full", 0)
    ref_toks = full.generate(prompt, T)
    covered = _engine(cfg, params, "rotary", cfg.moe.num_experts)
    np.testing.assert_array_equal(ref_toks, covered.generate(prompt, T))
    starved = _engine(cfg, params, "rotary", 5)
    np.testing.assert_array_equal(ref_toks, starved.generate(prompt, T))
    assert starved.stats.misses > 0          # quantized replay was exercised
    spec = _engine(cfg, params, "rotary", 5, spec_k=4)
    np.testing.assert_array_equal(ref_toks, spec.generate(prompt, T))
    assert spec.stats.replayed_steps > 0
    # every counted miss host-corrected (against dequantized weights)
    for eng in (starved, spec):
        s = eng.stats
        assert sum(l.host_computed for l in s.layers.values()) == s.misses


def test_int4_engine_shrinks_link_bytes(rng):
    """Same rotation workload, ~4x fewer bytes on the link: the int4 engine's
    per-expert upload is <= 0.30x the f16 cost, and bytes_uploaded threads
    through to EngineStats / summary()."""
    cfg, params = _f32()
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    eng = _engine(cfg, params, "rotary", 5)
    eng.generate(prompt, 6)
    store = eng.manager.stores[0]
    f16_bytes = quantized_expert_bytes(
        {n: w.shape[1:] for n, w in eng.host_experts[0].items()}, None, dtype_bytes=2
    )
    assert store.bytes_per_expert / f16_bytes <= 0.30
    assert eng.stats.bytes_uploaded > 0
    assert eng.stats.bytes_uploaded == sum(
        st.bytes_uploaded for st in eng.manager.stores
    )
    assert "bytes_uploaded_MB" in eng.stats.summary()


def test_int4_feasibility_uses_packed_bytes():
    """check_feasibility prices slots at packed bytes: int4 < int8 < f16."""
    cfg, _ = params_for("qwen36-35b-a3b")
    reports = {
        q: check_feasibility(
            cfg, ResidencyConfig(mode="rotary", num_slots=6, quantization=q),
            batch=1, cache_len=64,
        )
        for q in (None, "int8", "int4")
    }
    assert reports["int4"].slot_bytes < reports["int8"].slot_bytes
    assert reports["int8"].slot_bytes < reports[None].slot_bytes
    assert reports["int4"].slot_bytes <= 0.30 * reports[None].slot_bytes


def test_serve_quantization_cli_mapping():
    """The CLI spells the default as 'none' (choices=[None, ...] made it
    impossible to type) and maps it back to ResidencyConfig's None."""
    from repro.launch.serve import QUANT_CHOICES

    assert QUANT_CHOICES == {"none": None, "int8": "int8", "int4": "int4"}
    for spelling, value in QUANT_CHOICES.items():
        ResidencyConfig(mode="rotary", num_slots=6, quantization=value)
    with pytest.raises(ValueError):
        ResidencyConfig(mode="rotary", num_slots=6, quantization="fp4")
