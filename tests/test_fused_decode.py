"""Fused whole-stack decode: exactness vs the seed walk, replay under forced
misses, O(1) dispatches per miss-free token, batched slot uploads, LUT patch
regression, ring-delta seam, prefill-rate admission EMA.

Chunked prefill hot path (PR 5): fused-chunk logits and post-prefill KV
bit-identical to the chunked layer walk across residency modes and slot
formats, dispatch bounds (one whole-stack launch + one queue-draining pull
per chunk), power-of-two chunk plans, and bucketed serving admission matching
the batch-1 splice-in path row for row."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import params_for
from repro.config import ResidencyConfig
from repro.core import RotaryEngine, SlotStore
from repro.core.rotation import RotaryRing
from repro.models import init_params
from repro.models.transformer import Runtime
from repro.serving.scheduler import Scheduler


def _f32_setup():
    cfg, _ = params_for("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, mode, slots, **kw):
    return RotaryEngine(
        cfg, params, ResidencyConfig(mode=mode, num_slots=slots, prefetch_margin=2),
        rt=Runtime(cache_len=64), batch=2, **kw,
    )


def test_fused_matches_host_routing_with_forced_misses(rng):
    """Greedy tokens bit-identical to the seed-style per-layer baseline under
    every residency mode, INCLUDING a slot-starved rotary engine whose misses
    force the suffix replay, and LRU (which decodes via the sync walk)."""
    cfg, params = _f32_setup()
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    outs, engines = {}, {}
    for mode, slots in (("full", 0), ("rotary", 5), ("lru", 5), ("static", 5)):
        base = _engine(cfg, params, mode, slots, host_routing=True)
        eng = _engine(cfg, params, mode, slots)
        outs[mode] = (base.generate(prompt, 10), eng.generate(prompt, 10))
        engines[mode] = eng
    for mode, (ref, got) in outs.items():
        np.testing.assert_array_equal(ref, got, err_msg=mode)
    # the fused path actually ran where it should, and replay was exercised
    assert engines["full"]._fused_decode and engines["rotary"]._fused_decode
    assert not engines["lru"]._fused_decode
    assert engines["rotary"].stats.replayed_steps > 0
    assert engines["rotary"].stats.misses > 0
    # every counted miss was host-corrected (mechanism parity with the walk)
    s = engines["rotary"].stats
    assert sum(l.host_computed for l in s.layers.values()) == s.misses


def test_fused_one_pull_and_one_dispatch_per_token(rng):
    """Miss-free fused decode: exactly ONE queue-draining device->host pull
    AND one compiled-program launch per token — O(1), not O(layers). The
    per-layer hot path issues >= 2 launches per MoE layer per token."""
    cfg, params = _f32_setup()
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    steps = 6

    fused = _engine(cfg, params, "full", 0)
    logits = fused.prefill(prompt)
    pulls0, disp0 = fused.stats.sync_pulls, fused.stats.device_dispatches
    fused.decode(logits, steps)
    assert fused.stats.sync_pulls - pulls0 == steps
    assert fused.stats.device_dispatches - disp0 == steps
    assert fused.stats.misses == 0

    layer = _engine(cfg, params, "full", 0, fused_decode=False)
    logits = layer.prefill(prompt)
    disp0 = layer.stats.device_dispatches
    layer.decode(logits, steps)
    assert layer.stats.device_dispatches - disp0 >= 2 * cfg.num_layers * steps


def test_fused_decode_flag_validation():
    cfg, params = _f32_setup()
    with pytest.raises(AssertionError):
        _engine(cfg, params, "lru", 5, fused_decode=True)
    with pytest.raises(AssertionError):
        _engine(cfg, params, "rotary", 5, host_routing=True, fused_decode=True)


def test_lut_patch_at_most_one_dispatch_per_layer_per_step(rng):
    """Regression (perf): steady-state rotation issues AT MOST one LUT patch
    dispatch per MoE layer per decode step — the persistent device LUT is
    patched incrementally, never re-uploaded per layer."""
    cfg, params = _f32_setup()
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    eng = _engine(cfg, params, "rotary", 5)
    logits = eng.prefill(prompt)
    patches0 = eng.stats.lut_patch_dispatches
    steps = 8
    eng.decode(logits, steps)
    # replayed steps re-read the (clean) LUT and must not add patches
    assert eng.stats.lut_patch_dispatches - patches0 <= cfg.num_layers * steps


def test_write_batch_matches_per_expert_writes():
    """One fused scatter per write_batch == N per-expert writes, bit-for-bit,
    with ONE dispatch for every tensor together (and donation-safe)."""
    rng = np.random.default_rng(0)
    shapes = {"w_up": (8, 12), "w_down": (12, 8)}
    experts = [rng.standard_normal((8, 12)).astype(np.float32) for _ in range(3)]
    downs = [rng.standard_normal((12, 8)).astype(np.float32) for _ in range(3)]

    one = SlotStore(4, shapes, jnp.float32)
    for i, slot in enumerate((0, 2, 3)):
        one.write(slot, {"w_up": experts[i], "w_down": downs[i]})

    bat = SlotStore(4, shapes, jnp.float32)
    d0 = bat.dispatches
    moved = bat.write_batch(
        [0, 2, 3],
        {"w_up": np.stack(experts), "w_down": np.stack(downs)},
        donate=True,
    )
    assert bat.dispatches - d0 == 1          # one fused scatter for ALL tensors
    assert moved == 3 * (8 * 12 + 12 * 8) * 4
    for name in shapes:
        np.testing.assert_array_equal(
            np.asarray(one.buffers[name]), np.asarray(bat.buffers[name])
        )


def test_write_batch_int8_matches_single_quantization():
    rng = np.random.default_rng(1)
    shapes = {"w_up": (6, 10)}
    ws = [rng.standard_normal((6, 10)).astype(np.float32) for _ in range(2)]
    one = SlotStore(3, shapes, jnp.bfloat16, quantization="int8")
    for i, slot in enumerate((1, 2)):
        one.write(slot, {"w_up": ws[i]})
    bat = SlotStore(3, shapes, jnp.bfloat16, quantization="int8")
    bat.write_batch([1, 2], {"w_up": np.stack(ws)})
    np.testing.assert_array_equal(
        np.asarray(one.buffers["w_up"]), np.asarray(bat.buffers["w_up"])
    )
    np.testing.assert_array_equal(
        np.asarray(one.scales["w_up"]), np.asarray(bat.scales["w_up"])
    )


def test_ring_delta_seam_minimal_signed():
    """Tier-1 mirror of the hypothesis seam property (satellite fix): the
    cyclical-return delta wraps at the ring seam instead of reporting E-1."""
    e = 12
    assert RotaryRing._ring_delta(0, e - 1, e) == -1
    assert RotaryRing._ring_delta(e - 1, 0, e) == 1
    for src in range(e):
        for dst in range(e):
            d = RotaryRing._ring_delta(src, dst, e)
            assert (src + d) % e == dst
            assert abs(d) <= e // 2


def test_scheduler_prefill_rate_ema():
    """Admission no longer hard-codes prefill at 4x decode rate: the engine's
    measured prefill tok/s feedback moves the estimate (and the decision)."""
    from repro.serving.scheduler import Scheduler

    sch = Scheduler(2, est_tok_s=10.0)
    assert sch.est_prefill_tok_s == 40.0          # cold-start prior only
    # long prompt, tight deadline: rejected under the cold-start estimate
    r = sch.submit(np.zeros(400, np.int32), max_new=1, now=0.0, deadline_s=5.0)
    assert r.truncated and r.done
    sch.observe_prefill_rate(1000.0)
    sch.observe_prefill_rate(1000.0)
    assert sch.est_prefill_tok_s > 200.0
    r2 = sch.submit(np.zeros(400, np.int32), max_new=1, now=0.0, deadline_s=5.0)
    assert not r2.truncated                       # now admissible


# ===========================================================================
# chunked prefill hot path
# ===========================================================================
def _stacked_kv(eng):
    """Engine decode state as one stacked pytree, whichever layout it keeps."""
    if getattr(eng, "_dstate", None) is not None:
        return eng._dstate
    return eng._stack_state(eng.state)


def _chunk_engines(cfg, params, mode, slots, quant=None, chunk=8):
    def mk(**kw):
        return RotaryEngine(
            cfg, params,
            ResidencyConfig(mode=mode, num_slots=slots, prefetch_margin=2,
                            quantization=quant),
            rt=Runtime(cache_len=64), batch=2, **kw,
        )

    return mk(prefill_chunk=chunk), mk(prefill_chunk=chunk, fused_decode=False)


def test_chunked_prefill_exactness(rng):
    """The tentpole invariant: fused chunked prefill (ONE launch per chunk)
    produces logits AND post-prefill KV bit-identical to the chunked layer
    walk, across full / prefetch-covered rotary / slot-starved rotary (the
    starved case forces per-chunk suffix replay), and the greedy continuation
    matches the legacy full-sequence prefill token for token."""
    cfg, params = _f32_setup()
    prompt = rng.integers(0, 200, (2, 21)).astype(np.int32)   # plan [8,8,4,1]
    for mode, slots in (("full", 0), ("rotary", 8), ("rotary", 5)):
        fused, walk = _chunk_engines(cfg, params, mode, slots)
        lg_f = fused.prefill(prompt)
        lg_w = walk.prefill(prompt)
        np.testing.assert_array_equal(lg_f, lg_w, err_msg=f"{mode}/{slots}")
        for a, b in zip(
            jax.tree.leaves(_stacked_kv(fused)), jax.tree.leaves(_stacked_kv(walk))
        ):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"KV {mode}/{slots}"
            )
        legacy = RotaryEngine(
            cfg, params,
            ResidencyConfig(mode=mode, num_slots=slots, prefetch_margin=2),
            rt=Runtime(cache_len=64), batch=2,
        )
        o_legacy = legacy.generate(prompt, 8)
        np.testing.assert_array_equal(o_legacy, fused.decode(lg_f, 8))
        np.testing.assert_array_equal(o_legacy, walk.decode(lg_w, 8))
        if (mode, slots) == ("rotary", 5):
            # the starved case actually exercised the chunk replay machinery
            assert fused.stats.prefill_replays > 0
            assert fused.stats.misses > 0


@pytest.mark.parametrize("quant", ["int8", "int4"])
def test_chunked_prefill_exactness_quantized(rng, quant):
    """Same bit-identity on quantized slot stores, in the slot-starved regime
    whose misses replay against the dequantized weights (and the covered
    regime as a miss-free control)."""
    cfg, params = _f32_setup()
    prompt = rng.integers(0, 200, (2, 13)).astype(np.int32)
    for mode, slots in (("rotary", 8), ("rotary", 5)):
        fused, walk = _chunk_engines(cfg, params, mode, slots, quant=quant)
        lg_f = fused.prefill(prompt)
        lg_w = walk.prefill(prompt)
        np.testing.assert_array_equal(lg_f, lg_w, err_msg=f"{quant}/{slots}")
        for a, b in zip(
            jax.tree.leaves(_stacked_kv(fused)), jax.tree.leaves(_stacked_kv(walk))
        ):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"KV {quant}/{slots}"
            )
        np.testing.assert_array_equal(fused.decode(lg_f, 6), walk.decode(lg_w, 6))
    assert fused.stats.prefill_replays > 0          # starved case replayed


def test_chunked_prefill_dispatch_counts(rng):
    """Miss-free fused chunked prefill: exactly ONE whole-stack launch and
    ONE queue-draining pull per chunk, zero replays."""
    from repro.core.engine import prefill_chunk_plan

    cfg, params = _f32_setup()
    prompt = rng.integers(0, 200, (2, 21)).astype(np.int32)
    eng = _engine(cfg, params, "full", 0, prefill_chunk=8)
    pulls0 = eng.stats.sync_pulls
    eng.prefill(prompt)
    n = len(prefill_chunk_plan(21, 8))
    assert eng.stats.prefill_chunks == n
    assert eng.stats.sync_pulls - pulls0 == n
    assert eng.stats.prefill_replays == 0
    assert eng.stats.misses == 0


def test_prefill_chunk_plan():
    """Chunk plans are power-of-two lengths summing to the prompt, with the
    steady-state chunk repeated and a descending power-of-two tail (bounded
    compile cache)."""
    from repro.core.engine import prefill_chunk_plan

    assert prefill_chunk_plan(21, 8) == [8, 8, 4, 1]
    assert prefill_chunk_plan(64, 16) == [16, 16, 16, 16]
    assert prefill_chunk_plan(1, 64) == [1]
    for s in (1, 7, 16, 21, 100, 257):
        for c in (1, 4, 32):
            plan = prefill_chunk_plan(s, c)
            assert sum(plan) == s
            assert all(p & (p - 1) == 0 for p in plan)
            assert all(p <= c for p in plan)
    with pytest.raises(AssertionError):
        prefill_chunk_plan(8, 6)                    # chunk not a power of two


def test_chunked_prefill_flag_validation():
    """KV-only window-free stacks enable both chunked paths; a non-power-of-
    two chunk length is rejected up front."""
    cfg, params = _f32_setup()
    eng = _engine(cfg, params, "full", 0, prefill_chunk=8)
    assert eng._chunk_prefill_ok and eng._chunk_prefill_fused_ok
    with pytest.raises(AssertionError):
        _engine(cfg, params, "full", 0, prefill_chunk=6)   # not a power of two


def test_bucketed_admission_matches_batch1(rng):
    """The serving tentpole: admission through the shared compiled bucketed
    program (rows padded to the engine batch, spliced with the ragged
    machinery) emits the same per-request outputs as the batch-1 splice-in
    path — dense arch and rotary-residency MoE arch alike."""
    from repro.serving import ServingEngine

    for arch, res in (
        ("starcoder2-3b", None),
        ("qwen2-moe-a2.7b", ResidencyConfig(mode="rotary", num_slots=5)),
    ):
        cfg, params = params_for(arch)
        prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
                   for n in (5, 9, 12)]
        outs = {}
        for bucketed in (False, True):
            eng = ServingEngine(
                cfg, params, rt=Runtime(cache_len=64), num_slots=2,
                residency=res, bucketed_prefill=bucketed,
            )
            reqs = [eng.submit(p, max_new=5) for p in prompts]
            eng.run()
            outs[bucketed] = [r.output for r in reqs]
        assert outs[True] == outs[False], arch


def test_scheduler_prefill_bucket():
    """The scheduler owns the admission bucket: power-of-two cover of the
    longest admitted prompt, floored at 16 and clamped to the cache (over-
    capacity prompts never reach bucketing — submit rejects them)."""
    assert Scheduler.prefill_bucket([5], 256) == 16
    assert Scheduler.prefill_bucket([5, 17], 256) == 32
    assert Scheduler.prefill_bucket([64], 256) == 64
    assert Scheduler.prefill_bucket([1], 256) == 16
    # a prompt longer than the cache is rejected at submit time instead of
    # crashing mid-tick on the clamped bucket
    sch = Scheduler(2, max_prompt_len=64)
    r = sch.submit(np.zeros(65, np.int32), max_new=1, now=0.0)
    assert r.done and r.truncated and r in sch.rejected
    r2 = sch.submit(np.zeros(64, np.int32), max_new=1, now=0.0)
    assert not r2.done


def test_serving_feeds_prefill_rate(rng):
    """ServingEngine reports measured prefill rates to the scheduler — but
    only steady-state samples: a cold bucket's compile time must not poison
    the admission EMA."""
    from repro.serving import ServingEngine

    cfg, params = params_for("qwen2-moe-a2.7b")
    eng = ServingEngine(
        cfg, params, rt=Runtime(cache_len=32), num_slots=1,
        residency=ResidencyConfig(mode="rotary", num_slots=5),
    )
    default = eng.scheduler.est_prefill_tok_s
    # same prompt length -> same bucket: first prefill compiles (no sample)
    eng.submit(rng.integers(0, cfg.vocab_size, 6), max_new=2)
    eng.run()
    after_cold = eng.scheduler.est_prefill_tok_s
    assert after_cold == default
    eng.submit(rng.integers(0, cfg.vocab_size, 6), max_new=2)
    eng.run()
    assert eng.scheduler.est_prefill_tok_s != after_cold


# ===========================================================================
# asynchronous predictive prefetch: double-buffered slot generations
# ===========================================================================
def test_prefetch_flag_validation():
    """prefetch=True fails LOUDLY on combos with no in-flight launch to hide
    shadow uploads under, instead of silently running synchronous."""
    cfg, params = _f32_setup()
    with pytest.raises(ValueError, match="host_routing"):
        _engine(cfg, params, "rotary", 5, host_routing=True, prefetch=True)
    with pytest.raises(ValueError, match="fused"):
        _engine(cfg, params, "rotary", 5, fused_decode=False, prefetch=True)
    with pytest.raises(ValueError, match="fused"):
        _engine(cfg, params, "lru", 5, prefetch=True)


@pytest.mark.parametrize("mode,slots,quant,spec_k", [
    ("rotary", 5, None, 1),        # slot-starved: misses relaunch/replay
    ("rotary", 8, None, 1),        # prefetch-covered (all experts fit)
    ("full", 0, None, 1),          # never rotates: flag accepted, no shadow
    ("rotary", 5, None, 4),        # speculative windows over the flip
    ("rotary", 5, "int4", 1),      # grouped-int4 shadow planes
])
def test_prefetch_tokens_identical_to_sync(rng, mode, slots, quant, spec_k):
    """Greedy tokens with prefetch=True (shadow-generation uploads during the
    in-flight launch, boundary confirm/correct/flip, compiled-step miss
    relaunch) are bit-identical to the synchronous-rotation engine — across
    residency regimes, spec windows, and the int4 slot format."""
    cfg, params = _f32_setup()
    res = lambda: ResidencyConfig(mode=mode, num_slots=slots,
                                  quantization=quant)
    prompt = rng.integers(0, 200, (2, 7)).astype(np.int32)
    kw = dict(rt=Runtime(cache_len=64), batch=2, spec_k=spec_k)
    ref = RotaryEngine(cfg, params, res(), **kw).generate(prompt, 9)
    eng = RotaryEngine(cfg, params, res(), prefetch=True, **kw)
    np.testing.assert_array_equal(ref, eng.generate(prompt, 9))
    if mode == "rotary" and slots == 5:
        s = eng.stats
        assert s.misses > 0                     # starvation actually happened
        # every miss was resolved by the compiled-step relaunch or, past the
        # iteration cap, the replay fallback — never silently dropped
        assert s.relaunched_steps + s.replayed_steps > 0
