"""Fused whole-stack decode: exactness vs the seed walk, replay under forced
misses, O(1) dispatches per miss-free token, batched slot uploads, LUT patch
regression, ring-delta seam, prefill-rate admission EMA."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import params_for
from repro.config import ResidencyConfig
from repro.core import RotaryEngine, SlotStore
from repro.core.rotation import RotaryRing
from repro.models import init_params
from repro.models.transformer import Runtime


def _f32_setup():
    cfg, _ = params_for("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, mode, slots, **kw):
    return RotaryEngine(
        cfg, params, ResidencyConfig(mode=mode, num_slots=slots, prefetch_margin=2),
        rt=Runtime(cache_len=64), batch=2, **kw,
    )


def test_fused_matches_host_routing_with_forced_misses(rng):
    """Greedy tokens bit-identical to the seed-style per-layer baseline under
    every residency mode, INCLUDING a slot-starved rotary engine whose misses
    force the suffix replay, and LRU (which decodes via the sync walk)."""
    cfg, params = _f32_setup()
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    outs, engines = {}, {}
    for mode, slots in (("full", 0), ("rotary", 5), ("lru", 5), ("static", 5)):
        base = _engine(cfg, params, mode, slots, host_routing=True)
        eng = _engine(cfg, params, mode, slots)
        outs[mode] = (base.generate(prompt, 10), eng.generate(prompt, 10))
        engines[mode] = eng
    for mode, (ref, got) in outs.items():
        np.testing.assert_array_equal(ref, got, err_msg=mode)
    # the fused path actually ran where it should, and replay was exercised
    assert engines["full"]._fused_decode and engines["rotary"]._fused_decode
    assert not engines["lru"]._fused_decode
    assert engines["rotary"].stats.replayed_steps > 0
    assert engines["rotary"].stats.misses > 0
    # every counted miss was host-corrected (mechanism parity with the walk)
    s = engines["rotary"].stats
    assert sum(l.host_computed for l in s.layers.values()) == s.misses


def test_fused_one_pull_and_one_dispatch_per_token(rng):
    """Miss-free fused decode: exactly ONE queue-draining device->host pull
    AND one compiled-program launch per token — O(1), not O(layers). The
    per-layer hot path issues >= 2 launches per MoE layer per token."""
    cfg, params = _f32_setup()
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    steps = 6

    fused = _engine(cfg, params, "full", 0)
    logits = fused.prefill(prompt)
    pulls0, disp0 = fused.stats.sync_pulls, fused.stats.device_dispatches
    fused.decode(logits, steps)
    assert fused.stats.sync_pulls - pulls0 == steps
    assert fused.stats.device_dispatches - disp0 == steps
    assert fused.stats.misses == 0

    layer = _engine(cfg, params, "full", 0, fused_decode=False)
    logits = layer.prefill(prompt)
    disp0 = layer.stats.device_dispatches
    layer.decode(logits, steps)
    assert layer.stats.device_dispatches - disp0 >= 2 * cfg.num_layers * steps


def test_fused_decode_flag_validation():
    cfg, params = _f32_setup()
    with pytest.raises(AssertionError):
        _engine(cfg, params, "lru", 5, fused_decode=True)
    with pytest.raises(AssertionError):
        _engine(cfg, params, "rotary", 5, host_routing=True, fused_decode=True)


def test_lut_patch_at_most_one_dispatch_per_layer_per_step(rng):
    """Regression (perf): steady-state rotation issues AT MOST one LUT patch
    dispatch per MoE layer per decode step — the persistent device LUT is
    patched incrementally, never re-uploaded per layer."""
    cfg, params = _f32_setup()
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    eng = _engine(cfg, params, "rotary", 5)
    logits = eng.prefill(prompt)
    patches0 = eng.stats.lut_patch_dispatches
    steps = 8
    eng.decode(logits, steps)
    # replayed steps re-read the (clean) LUT and must not add patches
    assert eng.stats.lut_patch_dispatches - patches0 <= cfg.num_layers * steps


def test_write_batch_matches_per_expert_writes():
    """One stacked scatter per tensor == N per-expert writes, bit-for-bit,
    with one dispatch per tensor instead of N (and donation-safe)."""
    rng = np.random.default_rng(0)
    shapes = {"w_up": (8, 12), "w_down": (12, 8)}
    experts = [rng.standard_normal((8, 12)).astype(np.float32) for _ in range(3)]
    downs = [rng.standard_normal((12, 8)).astype(np.float32) for _ in range(3)]

    one = SlotStore(4, shapes, jnp.float32)
    for i, slot in enumerate((0, 2, 3)):
        one.write(slot, {"w_up": experts[i], "w_down": downs[i]})

    bat = SlotStore(4, shapes, jnp.float32)
    d0 = bat.dispatches
    moved = bat.write_batch(
        [0, 2, 3],
        {"w_up": np.stack(experts), "w_down": np.stack(downs)},
        donate=True,
    )
    assert bat.dispatches - d0 == 2          # one scatter per weight tensor
    assert moved == 3 * (8 * 12 + 12 * 8) * 4
    for name in shapes:
        np.testing.assert_array_equal(
            np.asarray(one.buffers[name]), np.asarray(bat.buffers[name])
        )


def test_write_batch_int8_matches_single_quantization():
    rng = np.random.default_rng(1)
    shapes = {"w_up": (6, 10)}
    ws = [rng.standard_normal((6, 10)).astype(np.float32) for _ in range(2)]
    one = SlotStore(3, shapes, jnp.bfloat16, quantization="int8")
    for i, slot in enumerate((1, 2)):
        one.write(slot, {"w_up": ws[i]})
    bat = SlotStore(3, shapes, jnp.bfloat16, quantization="int8")
    bat.write_batch([1, 2], {"w_up": np.stack(ws)})
    np.testing.assert_array_equal(
        np.asarray(one.buffers["w_up"]), np.asarray(bat.buffers["w_up"])
    )
    np.testing.assert_array_equal(
        np.asarray(one.scales["w_up"]), np.asarray(bat.scales["w_up"])
    )


def test_ring_delta_seam_minimal_signed():
    """Tier-1 mirror of the hypothesis seam property (satellite fix): the
    cyclical-return delta wraps at the ring seam instead of reporting E-1."""
    e = 12
    assert RotaryRing._ring_delta(0, e - 1, e) == -1
    assert RotaryRing._ring_delta(e - 1, 0, e) == 1
    for src in range(e):
        for dst in range(e):
            d = RotaryRing._ring_delta(src, dst, e)
            assert (src + d) % e == dst
            assert abs(d) <= e // 2


def test_scheduler_prefill_rate_ema():
    """Admission no longer hard-codes prefill at 4x decode rate: the engine's
    measured prefill tok/s feedback moves the estimate (and the decision)."""
    from repro.serving.scheduler import Scheduler

    sch = Scheduler(2, est_tok_s=10.0)
    assert sch.est_prefill_tok_s == 40.0          # cold-start prior only
    # long prompt, tight deadline: rejected under the cold-start estimate
    r = sch.submit(np.zeros(400, np.int32), max_new=1, now=0.0, deadline_s=5.0)
    assert r.truncated and r.done
    sch.observe_prefill_rate(1000.0)
    sch.observe_prefill_rate(1000.0)
    assert sch.est_prefill_tok_s > 200.0
    r2 = sch.submit(np.zeros(400, np.int32), max_new=1, now=0.0, deadline_s=5.0)
    assert not r2.truncated                       # now admissible


def test_serving_feeds_prefill_rate(rng):
    """ServingEngine reports measured prefill rates to the scheduler — but
    only steady-state samples: a cold bucket's compile time must not poison
    the admission EMA."""
    from repro.serving import ServingEngine

    cfg, params = params_for("qwen2-moe-a2.7b")
    eng = ServingEngine(
        cfg, params, rt=Runtime(cache_len=32), num_slots=1,
        residency=ResidencyConfig(mode="rotary", num_slots=5),
    )
    default = eng.scheduler.est_prefill_tok_s
    # same prompt length -> same bucket: first prefill compiles (no sample)
    eng.submit(rng.integers(0, cfg.vocab_size, 6), max_new=2)
    eng.run()
    after_cold = eng.scheduler.est_prefill_tok_s
    assert after_cold == default
    eng.submit(rng.integers(0, cfg.vocab_size, 6), max_new=2)
    eng.run()
    assert eng.scheduler.est_prefill_tok_s != after_cold
