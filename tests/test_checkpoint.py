"""Checkpointing: roundtrip, dtype preservation, retention, crash-safety,
elastic resharding."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_tree, restore_elastic, save_tree
from repro.checkpoint.serializer import arrays_to_tree, tree_to_arrays


def _tree():
    return {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16), "c": jnp.int32(7)},
        "list": [jnp.zeros((2, 2)), jnp.full((3,), 2.5)],
    }


def test_serializer_roundtrip(tmp_path):
    t = _tree()
    save_tree(str(tmp_path / "ck"), t, {"step": 3})
    t2, meta = load_tree(str(tmp_path / "ck"), t)
    assert meta["step"] == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_bf16_preserved(tmp_path):
    t = {"w": jnp.asarray([1.5, -2.25], jnp.bfloat16)}
    save_tree(str(tmp_path / "ck"), t, {})
    t2, _ = load_tree(str(tmp_path / "ck"), t)
    assert t2["w"].dtype == jnp.bfloat16


def test_shape_mismatch_rejected(tmp_path):
    t = _tree()
    save_tree(str(tmp_path / "ck"), t, {})
    bad = dict(t)
    bad["a"] = jnp.zeros((3, 3))
    with pytest.raises(ValueError):
        load_tree(str(tmp_path / "ck"), bad)


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    t = _tree()
    for step in (10, 20, 30):
        t["a"] = t["a"] + 1.0
        mgr.save(step, t)
    assert mgr.existing_steps() == [20, 30]
    step, t2, meta = mgr.restore_latest(t)
    assert step == 30


def test_uncommitted_checkpoint_skipped(tmp_path):
    """A crash mid-save leaves no COMMIT marker; restore must skip it."""
    mgr = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    t = _tree()
    mgr.save(10, t)
    # simulate a torn save at step 20
    torn = tmp_path / "step_00000020"
    os.makedirs(torn)
    np.savez(str(torn / "arrays.npz"), **tree_to_arrays(t))
    with open(torn / "meta.json", "w") as f:
        json.dump({"step": 20}, f)
    # no COMMIT file
    assert mgr.existing_steps() == [10]
    step, _, _ = mgr.restore_latest(t)
    assert step == 10


def test_async_save_consistent_snapshot(tmp_path):
    """Mutating the live tree after save() must not corrupt the checkpoint."""
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    t = {"w": np.zeros((1000,), np.float32)}
    mgr.save(1, t)
    t["w"][:] = 999.0        # mutate while the writer thread may still run
    mgr.wait()
    _, t2, _ = mgr.restore_latest(t)
    assert float(t2["w"].max()) == 0.0


def test_elastic_restore_replicated(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    t = _tree()
    mgr.save(5, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    step, t2, _ = restore_elastic(mgr, t, mesh)
    assert step == 5
    leaf = jax.tree.leaves(t2)[0]
    assert isinstance(leaf, jax.Array)
