"""Hypothesis properties for the grouped int4 subsystem (dev-deps only;
tier-1 mirrors live in test_quant.py and run without hypothesis)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.quant import (
    dequantize_int4,
    effective_group,
    quantize_int4,
    quantize_int4_batch,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

even = st.integers(1, 16).map(lambda n: 2 * n)          # rows must pack in pairs


@given(st.integers(0, 6), even, st.integers(1, 12), even, st.floats(0.1, 8.0))
def test_int4_roundtrip_bounded_by_group_scale(seed, rows, cols, group, spread):
    """For every element, |dequant(quant(w)) - w| <= its group's scale step
    (the affine code's quantization step, f16-rounded)."""
    w = (np.random.default_rng(seed).standard_normal((rows, cols)) * spread
         ).astype(np.float32)
    packed, scale, mn = quantize_int4(w, group)
    back = np.asarray(
        dequantize_int4(jnp.asarray(packed), jnp.asarray(scale), jnp.asarray(mn))
    )
    g = effective_group(rows, group)
    step = np.repeat(scale.astype(np.float32), g, axis=-2)
    assert (np.abs(back - w) <= step + 1e-6).all()


@given(st.integers(0, 6), st.integers(1, 6), even, st.integers(1, 10), even)
def test_int4_batch_bit_equal_to_single(seed, n, rows, cols, group):
    """quantize_int4_batch over a stacked expert axis is byte-identical to
    quantizing each expert alone — the one-scatter-per-tensor rotation upload
    must produce the same device bytes as N single-expert uploads (mirrors
    the int8 batch property in test_fused_decode)."""
    w = np.random.default_rng(seed).standard_normal((n, rows, cols)).astype(np.float32)
    pb, sb, mb = quantize_int4_batch(w, group)
    for i in range(n):
        p1, s1, m1 = quantize_int4(w[i], group)
        np.testing.assert_array_equal(pb[i], p1)
        np.testing.assert_array_equal(sb[i], s1)
        np.testing.assert_array_equal(mb[i], m1)
