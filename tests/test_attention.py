"""Attention: chunked-flash vs reference sweeps + decode/prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import AttentionConfig
from repro.models import attention as A


def _qkv(rng, b, sq, skv, h, hkv, dh, dtype=jnp.float32):
    q = jnp.asarray(rng.standard_normal((b, sq, h, dh)), dtype)
    k = jnp.asarray(rng.standard_normal((b, skv, hkv, dh)), dtype)
    v = jnp.asarray(rng.standard_normal((b, skv, hkv, dh)), dtype)
    return q, k, v


@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (4, 1)])
@pytest.mark.parametrize("window", [None, 24])
def test_chunked_matches_reference(rng, h, hkv, window):
    q, k, v = _qkv(rng, 2, 64, 64, h, hkv, 16)
    ref = A.reference_attention(q, k, v, causal=True, window=window)
    out = A.chunked_attention(q, k, v, causal=True, window=window,
                              q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_chunked_soft_cap(rng):
    q, k, v = _qkv(rng, 1, 32, 32, 2, 2, 8)
    ref = A.reference_attention(q, k, v, causal=True, soft_cap=10.0)
    out = A.chunked_attention(q, k, v, causal=True, soft_cap=10.0,
                              q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("qc,kc", [(8, 16), (32, 8), (64, 64)])
def test_chunk_size_invariance(rng, qc, kc):
    q, k, v = _qkv(rng, 1, 64, 64, 2, 1, 8)
    a = A.chunked_attention(q, k, v, q_chunk=qc, kv_chunk=kc)
    b = A.chunked_attention(q, k, v, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def _mk(acfg_kw=None, d_model=32):
    acfg = AttentionConfig(**{**dict(num_heads=4, num_kv_heads=2, head_dim=8),
                              **(acfg_kw or {})})
    p = A.init_attention(jax.random.PRNGKey(1), d_model, acfg, jnp.float32)
    return acfg, p


@pytest.mark.parametrize("kw", [{}, {"qk_norm": True},
                                {"window": 8, "num_kv_heads": 1}])
def test_decode_matches_prefill(rng, kw):
    """Token-by-token decode must reproduce the full prefill computation."""
    acfg, p = _mk(kw)
    d = 32
    s = 24
    x = jnp.asarray(rng.standard_normal((2, s, d)), jnp.float32)
    y_full = A.attention_train(p, acfg, x, q_chunk=8, kv_chunk=8)
    # prefill first 16, decode the rest
    y_pre, cache = A.attention_prefill(p, acfg, x[:, :16], cache_len=s)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :16]),
                               atol=3e-5)
    outs = []
    for t in range(16, s):
        y_t, cache = A.attention_decode(p, acfg, x[:, t : t + 1], cache,
                                        jnp.int32(t))
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, 16:]),
                               atol=3e-5)


def test_decode_per_row_lengths(rng):
    """Ragged decode (vector cur_len) matches per-row scalar decode."""
    acfg, p = _mk()
    d = 32
    x = jnp.asarray(rng.standard_normal((2, 10, d)), jnp.float32)
    # build caches at different lengths per row
    _, cache0 = A.attention_prefill(p, acfg, x[:1, :4], cache_len=16)
    _, cache1 = A.attention_prefill(p, acfg, x[1:, :7], cache_len=16)
    cache = {kk: jnp.concatenate([cache0[kk], cache1[kk]]) for kk in cache0}
    tok = jnp.asarray(rng.standard_normal((2, 1, d)), jnp.float32)
    y, _ = A.attention_decode(p, acfg, tok, cache,
                              jnp.asarray([4, 7], jnp.int32))
    y0, _ = A.attention_decode(p, acfg, tok[:1], cache0, jnp.int32(4))
    y1, _ = A.attention_decode(p, acfg, tok[1:], cache1, jnp.int32(7))
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(y0[0]), atol=3e-5)
    np.testing.assert_allclose(np.asarray(y[1]), np.asarray(y1[0]), atol=3e-5)


def test_windowed_ring_cache_wraps(rng):
    """Local attention: decoding past the window wraps the ring cache and
    still matches the full computation."""
    acfg, p = _mk({"window": 8, "num_kv_heads": 1})
    d = 32
    s = 20
    x = jnp.asarray(rng.standard_normal((1, s, d)), jnp.float32)
    y_full = A.attention_train(p, acfg, x)
    _, cache = A.attention_prefill(p, acfg, x[:, :4], cache_len=s)
    assert cache["k"].shape[1] == 8                     # capacity = window
    outs = []
    for t in range(4, s):
        y_t, cache = A.attention_decode(p, acfg, x[:, t : t + 1], cache, jnp.int32(t))
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, 4:]),
                               atol=3e-5)
