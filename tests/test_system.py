"""End-to-end system tests: train -> checkpoint -> serve with rotary residency.

This is the full paper loop on a reduced model: train a small MoE, save, reload,
then execute it under rotary residency with the slot budget below the expert
count — generation must match the full-residency reference token-for-token.
"""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import params_for
from repro.config import ResidencyConfig, RunConfig
from repro.checkpoint import CheckpointManager
from repro.core import RotaryEngine
from repro.data import SyntheticSpec, batch_at_step
from repro.models.transformer import Runtime
from repro.training import init_train_state, make_train_step


def test_train_checkpoint_serve_rotary(tmp_path, rng):
    cfg, params = params_for("qwen36-35b-a3b")
    rt = Runtime(cache_len=48)
    run = RunConfig(learning_rate=1e-3, warmup_steps=1)
    spec = SyntheticSpec(vocab_size=cfg.vocab_size, seq_len=24, global_batch=2,
                         kind="topic", num_topics=3, topic_len=8)
    state = init_train_state(cfg, params)
    step_fn = jax.jit(make_train_step(cfg, rt, run))
    for i in range(3):
        t, l = batch_at_step(spec, i)
        state, m = step_fn(state, jnp.asarray(t), jnp.asarray(l))
    assert np.isfinite(float(m["loss"]))

    mgr = CheckpointManager(str(tmp_path), keep=1, async_save=False)
    mgr.save(3, state)
    _, restored, _ = mgr.restore_latest(state)

    prompt = rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32)
    eng_full = RotaryEngine(cfg, restored["params"],
                            ResidencyConfig(mode="full"), rt=rt, batch=1)
    ref_tokens = eng_full.generate(prompt, 6)
    eng_rot = RotaryEngine(cfg, restored["params"],
                           ResidencyConfig(mode="rotary", num_slots=5),
                           rt=rt, batch=1)
    rot_tokens = eng_rot.generate(prompt, 6)
    np.testing.assert_array_equal(ref_tokens, rot_tokens)
    # residency actually constrained: fewer slots than experts, some traffic
    assert eng_rot.manager.num_slots < cfg.moe.num_experts
    assert eng_rot.stats.bytes_loaded > 0


def test_residency_policy_ordering(rng):
    """On a topic-cycling workload the rotary policy's hit rate should at
    least match static and keep loads off the critical path (stall ~ 0 vs
    LRU blocking loads)."""
    cfg, params = params_for("qwen2-moe-a2.7b")
    rt = Runtime(cache_len=64)
    spec = SyntheticSpec(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2,
                         kind="topic", num_topics=2, topic_len=8, seed=3)
    prompt, _ = batch_at_step(spec, 0)
    stats = {}
    for mode in ("rotary", "lru", "static"):
        eng = RotaryEngine(cfg, params,
                           ResidencyConfig(mode=mode, num_slots=5),
                           rt=rt, batch=2)
        eng.generate(prompt.astype(np.int32), 10)
        stats[mode] = eng.stats
    assert stats["rotary"].hit_rate >= stats["static"].hit_rate - 0.05
    assert stats["lru"].stall_s > 0.0
    assert stats["rotary"].stall_s <= stats["lru"].stall_s + 1e-9
