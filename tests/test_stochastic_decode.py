"""Temperature > 0 speculative decode: the stochastic accept rule and the
shared PRNG protocol, verified DISTRIBUTIONALLY (tier-1, fixed seeds).

Three proofs of exactness, per the PR contract:

* seeded-stream equivalence — spec-K sampled decode emits the bit-identical
  token stream as single-token sampled decode (same position-keyed draws)
  across full / rotary_hi / slot-starved / int4 / prefetch regimes;
* chi-squared goodness of fit — tokens emitted through accept-or-resample
  match the TARGET distribution q for adversarial draft/verify divergences
  (the property that makes speculative sampling "exact" in distribution);
* rejection-path properties — the first-rejection resample draws only from
  ``support(max(q - p, 0))``, the acceptance rate matches the analytic
  ``sum(min(p, q))``, and residency-miss truncation composes with stochastic
  rejection by per-row min.

``tests/test_sampler_properties.py`` mirrors the distributional checks as
hypothesis properties over drawn grids; this module is the always-run anchor.
"""
import numpy as np
import pytest

from conftest import params_for
from repro.config import ResidencyConfig
from repro.core import RotaryEngine
from repro.models import sampling
from repro.models.transformer import Runtime
from repro.serving.sampler import (
    Sampler,
    SamplerConfig,
    greedy_accept,
    stochastic_accept,
)


def chi2_crit(df: int, z: float = 2.33) -> float:
    """~99th-percentile chi-squared critical value (Wilson–Hilferty cube
    approximation — no scipy in the base environment)."""
    return df * (1.0 - 2.0 / (9.0 * df) + z * np.sqrt(2.0 / (9.0 * df))) ** 3


def chi2_stat(counts: np.ndarray, probs: np.ndarray) -> float:
    n = counts.sum()
    exp = n * probs
    keep = exp > 0
    return float(((counts[keep] - exp[keep]) ** 2 / exp[keep]).sum())


def _dists(v=8, seed=0):
    """An adversarial (p, q) pair: q concentrates mass where p is thin, so
    both the accept and the leftover-resample paths carry real traffic."""
    r = np.random.default_rng(seed)
    p = r.dirichlet(np.full(v, 0.4))
    q = np.roll(p, 3) * 0.7 + r.dirichlet(np.full(v, 0.4)) * 0.3
    return p, q / q.sum()


# ===========================================================================
# stochastic_accept: the rule itself
# ===========================================================================
def test_stochastic_accept_identical_dists_accept_all():
    """Self-drafting degeneracy: p == q means every ratio is exactly 1 and
    u < 1 always accepts — the in-engine invariant that makes stochastic
    rejection structurally unreachable (rejection comes only from misses)."""
    r = np.random.default_rng(0)
    k, b, v = 4, 3, 16
    probs = r.dirichlet(np.full(v, 0.5), size=(k, b))
    draft = np.stack(
        [[r.choice(v, p=probs[j, i]) for i in range(b)] for j in range(k)]
    ).astype(np.int32)
    for _ in range(50):
        acc, res = stochastic_accept(draft, probs, probs, r)
        assert (acc == k).all()
        assert (res == -1).all()


def test_greedy_accept_rule():
    draft = np.array([[3, 3], [5, 1], [2, 2]], np.int32)       # [K=3, B=2]
    verify = np.array([[3, 3], [5, 9], [7, 2]], np.int32)
    np.testing.assert_array_equal(greedy_accept(draft, verify), [2, 1])


def test_stochastic_accept_rate_matches_analytic():
    """E[1{accept}] per position = sum_t p(t) * min(1, q(t)/p(t))
    = sum_t min(p(t), q(t))."""
    p, q = _dists()
    analytic = np.minimum(p, q).sum()
    r = np.random.default_rng(1)
    n = 20_000
    draft = r.choice(len(p), size=(1, n), p=p).astype(np.int32)
    acc, _ = stochastic_accept(
        draft,
        np.broadcast_to(p, (1, n, len(p))),
        np.broadcast_to(q, (1, n, len(q))),
        r,
    )
    rate = acc.mean()
    assert abs(rate - analytic) < 4 * np.sqrt(analytic * (1 - analytic) / n)


def test_stochastic_resample_support_is_leftover_only():
    """Rejected rows must resample strictly inside support(max(q - p, 0)) —
    never from a token where the draft already over-covers the target."""
    p, q = _dists(seed=2)
    leftover_support = np.flatnonzero(np.maximum(q - p, 0.0) > 0)
    r = np.random.default_rng(3)
    n = 8_000
    draft = r.choice(len(p), size=(1, n), p=p).astype(np.int32)
    acc, res = stochastic_accept(
        draft,
        np.broadcast_to(p, (1, n, len(p))),
        np.broadcast_to(q, (1, n, len(q))),
        r,
    )
    rejected = res[acc == 0]
    assert rejected.size > 100                      # the path actually ran
    assert np.isin(rejected, leftover_support).all()


def test_stochastic_accept_chi_squared_output_matches_target():
    """THE exactness property: token-emitted-per-position (accepted draft OR
    leftover resample) is distributed exactly q, however far p diverges."""
    for seed in (0, 2, 7):
        p, q = _dists(seed=seed)
        v = len(p)
        r = np.random.default_rng(100 + seed)
        n = 30_000
        draft = r.choice(v, size=(1, n), p=p).astype(np.int32)
        acc, res = stochastic_accept(
            draft,
            np.broadcast_to(p, (1, n, v)),
            np.broadcast_to(q, (1, n, v)),
            r,
        )
        emitted = np.where(acc == 1, draft[0], res)
        counts = np.bincount(emitted, minlength=v)
        stat = chi2_stat(counts, q)
        assert stat < chi2_crit(v - 1), (seed, stat, chi2_crit(v - 1))


def test_stochastic_first_rejection_caps_window():
    """Multi-position windows: ``accepted`` is the index of the FIRST
    rejection (everything drafted after it is invalid), and a residency-miss
    cap composes by per-row min — the exact expression the serving tick
    uses: ``min(stoch_cap, miss_cap)``."""
    v = 8
    p = np.full(v, 1.0 / v)
    q = np.zeros(v)
    q[0] = 1.0                                   # q rejects every draft != 0
    k, n = 4, 2_000
    r = np.random.default_rng(5)
    draft = r.choice(v, size=(k, n), p=p).astype(np.int32)
    acc, res = stochastic_accept(
        draft,
        np.broadcast_to(p, (k, n, v)),
        np.broadcast_to(q, (k, n, v)),
        r,
    )
    # accepted == j  <=>  draft[0..j-1] == 0 (ratio v, certain accept) and
    # draft[j] != 0 (ratio 0, certain reject)
    expect = np.argmax(draft != 0, axis=0)
    expect = np.where((draft != 0).any(axis=0), expect, k)
    np.testing.assert_array_equal(acc, expect)
    assert (res[acc < k] == 0).all()             # leftover = q itself here
    # miss composition: a miss cap below the stochastic rejection wins, one
    # above it leaves the stochastic cap in charge
    stoch_cap = np.where(acc < k, acc + 1, k)
    miss_cap = np.full(n, 2, np.int32)
    composed = np.minimum(stoch_cap, miss_cap)
    assert (composed <= 2).all()
    assert (composed[stoch_cap < 2] == stoch_cap[stoch_cap < 2]).all()


# ===========================================================================
# host Sampler: top-k tie regression + vectorized draw
# ===========================================================================
def test_sampler_topk_tie_break_by_index():
    """Regression: ties at the k-th threshold must NOT widen the kept set.
    The old ``x < kth`` mask kept every tied candidate; the fix breaks ties
    toward the lower index, matching ``lax.top_k``."""
    s = Sampler(SamplerConfig(temperature=1.0, top_k=2, seed=0))
    logits = np.asarray([[1.0, 5.0, 5.0, 5.0, 0.0]] * 512)
    p = s.warp(logits)
    assert ((p > 0).sum(axis=-1) == 2).all()          # exactly k survivors
    # lowest-index ties win: tokens 1 and 2, never 3
    assert (p[:, [1, 2]] > 0).all() and (p[:, 3] == 0).all()
    toks = s(logits)
    assert set(np.unique(toks)) <= {1, 2}


def test_sampler_draw_matches_warp_distribution():
    """The batched inverse-CDF draw samples the warped distribution (chi²)."""
    s = Sampler(SamplerConfig(temperature=0.7, top_k=6, top_p=0.9, seed=0))
    v = 12
    logits = np.random.default_rng(4).normal(size=v)[None, :]
    target = s.warp(logits)[0]
    n = 30_000
    toks = s(np.broadcast_to(logits[0], (n, v)))
    counts = np.bincount(toks, minlength=v)
    df = int((target > 0).sum()) - 1
    assert chi2_stat(counts, target) < chi2_crit(df)


# ===========================================================================
# on-device draws: warp parity + chi-squared against the host target
# ===========================================================================
def test_device_draws_chi_squared_vs_host_target():
    """``sampling.sample_step`` draws (the in-window drafting path) are
    distributed per the host ``Sampler.warp`` target — device warp and
    device categorical together match the reference distribution."""
    import jax.numpy as jnp

    v = 12
    logits = np.random.default_rng(6).normal(size=v).astype(np.float32)
    sp = sampling.SampleParams(temperature=0.8, top_k=8, top_p=0.9)
    host = Sampler(SamplerConfig(temperature=0.8, top_k=8, top_p=0.9))
    target = host.warp(logits[None, :].astype(np.float64))[0]
    n = 20_000
    keys = sampling.row_keys(0, n)                 # n independent streams
    toks, probs, _ = sampling.sample_step(
        jnp.broadcast_to(jnp.asarray(logits), (n, v)), keys,
        jnp.int32(17), sp,
    )
    # warp parity: same kept set, same renormalized probs (f32 tolerance)
    probs0 = np.asarray(probs)[0]
    np.testing.assert_array_equal(probs0 > 0, target > 0)
    np.testing.assert_allclose(probs0, target, atol=1e-6)
    counts = np.bincount(np.asarray(toks), minlength=v)
    df = int((target > 0).sum()) - 1
    assert chi2_stat(counts, target) < chi2_crit(df)


# ===========================================================================
# seeded-stream equivalence: spec-K sampled == single-token sampled
# ===========================================================================
def _f32_setup():
    cfg, params = params_for("qwen2-moe-a2.7b")
    return cfg, params


_REGIMES = {
    "full": lambda e: dict(rescfg=ResidencyConfig(mode="full")),
    "rotary_hi": lambda e: dict(
        rescfg=ResidencyConfig(mode="rotary", num_slots=e)
    ),
    "slot_starved": lambda e: dict(
        rescfg=ResidencyConfig(mode="rotary", num_slots=5)
    ),
    "int4": lambda e: dict(
        rescfg=ResidencyConfig(mode="rotary", num_slots=e, quantization="int4")
    ),
    "prefetch": lambda e: dict(
        rescfg=ResidencyConfig(mode="rotary", num_slots=6, prefetch_margin=2),
        prefetch=True,
    ),
}


@pytest.mark.parametrize("regime", list(_REGIMES))
def test_sampled_spec_stream_equivalence(regime):
    """Spec-K sampled decode is BIT-IDENTICAL to single-token sampled decode
    under the stateless position-keyed PRNG protocol — across residency
    regimes, including miss-truncated windows (slot_starved: the stochastic
    cap composes with the miss cap and rejected positions re-draw with the
    SAME key after replay) and prefetch window relaunches."""
    cfg, params = _f32_setup()
    kw = _REGIMES[regime](cfg.moe.num_experts)
    rescfg = kw.pop("rescfg")
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 6)
    ).astype(np.int32)
    sc = SamplerConfig(temperature=0.9, top_k=0, top_p=0.92, seed=13)

    def run(spec_k):
        eng = RotaryEngine(
            cfg, params, rescfg, rt=Runtime(cache_len=64), batch=2,
            spec_k=spec_k, **kw,
        )
        return eng.generate(prompt, 10, sampler=sc), eng

    out1, _ = run(1)
    out4, eng4 = run(4)
    np.testing.assert_array_equal(out1, out4)
    assert eng4.stats.spec_windows > 0
    assert 0.0 <= eng4.stats.accept_rate <= 1.0
    if regime in ("full", "rotary_hi", "int4"):
        # miss-free regimes: self-drafting accepts every position
        assert eng4.stats.accept_rate == 1.0


def test_sampled_spec_respects_sampler_seed():
    """Different sampler seeds give different streams; the same seed twice is
    reproducible (the stream is a pure function of (seed, positions))."""
    cfg, params = _f32_setup()
    prompt = np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 5)
    ).astype(np.int32)

    def run(seed):
        eng = RotaryEngine(
            cfg, params, ResidencyConfig(mode="full"),
            rt=Runtime(cache_len=64), batch=2, spec_k=4,
        )
        return eng.generate(
            prompt, 8, sampler=SamplerConfig(temperature=1.0, seed=seed)
        )

    a, b, c = run(3), run(3), run(4)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_sampled_greedy_false_kwarg_speculates():
    """The legacy ``greedy=False`` spelling now rides the fused window path
    (temperature-1.0 sampling) instead of falling back to host-softmax
    single-token decode."""
    cfg, params = _f32_setup()
    prompt = np.random.default_rng(2).integers(
        0, cfg.vocab_size, (2, 5)
    ).astype(np.int32)
    eng = RotaryEngine(
        cfg, params, ResidencyConfig(mode="full"),
        rt=Runtime(cache_len=64), batch=2, spec_k=4,
    )
    logits = eng.prefill(prompt)
    out = eng.decode(logits, 8, greedy=False, seed=5)
    assert out.shape == (2, 8)
    assert eng.stats.spec_windows > 0
