"""Residency manager, policies, slot store, feasibility (Fig. 3 semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.config import ResidencyConfig, get_config
from repro.configs import reduce_for_smoke
from repro.core import (
    InitializationError,
    RotaryResidencyManager,
    SlotStore,
    check_feasibility,
    dequantize_int8,
    make_policy,
    quantize_int8,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _mgr(mode="rotary", slots=5, quant=None):
    cfg = reduce_for_smoke(get_config("qwen36-35b-a3b"))
    rng = np.random.default_rng(0)
    m = cfg.moe
    hw = [
        {
            "w_gate": rng.standard_normal((m.num_experts, cfg.d_model, m.expert_d_ff)).astype(np.float32),
            "w_up": rng.standard_normal((m.num_experts, cfg.d_model, m.expert_d_ff)).astype(np.float32),
            "w_down": rng.standard_normal((m.num_experts, m.expert_d_ff, cfg.d_model)).astype(np.float32),
        }
        for _ in range(cfg.num_layers)
    ]
    rescfg = ResidencyConfig(mode=mode, num_slots=slots, quantization=quant)
    return cfg, RotaryResidencyManager(cfg, rescfg, hw, batch=1, cache_len=64), hw


def test_full_policy_never_misses():
    cfg, mgr, _ = _mgr("full", 0)
    ids = np.random.default_rng(1).integers(0, cfg.moe.num_experts, (4, 2))
    lut, miss = mgr.resolve(0, ids)
    assert not miss.any()
    assert mgr.stats.hit_rate == 1.0


def test_rotary_prepare_loads_window():
    cfg, mgr, hw = _mgr("rotary", 5)
    e = cfg.moe.num_experts
    demand = np.zeros(e)
    demand[:5] = 1.0
    mgr.prepare_layer(0, demand)
    lut = mgr.policies[0].lut
    assert set(np.flatnonzero(demand).tolist()) <= set(lut.resident_experts.tolist())


def test_slot_contents_match_host_weights():
    """What sits in a slot is exactly the host expert the LUT claims."""
    cfg, mgr, hw = _mgr("rotary", 5)
    demand = np.random.default_rng(2).random(cfg.moe.num_experts)
    mgr.prepare_layer(0, demand)
    lut = mgr.policies[0].lut
    tree = mgr.stores[0].as_pytree()
    for e in lut.resident_experts:
        s = lut.slot_of(int(e))
        np.testing.assert_allclose(            # store dtype is bf16
            np.asarray(tree["w_up"][s], np.float32), hw[0]["w_up"][e],
            atol=0.02, rtol=0.02,
        )


def test_lru_blocking_load_on_miss():
    cfg, mgr, _ = _mgr("lru", 5)
    ids = np.asarray([[0, 1]], np.int32)
    lut, miss = mgr.resolve(0, ids)
    assert not miss.any()                      # LRU loads on miss
    assert mgr.stats.layer(0).loads >= 2


def test_static_policy_leaves_misses_to_host():
    cfg, mgr, _ = _mgr("static", 5)
    e = cfg.moe.num_experts
    demand = np.zeros(e); demand[:5] = 1.0
    mgr.prepare_layer(0, demand)
    ids = np.asarray([[e - 1, e - 2]], np.int32)   # cold experts
    lut, miss = mgr.resolve(0, ids)
    assert miss.all()


def test_feasibility_two_sided():
    cfg = reduce_for_smoke(get_config("qwen36-35b-a3b"))
    # floor: not enough slots for top_k + margin
    r = check_feasibility(cfg, ResidencyConfig(mode="rotary", num_slots=2,
                                               prefetch_margin=2),
                          batch=1, cache_len=64)
    assert not r.ok and "margin" in r.reason
    # ceiling: tiny HBM budget
    r2 = check_feasibility(cfg, ResidencyConfig(mode="rotary", num_slots=6,
                                                hbm_budget_bytes=1024),
                           batch=1, cache_len=64)
    assert not r2.ok and "budget" in r2.reason
    # fine
    r3 = check_feasibility(cfg, ResidencyConfig(mode="rotary", num_slots=6),
                           batch=1, cache_len=64)
    assert r3.ok


def test_manager_raises_on_infeasible():
    with pytest.raises(InitializationError):
        _mgr("rotary", 2)


@given(st.integers(1, 6), st.integers(4, 40), st.integers(3, 17))
def test_quantize_roundtrip_bounded(seed, rows, cols):
    w = np.random.default_rng(seed).standard_normal((rows, cols)).astype(np.float32)
    q, scale = quantize_int8(w)
    back = np.asarray(dequantize_int8(jnp.asarray(q), jnp.asarray(scale), jnp.float32))
    err = np.abs(back - w)
    # error bounded by half a quantization step per channel
    assert (err <= (np.abs(w).max(axis=0) / 127.0 + 1e-6)).all()


def test_int8_slot_store_halves_bytes():
    shapes = {"w_up": (16, 24), "w_down": (24, 16)}
    fp = SlotStore(4, shapes, jnp.bfloat16)
    q = SlotStore(4, shapes, jnp.bfloat16, quantization="int8")
    assert q.bytes_per_expert < fp.bytes_per_expert * 0.75


def test_int8_residency_engine_quality():
    """int8 slots (Q4_K_M analog): dequantized compute stays close to fp."""
    cfg, mgr_fp, hw = _mgr("rotary", 5)
    _, mgr_q, _ = _mgr("rotary", 5, quant="int8")
    demand = np.zeros(cfg.moe.num_experts); demand[:5] = 1.0
    mgr_fp.prepare_layer(0, demand)
    mgr_q.prepare_layer(0, demand)
    t_fp = mgr_fp.stores[0].as_pytree()
    t_q = mgr_q.stores[0].as_pytree()
    lut = mgr_fp.policies[0].lut
    s = lut.slot_of(int(lut.resident_experts[0]))
    a = np.asarray(t_fp["w_up"][s], np.float32)
    b = np.asarray(t_q["w_up"][s], np.float32)
    assert np.abs(a - b).max() < np.abs(a).max() / 64
