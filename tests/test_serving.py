"""Serving engine + scheduler: continuous batching correctness, deadlines."""
import numpy as np
import pytest

from conftest import params_for
from repro.config import ResidencyConfig
from repro.models.transformer import Runtime
from repro.serving import SamplerConfig, Sampler, ServingEngine
from repro.serving.scheduler import Scheduler


def test_sampler_greedy():
    s = Sampler(SamplerConfig(temperature=0.0))
    logits = np.asarray([[0.0, 3.0, 1.0], [5.0, 0.0, 0.0]])
    np.testing.assert_array_equal(s(logits), [1, 0])


def test_sampler_topk_restricts():
    s = Sampler(SamplerConfig(temperature=1.0, top_k=2, seed=0))
    logits = np.asarray([[10.0, 9.0, -50.0, -50.0]] * 64)
    toks = s(logits)
    assert set(toks.tolist()) <= {0, 1}


def test_scheduler_slots_and_deadlines():
    sch = Scheduler(num_slots=2, est_tok_s=10.0)
    r1 = sch.submit(np.arange(4), max_new=4, now=0.0)
    r2 = sch.submit(np.arange(4), max_new=4, now=0.0)
    r3 = sch.submit(np.arange(4), max_new=4, now=0.0)
    # infeasible deadline rejected up-front (straggler mitigation)
    r4 = sch.submit(np.arange(4), max_new=1000, now=0.0, deadline_s=0.5)
    assert r4.truncated and r4.done
    admitted = sch.admit(0.0)
    assert len(admitted) == 2 and not sch.free_slots
    for t in range(4):
        sch.step_done(r1.slot, 7, now=0.1 * t)
    assert r1.done and len(sch.free_slots) == 1
    assert sch.admit(1.0)[0] is r3 or True   # r3 admitted into freed slot


def test_continuous_batching_matches_single(rng):
    """Tokens from the batched engine == running each request alone (greedy).
    Ragged per-row lengths + KV splicing must be exact."""
    arch = "starcoder2-3b"
    cfg, params = params_for(arch)
    rt = Runtime(cache_len=64)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 9, 12)]
    # batched
    eng = ServingEngine(cfg, params, rt=rt, num_slots=2)
    reqs = [eng.submit(p, max_new=5) for p in prompts]
    eng.run()
    # singly
    singles = []
    for p in prompts:
        e1 = ServingEngine(cfg, params, rt=rt, num_slots=1)
        r = e1.submit(p, max_new=5)
        e1.run()
        singles.append(r.output)
    for req, ref in zip(reqs, singles):
        assert req.output == ref, (req.output, ref)


def test_serving_rotary_residency_runs(rng):
    cfg, params = params_for("qwen2-moe-a2.7b")
    eng = ServingEngine(
        cfg, params, rt=Runtime(cache_len=32), num_slots=2,
        residency=ResidencyConfig(mode="rotary", num_slots=5),
    )
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, 6), max_new=4)
            for _ in range(3)]
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.output) == 4 for r in done)
    assert eng.stats.hits + eng.stats.misses > 0
