"""Serving engine + scheduler: continuous batching correctness, deadlines."""
import numpy as np
import pytest

from conftest import params_for
from repro.config import ResidencyConfig
from repro.models.transformer import Runtime
from repro.serving import SamplerConfig, Sampler, ServingEngine
from repro.serving.scheduler import Scheduler


def test_sampler_greedy():
    s = Sampler(SamplerConfig(temperature=0.0))
    logits = np.asarray([[0.0, 3.0, 1.0], [5.0, 0.0, 0.0]])
    np.testing.assert_array_equal(s(logits), [1, 0])


def test_sampler_topk_restricts():
    s = Sampler(SamplerConfig(temperature=1.0, top_k=2, seed=0))
    logits = np.asarray([[10.0, 9.0, -50.0, -50.0]] * 64)
    toks = s(logits)
    assert set(toks.tolist()) <= {0, 1}


def test_scheduler_slots_and_deadlines():
    sch = Scheduler(num_slots=2, est_tok_s=10.0)
    r1 = sch.submit(np.arange(4), max_new=4, now=0.0)
    r2 = sch.submit(np.arange(4), max_new=4, now=0.0)
    r3 = sch.submit(np.arange(4), max_new=4, now=0.0)
    # infeasible deadline rejected up-front (straggler mitigation)
    r4 = sch.submit(np.arange(4), max_new=1000, now=0.0, deadline_s=0.5)
    assert r4.truncated and r4.done
    admitted = sch.admit(0.0)
    assert len(admitted) == 2 and not sch.free_slots
    for t in range(4):
        sch.step_done(r1.slot, 7, now=0.1 * t)
    assert r1.done and len(sch.free_slots) == 1
    assert sch.admit(1.0)[0] is r3 or True   # r3 admitted into freed slot


def test_continuous_batching_matches_single(rng):
    """Tokens from the batched engine == running each request alone (greedy).
    Ragged per-row lengths + KV splicing must be exact."""
    arch = "starcoder2-3b"
    cfg, params = params_for(arch)
    rt = Runtime(cache_len=64)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 9, 12)]
    # batched
    eng = ServingEngine(cfg, params, rt=rt, num_slots=2)
    reqs = [eng.submit(p, max_new=5) for p in prompts]
    eng.run()
    # singly
    singles = []
    for p in prompts:
        e1 = ServingEngine(cfg, params, rt=rt, num_slots=1)
        r = e1.submit(p, max_new=5)
        e1.run()
        singles.append(r.output)
    for req, ref in zip(reqs, singles):
        assert req.output == ref, (req.output, ref)


def test_serving_rotary_residency_runs(rng):
    cfg, params = params_for("qwen2-moe-a2.7b")
    eng = ServingEngine(
        cfg, params, rt=Runtime(cache_len=32), num_slots=2,
        residency=ResidencyConfig(mode="rotary", num_slots=5),
    )
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, 6), max_new=4)
            for _ in range(3)]
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.output) == 4 for r in done)
    assert eng.stats.hits + eng.stats.misses > 0


# ===========================================================================
# per-row learned speculative lengths
# ===========================================================================
def test_scheduler_spec_len_adapts_per_row():
    """Per-row speculative-length adaptation, driven by a deterministic fake
    clock (explicit ``now`` values — no wall time anywhere): rows with a high
    accept rate grow one step per window toward the cap, rows with a low rate
    halve toward single-token decode, and the two rows adapt independently."""
    sch = Scheduler(num_slots=2, spec_cap=8)
    fake_now = iter(float(t) for t in range(1000))
    r0 = sch.submit(np.arange(4), max_new=64, now=next(fake_now))
    r1 = sch.submit(np.arange(4), max_new=64, now=next(fake_now))
    sch.admit(next(fake_now))
    assert sch.spec_len(r0.slot) == 1 and sch.spec_len(r1.slot) == 1
    # row 0 accepts everything, row 1 keeps rejecting its drafted suffix
    for _ in range(12):
        k0, k1 = sch.spec_len(r0.slot), sch.spec_len(r1.slot)
        sch.observe_accept(r0.slot, drafted=k0, accepted=k0)
        sch.observe_accept(r1.slot, drafted=max(k1, 2), accepted=1)
    assert sch.spec_len(r0.slot) == sch.spec_cap        # grew to the cap
    assert sch.spec_len(r1.slot) == 1                   # shrank to no-spec
    # recovery: the shrunk row starts accepting again and re-grows
    for _ in range(12):
        k1 = sch.spec_len(r1.slot)
        sch.observe_accept(r1.slot, drafted=k1, accepted=k1)
    assert sch.spec_len(r1.slot) == sch.spec_cap


def test_scheduler_spec_len_bounds():
    sch = Scheduler(num_slots=1, spec_cap=4)
    sch.observe_accept(0, drafted=0, accepted=0)        # no-op, no div-by-zero
    assert sch.spec_len(0) == 1
    for _ in range(20):
        sch.observe_accept(0, drafted=4, accepted=4)
    assert sch.spec_len(0) == 4                         # capped
    for _ in range(20):
        sch.observe_accept(0, drafted=4, accepted=0)
    assert sch.spec_len(0) == 1                         # floored


def test_serving_spec_windows_match_sequential(rng):
    """Speculative serving ticks (spec_cap > 1) emit exactly the tokens the
    tick-by-tick engine emits on a dense arch, with strictly fewer
    queue-draining pulls once the learned lengths grow past 1."""
    arch = "starcoder2-3b"
    cfg, params = params_for(arch)
    rt = Runtime(cache_len=64)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 9)]

    def run(spec_cap):
        eng = ServingEngine(cfg, params, rt=rt, num_slots=2, spec_cap=spec_cap)
        reqs = [eng.submit(p, max_new=8) for p in prompts]
        eng.run()
        return eng, reqs

    eng_seq, reqs_seq = run(1)
    eng_spec, reqs_spec = run(4)
    for a, b in zip(reqs_spec, reqs_seq):
        assert a.output == b.output, (a.output, b.output)
    assert eng_spec.stats.spec_windows > 0
    assert eng_spec.stats.sync_pulls < eng_seq.stats.sync_pulls
    # dense arch: no residency misses, so self-drafting accepts everything
    assert eng_spec.stats.accepted_tokens == eng_spec.stats.drafted_tokens


def test_serving_spec_with_rotary_residency(rng):
    """Speculative windows + rotary residency: rows reject drafted suffixes at
    residency misses (per-row KV rollback on the ragged batch) yet every
    request still completes with the right token count, and the rejections
    show up as a sub-1.0 accept rate feeding the scheduler's adaptation."""
    cfg, params = params_for("qwen2-moe-a2.7b")
    eng = ServingEngine(
        cfg, params, rt=Runtime(cache_len=32), num_slots=2,
        residency=ResidencyConfig(mode="rotary", num_slots=5), spec_cap=4,
    )
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, 6), max_new=6)
            for _ in range(3)]
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.output) == 6 for r in done)
    assert eng.stats.spec_windows > 0
    assert eng.stats.drafted_tokens > 0
    assert eng.stats.accepted_tokens <= eng.stats.drafted_tokens
