"""Observability subsystem: tracer, metrics registry, contract auditor.

Unit coverage builds traces/metrics by hand (no model); integration coverage
captures REAL traces from every traced mode — fused decode, spec-K windows,
chunked prefill, asynchronous prefetch, continuous-batching serving — and
replays each through the auditor, plus the overlap_ms spans-vs-stats
regression and the per-layer stats table.
"""
import json

import numpy as np
import pytest

from repro.config import ResidencyConfig
from repro.core import RotaryEngine
from repro.models.transformer import Runtime
from repro.obs import (
    MACHINE_TRACKS,
    AuditError,
    MetricsRegistry,
    Tracer,
    audit,
    resolve_tracer,
)
from repro.obs.metrics import Histogram
from repro.serving import ServingEngine

from conftest import params_for


# ===========================================================================
# tracer unit coverage
# ===========================================================================
def test_tracer_span_instant_unit_and_export():
    tr = Tracer()
    u = tr.new_unit("decode")
    assert u == 1 and tr.unit == 1
    with tr.span("launch", "launch", args={"k": 2}):
        pass
    tr.complete("pull", "pull", 1.0, 1.5)
    tr.instant("miss", "launch", args={"layers": 3})
    tr.complete("queued", "request", 0.0, 0.25, lane=7)
    out = tr.chrome_trace()
    evs = [e for e in out["traceEvents"] if e["ph"] != "M"]
    meta = [e for e in out["traceEvents"] if e["ph"] == "M"]
    # every machine track is named in display order; request lane 7 is named
    names = {(m["pid"], m.get("tid")): m["args"]["name"] for m in meta
             if m["name"] == "thread_name"}
    for i, track in enumerate(MACHINE_TRACKS):
        assert names[(1, i)] == track
    assert names[(2, 7)] == "request 7"
    # spans carry dur, instants carry scope, all carry the unit in args
    span = next(e for e in evs if e["name"] == "launch")
    assert span["ph"] == "X" and span["dur"] >= 0
    assert span["args"]["unit"] == 1 and span["args"]["k"] == 2
    inst = next(e for e in evs if e["name"] == "miss")
    assert inst["ph"] == "i" and inst["s"] == "t"
    lane = next(e for e in evs if e["name"] == "queued")
    assert lane["pid"] == 2 and lane["tid"] == 7
    # the export is valid JSON end to end (what Perfetto actually parses)
    json.loads(json.dumps(out))


def test_tracer_ring_capacity_bounds_memory():
    tr = Tracer(capacity=10)
    for i in range(100):
        tr.instant("tick", "launch", args={"i": i})
    assert len(tr) == 10
    # oldest records dropped: the survivors are the 10 newest
    kept = [r[7]["i"] for r in tr.records()]
    assert kept == list(range(90, 100))


def test_resolve_tracer_normalises_disabled_to_none():
    assert resolve_tracer(None) is None
    assert resolve_tracer(Tracer(enabled=False)) is None
    tr = Tracer()
    assert resolve_tracer(tr) is tr


# ===========================================================================
# metrics unit coverage
# ===========================================================================
def test_histogram_percentiles_match_numpy():
    h = Histogram("x_ms")
    xs = np.random.default_rng(0).uniform(0.1, 900.0, 500)
    for v in xs:
        h.observe(v)
    for q in (50, 95, 99):
        assert h.percentile(q) == pytest.approx(np.percentile(xs, q))
    assert h.mean == pytest.approx(xs.mean())
    h.reset()
    assert h.count == 0 and h.percentile(50) == 0.0


def test_registry_exposition_prometheus_format():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests").inc(3)
    reg.gauge("pages_free").set(12)
    h = reg.histogram("lat_ms", "latency", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v)
    reg.set_from({"hit_rate": 0.9, "label": "ignored-non-numeric"})
    text = reg.exposition()
    assert "# TYPE req_total counter\nreq_total 3" in text
    assert "pages_free 12" in text
    assert "engine_hit_rate 0.9" in text
    assert "engine_label" not in text
    # cumulative bucket counts + the +Inf catch-all, sum and count
    assert 'lat_ms_bucket{le="1"} 1' in text
    assert 'lat_ms_bucket{le="10"} 2' in text
    assert 'lat_ms_bucket{le="+Inf"} 3' in text
    assert "lat_ms_sum 55.5" in text and "lat_ms_count 3" in text
    summ = reg.summary()
    assert summ["req_total"] == 3
    assert summ["lat_ms"]["count"] == 3


def test_serve_metrics_http_scrape():
    from urllib.request import urlopen

    from repro.obs import serve_metrics

    reg = MetricsRegistry()
    reg.counter("scrapes").inc()
    server = serve_metrics(lambda: reg, 0)        # port 0: ephemeral
    try:
        port = server.server_address[1]
        body = urlopen(f"http://127.0.0.1:{port}/metrics").read().decode()
        assert "scrapes 1" in body
        with pytest.raises(Exception):
            urlopen(f"http://127.0.0.1:{port}/nope")
    finally:
        server.shutdown()


# ===========================================================================
# auditor unit coverage: hand-built violating traces are rejected
# ===========================================================================
def _ev(name, ts, dur=None, unit=1, cat="launch", **args):
    e = {"ph": "X" if dur is not None else "i", "name": name, "pid": 1,
         "tid": 0, "ts": ts, "cat": cat, "args": {"unit": unit, **args}}
    if dur is not None:
        e["dur"] = dur
    return e


def _clean_unit(unit=1, t0=0.0):
    return [
        _ev("launch", t0, 100.0, unit),
        _ev("prefetch_ship", t0 + 10, 20.0, unit, cat="prefetch"),
        _ev("pull", t0 + 110, 50.0, unit, cat="pull"),
        _ev("rotation", t0 + 170, 30.0, unit, cat="rotation"),
    ]


def test_audit_accepts_clean_trace():
    rep = audit(_clean_unit(1) + _clean_unit(2, 1000.0))
    assert rep.ok and rep.units_checked == 2 and rep.miss_free_units == 2
    assert rep.overlap_ms == pytest.approx(0.04)  # 2 x 20us ship spans


def test_audit_rejects_double_pull_per_window():
    evs = _clean_unit() + [_ev("pull", 200.0, 10.0, cat="pull")]
    rep = audit(evs)
    assert not rep.ok
    assert any("2 primary pulls" in v for v in rep.violations)
    with pytest.raises(AuditError):
        rep.raise_for_violations()


def test_audit_rejects_rotation_mid_window():
    # rotation dispatched BEFORE the queue-draining pull = racing the window
    evs = [
        _ev("launch", 0.0, 100.0),
        _ev("rotation", 50.0, 30.0, cat="rotation"),
        _ev("pull", 110.0, 50.0, cat="pull"),
    ]
    rep = audit(evs)
    assert any("mid-window" in v for v in rep.violations)


def test_audit_rejects_prefetch_outside_overlap_window():
    # ship starts before the launch
    early = [
        _ev("prefetch_ship", 0.0, 5.0, cat="prefetch"),
        _ev("launch", 10.0, 100.0),
        _ev("pull", 120.0, 50.0, cat="pull"),
    ]
    assert any("before the launch" in v for v in audit(early).violations)
    # ship overruns the pull (not hidden under compute at all)
    late = [
        _ev("launch", 0.0, 100.0),
        _ev("prefetch_ship", 90.0, 200.0, cat="prefetch"),
        _ev("pull", 110.0, 50.0, cat="pull"),
    ]
    assert any("overruns the pull" in v for v in audit(late).violations)


def test_audit_rejects_kv_page_use_after_free():
    evs = [
        _ev("kv_ensure", 0.0, None, cat="kv_pool", uid=1, pages=[3, 4]),
        _ev("kv_use", 10.0, None, cat="kv_pool", pages=[3, 4]),
        _ev("kv_release", 20.0, None, cat="kv_pool", uid=1, pages=[3, 4]),
        _ev("kv_use", 30.0, None, cat="kv_pool", pages=[4]),
    ]
    rep = audit(evs)
    assert rep.kv_events == 4
    assert any("after release" in v for v in rep.violations)
    # double release is also flagged
    rep2 = audit(evs[:3] + [
        _ev("kv_release", 40.0, None, cat="kv_pool", uid=1, pages=[3])])
    assert any("double release" in v for v in rep2.violations)


def test_audit_exempts_units_with_misses_and_relaunches():
    # a missed unit legitimately carries extra launches/pulls (relaunch or
    # replay) — exempt from the count, still ordering-checked
    evs = [
        _ev("launch", 0.0, 100.0),
        _ev("miss", 105.0, None),
        _ev("pull", 110.0, 50.0, cat="pull"),
        _ev("launch", 200.0, 40.0, kind="relaunch"),
        _ev("pull", 250.0, 10.0, cat="pull", kind="relaunch"),
        _ev("rotation", 270.0, 30.0, cat="rotation"),
    ]
    rep = audit(evs)
    assert rep.ok and rep.miss_free_units == 0 and rep.units_checked == 1


# ===========================================================================
# integration: real traces from every traced mode pass the auditor
# ===========================================================================
def _trace_rotary(cfg, params, *, steps=4, tr=None, **kw):
    tr = tr if tr is not None else Tracer()
    eng = RotaryEngine(
        cfg, params, ResidencyConfig(mode="rotary", num_slots=6),
        rt=Runtime(cache_len=64), batch=1, trace=tr, **kw,
    )
    prompt = (np.random.default_rng(0)
              .integers(0, cfg.vocab_size, (1, 8)).astype(np.int32))
    eng.generate(prompt, steps)
    return eng, tr


@pytest.mark.parametrize("mode_kw", [
    {},                               # fused single-token decode
    {"spec_k": 2},                    # speculative windows
    {"prefill_chunk": 8},             # chunked prefill
    {"prefetch": True, "spec_k": 2},  # async prefetch under spec windows
])
def test_real_traces_pass_auditor(mode_kw):
    cfg, params = params_for("qwen2-moe-a2.7b")
    eng, tr = _trace_rotary(cfg, params, **mode_kw)
    rep = audit(tr)
    rep.raise_for_violations()
    assert rep.units_checked > 0 and rep.launches > 0 and rep.pulls > 0
    assert rep.rotations > 0
    if mode_kw.get("prefetch"):
        assert rep.prefetch_spans > 0


def test_cb_serving_trace_passes_auditor_with_lanes(tmp_path):
    cfg, params = params_for("qwen2-moe-a2.7b")
    tr = Tracer()
    eng = ServingEngine(
        cfg, params,
        residency=ResidencyConfig(mode="rotary", num_slots=6),
        rt=Runtime(cache_len=64), num_slots=2, spec_cap=2,
        kv_pages=16, kv_page_size=8, trace=tr,
    )
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32),
                       max_new=4) for _ in range(3)]
    eng.run()
    rep = audit(tr)
    rep.raise_for_violations()
    assert rep.kv_events > 0                       # the paged pool was traced
    # the exported file is Perfetto-loadable and shows one lane per request
    path = tmp_path / "cb.json"
    tr.write(path)
    out = json.load(open(path))
    lanes = {e["tid"] for e in out["traceEvents"]
             if e.get("pid") == 2 and e["ph"] != "M"}
    assert lanes == {r.uid for r in reqs}
    # each lane carries the full lifecycle: queued -> prefill -> decode/finish
    for r in reqs:
        names = {e["name"] for e in out["traceEvents"]
                 if e.get("pid") == 2 and e.get("tid") == r.uid}
        assert {"queued", "prefill", "finish"} <= names


def test_overlap_ms_spans_agree_with_legacy_stats():
    # miss-starved prefetch run: the prefetch_ship spans cover exactly the
    # interval the manager's wall-clock side channel accumulates, so the
    # span-derived overlap must agree with EngineStats.overlap_ms
    cfg, params = params_for("qwen2-moe-a2.7b")
    eng, tr = _trace_rotary(cfg, params, steps=6, prefetch=True)
    stats_ms = eng.stats.overlap_ms
    span_ms = tr.overlap_ms()
    assert stats_ms > 0
    assert span_ms == pytest.approx(stats_ms, rel=0.01, abs=1.0)
    assert audit(tr).overlap_ms == pytest.approx(span_ms, abs=0.01)


def test_tracing_off_is_structurally_free():
    # trace=None and a disabled tracer both normalise to NO tracer reference:
    # the hot path executes identical instructions and emits nothing
    cfg, params = params_for("qwen2-moe-a2.7b")
    dis = Tracer(enabled=False)
    eng_off, _ = _trace_rotary(cfg, params, tr=dis)
    assert eng_off._tr is None and eng_off.tracer is None
    assert len(dis) == 0


# ===========================================================================
# per-layer stats + metrics-backed latency summary
# ===========================================================================
def test_per_layer_table_matches_aggregate():
    cfg, params = params_for("qwen2-moe-a2.7b")
    eng, _ = _trace_rotary(cfg, params)
    rows = eng.stats.per_layer()
    assert [r["layer"] for r in rows] == sorted(eng.stats.layers)
    assert sum(r["misses"] for r in rows) == eng.stats.misses
    assert sum(r["hits"] for r in rows) == eng.stats.hits
    table = eng.stats.per_layer_table()
    assert "hit_rate" in table.splitlines()[0]
    assert len(table.splitlines()) == len(rows) + 1


def test_latency_summary_matches_legacy_percentiles():
    cfg, params = params_for("qwen2-moe-a2.7b")
    eng = ServingEngine(
        cfg, params, rt=Runtime(cache_len=64), num_slots=2, spec_cap=2,
        kv_pages=16, kv_page_size=8,
    )
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32),
                   max_new=4)
    eng.run()
    out = eng.latency_summary()
    assert out == eng.latency_summary()            # idempotent (reset+rebuild)
    # the metrics-backed percentiles reproduce the legacy np.percentile math
    done = eng.scheduler.completed
    ttft = [r.first_token_at - r.submitted_at for r in done if r.first_token_at]
    itl = [b - a for r in done
           for a, b in zip(r.token_times, r.token_times[1:])]
    assert out["completed"] == len(done) == 3
    assert out["ttft_p50_ms"] == pytest.approx(
        1e3 * np.percentile(ttft, 50), abs=1e-3)
    assert out["itl_p99_ms"] == pytest.approx(
        1e3 * np.percentile(itl, 99), abs=1e-3)
    # and the same histograms surface in the Prometheus exposition
    text = eng.metrics_registry().exposition()
    assert "ttft_ms_bucket" in text and "itl_ms_count" in text
    assert "engine_hit_rate" in text
