"""RotaryEngine: the exactness property (host miss-correction makes every
policy produce IDENTICAL greedy tokens) + accounting sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import params_for
from repro.config import ResidencyConfig
from repro.core import CostModel, RotaryEngine
from repro.models.transformer import Runtime


def _engine(arch, mode, slots, dtype=None, **kw):
    cfg, params = params_for(arch)
    if dtype is not None:
        import dataclasses

        import jax.numpy as jnp
        from repro.models import init_params

        cfg = dataclasses.replace(cfg, dtype=dtype)
        params = init_params(cfg, jax.random.PRNGKey(0))
    res = ResidencyConfig(mode=mode, num_slots=slots, prefetch_margin=2, **kw)
    return cfg, RotaryEngine(cfg, params, res, rt=Runtime(cache_len=64), batch=2)


@pytest.mark.parametrize("arch", ["qwen36-35b-a3b", "qwen2-moe-a2.7b"])
def test_all_policies_exact(arch, rng):
    """Greedy decode tokens are identical under full / rotary / lru / static —
    the engine's miss correction is exact, residency changes only WHERE
    compute happens (paper §4: behaviour preserved, residency managed).

    Exactness requires host dtype == device compute dtype (f32 here): under
    bf16 device compute the f32 host correction is *more* accurate than the
    device path it replaces, so near-tie argmax tokens may differ — that skew
    is bounded by bf16 epsilon and covered by test_int8_residency_close_logits.
    """
    prompt = rng.integers(0, 200, (2, 10)).astype(np.int32)
    outs = {}
    for mode, slots in [("full", 0), ("rotary", 5), ("lru", 5), ("static", 5)]:
        cfg, eng = _engine(arch, mode, slots, dtype="float32")
        outs[mode] = eng.generate(prompt, 8)
    for mode in ("rotary", "lru", "static"):
        np.testing.assert_array_equal(outs["full"], outs[mode])


def test_rotary_prefetch_beats_lru_on_bytes(rng):
    """Rotary moves bytes off the critical path: stalls modeled lower than
    LRU's blocking loads under a recurring workload."""
    prompt = rng.integers(0, 200, (2, 12)).astype(np.int32)
    _, rot = _engine("qwen36-35b-a3b", "rotary", 5)
    rot.generate(prompt, 12)
    _, lru = _engine("qwen36-35b-a3b", "lru", 5)
    lru.generate(prompt, 12)
    # LRU stalls on every miss-load; rotary misses go to host & prefetch hides DMA
    assert rot.stats.hit_rate >= 0.3
    assert lru.stats.stall_s > 0.0


def test_residency_restricts_device_params():
    """With rotary residency, the device layer params must NOT contain the
    full expert store (the warehouse stays in host memory)."""
    cfg, eng = _engine("qwen36-35b-a3b", "rotary", 5)
    for kind, p_l in eng.layers:
        if kind == "attn_moe":
            assert "experts" not in p_l["moe"]
    cfg2, eng_full = _engine("qwen36-35b-a3b", "full", 0)
    for kind, p_l in eng_full.layers:
        if kind == "attn_moe":
            assert "experts" in p_l["moe"]


def test_stats_accounting(rng):
    cfg, eng = _engine("qwen36-35b-a3b", "rotary", 5)
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    eng.generate(prompt, 6)
    s = eng.stats
    assert s.steps == 6
    assert s.tokens == 2 * 8 + 2 * 6
    assert s.hits + s.misses == (8 * 2 + 6 * 2) * cfg.moe.top_k * cfg.num_layers
    assert s.bytes_loaded > 0
    assert s.compute_s > 0
    assert s.modeled_step_time() > 0


def test_int8_residency_close_logits(rng):
    """int8 slot quantization (Q4_K_M analog) perturbs logits only mildly on
    the reduced model."""
    cfg, params = params_for("qwen36-35b-a3b")
    prompt = rng.integers(0, 200, (1, 8)).astype(np.int32)
    eng_fp = RotaryEngine(cfg, params, ResidencyConfig(mode="rotary", num_slots=6),
                          rt=Runtime(cache_len=32), batch=1)
    lg_fp = eng_fp.prefill(prompt)
    eng_q = RotaryEngine(cfg, params,
                         ResidencyConfig(mode="rotary", num_slots=6, quantization="int8"),
                         rt=Runtime(cache_len=32), batch=1)
    lg_q = eng_q.prefill(prompt)
    denom = np.abs(lg_fp).max() + 1e-9
    assert np.abs(lg_fp - lg_q).max() / denom < 0.2


def test_modeled_full_scale_throughput():
    """CostModel on the FULL paper arch: decode should land in a plausible
    tok/s range for a v5e chip (sanity of the Table-4 modeling path)."""
    from repro.config import get_config
    from repro.models.params import analytic_params

    cfg = get_config("qwen36-35b-a3b")
    cost = CostModel()
    active_bytes = 2 * analytic_params(cfg, active_only=True)
    t = cost.compute_s(2 * analytic_params(cfg, active_only=True), active_bytes)
    assert 1.0 / t > 50.0          # decode is HBM-bound; far above the paper's 21 tok/s on 8GB-laptop


def test_batch2_matches_two_batch1_runs(rng):
    """Batched greedy decode is row-exact: a batch=2 engine produces the same
    tokens as two independent batch=1 engines over the same prompts (residency
    rotation sees different aggregate demand, but miss correction keeps the
    computed tokens independent of residency)."""
    from conftest import params_for
    import dataclasses
    from repro.models import init_params

    cfg, _ = params_for("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = rng.integers(0, 200, (2, 9)).astype(np.int32)

    def make(batch):
        return RotaryEngine(
            cfg, params, ResidencyConfig(mode="rotary", num_slots=5),
            rt=Runtime(cache_len=64), batch=batch,
        )

    out2 = make(2).generate(prompt, 8)
    out_a = make(1).generate(prompt[:1], 8)
    out_b = make(1).generate(prompt[1:], 8)
    np.testing.assert_array_equal(out2[0], out_a[0])
    np.testing.assert_array_equal(out2[1], out_b[0])


def test_full_matches_rotary_tokens(rng):
    """Full-residency (everything on device, hot path, zero misses) and the
    rotary path (slots + replayed miss correction) agree token-for-token."""
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    _, eng_full = _engine("qwen2-moe-a2.7b", "full", 0, dtype="float32")
    _, eng_rot = _engine("qwen2-moe-a2.7b", "rotary", 5, dtype="float32")
    np.testing.assert_array_equal(
        eng_full.generate(prompt, 10), eng_rot.generate(prompt, 10)
    )


def test_hot_path_matches_host_routing_baseline(rng):
    """The device-resident hot path reproduces the seed-style engine
    (per-layer blocking host routing) token-for-token, with strictly fewer
    queue-draining device->host pulls."""
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    cfg, params = params_for("qwen2-moe-a2.7b")
    import dataclasses
    from repro.models import init_params

    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))

    def make(host_routing):
        return RotaryEngine(
            cfg, params, ResidencyConfig(mode="rotary", num_slots=5),
            rt=Runtime(cache_len=64), batch=2, host_routing=host_routing,
        )

    eng_hot, eng_base = make(False), make(True)
    out_hot = eng_hot.generate(prompt, 8)
    out_base = eng_base.generate(prompt, 8)
    np.testing.assert_array_equal(out_hot, out_base)
    assert eng_hot._hot_decode and not eng_base._hot_decode
    # mechanism parity: same number of routed assignments accounted, and every
    # counted miss was host-corrected in both engines
    assert (eng_hot.stats.hits + eng_hot.stats.misses
            == eng_base.stats.hits + eng_base.stats.misses)
    assert sum(l.host_computed for l in eng_hot.stats.layers.values()) \
        == eng_hot.stats.misses
    assert sum(l.host_computed for l in eng_base.stats.layers.values()) \
        == eng_base.stats.misses


def test_hot_decode_one_sync_pull_per_token(rng):
    """Acceptance: on the miss-free path (full residency) the decode step
    issues exactly ONE queue-draining device->host transfer per token."""
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    _, eng = _engine("qwen2-moe-a2.7b", "full", 0)
    logits = eng.prefill(prompt)
    pulls_after_prefill = eng.stats.sync_pulls
    steps = 6
    eng.decode(logits, steps)
    assert eng.stats.sync_pulls - pulls_after_prefill == steps
    assert eng.stats.misses == 0
