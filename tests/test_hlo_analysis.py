"""HLO analyzer: loop multipliers, dot flops, collective wire bytes."""
import numpy as np

from repro.launch.hlo_analysis import (
    HloAnalysis,
    analyze_hlo,
    parse_module,
    shape_bytes,
    shape_dims,
    xla_cost_analysis,
)

HLO = """
HloModule jit_f, num_partitions=16

%body (param: (s32[], f32[4,256], f32[8,256,64])) -> (s32[], f32[4,256], f32[8,256,64]) {
  %param = (s32[], f32[4,256]{1,0}, f32[8,256,64]{2,1,0}) parameter(0)
  %gte1 = f32[4,256]{1,0} get-tuple-element(%param), index=1
  %gte2 = f32[8,256,64]{2,1,0} get-tuple-element(%param), index=2
  %slice = f32[256,64]{1,0} bitcast(%gte2)
  %dot = f32[4,64]{1,0} dot(%gte1, %slice), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %all-gather = f32[4,256]{0,1} all-gather(%dot), channel_id=1, replica_groups=[4,4]<=[16], dimensions={1}
  %ar = f32[4,256]{1,0} all-reduce(%all-gather), channel_id=2, replica_groups={{0,1,2,3},{4,5,6,7},{8,9,10,11},{12,13,14,15}}, to_apply=%add
  ROOT %tuple = (s32[], f32[4,256]{1,0}, f32[8,256,64]{2,1,0}) tuple(%gte1, %ar, %gte2)
}

%cond (param.1: (s32[], f32[4,256], f32[8,256,64])) -> pred[] {
  %param.1 = (s32[], f32[4,256]{1,0}, f32[8,256,64]{2,1,0}) parameter(0)
  ROOT %lt = pred[] compare(%param.1, %param.1), direction=LT
}

ENTRY %main (p0: f32[8,256,64], p1: f32[4,256]) -> f32[4,256] {
  %p0 = f32[8,256,64]{2,1,0} parameter(0)
  %p1 = f32[4,256]{1,0} parameter(1)
  %tuple.0 = (s32[], f32[4,256]{1,0}, f32[8,256,64]{2,1,0}) tuple(%p0, %p1, %p0)
  %while = (s32[], f32[4,256]{1,0}, f32[8,256,64]{2,1,0}) while(%tuple.0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"8"}}
  ROOT %out = f32[4,256]{1,0} get-tuple-element(%while), index=1
}
"""


def test_shape_parsing():
    assert shape_dims("f32[4,256]{1,0}") == [4, 256]
    assert shape_bytes("f32[4,256]{1,0}") == 4 * 256 * 4
    assert shape_bytes("bf16[8]") == 16
    assert shape_bytes("(s32[], f32[2,2])") == 4 + 16
    assert shape_bytes("pred[]") == 1


def test_module_parse():
    comps, entry = parse_module(HLO)
    assert entry == "main"
    assert set(comps) == {"body", "cond", "main"}
    assert any(op.opcode == "while" for op in comps["main"].ops)


def test_loop_multiplied_flops_and_collectives():
    a = analyze_hlo(HLO)
    # dot: 2 * 4*64 * 256 per iteration, 8 iterations
    assert a.flops == 8 * 2 * 4 * 64 * 256
    # all-gather result f32[4,256] = 4096B, factor (4-1)/4, 8 iterations
    ag = 8 * 4096 * 3 / 4
    # all-reduce operand f32[4,256] = 4096B, factor 2*(4-1)/4
    ar = 8 * 4096 * 2 * 3 / 4
    assert abs(a.collectives["all-gather"] - ag) < 1e-6
    assert abs(a.collectives["all-reduce"] - ar) < 1e-6
    assert a.collective_counts["all-gather"] == 8


def test_real_compile_roundtrip():
    """Analyzer vs an unrolled (loop-free) module where XLA's own cost
    analysis is trustworthy: flops must agree."""
    import jax
    import jax.numpy as jnp

    def f(a, b):
        return (a @ b).sum()

    aS = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    bS = jax.ShapeDtypeStruct((64, 16), jnp.float32)
    comp = jax.jit(f).lower(aS, bS).compile()
    mine = analyze_hlo(comp.as_text())
    theirs = xla_cost_analysis(comp)["flops"]
    assert abs(mine.flops - theirs) <= 0.1 * theirs + 128
