"""Per-arch smoke tests (deliverable f): every assigned architecture's reduced
config runs a real forward/train step on CPU — correct shapes, no NaNs — plus
prefill+decode consistency for one arch per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import params_for
from repro.configs import ALL_ARCHS
from repro.models import decode_model, lm_loss, prefill_model
from repro.models.transformer import Runtime


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train_step(arch, rng):
    cfg, params = params_for(arch)
    rt = Runtime()
    s = 24
    s_tok = s - (cfg.frontend_len if cfg.frontend else 0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, s_tok)), jnp.int32)
    fe = None
    if cfg.frontend:
        fe = jnp.asarray(rng.standard_normal((2, cfg.frontend_len, cfg.frontend_dim)),
                         jnp.float32)
    (loss, aux), grads = jax.value_and_grad(
        lambda p: lm_loss(cfg, p, tokens, tokens, rt, fe), has_aux=True
    )(params)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_decode_step(arch, rng):
    cfg, params = params_for(arch)
    rt = Runtime(cache_len=32)
    s_tok = 16 - (cfg.frontend_len if cfg.frontend else 0)
    if cfg.frontend:
        s_tok = 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, s_tok)), jnp.int32)
    fe = None
    if cfg.frontend:
        fe = jnp.asarray(rng.standard_normal((2, cfg.frontend_len, cfg.frontend_dim)),
                         jnp.float32)
    logits, state = prefill_model(cfg, params, tokens, rt, fe)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    cur = s_tok + (cfg.frontend_len if cfg.frontend else 0)
    lg2, state, _ = decode_model(cfg, params, jnp.argmax(logits, -1).astype(jnp.int32),
                                 state, jnp.int32(cur), rt)
    assert lg2.shape == (2, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(lg2, np.float32)))


@pytest.mark.parametrize("arch", ["starcoder2-3b", "qwen2-moe-a2.7b",
                                  "recurrentgemma-2b", "xlstm-350m"])
def test_decode_matches_teacher_forcing(arch, rng):
    """Greedy decode logits must match the training forward at the same
    positions (KV-cache / recurrent-state correctness end to end)."""
    cfg, params = params_for(arch)
    rt = Runtime(cache_len=24)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)), jnp.int32)
    from repro.models import forward_train, lm_logits

    h, _ = forward_train(cfg, params, tokens, rt)
    logits_tf = lm_logits(cfg, params, h)              # [1, 12, V]
    # bf16 params + different-but-equivalent dispatch paths (train: sorted,
    # decode: gathered) round differently; compare within bf16 noise and on
    # the greedy decision
    tol = dict(atol=6e-2, rtol=6e-2)
    logits_pre, state = prefill_model(cfg, params, tokens[:, :8], rt)
    np.testing.assert_allclose(
        np.asarray(logits_pre, np.float32), np.asarray(logits_tf[:, 7], np.float32),
        **tol,
    )
    assert int(np.argmax(logits_pre)) == int(np.argmax(logits_tf[:, 7]))
    for t in range(8, 12):
        lg, state, _ = decode_model(cfg, params, tokens[:, t], state,
                                    jnp.int32(t), rt)
        np.testing.assert_allclose(
            np.asarray(lg, np.float32), np.asarray(logits_tf[:, t], np.float32),
            **tol,
        )
        assert int(np.argmax(lg)) == int(np.argmax(logits_tf[:, t]))
