"""Speculative multi-token decode on the fused step: the exactness harness.

The suite proves (not assumes) the spec-window invariants:
  * greedy tokens from spec-K decode (K in {2, 4, 8}) are bit-identical to the
    single-token fused path AND the seed per-layer walk, across full / rotary
    (prefetch-covered) / rotary-with-forced-misses;
  * pull-count regression: <= ceil(T/K) + replayed_steps queue-draining pulls
    per sequence (net of the replay machinery's own accounted reads), and
    EXACTLY ceil(T/K) on the miss-free paths;
  * accept/draft accounting: greedy self-drafting accepts everything miss-free
    (accept_rate == 1.0 — the KV-rollback canary) and only misses reject;
  * the KV rollback helper truncates bit-exactly (tier-1 mirror of the
    hypothesis property in test_rotation_properties);
  * window-deferred rotation leaves residency bit-identical to rotating after
    every token (tier-1 mirror), while moving no MORE bytes over the link.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import params_for
from repro.config import ResidencyConfig
from repro.core import DemandPredictor, RotaryEngine, RotaryResidencyManager
from repro.models import init_params
from repro.models import transformer as tfm
from repro.models.transformer import Runtime


def _f32_setup():
    cfg, _ = params_for("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, mode, slots, **kw):
    return RotaryEngine(
        cfg, params, ResidencyConfig(mode=mode, num_slots=slots, prefetch_margin=2),
        rt=Runtime(cache_len=64), batch=2, **kw,
    )


# ===========================================================================
# exactness: spec-K == single-token fused == seed walk, every residency mode
# ===========================================================================
@pytest.mark.parametrize("spec_k", [2, 4, 8])
def test_spec_decode_exact_all_modes(rng, spec_k):
    """Greedy tokens from speculative windows are bit-identical to the
    single-token fused path and to the seed-style per-layer walk under full
    residency, prefetch-covered rotary, AND a slot-starved rotary engine
    whose misses force KV rollback + replay on nearly every window."""
    cfg, params = _f32_setup()
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    T = 10
    e = cfg.moe.num_experts
    for mode, slots in (("full", 0), ("rotary", e), ("rotary", 5)):
        seed_walk = _engine(cfg, params, mode, slots, host_routing=True)
        fused = _engine(cfg, params, mode, slots)
        spec = _engine(cfg, params, mode, slots, spec_k=spec_k)
        ref = seed_walk.generate(prompt, T)
        np.testing.assert_array_equal(
            ref, fused.generate(prompt, T), err_msg=f"{mode}/{slots} fused"
        )
        np.testing.assert_array_equal(
            ref, spec.generate(prompt, T),
            err_msg=f"{mode}/{slots} spec_k={spec_k}",
        )
        assert spec._fused_decode and spec.stats.spec_windows > 0
        if slots == 5:
            # the starved config actually exercised rollback + replay
            assert spec.stats.replayed_steps > 0
            assert spec.stats.misses > 0
            assert spec.stats.accepted_tokens < spec.stats.drafted_tokens
        # mechanism parity: every counted miss was host-corrected
        s = spec.stats
        assert sum(l.host_computed for l in s.layers.values()) == s.misses


def test_spec_matches_chained_decodes(rng):
    """Window state carries across decode() calls: chained spec decodes from
    ``last_logits`` continue the exact greedy sequence."""
    cfg, params = _f32_setup()
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    ref = _engine(cfg, params, "rotary", 5).generate(prompt, 12)
    eng = _engine(cfg, params, "rotary", 5, spec_k=4)
    logits = eng.prefill(prompt)
    a = eng.decode(logits, 7)
    b = eng.decode(eng.last_logits, 5)
    np.testing.assert_array_equal(ref, np.concatenate([a, b], axis=1))


# ===========================================================================
# pull-count regression
# ===========================================================================
def test_spec_pull_count_miss_free(rng):
    """Miss-free spec decode: EXACTLY ceil(T/K) queue-draining pulls (and
    compiled-program launches) for T tokens — the window amortizes the
    per-token pull the fused single-token path was bounded by."""
    cfg, params = _f32_setup()
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    for T, K in ((12, 4), (10, 4), (12, 2)):
        eng = _engine(cfg, params, "full", 0, spec_k=K)
        logits = eng.prefill(prompt)
        pulls0, disp0 = eng.stats.sync_pulls, eng.stats.device_dispatches
        eng.decode(logits, T)
        want = math.ceil(T / K)
        assert eng.stats.sync_pulls - pulls0 == want, (T, K)
        assert eng.stats.device_dispatches - disp0 == want, (T, K)
        assert eng.stats.misses == 0


def test_spec_pull_count_with_replays(rng):
    """Slot-starved spec decode: window-level queue-draining pulls (sync
    pulls net of the replay machinery's own accounted reads) stay within
    ceil(T/K) + replayed_steps — every replayed window still commits at
    least one token, so rejection cannot blow up the pull budget."""
    cfg, params = _f32_setup()
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    T, K = 12, 4
    eng = _engine(cfg, params, "rotary", 5, spec_k=K)
    logits = eng.prefill(prompt)
    pulls0, rp0 = eng.stats.sync_pulls, eng.stats.replay_pulls
    eng.decode(logits, T)
    window_pulls = (eng.stats.sync_pulls - pulls0) - (eng.stats.replay_pulls - rp0)
    assert eng.stats.replayed_steps > 0          # the bound is exercised
    assert window_pulls <= math.ceil(T / K) + eng.stats.replayed_steps


# ===========================================================================
# accept/draft counters
# ===========================================================================
def test_spec_accept_rate_miss_free_is_one(rng):
    """Greedy self-drafting with identical weights must accept EVERY drafted
    token when no residency miss occurs — accept_rate < 1.0 here would mean
    the KV rollback / replay machinery corrupted the window state."""
    cfg, params = _f32_setup()
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    eng = _engine(cfg, params, "full", 0, spec_k=4)
    eng.generate(prompt, 12)
    assert eng.stats.drafted_tokens == 12
    assert eng.stats.accepted_tokens == eng.stats.drafted_tokens
    assert eng.stats.accept_rate >= 1.0


def test_greedy_accept_rule():
    """The sampler-level accept rule: longest agreeing prefix, per row."""
    from repro.serving.sampler import greedy_accept, stochastic_accept

    draft = np.array([[1, 5], [2, 6], [3, 7]], np.int32)          # [K=3, B=2]
    verify = np.array([[1, 5], [2, 9], [3, 7]], np.int32)
    np.testing.assert_array_equal(greedy_accept(draft, verify), [3, 1])
    np.testing.assert_array_equal(greedy_accept(draft, draft), [3, 3])
    # position 0 disagreement rejects the whole window for that row
    verify0 = verify.copy(); verify0[0, 0] = 99
    np.testing.assert_array_equal(greedy_accept(draft, verify0), [0, 1])
    # the stochastic counterpart degenerates to accept-all when draft and
    # verify distributions coincide (ratio 1.0, u < 1 always); the full
    # distributional contract lives in tests/test_stochastic_decode.py
    probs = np.full((3, 2, 8), 1 / 8)
    acc, res = stochastic_accept(draft, probs, probs, np.random.default_rng(0))
    np.testing.assert_array_equal(acc, [3, 3])
    np.testing.assert_array_equal(res, [-1, -1])


def test_spec_k_validation():
    cfg, params = _f32_setup()
    with pytest.raises(AssertionError):
        _engine(cfg, params, "lru", 5, spec_k=4)          # LRU: no fused path
    with pytest.raises(AssertionError):
        _engine(cfg, params, "rotary", 5, host_routing=True, spec_k=4)
    with pytest.raises(AssertionError):
        _engine(cfg, params, "full", 0, spec_k=65)        # > cache capacity


def test_spec_speculates_for_sampled_decode(rng):
    """Non-greedy decode runs through the SAME fused speculative windows as
    greedy: the stochastic accept rule keeps the output stream exactly the
    seeded target distribution's draw, so spec_windows > 0 and the tokens
    bitwise-match a single-token sampled engine (the deep stream-equivalence
    matrix lives in tests/test_stochastic_decode.py)."""
    cfg, params = _f32_setup()
    prompt = rng.integers(0, 200, (2, 8)).astype(np.int32)
    eng = _engine(cfg, params, "full", 0, spec_k=4)
    logits = eng.prefill(prompt)
    out = eng.decode(logits, 4, greedy=False, seed=3)
    assert out.shape == (2, 4)
    assert eng.stats.spec_windows > 0
    ref = _engine(cfg, params, "full", 0)
    logits = ref.prefill(prompt)
    np.testing.assert_array_equal(out, ref.decode(logits, 4, greedy=False, seed=3))


# ===========================================================================
# tier-1 mirrors of the hypothesis properties
# ===========================================================================
def test_kv_rollback_truncate_then_redecode():
    """tfm.rollback_kv_window: truncate-then-redecode == never-decoded, for
    both full and ring (windowed) caches, at several keep points."""
    cfg, _ = params_for("qwen2-moe-a2.7b")
    batch, cache_len, c0, K = 2, 16, 6, 4

    def write(state, pos, tag):
        """Deterministic stand-in for a decode step's KV write at ``pos``."""
        segs = []
        for si, (unit, reps) in enumerate(cfg.segments):
            unit_new = []
            for pi, kind in enumerate(unit):
                st = state[si][pi]
                if kind in tfm._KV_KINDS:
                    def put(c):
                        cap = c.shape[2]
                        val = jnp.full(c.shape[-2:], tag * 1000 + pos, c.dtype)
                        return c.at[:, :, pos % cap].set(val)
                    st = jax.tree.map(put, st)
                unit_new.append(st)
            segs.append(tuple(unit_new))
        return tuple(segs)

    def leaves(state):
        return [np.asarray(x) for x in jax.tree.leaves(state)]

    for keep in (0, 2, 4):
        state = tfm.zero_state(cfg, batch, cache_len)
        for p in range(c0):
            state = write(state, p, tag=1)              # committed history
        saved = tfm.snapshot_kv_window(cfg, state, jnp.int32(c0), K)
        for j in range(K):
            state = write(state, c0 + j, tag=7)         # speculative window
        state = tfm.rollback_kv_window(
            cfg, state, saved, jnp.int32(c0), K, jnp.int32(keep)
        )
        for p in range(c0 + keep, c0 + K):
            state = write(state, p, tag=1)              # redecode the suffix
        ref = tfm.zero_state(cfg, batch, cache_len)
        for p in range(c0 + K):
            ref = write(ref, p, tag=1)                  # never speculated
        # accepted window positions keep their (tag=7) speculative writes;
        # neutralize them in both trees before comparing the rest
        for p in range(c0, c0 + keep):
            state = write(state, p, tag=0)
            ref = write(ref, p, tag=0)
        for a, b in zip(leaves(state), leaves(ref)):
            np.testing.assert_array_equal(a, b)


def test_window_rotation_matches_sequential():
    """rotate_window_from_telemetry leaves residency (LUT, ring position,
    resident slot contents, predictor EMA) bit-identical to feeding the same
    steps through rotate_from_telemetry one at a time — while never moving
    MORE bytes (uploads coalesce to the last write per slot)."""
    cfg, _ = params_for("qwen2-moe-a2.7b")
    E, L, T, k, K = cfg.moe.num_experts, 2, 4, cfg.moe.top_k, 4
    rng = np.random.default_rng(3)

    def mk(seed):
        r = np.random.default_rng(seed)
        hw = [
            {n: r.standard_normal(s).astype(np.float32)
             for n, s in (("w_gate", (E, 4, 3)), ("w_up", (E, 4, 3)),
                          ("w_down", (E, 3, 4)))}
            for _ in range(L)
        ]
        routers = [r.standard_normal((4, E)).astype(np.float32) for _ in range(L)]
        mgr = RotaryResidencyManager(
            cfg, ResidencyConfig(mode="rotary", num_slots=5), hw,
            batch=1, cache_len=16, seed=7,
        )
        return mgr, DemandPredictor(routers)

    m_seq, p_seq = mk(1)
    m_win, p_win = mk(1)
    ids = rng.integers(0, E, (K, L, T, k)).astype(np.int32)
    w = rng.random((K, L, T, k)).astype(np.float32)
    miss = rng.random((K, L, T, k)) < 0.2
    dem = rng.random((K, L, E))
    for s in range(K):
        m_seq.rotate_from_telemetry(p_seq, ids[s], w[s], miss[s], dem[s])
    m_win.rotate_window_from_telemetry(p_win, ids, w, miss, dem)
    for l in range(L):
        np.testing.assert_array_equal(
            m_seq.policies[l].lut.e2s, m_win.policies[l].lut.e2s
        )
        assert m_seq.policies[l].ring.pos == m_win.policies[l].ring.pos
        np.testing.assert_array_equal(p_seq.smoothed[l], p_win.smoothed[l])
        for s_ in range(m_seq.num_slots):
            e = int(m_seq.policies[l].lut.s2e[s_])
            if e < 0:
                continue
            for n in m_seq.stores[l].buffers:
                np.testing.assert_array_equal(
                    np.asarray(m_seq.stores[l].buffers[n][s_]),
                    np.asarray(m_win.stores[l].buffers[n][s_]),
                )
        assert m_seq.stats.layer(l).hits == m_win.stats.layer(l).hits
        assert m_seq.stats.layer(l).misses == m_win.stats.layer(l).misses
    assert m_win.stats.bytes_loaded <= m_seq.stats.bytes_loaded
