"""Hypothesis properties for temperature > 0 decode: host/device warp parity
over drawn (temperature, top_k, top_p) grids, top-k tie discipline, and the
distributional exactness of ``stochastic_accept`` (output ~ q, acceptance rate
= sum(min(p, q)), leftover-only resampling) for arbitrary draft/verify
divergences. ``tests/test_stochastic_decode.py`` anchors the same claims at
fixed seeds in tier-1; this module fuzzes them."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.models import sampling
from repro.serving.sampler import Sampler, SamplerConfig, stochastic_accept

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")

# logits quantized to a coarse grid: warp parity is bitwise-on-support, and a
# f32(device)-vs-f64(host) comparison must not flake on near-ties at the
# top-k/top-p boundary that the two precisions order differently
_logit = st.integers(-8, 8).map(lambda i: i * 0.5)


@given(
    logits=st.lists(_logit, min_size=4, max_size=16),
    temperature=st.floats(0.2, 2.0),
    top_k=st.integers(0, 16),
    top_p=st.floats(0.3, 1.0),
)
def test_warp_parity_host_vs_device(logits, temperature, top_k, top_p):
    """The on-device warp (``sampling.warp_probs`` — what decode_window
    drafts from) matches the host ``Sampler`` reference: identical kept set,
    renormalized probabilities equal within f32 tolerance."""
    x = np.asarray(logits, np.float64)[None, :]
    host = Sampler(SamplerConfig(
        temperature=temperature, top_k=top_k, top_p=top_p
    )).warp(x)[0]
    sp = sampling.SampleParams(
        temperature=float(temperature), top_k=int(top_k), top_p=float(top_p)
    )
    dev = np.asarray(sampling.warp_probs(jnp.asarray(x, jnp.float32), sp))[0]
    np.testing.assert_array_equal(dev > 0, host > 0)
    np.testing.assert_allclose(dev, host, atol=2e-5)


@given(
    v=st.integers(4, 24),
    k=st.integers(1, 24),
    tie_value=_logit,
    n_tied=st.integers(2, 8),
)
def test_topk_keeps_exactly_k_under_ties(v, k, tie_value, n_tied):
    """However many logits tie at the threshold, the kept set has exactly
    min(k, v) members and ties break toward the lower index."""
    k = min(k, v)
    n_tied = min(n_tied, v)
    logits = np.linspace(-4, -2, v)
    logits[:n_tied] = tie_value                  # a tie block at the top/front
    host = Sampler(SamplerConfig(temperature=1.0, top_k=k)).warp(
        logits[None, :]
    )[0]
    assert (host > 0).sum() == k
    sp = sampling.SampleParams(temperature=1.0, top_k=int(k))
    dev = np.asarray(
        sampling.warp_probs(jnp.asarray(logits[None, :], jnp.float32), sp)
    )[0]
    np.testing.assert_array_equal(dev > 0, host > 0)


@st.composite
def _dist_pair(draw):
    v = draw(st.integers(3, 12))
    raw_p = draw(st.lists(st.integers(1, 50), min_size=v, max_size=v))
    raw_q = draw(st.lists(st.integers(0, 50), min_size=v, max_size=v))
    p = np.asarray(raw_p, np.float64)
    q = np.asarray(raw_q, np.float64)
    if q.sum() == 0:
        q[draw(st.integers(0, v - 1))] = 1.0
    return p / p.sum(), q / q.sum()


@given(pq=_dist_pair(), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_stochastic_accept_output_is_target_distributed(pq, seed):
    """Accept-or-resample emits exactly q for ANY (p, q): chi-squared on the
    emitted tokens plus the analytic acceptance-rate identity."""
    p, q = pq
    v = len(p)
    r = np.random.default_rng(seed)
    n = 15_000
    draft = r.choice(v, size=(1, n), p=p).astype(np.int32)
    acc, res = stochastic_accept(
        draft, np.broadcast_to(p, (1, n, v)), np.broadcast_to(q, (1, n, v)), r
    )
    emitted = np.where(acc == 1, draft[0], res)
    counts = np.bincount(emitted, minlength=v)
    exp = n * q
    keep = exp > 0
    stat = ((counts[keep] - exp[keep]) ** 2 / exp[keep]).sum()
    df = int(keep.sum()) - 1
    crit = df * (1 - 2 / (9 * df) + 3.1 * np.sqrt(2 / (9 * df))) ** 3
    assert stat < crit, (stat, crit)
    assert counts[~keep].sum() == 0              # never emits outside q
    analytic = np.minimum(p, q).sum()
    tol = 5 * np.sqrt(max(analytic * (1 - analytic), 1e-4) / n)
    assert abs(acc.mean() - analytic) < tol
    rejected = res[acc == 0]
    if rejected.size:
        support = np.flatnonzero(np.maximum(q - p, 0) > 0)
        if support.size:                          # p == q -> fallback to q
            assert np.isin(rejected, support).all()


@given(
    seed=st.integers(0, 2**31 - 1),
    temperature=st.floats(0.3, 1.5),
    top_k=st.integers(0, 10),
    top_p=st.floats(0.5, 1.0),
)
@settings(max_examples=15, deadline=None)
def test_host_draw_matches_warp_distribution(seed, temperature, top_k, top_p):
    """The vectorized inverse-CDF draw honors the warped distribution: every
    drawn token is on-support, and single-outcome supports draw surely."""
    v = 10
    logits = np.random.default_rng(seed).normal(size=v)
    s = Sampler(SamplerConfig(
        temperature=temperature, top_k=top_k, top_p=top_p, seed=seed
    ))
    target = s.warp(logits[None, :])[0]
    toks = s(np.broadcast_to(logits, (256, v)))
    assert np.isin(toks, np.flatnonzero(target > 0)).all()
    if (target > 0).sum() == 1:
        assert (toks == int(np.argmax(target))).all()
