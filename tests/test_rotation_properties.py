"""Hypothesis property tests for the paper's core invariants:
LUT bijectivity, rotation boundedness, window coverage, cyclic return —
plus the speculative-decode invariants: KV rollback (truncate-then-redecode
== never-decoded) and window-deferred rotation (residency after a window ==
residency after the same tokens applied one-by-one)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.lut import SlotLUT
from repro.core.rotation import RotaryRing, cosine

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


@given(
    e=st.integers(4, 64),
    s=st.integers(1, 16),
    ops=st.lists(st.tuples(st.integers(0, 1000), st.integers(0, 1000)), max_size=60),
)
def test_lut_stays_consistent(e, s, ops):
    """assign/evict in any order keeps e2s and s2e mutually inverse."""
    s = min(s, e)
    lut = SlotLUT(e, s)
    for a, b in ops:
        expert = a % e
        if b % 3 == 0:
            lut.evict(expert)
        else:
            lut.assign(expert, b % s)
        lut.check_consistent()
    assert len(lut.resident_experts) <= s


@given(
    e=st.integers(8, 64),
    frac=st.floats(0.2, 0.9),
    stride=st.integers(1, 6),
    steps=st.integers(1, 40),
    seed=st.integers(0, 5),
)
def test_rotation_window_properties(e, frac, stride, steps, seed):
    s = max(2, int(e * frac))
    ring = RotaryRing(e, s, max_stride=stride, seed=seed)
    rng = np.random.default_rng(seed)
    prev_pos = ring.pos
    for _ in range(steps):
        demand = rng.random(e)
        dec = ring.rotate(demand)
        # window is always exactly s distinct experts
        assert len(dec.window) == s
        assert len(np.unique(dec.window)) == s
        assert set(dec.window.tolist()) <= set(range(e))
        # non-jump transitions are bounded by the stride
        if not dec.reverse_jump:
            assert abs(dec.delta) <= stride
        prev_pos = ring.pos


def test_rotation_prefers_demand():
    """The window rotates toward concentrated demand."""
    e, s = 16, 4
    ring = RotaryRing(e, s, max_stride=4, rering_every=10**9, snapshot_every=10**9)
    demand = np.zeros(e)
    demand[6:10] = 1.0            # hot experts sit at ring positions 6..9
    for _ in range(6):
        dec = ring.rotate(demand)
    assert set(dec.window.tolist()) == {6, 7, 8, 9}


def test_cyclic_return_on_recurring_context():
    """After visiting context A then B, re-presenting A's demand vector jumps
    the window back (the paper's reverse rotation / cyclical return)."""
    e, s = 32, 8
    ring = RotaryRing(e, s, max_stride=2, reverse_threshold=0.9,
                      snapshot_every=1, rering_every=10**9)
    rng = np.random.default_rng(0)
    demand_a = np.zeros(e); demand_a[0:8] = rng.random(8) + 1.0
    demand_b = np.zeros(e); demand_b[20:28] = rng.random(8) + 1.0
    for _ in range(4):
        ring.rotate(demand_a)
    pos_a = ring.pos
    for _ in range(12):
        ring.rotate(demand_b)
    assert ring.pos != pos_a
    dec = ring.rotate(demand_a)               # recurring context
    assert dec.reverse_jump
    assert ring.pos == pos_a


def test_ring_delta_wraps_at_seam():
    """A cyclical-return jump across the ring seam reports the MINIMAL signed
    delta: pos 0 -> pos E-1 is one reverse step, not E-1 forward steps."""
    e = 16
    assert RotaryRing._ring_delta(0, e - 1, e) == -1
    assert RotaryRing._ring_delta(e - 1, 0, e) == 1
    assert RotaryRing._ring_delta(2, 5, e) == 3
    assert RotaryRing._ring_delta(5, 2, e) == -3
    assert RotaryRing._ring_delta(3, 3, e) == 0
    # exactly half the ring: forward direction preferred
    assert RotaryRing._ring_delta(0, e // 2, e) == e // 2


@given(
    e=st.integers(4, 64),
    src=st.integers(0, 1000),
    dst=st.integers(0, 1000),
)
def test_ring_delta_minimal_and_consistent(e, src, dst):
    """_ring_delta is the minimal signed distance and actually moves src->dst."""
    src, dst = src % e, dst % e
    d = RotaryRing._ring_delta(src, dst, e)
    assert (src + d) % e == dst
    assert abs(d) <= e // 2


@given(st.integers(2, 50))
def test_cosine_self_similarity(n):
    v = np.random.default_rng(n).random(n) + 0.1
    assert abs(cosine(v, v) - 1.0) < 1e-9
    assert cosine(v, np.zeros(n)) == 0.0


# ===========================================================================
# speculative decode: KV rollback + window-deferred rotation
# ===========================================================================
class _KvStubCfg:
    """Duck-typed stand-in: the KV window helpers only read ``segments``."""

    def __init__(self, reps: int):
        self.segments = ((("attn_moe",), reps), (("attn_mlp",), 1))


@given(
    cap=st.integers(3, 12),
    c0=st.integers(0, 40),
    k_steps=st.integers(1, 6),
    keep_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 10),
)
def test_kv_rollback_restores_rejected_slots(cap, c0, k_steps, keep_frac, seed):
    """snapshot -> speculative writes -> rollback(keep) restores EXACTLY the
    slots of offsets >= keep to their pre-window contents (previous-lap ring
    entries included: c0 may lap the capacity many times over) and leaves the
    accepted offsets' writes in place — truncate-then-redecode therefore
    equals never-decoded."""
    import jax
    import jax.numpy as jnp
    from repro.models import transformer as tfm

    k_steps = min(k_steps, cap)
    keep = int(round(keep_frac * k_steps))
    cfg = _KvStubCfg(reps=2)
    rng = np.random.default_rng(seed)
    b, h, dh = 2, 2, 3

    def fresh(tag):
        return {
            "k": jnp.asarray(rng.standard_normal((2, b, cap, h, dh)) + tag,
                             jnp.float32),
            "v": jnp.asarray(rng.standard_normal((2, b, cap, h, dh)) - tag,
                             jnp.float32),
        }

    state = (( fresh(0), ), ( fresh(1), ))
    before = [np.asarray(x) for x in jax.tree.leaves(state)]
    saved = tfm.snapshot_kv_window(cfg, state, jnp.int32(c0), k_steps)
    # speculative window: garbage into the slots positions c0..c0+K-1 own
    slots = (c0 + np.arange(k_steps)) % cap
    garbage = (
        ( {n: state[0][0][n].at[:, :, slots].set(99.0) for n in ("k", "v")}, ),
        ( {n: state[1][0][n].at[:, :, slots].set(77.0) for n in ("k", "v")}, ),
    )
    rolled = tfm.rollback_kv_window(
        cfg, garbage, saved, jnp.int32(c0), k_steps, jnp.int32(keep)
    )
    after = [np.asarray(x) for x in jax.tree.leaves(rolled)]
    garb = [np.asarray(x) for x in jax.tree.leaves(garbage)]
    kept_slots = {int(s) for s in slots[:keep]}
    # accepted offsets could share a slot with a restored one only if the
    # window wrapped the capacity (k_steps <= cap forbids that), so the
    # partition is exact: accepted slots hold the window's writes, every
    # other slot holds its pre-window bits
    for a, g, pre in zip(after, garb, before):
        for s in range(cap):
            want = g[:, :, s] if s in kept_slots else pre[:, :, s]
            np.testing.assert_array_equal(a[:, :, s], want)


@given(
    k_steps=st.integers(1, 5),
    miss_rate=st.floats(0.0, 0.5),
    seed=st.integers(0, 6),
)
@settings(max_examples=10, deadline=None)
def test_window_rotation_equals_one_by_one(k_steps, miss_rate, seed):
    """Residency after rotate_window_from_telemetry == residency after the
    same steps through rotate_from_telemetry one at a time: LUT, ring
    position, predictor EMA, and the contents of every RESIDENT slot are
    bit-identical, and the window path never moves more bytes (coalescing)."""
    import jax

    jax.config.update("jax_platform_name", "cpu")
    from conftest import params_for
    from repro.config import ResidencyConfig
    from repro.core import DemandPredictor, RotaryResidencyManager

    cfg, _ = params_for("qwen2-moe-a2.7b")
    E, L, T, topk = cfg.moe.num_experts, 2, 3, cfg.moe.top_k
    rng = np.random.default_rng(seed)

    def mk():
        r = np.random.default_rng(seed + 100)
        hw = [
            {n: r.standard_normal(s).astype(np.float32)
             for n, s in (("w_gate", (E, 4, 3)), ("w_up", (E, 4, 3)),
                          ("w_down", (E, 3, 4)))}
            for _ in range(L)
        ]
        routers = [r.standard_normal((4, E)).astype(np.float32)
                   for _ in range(L)]
        mgr = RotaryResidencyManager(
            cfg, ResidencyConfig(mode="rotary", num_slots=5), hw,
            batch=1, cache_len=16, seed=11,
        )
        return mgr, DemandPredictor(routers)

    m_seq, p_seq = mk()
    m_win, p_win = mk()
    ids = rng.integers(0, E, (k_steps, L, T, topk)).astype(np.int32)
    w = rng.random((k_steps, L, T, topk)).astype(np.float32)
    miss = rng.random((k_steps, L, T, topk)) < miss_rate
    dem = rng.random((k_steps, L, E))
    for s in range(k_steps):
        m_seq.rotate_from_telemetry(p_seq, ids[s], w[s], miss[s], dem[s])
    m_win.rotate_window_from_telemetry(p_win, ids, w, miss, dem)
    for l in range(L):
        np.testing.assert_array_equal(
            m_seq.policies[l].lut.e2s, m_win.policies[l].lut.e2s
        )
        assert m_seq.policies[l].ring.pos == m_win.policies[l].ring.pos
        np.testing.assert_array_equal(p_seq.smoothed[l], p_win.smoothed[l])
        for s_ in range(m_seq.num_slots):
            if int(m_seq.policies[l].lut.s2e[s_]) < 0:
                continue
            for n in m_seq.stores[l].buffers:
                np.testing.assert_array_equal(
                    np.asarray(m_seq.stores[l].buffers[n][s_]),
                    np.asarray(m_win.stores[l].buffers[n][s_]),
                )
    assert m_win.stats.bytes_loaded <= m_seq.stats.bytes_loaded


@given(
    k_steps=st.integers(2, 8),
    miss_rate=st.floats(0.0, 0.5),
    seed=st.integers(0, 6),
)
@settings(max_examples=10, deadline=None)
def test_prefetch_shadow_flip_equals_sync_rotation(k_steps, miss_rate, seed):
    """Double-buffered prefetch (speculative shadow uploads during the window,
    then boundary confirm / mispredict-correct / d2d catch-up / pointer flip)
    leaves the LIVE generation bit-identical to the synchronous rotation path
    after every boundary: same LUT, same ring position, and byte-for-byte the
    same contents in every resident slot — regardless of how well the
    speculative plans matched the authoritative transitions."""
    import jax

    jax.config.update("jax_platform_name", "cpu")
    from conftest import params_for
    from repro.config import ResidencyConfig
    from repro.core import DemandPredictor, RotaryResidencyManager

    cfg, _ = params_for("qwen2-moe-a2.7b")
    E, L, T, topk = cfg.moe.num_experts, 2, 3, cfg.moe.top_k
    rng = np.random.default_rng(seed)

    def mk():
        r = np.random.default_rng(seed + 100)
        hw = [
            {n: r.standard_normal(s).astype(np.float32)
             for n, s in (("w_gate", (E, 4, 3)), ("w_up", (E, 4, 3)),
                          ("w_down", (E, 3, 4)))}
            for _ in range(L)
        ]
        routers = [r.standard_normal((4, E)).astype(np.float32)
                   for _ in range(L)]
        mgr = RotaryResidencyManager(
            cfg, ResidencyConfig(mode="rotary", num_slots=5), hw,
            batch=1, cache_len=16, seed=11,
        )
        return mgr, DemandPredictor(routers)

    m_sync, p_sync = mk()
    m_pf, p_pf = mk()
    # margin 0: steering off, so the authoritative transitions are the SAME
    # sequence on both managers — exactly the engine's operating point
    m_pf.enable_prefetch(margin=0)
    for step in range(k_steps):
        ids = rng.integers(0, E, (L, T, topk)).astype(np.int32)
        w = rng.random((L, T, topk)).astype(np.float32)
        miss = rng.random((L, T, topk)) < miss_rate
        dem = rng.random((L, E))
        # prefetch manager ships speculative plans mid-"window" ...
        m_pf.begin_prefetch(p_pf)
        # ... and both reconcile the same authoritative telemetry
        m_sync.rotate_from_telemetry(p_sync, ids, w, miss, dem)
        m_pf.rotate_from_telemetry(p_pf, ids, w, miss, dem)
        for l in range(L):
            np.testing.assert_array_equal(
                m_sync.policies[l].lut.e2s, m_pf.policies[l].lut.e2s
            )
            assert m_sync.policies[l].ring.pos == m_pf.policies[l].ring.pos
            for s_ in range(m_sync.num_slots):
                if int(m_sync.policies[l].lut.s2e[s_]) < 0:
                    continue
                for n in m_sync.stores[l].buffers:
                    np.testing.assert_array_equal(
                        np.asarray(m_sync.stores[l].buffers[n][s_]),
                        np.asarray(m_pf.stores[l].buffers[n][s_]),
                        err_msg=f"step {step} layer {l} slot {s_} {n}",
                    )


@given(
    e=st.integers(8, 40),
    s=st.integers(2, 8),
    steps=st.integers(70, 90),
)
def test_rering_preserves_residents(e, s, steps):
    """Periodic re-ringing must never force loads by itself: the current
    window's experts stay resident across a re-ring."""
    s = min(s, e)
    ring = RotaryRing(e, s, rering_every=64, snapshot_every=10**9, seed=1)
    rng = np.random.default_rng(2)
    for i in range(steps):
        before = set(ring.window.tolist())
        dec = ring.rotate(rng.random(e))
        if ring.step % ring.rering_every == 0:
            # the rotate both moved (<= stride) and re-rang; residents at the
            # *new* position must be drawn from ring contents consistently
            assert len(set(dec.window.tolist())) == s
        # ring remains a permutation
        assert sorted(ring.ring.tolist()) == list(range(e))
