"""Hypothesis property tests for the paper's core invariants:
LUT bijectivity, rotation boundedness, window coverage, cyclic return."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.lut import SlotLUT
from repro.core.rotation import RotaryRing, cosine

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


@given(
    e=st.integers(4, 64),
    s=st.integers(1, 16),
    ops=st.lists(st.tuples(st.integers(0, 1000), st.integers(0, 1000)), max_size=60),
)
def test_lut_stays_consistent(e, s, ops):
    """assign/evict in any order keeps e2s and s2e mutually inverse."""
    s = min(s, e)
    lut = SlotLUT(e, s)
    for a, b in ops:
        expert = a % e
        if b % 3 == 0:
            lut.evict(expert)
        else:
            lut.assign(expert, b % s)
        lut.check_consistent()
    assert len(lut.resident_experts) <= s


@given(
    e=st.integers(8, 64),
    frac=st.floats(0.2, 0.9),
    stride=st.integers(1, 6),
    steps=st.integers(1, 40),
    seed=st.integers(0, 5),
)
def test_rotation_window_properties(e, frac, stride, steps, seed):
    s = max(2, int(e * frac))
    ring = RotaryRing(e, s, max_stride=stride, seed=seed)
    rng = np.random.default_rng(seed)
    prev_pos = ring.pos
    for _ in range(steps):
        demand = rng.random(e)
        dec = ring.rotate(demand)
        # window is always exactly s distinct experts
        assert len(dec.window) == s
        assert len(np.unique(dec.window)) == s
        assert set(dec.window.tolist()) <= set(range(e))
        # non-jump transitions are bounded by the stride
        if not dec.reverse_jump:
            assert abs(dec.delta) <= stride
        prev_pos = ring.pos


def test_rotation_prefers_demand():
    """The window rotates toward concentrated demand."""
    e, s = 16, 4
    ring = RotaryRing(e, s, max_stride=4, rering_every=10**9, snapshot_every=10**9)
    demand = np.zeros(e)
    demand[6:10] = 1.0            # hot experts sit at ring positions 6..9
    for _ in range(6):
        dec = ring.rotate(demand)
    assert set(dec.window.tolist()) == {6, 7, 8, 9}


def test_cyclic_return_on_recurring_context():
    """After visiting context A then B, re-presenting A's demand vector jumps
    the window back (the paper's reverse rotation / cyclical return)."""
    e, s = 32, 8
    ring = RotaryRing(e, s, max_stride=2, reverse_threshold=0.9,
                      snapshot_every=1, rering_every=10**9)
    rng = np.random.default_rng(0)
    demand_a = np.zeros(e); demand_a[0:8] = rng.random(8) + 1.0
    demand_b = np.zeros(e); demand_b[20:28] = rng.random(8) + 1.0
    for _ in range(4):
        ring.rotate(demand_a)
    pos_a = ring.pos
    for _ in range(12):
        ring.rotate(demand_b)
    assert ring.pos != pos_a
    dec = ring.rotate(demand_a)               # recurring context
    assert dec.reverse_jump
    assert ring.pos == pos_a


def test_ring_delta_wraps_at_seam():
    """A cyclical-return jump across the ring seam reports the MINIMAL signed
    delta: pos 0 -> pos E-1 is one reverse step, not E-1 forward steps."""
    e = 16
    assert RotaryRing._ring_delta(0, e - 1, e) == -1
    assert RotaryRing._ring_delta(e - 1, 0, e) == 1
    assert RotaryRing._ring_delta(2, 5, e) == 3
    assert RotaryRing._ring_delta(5, 2, e) == -3
    assert RotaryRing._ring_delta(3, 3, e) == 0
    # exactly half the ring: forward direction preferred
    assert RotaryRing._ring_delta(0, e // 2, e) == e // 2


@given(
    e=st.integers(4, 64),
    src=st.integers(0, 1000),
    dst=st.integers(0, 1000),
)
def test_ring_delta_minimal_and_consistent(e, src, dst):
    """_ring_delta is the minimal signed distance and actually moves src->dst."""
    src, dst = src % e, dst % e
    d = RotaryRing._ring_delta(src, dst, e)
    assert (src + d) % e == dst
    assert abs(d) <= e // 2


@given(st.integers(2, 50))
def test_cosine_self_similarity(n):
    v = np.random.default_rng(n).random(n) + 0.1
    assert abs(cosine(v, v) - 1.0) < 1e-9
    assert cosine(v, np.zeros(n)) == 0.0


@given(
    e=st.integers(8, 40),
    s=st.integers(2, 8),
    steps=st.integers(70, 90),
)
def test_rering_preserves_residents(e, s, steps):
    """Periodic re-ringing must never force loads by itself: the current
    window's experts stay resident across a re-ring."""
    s = min(s, e)
    ring = RotaryRing(e, s, rering_every=64, snapshot_every=10**9, seed=1)
    rng = np.random.default_rng(2)
    for i in range(steps):
        before = set(ring.window.tolist())
        dec = ring.rotate(rng.random(e))
        if ring.step % ring.rering_every == 0:
            # the rotate both moved (<= stride) and re-rang; residents at the
            # *new* position must be drawn from ring contents consistently
            assert len(set(dec.window.tolist())) == s
        # ring remains a permutation
        assert sorted(ring.ring.tolist()) == list(range(e))
